//! L3 — the serving coordinator for ONE bank.
//!
//! The paper's device is a lookup engine; the coordinator wraps it the way
//! a TLB/router integration would: a threaded request loop with a dynamic
//! batcher in front of the decode stage, an insert/delete path that keeps
//! the CNN consistent with the array, and per-request energy/latency
//! accounting.  Everything here is per-bank by construction — one
//! [`LookupEngine`], one [`Batcher`], one [`Metrics`] per engine thread —
//! which is exactly what lets [`crate::shard`] stack `S` of these behind a
//! scatter-gather router and aggregate the per-bank snapshots into a fleet
//! view.
//!
//! * [`engine`] — one CAM macro + its CNN classifier (the Fig. 1 system),
//!   split read/write: an immutable [`SearchState`] shared behind an `Arc`
//!   (lookups are `&self` + a per-thread [`DecodeScratch`]) and the
//!   single-writer [`LookupEngine`] that copy-on-writes it.
//! * [`batcher`] — size/deadline dynamic batching for the decode stage
//!   (feeds the PJRT artifact whose batch sizes are fixed at AOT time).
//! * [`server`] — the serving threads: one writer (mutations, barriers,
//!   RCU publish through [`SharedSearch`]) plus a sized reader pool that
//!   serves lookups concurrently from the published snapshot; graceful
//!   drain, non-blocking admission ([`EngineError::Busy`] on queue-shed,
//!   [`EngineError::Full`] strictly for "no free CAM slot").
//! * [`metrics`] — counters + latency/energy aggregation (striped across
//!   reader threads, merged on snapshot).
//!
//! Multi-bank scale-out (placement, scatter-gather, fleet metrics) lives
//! one layer up in [`crate::shard`]; the network front-end that exposes a
//! fleet over TCP — wire-typed [`EngineError`]s, with lookups served as
//! direct snapshot reads on the reactor's worker pool (no admission
//! queue, so that pool's width, not [`ServerHandle::try_lookup`]'s
//! `Busy` shed, bounds wire read concurrency) — lives two layers up in
//! [`crate::net`].

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{
    DecodeScratch, EngineError, LookupEngine, LookupOutcome, SearchState, SharedSearch,
};
pub use metrics::Metrics;
pub use server::{
    CamServer, DecodeBackend, PendingBulk, PendingLookup, PendingPersist, PersistError,
    ServerHandle, DEFAULT_QUEUE_CAPACITY, DEFAULT_READERS,
};
