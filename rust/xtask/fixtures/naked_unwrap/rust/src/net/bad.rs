/// Fixture serving path: one naked unwrap, one excused expect, and
/// test-module panics that the analyzer must ignore.
pub fn read_len(buf: &[u8]) -> u32 {
    u32::from_le_bytes(<[u8; 4]>::try_from(&buf[0..4]).unwrap())
}

pub fn checked_len(buf: &[u8]) -> u32 {
    // lint:allow(infallible: caller guarantees a 4-byte prefix)
    u32::from_le_bytes(<[u8; 4]>::try_from(&buf[0..4]).expect("4 bytes"))
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(super::read_len(&[1, 0, 0, 0]), "1".parse::<u32>().unwrap());
    }
}
