// Fixture fuzz battery: Pong is missing.

fn sample_requests() {
    let _ = Request::Ping;
}
