//! Coordinator throughput: lookups/s through the threaded serve loop under
//! varying client concurrency and batch policies — the L3 claim is that the
//! coordinator never bottlenecks the modelled device (see rust/README.md).
//!
//! Run: `cargo bench --bench coordinator_throughput`

use std::time::{Duration, Instant};

use cscam::config::DesignConfig;
use cscam::coordinator::{BatchPolicy, CamServer, DecodeBackend, LookupEngine};
use cscam::util::Rng;
use cscam::workload::{QueryMix, TagDistribution};

fn run_serve(
    name: &str,
    backend: DecodeBackend,
    threads: usize,
    lookups: usize,
    policy: BatchPolicy,
) {
    let cfg = DesignConfig::reference();
    let mut engine = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(1);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &stored {
        engine.insert(t).unwrap();
    }
    let h = CamServer::with_engine(engine, backend, policy).spawn();

    let mix = QueryMix { hit_ratio: 0.9, zipf_s: 0.0 };
    let mut per_thread: Vec<Vec<cscam::bits::BitVec>> = vec![Vec::new(); threads];
    for i in 0..lookups {
        let (tag, _) = mix.sample(&stored, cfg.n, &mut rng);
        per_thread[i % threads].push(tag);
    }

    let t0 = Instant::now();
    let joins: Vec<_> = per_thread
        .into_iter()
        .map(|qs| {
            let h = h.clone();
            std::thread::spawn(move || {
                for t in qs {
                    let _ = h.lookup(t).unwrap();
                }
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed();
    let m = h.metrics().unwrap();
    println!(
        "{:<44} {:>10.0} lookups/s  (batch̄ {:>5.1}, p50 {:>7} ns, p99 {:>8} ns)",
        name,
        lookups as f64 / wall.as_secs_f64(),
        m.batch_size.mean(),
        m.host_latency_ns.quantile(0.5),
        m.host_latency_ns.quantile(0.99),
    );
}

fn run_bulk(name: &str, backend: DecodeBackend, lookups: usize, chunk: usize) {
    let cfg = DesignConfig::reference();
    let mut engine = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(1);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &stored {
        engine.insert(t).unwrap();
    }
    let h = CamServer::with_engine(
        engine,
        backend,
        BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(100) },
    )
    .spawn();
    let mix = QueryMix { hit_ratio: 0.9, zipf_s: 0.0 };
    let batches: Vec<Vec<cscam::bits::BitVec>> = (0..lookups / chunk)
        .map(|_| (0..chunk).map(|_| mix.sample(&stored, cfg.n, &mut rng).0).collect())
        .collect();
    let t0 = Instant::now();
    for b in batches {
        for r in h.lookup_many(b) {
            let _ = r.unwrap();
        }
    }
    let wall = t0.elapsed();
    println!(
        "{:<44} {:>10.0} lookups/s  (bulk chunks of {chunk})",
        name,
        lookups as f64 / wall.as_secs_f64()
    );
}

fn main() {
    println!("# coordinator throughput (reference design, 90 % hit mix)");
    let fast = BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(100) };
    for threads in [1usize, 2, 4, 8, 16] {
        run_serve(
            &format!("native/threads={threads}/max_batch=64"),
            DecodeBackend::Native,
            threads,
            200_000,
            fast,
        );
    }
    println!();
    for max_batch in [1usize, 8, 64, 256] {
        run_serve(
            &format!("native/threads=8/max_batch={max_batch}"),
            DecodeBackend::Native,
            8,
            200_000,
            BatchPolicy { max_batch, max_wait: Duration::from_micros(100) },
        );
    }

    println!();
    run_bulk("native/bulk=256", DecodeBackend::Native, 500_000, 256);
    run_bulk("native/bulk=4096", DecodeBackend::Native, 500_000, 4096);

    pjrt_rows(fast);
}

#[cfg(feature = "pjrt")]
fn pjrt_rows(fast: BatchPolicy) {
    use cscam::runtime::{artifacts_available, default_artifact_dir, ArtifactStore};

    if !artifacts_available() {
        println!("(skipping pjrt rows: run `make artifacts`)");
        return;
    }
    println!();
    for threads in [4usize, 16] {
        let store = ArtifactStore::load(&default_artifact_dir()).expect("artifacts");
        run_serve(
            &format!("pjrt/threads={threads}/max_batch=64"),
            DecodeBackend::pjrt(store),
            threads,
            20_000,
            fast,
        );
    }
    let store = ArtifactStore::load(&default_artifact_dir()).expect("artifacts");
    run_bulk("pjrt/bulk=64", DecodeBackend::pjrt(store), 50_000, 64);
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_rows(_fast: BatchPolicy) {
    println!("(skipping pjrt rows: built without the `pjrt` feature)");
}
