//! L5 — the network serving layer: a wire protocol + TCP front-end that
//! puts the sharded CAM fleet ([`crate::shard`]) on the network.
//!
//! Everything is `std::net` + the crate's own primitives — no new
//! dependencies.  The stack, bottom to top:
//!
//! * [`proto`] — versioned, length-prefixed binary frames with FNV-1a
//!   checksums ([`crate::util::hash`], the same definition that places
//!   tags on banks), request ids for pipelining, and responses that carry
//!   the full [`crate::shard::ShardedOutcome`] — matched global address,
//!   λ, energy breakdown, delay — bit-identical to an in-process lookup.
//!   Engine failures map to typed error codes — v3 splits
//!   [`crate::coordinator::EngineError::Busy`] (queue-shed admission)
//!   from `Full` (no free CAM slot) — and the v2 durability ops
//!   `Snapshot`/`Flush` let an operator compact or fsync the fleet's
//!   stores ([`crate::store`]) over the wire.  v4 adds `Metrics`, which
//!   returns the fleet's Prometheus-text exposition ([`crate::obs`])
//!   in-band, so a client can scrape without a second listener.  v5 adds
//!   the replication transport — `SubscribeLog` polls a primary's
//!   per-bank WAL and is answered with `LogBatch` (framed records past
//!   the acked offset) or `SnapshotTransfer` (bootstrap / post-compaction
//!   restart), with `ERR_FENCED` refusing subscribers from a pre-promotion
//!   epoch ([`crate::repl`]).
//!   v6 adds the `multiplex` hello flag: responses on one connection may
//!   arrive in *completion* order, and clients re-match them by request
//!   id.
//! * [`poll`] — a minimal readiness poller (epoll on Linux via raw FFI,
//!   `poll(2)` elsewhere — no async runtime, no new crates) plus the
//!   wake-pair doorbell the worker pool rings to get the reactor's
//!   attention.
//! * [`server`] — [`CamTcpServer`]: a single reactor thread owns every
//!   nonblocking connection and reassembles frames from per-connection
//!   buffers (a stalled or byte-at-a-time peer costs buffer space, not a
//!   thread); decoded requests cross a bounded lock-free
//!   [`crate::util::sync::BatchChannel`] to a small worker pool that
//!   executes them against the banks' published search snapshots
//!   (mutations route to the banks' writer threads) and completions flow
//!   back to be written in completion order.  Connection cap with a
//!   deterministic `busy` hello, per-connection backpressure instead of
//!   unbounded buffering, and a clean shutdown that drains every bank and
//!   flushes every WAL.
//! * [`client`] — [`CamClient`]: blocking client with handshake,
//!   reconnect, and windowed multiplexed `lookup_bulk` (responses
//!   re-matched by request id, so out-of-order completion is invisible).
//! * [`loadgen`] — [`LoadGen`]: multi-threaded QPS/latency runner over
//!   [`crate::workload`] streams — closed-loop (fire on answer) or
//!   open-loop (fixed arrival rate, latency measured from each frame's
//!   intended start so queue delay is never hidden) — reporting into the
//!   [`crate::util::bench`] trajectory schema.
//!
//! Entry points: `cscam serve --listen <addr>` starts a server,
//! `cscam loadgen --connect <addr>` drives it, and the `cam_client`
//! example walks the client API.

pub mod client;
pub mod loadgen;
pub mod poll;
pub mod proto;
pub mod server;

pub use client::{CamClient, LogPoll};
pub use loadgen::{LoadGen, LoadReport};
pub use proto::{Request, Response, ServerHello, StatsReport, WireError, VERSION};
pub use server::{CamTcpServer, NetConfig, NetServerHandle};
