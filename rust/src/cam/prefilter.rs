//! Per-bank counting-bloom pre-filter over the stored tag set.
//!
//! SMLE-CAM (PAPERS.md, 1406.7662) pre-screens match-lines with a cheap
//! single-transistor stage so definite-miss rows are never energized.  The
//! software analog sits one level higher: before the CNN decode even runs,
//! the bank asks a bloom filter whether the queried tag *could* be stored.
//! A negative answer is definitive (bloom filters have no false negatives),
//! so the lookup returns a miss having compared **zero** rows — the modelled
//! energy/delay accounting is exactly that of a decode that activated no
//! P_II neuron (λ = 0, no enabled blocks), mirroring a never-energized
//! match-line.
//!
//! The filter is *counting* (u32 cells, not bits) so the single writer can
//! maintain it incrementally through insert → delete → overwrite histories
//! without rebuild storms; a plain bit filter would have to be regenerated
//! on every delete.  Cells are u32 because the worst case — all M tags
//! hashing both probes into one cell — is still far below overflow.
//!
//! Hashing is the crate's pinned [`Fnv1a`](crate::util::hash::Fnv1a) (the
//! same definition the shard router and wire checksums use), split
//! Kirsch–Mitzenmacher style: two independent base hashes `h1`, `h2` from
//! differently-seeded FNV streams yield probe `i` as `h1 + i·h2`.  The
//! bloom-filter WNN of SNIPPETS.md (zero_g `wnn.rs`) derives its probes
//! from one hash the same way.  Determinism matters: a rebuilt filter (old
//! snapshot, no filter section) must equal the serialized one bit for bit.

use crate::bits::BitVec;
use crate::util::hash::Fnv1a;

/// Probes per key.  Two keeps maintenance cheap and, with 8 cells per
/// entry, lands the full-occupancy false-positive rate near
/// `(1 - e^(-2/8))^2 ≈ 4.9 %` — false positives only cost the unfiltered
/// decode we would have done anyway.
pub const PROBES: usize = 2;

/// Cells per CAM entry before rounding the table up to a power of two.
pub const CELLS_PER_ENTRY: usize = 8;

/// Seed byte folded into the first base hash (distinct streams for h1/h2).
const SEED_H1: u8 = 0xC5;
/// Seed byte folded into the second base hash.
const SEED_H2: u8 = 0x5C;

/// Counting bloom filter over a bank's valid tags.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BankFilter {
    /// Power-of-two cell count; probe indices are masked, not modded.
    cells: Vec<u32>,
    /// `cells.len() - 1`, cached for the probe mask.
    mask: u64,
    /// Number of tags currently folded in (diagnostics + serialization).
    keys: u64,
}

impl BankFilter {
    /// Empty filter sized for a bank of `m` entries.
    pub fn new(m: usize) -> Self {
        let len = (m.max(1) * CELLS_PER_ENTRY).next_power_of_two();
        BankFilter { cells: vec![0; len], mask: (len - 1) as u64, keys: 0 }
    }

    /// Rebuild from a full tag iterator (snapshot restore without a filter
    /// section, retrain-style compaction).  Deterministic: equal tag
    /// multisets yield equal filters regardless of insertion order.
    pub fn from_tags<'a>(m: usize, tags: impl IntoIterator<Item = &'a BitVec>) -> Self {
        let mut f = BankFilter::new(m);
        for t in tags {
            f.add(t);
        }
        f
    }

    /// Number of cells in the table.
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    /// True if no key has been added.
    pub fn is_empty(&self) -> bool {
        self.keys == 0
    }

    /// Number of keys currently folded in.
    pub fn keys(&self) -> u64 {
        self.keys
    }

    /// Raw cell values (snapshot encoding).
    pub fn cells(&self) -> &[u32] {
        &self.cells
    }

    /// Restore from serialized parts.  Returns an error string (the store
    /// layer wraps it into a typed `Corrupt`) instead of panicking — the
    /// input may come from a damaged file.
    pub fn from_parts(cells: Vec<u32>, keys: u64) -> Result<Self, String> {
        if !cells.len().is_power_of_two() {
            return Err(format!("filter cell count {} is not a power of two", cells.len()));
        }
        let mask = (cells.len() - 1) as u64;
        Ok(BankFilter { cells, mask, keys })
    }

    /// The two probe indices for a tag (Kirsch–Mitzenmacher: `h1 + i·h2`).
    #[inline]
    fn probes(&self, tag: &BitVec) -> [usize; PROBES] {
        let mut h1 = Fnv1a::new();
        h1.update(&[SEED_H1]);
        let mut h2 = Fnv1a::new();
        h2.update(&[SEED_H2]);
        for &w in tag.words() {
            let b = w.to_le_bytes();
            h1.update(&b);
            h2.update(&b);
        }
        // Force h2 odd so the stride is coprime with the power-of-two table
        // and the two probes never collapse onto one cell for every key.
        let (h1, h2) = (h1.finish(), h2.finish() | 1);
        let mut out = [0usize; PROBES];
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = (h1.wrapping_add((i as u64).wrapping_mul(h2)) & self.mask) as usize;
        }
        out
    }

    /// Fold a tag in (writer path: insert / overwrite-new-side).
    pub fn add(&mut self, tag: &BitVec) {
        for p in self.probes(tag) {
            self.cells[p] = self.cells[p].saturating_add(1);
        }
        self.keys += 1;
    }

    /// Remove one occurrence of a tag (writer path: delete /
    /// overwrite-old-side).  Counts saturate at zero rather than panic: the
    /// writer only removes tags it previously added, and a violated
    /// assumption must degrade to extra false positives, never to a lookup
    /// failure.
    pub fn remove(&mut self, tag: &BitVec) {
        for p in self.probes(tag) {
            self.cells[p] = self.cells[p].saturating_sub(1);
        }
        self.keys = self.keys.saturating_sub(1);
    }

    /// `false` means the tag is definitely not stored (no false negatives);
    /// `true` means "possibly stored — run the real decode".
    #[inline]
    pub fn may_contain(&self, tag: &BitVec) -> bool {
        self.probes(tag).into_iter().all(|p| self.cells[p] > 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tag(v: u128, n: usize) -> BitVec {
        BitVec::from_u128(v, n)
    }

    #[test]
    fn table_is_power_of_two_sized() {
        for m in [1usize, 7, 64, 100, 1024] {
            let f = BankFilter::new(m);
            assert!(f.len().is_power_of_two(), "m={m}");
            assert!(f.len() >= m * CELLS_PER_ENTRY, "m={m}");
        }
    }

    #[test]
    fn no_false_negatives_through_add_remove_history() {
        let mut f = BankFilter::new(64);
        let stored: Vec<BitVec> = (0..64u128).map(|v| tag(v * 7 + 1, 32)).collect();
        for t in &stored {
            f.add(t);
        }
        for t in &stored {
            assert!(f.may_contain(t));
        }
        // remove half; the survivors must still all pass
        for t in &stored[..32] {
            f.remove(t);
        }
        for t in &stored[32..] {
            assert!(f.may_contain(t));
        }
        assert_eq!(f.keys(), 32);
    }

    #[test]
    fn empty_filter_rejects_everything() {
        let f = BankFilter::new(64);
        for v in 0..100u128 {
            assert!(!f.may_contain(&tag(v, 32)));
        }
    }

    #[test]
    fn removal_to_empty_rejects_again() {
        let mut f = BankFilter::new(16);
        let t = tag(0xDEAD, 32);
        f.add(&t);
        assert!(f.may_contain(&t));
        f.remove(&t);
        assert!(!f.may_contain(&t));
        assert!(f.is_empty());
    }

    #[test]
    fn rebuild_is_order_independent_and_equals_incremental() {
        let tags: Vec<BitVec> = (0..40u128).map(|v| tag(v * 13 + 5, 48)).collect();
        let forward = BankFilter::from_tags(64, tags.iter());
        let reverse = BankFilter::from_tags(64, tags.iter().rev());
        assert_eq!(forward, reverse);

        let mut incremental = BankFilter::new(64);
        for t in &tags {
            incremental.add(t);
        }
        assert_eq!(forward, incremental);
    }

    #[test]
    fn false_positive_rate_is_sane_at_full_occupancy() {
        // 256 stored keys in a filter sized for m=256; probe 10k absent
        // keys. Expected FP ≈ (1 - e^(-2·256/2048))^2 ≈ 4.9%; assert a
        // loose ceiling so hash quality regressions get caught.
        let stored: Vec<BitVec> = (0..256u128).map(|v| tag(v + 1, 64)).collect();
        let f = BankFilter::from_tags(256, stored.iter());
        let fps = (0..10_000u128).filter(|v| f.may_contain(&tag(0x1_0000_0000 + v, 64))).count();
        assert!(fps < 1_000, "false-positive rate {fps}/10000 is implausibly high");
    }

    #[test]
    fn parts_roundtrip() {
        let tags: Vec<BitVec> = (0..20u128).map(|v| tag(v * 3, 32)).collect();
        let f = BankFilter::from_tags(32, tags.iter());
        let back = BankFilter::from_parts(f.cells().to_vec(), f.keys()).unwrap();
        assert_eq!(f, back);
        assert!(BankFilter::from_parts(vec![0; 3], 0).is_err(), "non-pow2 cell count");
    }

    #[test]
    fn saturating_remove_never_underflows() {
        let mut f = BankFilter::new(8);
        let t = tag(7, 16);
        f.remove(&t); // never added: must not panic or wrap
        assert!(f.is_empty());
        f.add(&t);
        assert!(f.may_contain(&t));
    }
}
