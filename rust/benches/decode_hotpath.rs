//! Micro-benchmarks of the L3 hot path (see rust/README.md):
//! the native CNN decode (`decode_into`), tag-bit selection, the ζ-group
//! OR, the full engine lookup, and — with the `pjrt` feature and artifacts
//! present — the batched PJRT decode per-query cost.
//!
//! Perf target: native decode ≥ 10 M lookups/s single-thread at the
//! reference geometry, so the coordinator is never the bottleneck against
//! the modelled 1.4 GHz device.
//!
//! Run: `cargo bench --bench decode_hotpath`

use cscam::bits::BitVec;
use cscam::cnn::{ClusteredNetwork, Selection};
use cscam::config::DesignConfig;
use cscam::coordinator::LookupEngine;
use cscam::util::bench::{black_box, BenchTimer};
use cscam::util::Rng;
use cscam::workload::TagDistribution;

fn trained(cfg: &DesignConfig, seed: u64) -> (ClusteredNetwork, Vec<Vec<u16>>) {
    let mut rng = Rng::seed_from_u64(seed);
    let mut net = ClusteredNetwork::from_config(cfg);
    let mut idxs = Vec::new();
    for addr in 0..cfg.m {
        let idx: Vec<u16> = (0..cfg.c).map(|_| rng.gen_range(cfg.l) as u16).collect();
        net.train(&idx, addr);
        idxs.push(idx);
    }
    (net, idxs)
}

fn main() {
    let timer = BenchTimer::default();
    let cfg = DesignConfig::reference();

    // 1. native GD decode, reference geometry (512 entries, c=3)
    let (net, idxs) = trained(&cfg, 1);
    let mut act = BitVec::zeros(cfg.m);
    let mut en = BitVec::zeros(cfg.beta());
    let mut i = 0usize;
    let r = timer.run("cnn_decode_into/M=512,c=3,l=8,zeta=8", || {
        i = (i + 1) % idxs.len();
        net.decode_into(&idxs[i], &mut act, &mut en)
    });
    println!(
        "   → {:.1} M decodes/s (target ≥ 10 M/s: {})",
        r.per_second() / 1e6,
        if r.per_second() >= 10e6 { "PASS" } else { "MISS" }
    );

    // 2. geometry scaling of the decode
    for (m, c) in [(1024usize, 3usize), (4096, 3), (512, 6)] {
        let big = DesignConfig { m, c, zeta: 8, ..DesignConfig::reference() };
        let (net, idxs) = trained(&big, 2);
        let mut act = BitVec::zeros(big.m);
        let mut en = BitVec::zeros(big.beta());
        let mut i = 0usize;
        timer.run(&format!("cnn_decode_into/M={m},c={c}"), || {
            i = (i + 1) % idxs.len();
            net.decode_into(&idxs[i], &mut act, &mut en)
        });
    }

    // 3. tag-bit selection (strided), hot-path variant
    let sel = Selection::strided(cfg.n, cfg.c, cfg.k());
    let mut rng = Rng::seed_from_u64(3);
    let tags: Vec<BitVec> =
        (0..256).map(|_| cscam::workload::random_tag(cfg.n, &mut rng)).collect();
    let mut buf = Vec::new();
    let mut i = 0usize;
    timer.run("selection_apply_into/N=128,q=9", || {
        i = (i + 1) % tags.len();
        sel.apply_into(&tags[i], &mut buf);
        buf.len()
    });

    // 4. full engine lookup (selection + decode + CAM search + energy)
    let mut engine = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(4);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &stored {
        engine.insert(t).unwrap();
    }
    let mut i = 0usize;
    let r = timer.run("engine_lookup/reference,hit", || {
        i = (i + 1) % stored.len();
        black_box(engine.lookup(&stored[i]).unwrap().comparisons)
    });
    println!("   → {:.2} M lookups/s end-to-end (incl. energy accounting)", r.per_second() / 1e6);
    let miss = cscam::workload::random_tag(cfg.n, &mut rng);
    timer.run("engine_lookup/reference,miss", || {
        black_box(engine.lookup(&miss).unwrap().comparisons)
    });

    // 5. PJRT batched decode (per-query amortized), if built with the
    //    `pjrt` feature and artifacts exist
    pjrt_decode_benches(&timer);
}

#[cfg(feature = "pjrt")]
fn pjrt_decode_benches(timer: &BenchTimer) {
    use cscam::runtime::{artifacts_available, default_artifact_dir, ArtifactStore};

    if !artifacts_available() {
        println!("(skipping pjrt_decode benches: run `make artifacts`)");
        return;
    }
    let mut store = ArtifactStore::load(&default_artifact_dir()).expect("artifacts");
    let mcfg = store.manifest().config.clone();
    let acfg = DesignConfig {
        m: mcfg.m,
        zeta: mcfg.zeta,
        c: mcfg.c,
        l: mcfg.l,
        ..DesignConfig::reference()
    };
    let (net, idxs) = trained(&acfg, 5);
    store.set_weights(net.rows()).expect("weights");
    for &batch in &store.batch_sizes() {
        let queries: Vec<Vec<u16>> = (0..batch).map(|i| idxs[i % idxs.len()].clone()).collect();
        let r = timer.run(&format!("pjrt_decode/batch={batch}"), || {
            store.decode(&queries).unwrap().lambda.len()
        });
        println!(
            "   → {:.2} µs/query amortized at batch {batch}",
            r.mean_ns / 1000.0 / batch as f64
        );
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_decode_benches(_timer: &BenchTimer) {
    println!("(skipping pjrt_decode benches: built without the `pjrt` feature)");
}
