//! Micro-benchmark timer used by the `harness = false` bench binaries
//! (criterion-style warmup + repeated sampling, implemented in-tree).

use std::time::{Duration, Instant};

/// Result of one benchmark: per-iteration timings in nanoseconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Mean ns/iter.
    pub mean_ns: f64,
    /// Standard deviation of the sample means.
    pub std_ns: f64,
    /// Best sample (ns/iter).
    pub min_ns: f64,
    /// Iterations per sample.
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchResult {
    /// criterion-ish one-liner.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12.1} ns/iter (±{:>8.1}, min {:>10.1}, {} samples × {} iters)",
            self.name, self.mean_ns, self.std_ns, self.min_ns, self.samples, self.iters_per_sample
        )
    }

    /// Throughput helper.
    pub fn per_second(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Warmup-then-sample bench driver.
pub struct BenchTimer {
    warmup: Duration,
    sample_time: Duration,
    samples: usize,
}

impl Default for BenchTimer {
    fn default() -> Self {
        BenchTimer {
            warmup: Duration::from_millis(300),
            sample_time: Duration::from_millis(200),
            samples: 10,
        }
    }
}

impl BenchTimer {
    pub fn new(warmup: Duration, sample_time: Duration, samples: usize) -> Self {
        assert!(samples >= 2);
        BenchTimer { warmup, sample_time, samples }
    }

    /// Quick preset for heavyweight bodies (whole-workload benches).
    pub fn coarse() -> Self {
        BenchTimer {
            warmup: Duration::from_millis(50),
            sample_time: Duration::from_millis(400),
            samples: 5,
        }
    }

    /// Run `body` repeatedly; `body` must return something observable to
    /// keep the optimizer honest (its result is black-boxed here).
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut body: F) -> BenchResult {
        // warmup + calibration: how many iters fit in sample_time?
        let w0 = Instant::now();
        let mut warm_iters: u64 = 0;
        while w0.elapsed() < self.warmup {
            black_box(body());
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.sample_time.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut means = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(body());
            }
            means.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let mean = means.iter().sum::<f64>() / means.len() as f64;
        let var =
            means.iter().map(|m| (m - mean) * (m - mean)).sum::<f64>() / (means.len() - 1) as f64;
        let min = means.iter().copied().fold(f64::INFINITY, f64::min);
        let result = BenchResult {
            name: name.to_string(),
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: min,
            iters_per_sample: iters,
            samples: self.samples,
        };
        println!("{}", result.report());
        result
    }
}

/// Optimizer barrier (stable-Rust equivalent of `std::hint::black_box` —
/// which we also call; the volatile read guards against inlining through).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One named row of a `BENCH_*.json` trajectory snapshot: a bench scenario
/// plus its measured metrics, in insertion order.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub name: String,
    pub metrics: Vec<(String, f64)>,
}

impl BenchRecord {
    pub fn new(name: impl Into<String>) -> Self {
        BenchRecord { name: name.into(), metrics: Vec::new() }
    }

    /// Append one metric (kept in insertion order for stable diffs).
    pub fn push(&mut self, key: &str, value: f64) {
        self.metrics.push((key.to_string(), value));
    }
}

/// Serialize one run's records to the legacy single-bench trajectory format
/// (schema 1).  Kept for the reader's compatibility tests; the on-disk
/// snapshots are written in the merged schema-2 format by
/// [`write_bench_json`].
pub fn bench_records_json(bench: &str, records: &[BenchRecord]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"schema\": 1,\n  \"bench\": \"{}\",\n  \"rows\": [\n",
        json_escape(bench)
    ));
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!("    {{\"name\": \"{}\"", json_escape(&r.name)));
        for (k, v) in &r.metrics {
            let v = if v.is_finite() { format!("{v}") } else { "null".to_string() };
            s.push_str(&format!(", \"{}\": {}", json_escape(k), v));
        }
        s.push_str(if i + 1 == records.len() { "}\n" } else { "},\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// One row of a merged `BENCH_*.json` trajectory: which bench produced it,
/// the run ordinal within that bench, and the measured record.
#[derive(Debug, Clone)]
pub struct TaggedRecord {
    pub bench: String,
    /// 1-based ordinal of the run that produced this row, per bench tag —
    /// rows accumulate across runs instead of overwriting, so the file is a
    /// real performance trajectory.
    pub run: u64,
    pub rec: BenchRecord,
}

/// Serialize merged trajectory rows (schema 2: per-row `bench`/`run` tags).
/// Deterministic — metric keys are emitted *alphabetized*, one row per
/// line — so appending a run never rewrites earlier rows (the reader
/// alphabetizes on parse; if fresh rows kept insertion order, every append
/// would churn the whole file's diff).  Non-finite values serialize as
/// `null`.
pub fn bench_rows_json(rows: &[TaggedRecord]) -> String {
    let mut s = String::new();
    s.push_str("{\n  \"schema\": 2,\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"bench\": \"{}\", \"run\": {}",
            json_escape(&r.rec.name),
            json_escape(&r.bench),
            r.run
        ));
        let mut metrics: Vec<&(String, f64)> = r.rec.metrics.iter().collect();
        metrics.sort_by(|a, b| a.0.cmp(&b.0));
        for (k, v) in metrics {
            let v = if v.is_finite() { format!("{v}") } else { "null".to_string() };
            s.push_str(&format!(", \"{}\": {}", json_escape(k), v));
        }
        s.push_str(if i + 1 == rows.len() { "}\n" } else { "},\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// Parse a trajectory snapshot back into tagged rows.  Tolerant of both
/// formats: schema-2 rows carry their own `bench`/`run`; schema-1 rows
/// inherit the document's top-level `bench` and run 1.  Unparseable text
/// yields no rows ([`write_bench_json`] refuses to overwrite such a file).
/// Non-numeric row fields other than the tags are ignored; `null` metrics
/// round-trip as NaN (re-serialized as `null`).
pub fn read_bench_rows(text: &str) -> Vec<TaggedRecord> {
    use crate::util::json::JsonValue;
    let Ok(doc) = JsonValue::parse(text) else {
        return Vec::new();
    };
    let default_bench = doc
        .get("bench")
        .and_then(|b| b.as_str().ok())
        .unwrap_or("bench")
        .to_string();
    let Some(Ok(rows)) = doc.get("rows").map(|r| r.as_array()) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for row in rows {
        let Ok(obj) = row.as_object() else { continue };
        let mut rec = BenchRecord::new(
            row.get("name").and_then(|n| n.as_str().ok()).unwrap_or(""),
        );
        // BTreeMap iteration: metric keys come back alphabetized, which
        // stays deterministic even though insertion order is lost.
        for (k, v) in obj {
            if k == "name" || k == "bench" || k == "run" {
                continue;
            }
            match v {
                JsonValue::Number(x) => rec.push(k, *x),
                JsonValue::Null => rec.push(k, f64::NAN),
                _ => {}
            }
        }
        out.push(TaggedRecord {
            bench: row
                .get("bench")
                .and_then(|b| b.as_str().ok())
                .unwrap_or(&default_bench)
                .to_string(),
            run: row.get("run").and_then(|r| r.as_usize().ok()).unwrap_or(1) as u64,
            rec,
        });
    }
    out
}

/// Append this run's records to a `BENCH_*.json` trajectory snapshot.
///
/// Existing rows (schema 1 or 2) are preserved; the new records are tagged
/// with `bench` and the next run ordinal for that bench, so repeated runs
/// accumulate a trajectory instead of overwriting each other, and several
/// benches (e.g. `coordinator` and `net`) can share one file.  A missing
/// file starts a fresh trajectory; an existing file that does not parse as
/// JSON is an **error** — silently replacing it would destroy the
/// accumulated history this function exists to protect.
pub fn write_bench_json(
    path: &std::path::Path,
    bench: &str,
    records: &[BenchRecord],
) -> std::io::Result<()> {
    let mut rows = match std::fs::read_to_string(path) {
        Ok(text) => {
            if crate::util::json::JsonValue::parse(&text).is_err() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!(
                        "{} exists but is not valid JSON; refusing to overwrite a \
                         possibly-torn trajectory snapshot",
                        path.display()
                    ),
                ));
            }
            read_bench_rows(&text)
        }
        Err(_) => Vec::new(),
    };
    let run = rows.iter().filter(|r| r.bench == bench).map(|r| r.run).max().unwrap_or(0) + 1;
    rows.extend(records.iter().map(|rec| TaggedRecord {
        bench: bench.to_string(),
        run,
        rec: rec.clone(),
    }));
    std::fs::write(path, bench_rows_json(&rows))
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_produces_sane_numbers() {
        let t = BenchTimer::new(
            Duration::from_millis(5),
            Duration::from_millis(5),
            3,
        );
        let r = t.run("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns * 1.5);
        assert!(r.per_second() > 0.0);
    }

    #[test]
    fn report_contains_name() {
        let t = BenchTimer::new(Duration::from_millis(2), Duration::from_millis(2), 2);
        let r = t.run("my-bench", || 42u32);
        assert!(r.report().contains("my-bench"));
    }

    #[test]
    fn bench_json_roundtrips_through_the_parser() {
        use crate::util::json::JsonValue;

        let mut a = BenchRecord::new("sharded/banks=1");
        a.push("shards", 1.0);
        a.push("throughput_lps", 123456.75);
        let mut b = BenchRecord::new("sharded/banks=4 \"quoted\"");
        b.push("p99_ns", 9000.0);
        b.push("weird", f64::NAN);
        let text = bench_records_json("coordinator", &[a, b]);
        let v = JsonValue::parse(&text).expect("self-emitted JSON must parse");
        assert_eq!(v.req("schema").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.req("bench").unwrap().as_str().unwrap(), "coordinator");
        let rows = v.req("rows").unwrap().as_array().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].req("name").unwrap().as_str().unwrap(), "sharded/banks=1");
        assert_eq!(
            rows[0].req("throughput_lps").unwrap(),
            &JsonValue::Number(123456.75)
        );
        assert_eq!(
            rows[1].req("name").unwrap().as_str().unwrap(),
            "sharded/banks=4 \"quoted\""
        );
        assert_eq!(rows[1].req("weird").unwrap(), &JsonValue::Null, "NaN maps to null");
    }

    #[test]
    fn bench_json_handles_empty_rows() {
        let text = bench_records_json("coordinator", &[]);
        let v = crate::util::json::JsonValue::parse(&text).unwrap();
        assert!(v.req("rows").unwrap().as_array().unwrap().is_empty());
    }

    fn rec(name: &str, key: &str, v: f64) -> BenchRecord {
        let mut r = BenchRecord::new(name);
        r.push(key, v);
        r
    }

    #[test]
    fn write_bench_json_appends_across_runs_and_benches() {
        let path = std::env::temp_dir().join("cscam_bench_merge_test.json");
        let _ = std::fs::remove_file(&path);
        // run 1 of 'coordinator'
        write_bench_json(&path, "coordinator", &[rec("banks=1", "throughput_lps", 100.0)])
            .unwrap();
        // run 1 of 'net' joins the same file
        write_bench_json(&path, "net", &[rec("net/threads=4", "p99_ns", 9000.0)]).unwrap();
        // run 2 of 'coordinator' appends, not overwrites
        write_bench_json(&path, "coordinator", &[rec("banks=1", "throughput_lps", 120.0)])
            .unwrap();

        let rows = read_bench_rows(&std::fs::read_to_string(&path).unwrap());
        assert_eq!(rows.len(), 3, "trajectory accumulates");
        let coord: Vec<_> = rows.iter().filter(|r| r.bench == "coordinator").collect();
        assert_eq!(coord.len(), 2);
        assert_eq!(coord[0].run, 1);
        assert_eq!(coord[1].run, 2);
        assert_eq!(coord[1].rec.metrics[0], ("throughput_lps".to_string(), 120.0));
        let net: Vec<_> = rows.iter().filter(|r| r.bench == "net").collect();
        assert_eq!(net.len(), 1);
        assert_eq!(net[0].run, 1, "run ordinals count per bench");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn appends_do_not_rewrite_earlier_rows() {
        // The trajectory's value is its git diff: appending a run must
        // leave every earlier row byte-identical (keys are alphabetized on
        // both write and re-write, so parse→append→emit cannot churn).
        let path = std::env::temp_dir().join("cscam_bench_stability_test.json");
        let _ = std::fs::remove_file(&path);
        let mut r1 = BenchRecord::new("row1");
        r1.push("zeta", 1.0);
        r1.push("alpha", 2.0); // deliberately non-alphabetical push order
        write_bench_json(&path, "net", &[r1]).unwrap();
        let first = std::fs::read_to_string(&path).unwrap();
        let row1_line = first
            .lines()
            .find(|l| l.contains("row1"))
            .unwrap()
            .trim_end_matches(',')
            .to_string();
        assert!(row1_line.contains("\"alpha\": 2, \"zeta\": 1"), "{row1_line}");
        write_bench_json(&path, "net", &[rec("row2", "x", 3.0)]).unwrap();
        let second = std::fs::read_to_string(&path).unwrap();
        assert!(
            second.contains(&row1_line),
            "appending run 2 rewrote run 1's row:\n{second}"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn read_bench_rows_upgrades_the_legacy_schema() {
        // A schema-1 snapshot (top-level bench, no per-row tags) reads back
        // as run-1 rows of that bench — the committed bootstrap upgrades in
        // place on the first merged write.
        let legacy = bench_records_json("coordinator", &[rec("banks=4", "shards", 4.0)]);
        let rows = read_bench_rows(&legacy);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].bench, "coordinator");
        assert_eq!(rows[0].run, 1);
        assert_eq!(rows[0].rec.name, "banks=4");
        assert_eq!(rows[0].rec.metrics, vec![("shards".to_string(), 4.0)]);
    }

    #[test]
    fn read_bench_rows_tolerates_garbage_and_empty_docs() {
        assert!(read_bench_rows("not json at all").is_empty());
        assert!(read_bench_rows("{\"schema\": 2}").is_empty());
        assert!(read_bench_rows("{\"schema\": 2, \"rows\": []}").is_empty());
    }

    #[test]
    fn writer_refuses_to_clobber_an_unparseable_snapshot() {
        // A torn/corrupt file must surface as an error — silently replacing
        // it would destroy the accumulated trajectory.
        let path = std::env::temp_dir().join("cscam_bench_torn_test.json");
        std::fs::write(&path, "{\"schema\": 2, \"rows\": [trunca").unwrap();
        let err = write_bench_json(&path, "net", &[rec("r", "x", 1.0)]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            "{\"schema\": 2, \"rows\": [trunca",
            "the torn file must be left untouched"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn merged_rows_reserialize_deterministically() {
        let rows = vec![
            TaggedRecord { bench: "net".into(), run: 1, rec: rec("a", "x", 1.5) },
            TaggedRecord { bench: "net".into(), run: 2, rec: rec("b", "y", f64::NAN) },
        ];
        let text = bench_rows_json(&rows);
        let back = read_bench_rows(&text);
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].rec.metrics, vec![("x".to_string(), 1.5)]);
        assert!(back[1].rec.metrics[0].1.is_nan(), "null round-trips as NaN");
        assert_eq!(text, bench_rows_json(&back), "emit → parse → emit is a fixed point");
    }
}
