//! The crate's one FNV-1a implementation.
//!
//! Two subsystems need a stable, dependency-free 64-bit hash with a pinned
//! byte order: shard placement ([`crate::shard::ShardRouter`] routes a tag
//! to its owning bank by hashing the packed words) and the wire protocol
//! ([`crate::net::proto`] checksums every frame).  Both MUST agree across
//! hosts and across versions — a drifting hash silently re-homes every
//! stored tag — so the definition lives here exactly once.

use crate::bits::BitVec;

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x1000_0000_01b3;

/// Streaming FNV-1a hasher (for checksumming a frame as it is assembled).
#[derive(Debug, Clone, Copy)]
pub struct Fnv1a(u64);

impl Fnv1a {
    pub fn new() -> Self {
        Fnv1a(FNV_OFFSET)
    }

    /// Fold more bytes into the running hash.
    #[inline]
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    /// The hash of everything updated so far.
    #[inline]
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

/// FNV-1a of a byte slice.
pub fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.finish()
}

/// Stable FNV-1a over a tag's packed words (byte order pinned to
/// little-endian so placement never depends on the host).
pub fn fnv1a(tag: &BitVec) -> u64 {
    let mut h = Fnv1a::new();
    for &w in tag.words() {
        h.update(&w.to_le_bytes());
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a_bytes(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_bytes(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_bytes(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn streaming_equals_one_shot() {
        let mut h = Fnv1a::new();
        h.update(b"foo");
        h.update(b"bar");
        assert_eq!(h.finish(), fnv1a_bytes(b"foobar"));
    }

    #[test]
    fn tag_hash_is_the_le_byte_hash_of_its_words() {
        let t = BitVec::from_u128(0xDEAD_BEEF_0123_4567_89AB_CDEF_0F1E_2D3C, 100);
        let mut bytes = Vec::new();
        for &w in t.words() {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        assert_eq!(fnv1a(&t), fnv1a_bytes(&bytes));
    }

    #[test]
    fn tag_hashes_differ_across_lengths_of_same_value() {
        // Length is part of the words() extent, so a zero-extended copy of
        // the same value hashes differently — placements must not collide
        // tags of different widths.
        let a = BitVec::from_u128(7, 64);
        let b = BitVec::from_u128(7, 128);
        assert_ne!(fnv1a(&a), fnv1a(&b));
    }
}
