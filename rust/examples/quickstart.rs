//! Quickstart: build the Table I reference design, store some tags, look
//! them up, and read the physics (energy / delay / ambiguity) off the
//! outcome — the whole paper in thirty lines.
//!
//! Run: `cargo run --release --example quickstart`

use cscam::config::DesignConfig;
use cscam::coordinator::LookupEngine;
use cscam::util::Rng;
use cscam::workload::TagDistribution;

fn main() -> anyhow::Result<()> {
    // The paper's reference design point (Table I): 512 entries × 128-bit
    // tags, 64 compare-enabled sub-blocks of ζ=8 rows, CNN with c=3
    // clusters of l=8 neurons fed by a q=9-bit reduced tag.
    let cfg = DesignConfig::reference();
    let mut engine = LookupEngine::new(cfg.clone());

    // Store 512 random tags (a full TLB / router table).
    let mut rng = Rng::seed_from_u64(2013);
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &tags {
        engine.insert(t)?;
    }
    println!("stored {} tags in a {}x{} CAM (β={} sub-blocks)", cfg.m, cfg.m, cfg.n, cfg.beta());

    // Look one up: the CNN narrows 512 candidate rows to ~2 sub-blocks.
    let out = engine.lookup(&tags[123])?;
    println!("\nlookup tags[123]:");
    println!("  matched address   : {:?}", out.addr);
    println!("  λ (P_II neurons)  : {}", out.lambda);
    println!("  sub-blocks enabled: {} of {}", out.enabled_blocks, cfg.beta());
    println!("  rows compared     : {} of {}", out.comparisons, cfg.m);
    println!(
        "  energy            : {:.1} fJ ({:.4} fJ/bit/search)",
        out.energy.total_fj(),
        out.energy.per_bit(cfg.m, cfg.n)
    );
    println!("  cycle / latency   : {:.3} / {:.3} ns", out.delay.cycle_ns, out.delay.latency_ns);

    // The headline comparison: the same lookup on a conventional NAND CAM.
    let conv = engine.lookup_conventional(&tags[123], cscam::cam::MatchlineKind::Nand)?;
    println!("\nsame lookup, conventional NAND CAM:");
    println!("  rows compared     : {} of {}", conv.comparisons, cfg.m);
    println!(
        "  energy            : {:.1} fJ ({:.4} fJ/bit/search)",
        conv.energy.total_fj(),
        conv.energy.per_bit(cfg.m, cfg.n)
    );
    println!(
        "\nenergy ratio: {:.1} %  (paper: 9.5 %)",
        100.0 * out.energy.total_fj() / conv.energy.total_fj()
    );

    // Misses whose reduced tag collides with nothing stored burn ~zero
    // comparisons — the CNN predicts "no sub-block" before any match-line
    // precharges.
    let miss = cscam::workload::random_tag(cfg.n, &mut rng);
    let out = engine.lookup(&miss)?;
    println!(
        "\nrandom miss: matched={:?}, comparisons={}, energy={:.1} fJ (CNN-only floor)",
        out.addr,
        out.comparisons,
        out.energy.total_fj()
    );
    Ok(())
}
