//! Micro-benchmark timer used by the `harness = false` bench binaries
//! (criterion-style warmup + repeated sampling, implemented in-tree).

use std::time::{Duration, Instant};

/// Result of one benchmark: per-iteration timings in nanoseconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Mean ns/iter.
    pub mean_ns: f64,
    /// Standard deviation of the sample means.
    pub std_ns: f64,
    /// Best sample (ns/iter).
    pub min_ns: f64,
    /// Iterations per sample.
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchResult {
    /// criterion-ish one-liner.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12.1} ns/iter (±{:>8.1}, min {:>10.1}, {} samples × {} iters)",
            self.name, self.mean_ns, self.std_ns, self.min_ns, self.samples, self.iters_per_sample
        )
    }

    /// Throughput helper.
    pub fn per_second(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Warmup-then-sample bench driver.
pub struct BenchTimer {
    warmup: Duration,
    sample_time: Duration,
    samples: usize,
}

impl Default for BenchTimer {
    fn default() -> Self {
        BenchTimer {
            warmup: Duration::from_millis(300),
            sample_time: Duration::from_millis(200),
            samples: 10,
        }
    }
}

impl BenchTimer {
    pub fn new(warmup: Duration, sample_time: Duration, samples: usize) -> Self {
        assert!(samples >= 2);
        BenchTimer { warmup, sample_time, samples }
    }

    /// Quick preset for heavyweight bodies (whole-workload benches).
    pub fn coarse() -> Self {
        BenchTimer {
            warmup: Duration::from_millis(50),
            sample_time: Duration::from_millis(400),
            samples: 5,
        }
    }

    /// Run `body` repeatedly; `body` must return something observable to
    /// keep the optimizer honest (its result is black-boxed here).
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut body: F) -> BenchResult {
        // warmup + calibration: how many iters fit in sample_time?
        let w0 = Instant::now();
        let mut warm_iters: u64 = 0;
        while w0.elapsed() < self.warmup {
            black_box(body());
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.sample_time.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut means = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(body());
            }
            means.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let mean = means.iter().sum::<f64>() / means.len() as f64;
        let var =
            means.iter().map(|m| (m - mean) * (m - mean)).sum::<f64>() / (means.len() - 1) as f64;
        let min = means.iter().copied().fold(f64::INFINITY, f64::min);
        let result = BenchResult {
            name: name.to_string(),
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: min,
            iters_per_sample: iters,
            samples: self.samples,
        };
        println!("{}", result.report());
        result
    }
}

/// Optimizer barrier (stable-Rust equivalent of `std::hint::black_box` —
/// which we also call; the volatile read guards against inlining through).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One named row of a `BENCH_*.json` trajectory snapshot: a bench scenario
/// plus its measured metrics, in insertion order.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub name: String,
    pub metrics: Vec<(String, f64)>,
}

impl BenchRecord {
    pub fn new(name: impl Into<String>) -> Self {
        BenchRecord { name: name.into(), metrics: Vec::new() }
    }

    /// Append one metric (kept in insertion order for stable diffs).
    pub fn push(&mut self, key: &str, value: f64) {
        self.metrics.push((key.to_string(), value));
    }
}

/// Serialize bench records to the `BENCH_*.json` trajectory format
/// (schema 1).  Future PRs diff these snapshots for perf regressions, so
/// the output is deterministic: stable key order, one row per line.
/// Non-finite values serialize as `null`.
pub fn bench_records_json(bench: &str, records: &[BenchRecord]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"schema\": 1,\n  \"bench\": \"{}\",\n  \"rows\": [\n",
        json_escape(bench)
    ));
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!("    {{\"name\": \"{}\"", json_escape(&r.name)));
        for (k, v) in &r.metrics {
            let v = if v.is_finite() { format!("{v}") } else { "null".to_string() };
            s.push_str(&format!(", \"{}\": {}", json_escape(k), v));
        }
        s.push_str(if i + 1 == records.len() { "}\n" } else { "},\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// Write a `BENCH_*.json` snapshot (see [`bench_records_json`]).
pub fn write_bench_json(
    path: &std::path::Path,
    bench: &str,
    records: &[BenchRecord],
) -> std::io::Result<()> {
    std::fs::write(path, bench_records_json(bench, records))
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_produces_sane_numbers() {
        let t = BenchTimer::new(
            Duration::from_millis(5),
            Duration::from_millis(5),
            3,
        );
        let r = t.run("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns * 1.5);
        assert!(r.per_second() > 0.0);
    }

    #[test]
    fn report_contains_name() {
        let t = BenchTimer::new(Duration::from_millis(2), Duration::from_millis(2), 2);
        let r = t.run("my-bench", || 42u32);
        assert!(r.report().contains("my-bench"));
    }

    #[test]
    fn bench_json_roundtrips_through_the_parser() {
        use crate::util::json::JsonValue;

        let mut a = BenchRecord::new("sharded/banks=1");
        a.push("shards", 1.0);
        a.push("throughput_lps", 123456.75);
        let mut b = BenchRecord::new("sharded/banks=4 \"quoted\"");
        b.push("p99_ns", 9000.0);
        b.push("weird", f64::NAN);
        let text = bench_records_json("coordinator", &[a, b]);
        let v = JsonValue::parse(&text).expect("self-emitted JSON must parse");
        assert_eq!(v.req("schema").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.req("bench").unwrap().as_str().unwrap(), "coordinator");
        let rows = v.req("rows").unwrap().as_array().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].req("name").unwrap().as_str().unwrap(), "sharded/banks=1");
        assert_eq!(
            rows[0].req("throughput_lps").unwrap(),
            &JsonValue::Number(123456.75)
        );
        assert_eq!(
            rows[1].req("name").unwrap().as_str().unwrap(),
            "sharded/banks=4 \"quoted\""
        );
        assert_eq!(rows[1].req("weird").unwrap(), &JsonValue::Null, "NaN maps to null");
    }

    #[test]
    fn bench_json_handles_empty_rows() {
        let text = bench_records_json("coordinator", &[]);
        let v = crate::util::json::JsonValue::parse(&text).unwrap();
        assert!(v.req("rows").unwrap().as_array().unwrap().is_empty());
    }
}
