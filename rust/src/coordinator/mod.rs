//! L3 — the serving coordinator for ONE bank.
//!
//! The paper's device is a lookup engine; the coordinator wraps it the way
//! a TLB/router integration would: a threaded request loop with a dynamic
//! batcher in front of the decode stage, an insert/delete path that keeps
//! the CNN consistent with the array, and per-request energy/latency
//! accounting.  Everything here is per-bank by construction — one
//! [`LookupEngine`], one [`Batcher`], one [`Metrics`] per engine thread —
//! which is exactly what lets [`crate::shard`] stack `S` of these behind a
//! scatter-gather router and aggregate the per-bank snapshots into a fleet
//! view.
//!
//! * [`engine`] — one CAM macro + its CNN classifier (the Fig. 1 system).
//! * [`batcher`] — size/deadline dynamic batching for the decode stage
//!   (feeds the PJRT artifact whose batch sizes are fixed at AOT time).
//! * [`server`] — threaded serve loop: mpsc in, per-request response
//!   channels out, non-blocking admission, graceful drain.
//! * [`metrics`] — counters + latency/energy aggregation.
//!
//! Multi-bank scale-out (placement, scatter-gather, fleet metrics) lives
//! one layer up in [`crate::shard`]; the network front-end that exposes a
//! fleet over TCP — including the wire mapping of [`EngineError`] and the
//! `Full` shed-on-overload contract of [`ServerHandle::try_lookup`] —
//! lives two layers up in [`crate::net`].

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{EngineError, LookupEngine, LookupOutcome};
pub use metrics::Metrics;
pub use server::{
    CamServer, DecodeBackend, PendingBulk, PendingLookup, PendingPersist, PersistError,
    ServerHandle, DEFAULT_QUEUE_CAPACITY,
};
