//! Self-contained utilities (the build environment is offline, so the crate
//! carries its own deterministic RNG, JSON parser, CLI helper and bench
//! timer instead of pulling `rand`/`serde_json`/`clap`/`criterion`).

pub mod bench;
pub mod cli;
pub mod codec;
pub mod hash;
pub mod json;
pub mod rng;
pub mod sync;

pub use bench::BenchTimer;
pub use hash::Fnv1a;
pub use json::JsonValue;
pub use rng::Rng;
