//! The CAM array — the modelled device (Fig. 5).
//!
//! Functional simulator of a binary CAM of `M` entries × `N` tag bits,
//! hierarchically organized into `β = M/ζ` sub-blocks that can be
//! compare-enabled independently (the paper's architectural hook).  A search
//! both *answers the query* (which valid entries match) and *accounts the
//! switching activity* (how many rows were enabled, how many bits compared,
//! how many match-lines discharged) that the energy model turns into
//! femtojoules.

pub mod array;
pub mod prefilter;

pub use array::{CamArray, SearchResult};
pub use prefilter::BankFilter;


/// Match-line circuit family (survey [7]; Table II "ML Arch.").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MatchlineKind {
    /// Parallel NOR match-line: fast (single pull-down depth) but every
    /// mismatching row discharges its precharged ML — high energy.
    Nor,
    /// Series NAND chain: only the matching row conducts end-to-end — low
    /// energy, but delay grows with the chain length N.
    Nand,
}

impl MatchlineKind {
    pub fn name(&self) -> &'static str {
        match self {
            MatchlineKind::Nor => "NOR",
            MatchlineKind::Nand => "NAND",
        }
    }
}

/// CAM cell circuit (Table I "CAM type"). Determines the transistor count
/// and which ML families it can drive.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CellKind {
    /// 9-transistor XOR-type cell (the paper's choice for the proposed and
    /// Ref. NOR designs): 6T storage + 3T XOR compare.
    Xor9T,
    /// 10-transistor NAND-type cell (conventional Ref. NAND design):
    /// 6T storage + 4T compare/pass.
    Nand10T,
}

impl CellKind {
    /// Transistors per cell.
    pub fn transistors(&self) -> usize {
        match self {
            CellKind::Xor9T => 9,
            CellKind::Nand10T => 10,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CellKind::Xor9T => "XOR-9T",
            CellKind::Nand10T => "NAND-10T",
        }
    }
}
