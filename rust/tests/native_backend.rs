//! Native-only serving path: the whole insert → lookup → delete → metrics
//! lifecycle through [`CamServer`] with [`DecodeBackend::Native`].
//!
//! This file deliberately uses nothing behind the `pjrt` feature, so it
//! exercises the default / `--no-default-features` build — the pure-Rust
//! configuration the tier-1 gate ships.

use std::time::Duration;

use cscam::config::DesignConfig;
use cscam::coordinator::{BatchPolicy, CamServer, DecodeBackend, EngineError};
use cscam::util::Rng;
use cscam::workload::TagDistribution;

#[test]
fn native_server_full_lifecycle() {
    let cfg = DesignConfig::small_test();
    let server = CamServer::new(
        cfg.clone(),
        DecodeBackend::Native,
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
    );
    let h = server.spawn();

    // Insert a table's worth of tags; addresses are allocated in order.
    let mut rng = Rng::seed_from_u64(99);
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 32, &mut rng);
    for (i, t) in tags.iter().enumerate() {
        assert_eq!(h.insert(t.clone()).unwrap(), i);
    }

    // Every stored tag resolves to its address, with the paper's physics
    // attached to the outcome.
    for (i, t) in tags.iter().enumerate() {
        let out = h.lookup(t.clone()).unwrap();
        assert_eq!(out.addr, Some(i));
        assert!(out.lambda >= 1);
        assert!(out.enabled_blocks >= 1);
        assert!(out.energy.total_fj() > 0.0);
    }

    // Bulk lookups agree with singles and keep order.
    let bulk = h.lookup_many(tags.clone());
    for (i, r) in bulk.iter().enumerate() {
        assert_eq!(r.as_ref().unwrap().addr, Some(i));
    }

    // Delete a slot: subsequent lookups of its tag miss, others still hit.
    h.delete(5).unwrap();
    assert_eq!(h.lookup(tags[5].clone()).unwrap().addr, None);
    assert_eq!(h.lookup(tags[6].clone()).unwrap().addr, Some(6));
    assert_eq!(h.delete(cfg.m), Err(EngineError::BadAddress(cfg.m)));

    // Metrics observed the whole lifecycle.
    h.drain();
    let m = h.metrics().unwrap();
    assert_eq!(m.inserts, 32);
    assert_eq!(m.deletes, 1);
    assert_eq!(m.lookups, 32 + 32 + 2);
    assert_eq!(m.misses, 1);
    assert_eq!(m.hits, m.lookups - 1);
    assert!(m.batches >= 1);
    assert!(m.energy_fj.mean() > 0.0);
}

#[test]
fn native_server_rejects_malformed_requests() {
    let cfg = DesignConfig::small_test();
    let h = CamServer::new(cfg.clone(), DecodeBackend::Native, BatchPolicy::default()).spawn();
    let wrong_width = cscam::bits::BitVec::zeros(cfg.n + 8);
    assert!(matches!(h.insert(wrong_width.clone()), Err(EngineError::TagWidth { .. })));
    assert!(matches!(h.lookup(wrong_width), Err(EngineError::TagWidth { .. })));
    assert_eq!(h.delete(cfg.m + 1), Err(EngineError::BadAddress(cfg.m + 1)));
}
