//! Per-component energy breakdown and the switching-activity counters that
//! feed it.


/// Switching activity of one CAM search — what the functional simulator
/// ([`crate::cam::CamArray::search`]) actually observed.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SearchActivity {
    /// Total sub-blocks in the array (β).
    pub total_blocks: usize,
    /// Sub-blocks that were compare-enabled this search.
    pub enabled_blocks: usize,
    /// Rows inside enabled blocks (= enabled_blocks × ζ).
    pub enabled_rows: usize,
    /// Enabled rows holding valid entries (these resolve full comparisons).
    pub compared_rows: usize,
    /// Valid rows whose tag matched the query exactly.
    pub matched_rows: usize,
    /// Enabled rows that mismatched (valid mismatches + invalid rows).
    pub mismatched_rows: usize,
    /// Exact number of bit positions compared (compared_rows × N).
    pub compared_bits: usize,
    /// Exact number of mismatching bit positions (ML discharge paths).
    pub mismatch_bits: usize,
    /// Tag width N.
    pub tag_bits: usize,
}

impl SearchActivity {
    /// Merge another search's counters into this one (for aggregating a
    /// whole workload's activity).
    pub fn accumulate(&mut self, other: &SearchActivity) {
        self.total_blocks = other.total_blocks;
        self.tag_bits = other.tag_bits;
        self.enabled_blocks += other.enabled_blocks;
        self.enabled_rows += other.enabled_rows;
        self.compared_rows += other.compared_rows;
        self.matched_rows += other.matched_rows;
        self.mismatched_rows += other.mismatched_rows;
        self.compared_bits += other.compared_bits;
        self.mismatch_bits += other.mismatch_bits;
    }
}

/// Energy of one search, split by physical component (femtojoules).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// Search-line gate+local-wire energy in enabled rows.
    pub searchline_fj: f64,
    /// Match-line precharge/evaluate energy in enabled rows.
    pub matchline_fj: f64,
    /// Un-gateable global search-data broadcast wire.
    pub global_wire_fj: f64,
    /// CNN weight-SRAM row reads (c rows of M bits).
    pub sram_read_fj: f64,
    /// CNN one-hot decoders.
    pub decoder_fj: f64,
    /// P_II AND/OR logic.
    pub pii_logic_fj: f64,
    /// Compare-enable line drivers (activated blocks).
    pub enable_driver_fj: f64,
    /// Per-row enable gating overhead on the precharge path.
    pub enable_gate_fj: f64,
}

impl EnergyBreakdown {
    /// Total energy per search in femtojoules.
    pub fn total_fj(&self) -> f64 {
        self.searchline_fj
            + self.matchline_fj
            + self.global_wire_fj
            + self.sram_read_fj
            + self.decoder_fj
            + self.pii_logic_fj
            + self.enable_driver_fj
            + self.enable_gate_fj
    }

    /// The CNN classifier's share (everything that is not the CAM array).
    pub fn cnn_fj(&self) -> f64 {
        self.sram_read_fj + self.decoder_fj + self.pii_logic_fj + self.enable_driver_fj
    }

    /// The CAM array's share.
    pub fn cam_fj(&self) -> f64 {
        self.searchline_fj + self.matchline_fj + self.global_wire_fj + self.enable_gate_fj
    }

    /// Table II's metric: fJ/bit/search over an M×N array.
    pub fn per_bit(&self, m: usize, n: usize) -> f64 {
        self.total_fj() / (m as f64 * n as f64)
    }

    /// Element-wise sum (aggregate a workload, then divide by searches).
    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.searchline_fj += other.searchline_fj;
        self.matchline_fj += other.matchline_fj;
        self.global_wire_fj += other.global_wire_fj;
        self.sram_read_fj += other.sram_read_fj;
        self.decoder_fj += other.decoder_fj;
        self.pii_logic_fj += other.pii_logic_fj;
        self.enable_driver_fj += other.enable_driver_fj;
        self.enable_gate_fj += other.enable_gate_fj;
    }

    /// Scale every component (e.g. averaging, technology scaling).
    pub fn scaled(&self, k: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            searchline_fj: self.searchline_fj * k,
            matchline_fj: self.matchline_fj * k,
            global_wire_fj: self.global_wire_fj * k,
            sram_read_fj: self.sram_read_fj * k,
            decoder_fj: self.decoder_fj * k,
            pii_logic_fj: self.pii_logic_fj * k,
            enable_driver_fj: self.enable_driver_fj * k,
            enable_gate_fj: self.enable_gate_fj * k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_are_component_sums() {
        let b = EnergyBreakdown {
            searchline_fj: 1.0,
            matchline_fj: 2.0,
            global_wire_fj: 3.0,
            sram_read_fj: 4.0,
            decoder_fj: 5.0,
            pii_logic_fj: 6.0,
            enable_driver_fj: 7.0,
            enable_gate_fj: 8.0,
        };
        assert_eq!(b.total_fj(), 36.0);
        assert_eq!(b.cnn_fj(), 22.0);
        assert_eq!(b.cam_fj(), 14.0);
        assert!((b.cnn_fj() + b.cam_fj() - b.total_fj()).abs() < 1e-12);
    }

    #[test]
    fn per_bit_normalizes() {
        let b = EnergyBreakdown { searchline_fj: 650.0, ..Default::default() };
        assert!((b.per_bit(512, 128) - 650.0 / 65536.0).abs() < 1e-12);
    }

    #[test]
    fn accumulate_sums_activity() {
        let mut a = SearchActivity { enabled_blocks: 2, enabled_rows: 16, ..Default::default() };
        let b = SearchActivity { enabled_blocks: 3, enabled_rows: 24, ..Default::default() };
        a.accumulate(&b);
        assert_eq!(a.enabled_blocks, 5);
        assert_eq!(a.enabled_rows, 40);
    }

    #[test]
    fn scaled_is_linear() {
        let b = EnergyBreakdown { matchline_fj: 10.0, sram_read_fj: 4.0, ..Default::default() };
        assert_eq!(b.scaled(0.5).total_fj(), 7.0);
    }
}
