//! Offline stand-in for the `xla` crate (the xla-rs bindings over the
//! XLA/PJRT C++ toolchain).
//!
//! The `cscam` crate's `pjrt` feature compiles `cscam::runtime` against this
//! API surface so the PJRT code path stays type-checked on machines without
//! the XLA toolchain installed.  Every constructor returns an error at
//! runtime — [`PjRtClient::cpu`] is the only entry point, so no value of any
//! of these types can ever be observed.  To execute real artifacts, point the
//! `xla` path dependency in `rust/Cargo.toml` at the real bindings (same
//! module paths and method names) instead of this stub.
//!
//! The handle types deliberately contain an `Rc` so they are `!Send`, exactly
//! like the real FFI handles — code that compiles against the stub makes the
//! same thread-safety promises it will need against the real crate.

use std::fmt;
use std::path::Path;
use std::rc::Rc;

/// Error type mirroring the real bindings' error enum (Display only is used).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "xla stub: this build links the in-tree type-level stub, not the real \
     XLA/PJRT toolchain; point the `xla` path dependency at the real bindings to execute artifacts";

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Element types that can cross the host/device boundary.
pub trait ArrayElement: Copy {}

impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u8 {}

/// PJRT client handle (`!Send`, like the real FFI wrapper).
pub struct PjRtClient {
    _handle: Rc<()>,
}

impl PjRtClient {
    /// CPU client — always fails in the stub.
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _handle: Rc<()>,
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// A resident device buffer.
pub struct PjRtBuffer {
    _handle: Rc<()>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A host-side literal value.
pub struct Literal {
    _handle: Rc<()>,
}

impl Literal {
    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        unavailable()
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse HLO text — always fails in the stub.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable()
    }
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("xla stub"));
    }
}
