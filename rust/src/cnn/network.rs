//! Bit-packed clustered-sparse-network: training and global decoding.


use crate::bits::{kernel, BitSlab, BitVec};

/// Result of one decode: the P_II activation map and the derived
/// compare-enable mask.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Activation {
    /// P_II neural values — bit `i` set iff entry `i`'s neuron activated.
    pub act: BitVec,
    /// ζ-group OR of `act` — bit `b` set iff sub-block `b` must be
    /// compare-enabled (the `En` lines of Fig. 5).
    pub enables: BitVec,
    /// λ — number of activated P_II neurons (ambiguity count, Fig. 3).
    pub lambda: usize,
}

/// The CNN of Fig. 2: `c` clusters of `l` binary neurons in P_I, fully
/// (binary-)connected to `M` neurons in P_II.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusteredNetwork {
    c: usize,
    l: usize,
    m: usize,
    zeta: usize,
    /// `c·l` rows of `M` bits in one contiguous slab; row `i·l + j` holds
    /// w_{(i,j)(·)} — the SRAM layout of Fig. 4.  A decode touches `c` rows
    /// spaced `l` rows apart, and the slab keeps each a single contiguous
    /// word run.
    rows: BitSlab,
}

impl ClusteredNetwork {
    /// Untrained network. `l` must be a power of two; `zeta` must divide `m`.
    pub fn new(c: usize, l: usize, m: usize, zeta: usize) -> Self {
        assert!(c > 0 && l.is_power_of_two(), "bad cluster geometry");
        assert!(zeta > 0 && m % zeta == 0, "ζ must divide M");
        ClusteredNetwork { c, l, m, zeta, rows: BitSlab::zeros(c * l, m) }
    }

    /// Build with geometry from a design config.
    pub fn from_config(cfg: &crate::config::DesignConfig) -> Self {
        Self::new(cfg.c, cfg.l, cfg.m, cfg.zeta)
    }

    /// Rebuild from persisted weight rows (the snapshot restore path).
    /// Returns an error instead of panicking — the rows may come from a
    /// corrupt file.
    pub fn from_rows(
        c: usize,
        l: usize,
        m: usize,
        zeta: usize,
        rows: Vec<BitVec>,
    ) -> Result<Self, String> {
        if c == 0 || !l.is_power_of_two() {
            return Err(format!("bad cluster geometry: c={c}, l={l}"));
        }
        if m == 0 || zeta == 0 || m % zeta != 0 {
            return Err(format!("ζ={zeta} must divide M={m}"));
        }
        if rows.len() != c * l {
            return Err(format!("{} weight rows, expected c·l={}", rows.len(), c * l));
        }
        if let Some((i, r)) = rows.iter().enumerate().find(|(_, r)| r.len() != m) {
            return Err(format!("weight row {i} is {} bits, expected M={m}", r.len()));
        }
        Ok(ClusteredNetwork { c, l, m, zeta, rows: BitSlab::from_rows(&rows, m) })
    }

    pub fn c(&self) -> usize {
        self.c
    }
    pub fn l(&self) -> usize {
        self.l
    }
    pub fn m(&self) -> usize {
        self.m
    }
    pub fn zeta(&self) -> usize {
        self.zeta
    }
    pub fn beta(&self) -> usize {
        self.m / self.zeta
    }

    /// Number of stored (set) weights — hardware occupancy statistic.
    pub fn weight_count(&self) -> usize {
        (0..self.rows.rows())
            .map(|r| self.rows.row_words(r).iter().map(|w| w.count_ones() as usize).sum::<usize>())
            .sum()
    }

    /// Materialized weight rows (the Fig. 4 SRAM contents) — used to ship W
    /// to the PJRT decode artifact and by the snapshot encoder.  Cold path;
    /// the hot decode reads the slab words directly.
    pub fn weight_rows(&self) -> Vec<BitVec> {
        self.rows.to_rows()
    }

    /// The backing weight slab (row `i·l + j` ↦ w_{(i,j)(·)}).
    pub fn slab(&self) -> &BitSlab {
        &self.rows
    }

    /// Train the association between a reduced tag (as `c` cluster indices,
    /// each `< l`) and CAM address `addr` (§II-A.1).
    pub fn train(&mut self, idx: &[u16], addr: usize) {
        assert_eq!(idx.len(), self.c, "need one index per cluster");
        assert!(addr < self.m, "address out of range");
        for (cluster, &j) in idx.iter().enumerate() {
            assert!((j as usize) < self.l, "neuron index out of range");
            self.rows.set(cluster * self.l + j as usize, addr, true);
        }
    }

    /// Forget everything (weights are superposed, so deleting a single
    /// association requires a rebuild — see the coordinator's retrain path).
    pub fn clear(&mut self) {
        self.rows.clear();
    }

    /// Rebuild from a full association list.
    pub fn retrain_from<'a>(&mut self, entries: impl IntoIterator<Item = (&'a [u16], usize)>) {
        self.clear();
        for (idx, addr) in entries {
            self.train(idx, addr);
        }
    }

    /// Global decode (eq. 1): AND of the one selected row per cluster, then
    /// the ζ-group OR producing the compare-enable mask (§II-A.2).
    pub fn decode(&self, idx: &[u16]) -> Activation {
        let mut act = BitVec::zeros(self.m);
        let mut enables = BitVec::zeros(self.beta());
        let lambda = self.decode_into(idx, &mut act, &mut enables);
        Activation { act, enables, lambda }
    }

    /// Allocation-free decode into caller-provided buffers; returns λ.
    /// This is the coordinator's hot path.
    #[inline]
    pub fn decode_into(&self, idx: &[u16], act: &mut BitVec, enables: &mut BitVec) -> usize {
        debug_assert_eq!(idx.len(), self.c);
        debug_assert_eq!(act.len(), self.m);
        debug_assert_eq!(enables.len(), self.beta());

        // AND the selected row of each cluster (LD fused into row select).
        // Each row is one contiguous word run inside the slab, so this is a
        // pure streaming AND-reduce with no per-row pointer chase.
        act.words_mut().copy_from_slice(self.rows.row_words(idx[0] as usize));
        for (cluster, &j) in idx.iter().enumerate().skip(1) {
            debug_assert!((j as usize) < self.l);
            kernel::and_words(act.words_mut(), self.rows.row_words(cluster * self.l + j as usize));
        }
        act.ensure_tail_clear();

        // ζ-group OR → enable bits, plus λ popcount, in one pass.
        let mut lambda = 0usize;
        for w in enables.words_mut() {
            *w = 0;
        }
        if self.zeta.is_power_of_two() && self.zeta <= 64 {
            group_or_pow2(act.words(), self.m, self.zeta, enables.words_mut(), &mut lambda);
        } else {
            lambda = act.count_ones();
            for i in act.iter_ones() {
                enables.set(i / self.zeta, true);
            }
        }
        lambda
    }

    /// Convenience: decode and return just the enable mask.
    pub fn enables(&self, idx: &[u16]) -> BitVec {
        self.decode(idx).enables
    }
}

/// Fold an M-bit activation map into M/ζ enable bits for power-of-two ζ,
/// word-at-a-time, accumulating λ on the way.
///
/// Perf notes: activation maps are sparse (λ ≈ 2 of
/// M bits at the reference point), so all-zero words short-circuit; for the
/// reference ζ = 8 the per-group bit pick is a single multiply-gather of
/// the byte LSBs instead of a 8-iteration shift loop.
#[inline]
fn group_or_pow2(act: &[u64], m: usize, zeta: usize, enables: &mut [u64], lambda: &mut usize) {
    let mut out_bit = 0usize;
    for (wi, &w0) in act.iter().enumerate() {
        let groups_in_word = (64 / zeta).min((m - wi * 64).div_ceil(zeta));
        if w0 == 0 {
            // fast path: nothing activated in this word (the common case)
            out_bit += groups_in_word;
            continue;
        }
        *lambda += w0.count_ones() as usize;
        let mut w = w0;
        // OR-fold within the word: after k steps each surviving bit is the
        // OR of a 2^k-bit group aligned to its low end.
        let mut width = 1usize;
        while width < zeta {
            w |= w >> width;
            width *= 2;
        }
        if zeta == 8 && groups_in_word == 8 {
            // gather the 8 byte-LSBs in one multiply: masked bits sit at
            // positions 8i; ·0x0102040810204080 places bit i of the result
            // at position 56+i with provably no carry collisions.
            let gathered =
                (w & 0x0101_0101_0101_0101).wrapping_mul(0x0102_0408_1020_4080) >> 56;
            enables[out_bit / 64] |= gathered << (out_bit % 64);
            out_bit += 8;
            continue;
        }
        // pick every ζ-th bit
        for g in 0..groups_in_word {
            if (w >> (g * zeta)) & 1 == 1 {
                enables[out_bit / 64] |= 1 << (out_bit % 64);
            }
            out_bit += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_then_decode_activates_exactly_trained_entry() {
        let mut net = ClusteredNetwork::new(3, 8, 64, 8);
        net.train(&[1, 5, 7], 42);
        let a = net.decode(&[1, 5, 7]);
        assert!(a.act.get(42));
        assert_eq!(a.lambda, 1);
        assert!(a.enables.get(42 / 8));
        assert_eq!(a.enables.count_ones(), 1);
    }

    #[test]
    fn untrained_pattern_activates_nothing() {
        let mut net = ClusteredNetwork::new(3, 8, 64, 8);
        net.train(&[1, 5, 7], 42);
        let a = net.decode(&[2, 5, 7]);
        assert_eq!(a.lambda, 0);
        assert!(a.enables.is_zero());
    }

    #[test]
    fn paper_example_section_iia() {
        // §II-A.1: c=2, q=6 (l=8), truncated tag '101110' → clusters
        // '101'=5, '110'=6, fourth entry ⇒ w_(1,5)(4) and w_(2,6)(4) set.
        let mut net = ClusteredNetwork::new(2, 8, 16, 4);
        net.train(&[5, 6], 4);
        assert!(net.slab().get(5, 4)); // cluster 1, neuron 5
        assert!(net.slab().get(8 + 6, 4)); // cluster 2, neuron 6
        assert_eq!(net.weight_count(), 2);
        assert_eq!(net.decode(&[5, 6]).lambda, 1);
    }

    #[test]
    fn superposition_creates_ambiguity_not_misses() {
        // Two entries sharing the same reduced tag must both activate —
        // "ambiguities cost power but never correctness" (§I).
        let mut net = ClusteredNetwork::new(3, 4, 32, 4);
        net.train(&[0, 1, 2], 3);
        net.train(&[0, 1, 2], 17);
        let a = net.decode(&[0, 1, 2]);
        assert_eq!(a.lambda, 2);
        assert!(a.act.get(3) && a.act.get(17));
        assert!(a.enables.get(0) && a.enables.get(4));
    }

    #[test]
    fn cross_cluster_phantom_activation() {
        // The classic Gripon–Berrou phantom: entries (0,0)→a and (1,1)→b do
        // NOT make (0,1) activate anything, but (0,0) trained to two
        // different addresses keeps both. Check a genuine phantom case:
        // entry A trains (0,*,0)→1, entry B trains (0,*,1)→2 with shared
        // first cluster; query (0,*,1) must not activate entry 1.
        let mut net = ClusteredNetwork::new(2, 4, 8, 2);
        net.train(&[0, 0], 1);
        net.train(&[0, 1], 2);
        let a = net.decode(&[0, 1]);
        assert!(a.act.get(2) && !a.act.get(1));
    }

    #[test]
    fn decode_into_matches_decode_and_is_reusable() {
        let mut net = ClusteredNetwork::new(3, 8, 128, 8);
        for e in 0..64 {
            net.train(&[(e % 8) as u16, ((e / 8) % 8) as u16, ((e / 64) % 8) as u16], e);
        }
        let mut act = BitVec::zeros(128);
        let mut en = BitVec::zeros(16);
        for q in 0..8u16 {
            let idx = [q % 8, (q + 3) % 8, 0];
            let lam = net.decode_into(&idx, &mut act, &mut en);
            let full = net.decode(&idx);
            assert_eq!(lam, full.lambda);
            assert_eq!(act, full.act);
            assert_eq!(en, full.enables);
        }
    }

    #[test]
    fn group_or_handles_all_pow2_zetas() {
        for zeta in [1usize, 2, 4, 8, 16, 32, 64] {
            let m = 256;
            let mut net = ClusteredNetwork::new(2, 4, m, zeta);
            net.train(&[3, 2], 200);
            net.train(&[3, 2], 5);
            let a = net.decode(&[3, 2]);
            assert_eq!(a.lambda, 2, "zeta={zeta}");
            assert_eq!(
                a.enables.iter_ones().collect::<Vec<_>>(),
                {
                    let mut v = vec![5 / zeta, 200 / zeta];
                    v.dedup();
                    v
                },
                "zeta={zeta}"
            );
        }
    }

    #[test]
    fn retrain_rebuilds_cleanly() {
        let mut net = ClusteredNetwork::new(2, 4, 16, 4);
        net.train(&[1, 1], 7);
        let e1: Vec<(Vec<u16>, usize)> = vec![(vec![2, 3], 9), (vec![0, 0], 0)];
        net.retrain_from(e1.iter().map(|(i, a)| (i.as_slice(), *a)));
        assert_eq!(net.decode(&[1, 1]).lambda, 0, "old association gone");
        assert_eq!(net.decode(&[2, 3]).lambda, 1);
        assert_eq!(net.decode(&[0, 0]).lambda, 1);
        assert_eq!(net.weight_count(), 4);
    }

    #[test]
    fn weight_count_saturates_on_duplicates() {
        let mut net = ClusteredNetwork::new(3, 8, 64, 8);
        net.train(&[1, 2, 3], 10);
        net.train(&[1, 2, 3], 10);
        assert_eq!(net.weight_count(), 3);
    }

    #[test]
    #[should_panic(expected = "address out of range")]
    fn train_rejects_bad_address() {
        let mut net = ClusteredNetwork::new(2, 4, 16, 4);
        net.train(&[0, 0], 16);
    }

    #[test]
    #[should_panic(expected = "neuron index out of range")]
    fn train_rejects_bad_neuron() {
        let mut net = ClusteredNetwork::new(2, 4, 16, 4);
        net.train(&[4, 0], 3);
    }
}
