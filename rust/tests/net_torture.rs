//! Adversarial-peer torture tests for the reactor front-end: peers that
//! deliver frames one byte at a time, stall mid-frame forever, pipeline
//! far past the multiplexing window, or never read their responses.  The
//! reactor must treat all of them as *state*, not threads — slow peers
//! cost buffer space, stalled peers are disconnected on the stall clock,
//! and a peer that refuses to drain its responses hits the bounded write
//! buffer's hard cap (typed disconnect, never unbounded memory).  The
//! over-cap shed path must answer `busy` deterministically — the old
//! thread-per-connection accept loop could silently drop a connection
//! when a handler-thread spawn failed; the reactor answers inline and has
//! no spawn to fail.

use cscam::bits::BitVec;
use cscam::config::DesignConfig;
use cscam::coordinator::BatchPolicy;
use cscam::net::proto::{self, Request, Response};
use cscam::net::{CamClient, CamTcpServer, LoadGen, NetConfig, NetServerHandle, WireError};
use cscam::shard::{PlacementMode, ShardedCamServer, ShardedServerHandle};
use cscam::util::Rng;
use cscam::workload::TagDistribution;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn fleet_cfg() -> DesignConfig {
    DesignConfig { m: 256, n: 32, zeta: 4, c: 3, l: 4, shards: 4, ..DesignConfig::reference() }
}

fn start(net: NetConfig) -> (NetServerHandle, ShardedServerHandle, String) {
    let policy = BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(100) };
    let fleet = ShardedCamServer::new(&fleet_cfg(), PlacementMode::TagHash, policy).spawn();
    let server = CamTcpServer::bind(fleet.clone(), "127.0.0.1:0", net).expect("bind loopback");
    let addr = server.local_addr().expect("local addr").to_string();
    let handle = server.spawn().expect("spawn server");
    (handle, fleet, addr)
}

fn stop(server: NetServerHandle, addr: &str) {
    match CamClient::connect(addr.to_string()) {
        Ok(mut c) => {
            let _ = c.shutdown();
        }
        Err(_) => server.shutdown(),
    }
    server.join();
}

/// Handshake + one request delivered one byte at a time: the resumable
/// codec must reassemble the frame across dozens of readiness events and
/// answer as if it had arrived whole.
#[test]
fn byte_at_a_time_frames_are_reassembled() {
    let (server, _fleet, addr) = start(NetConfig::default());
    let mut raw = TcpStream::connect(&addr).expect("raw connect");
    raw.set_nodelay(true).expect("nodelay");
    raw.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");

    let mut hello = Vec::new();
    proto::write_client_hello(&mut hello).expect("serialize hello");
    let mut frame = Vec::new();
    proto::write_request(&mut frame, 42, &Request::Stats).expect("serialize request");
    for chunk in [hello, frame] {
        for b in chunk {
            raw.write_all(&[b]).expect("dribble byte");
            raw.flush().expect("flush byte");
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    let mut r = std::io::BufReader::new(raw.try_clone().expect("clone"));
    let srv_hello = proto::read_server_hello(&mut r).expect("server hello");
    assert!(srv_hello.multiplex);
    assert!(!srv_hello.busy);
    let (id, resp) = proto::read_response(&mut r).expect("response to dribbled frame");
    assert_eq!(id, 42);
    assert!(matches!(resp, Response::Stats(_)), "got {resp:?}");
    drop(raw);
    stop(server, &addr);
}

/// A peer that goes silent mid-frame is disconnected once the stall
/// budget expires — it cannot pin a connection slot forever — while the
/// budget resets on progress (the byte-at-a-time test above survives a
/// much longer wall-clock than the budget here).
#[test]
fn stalled_mid_frame_writer_is_disconnected() {
    let net = NetConfig { stall_budget: Duration::from_millis(300), ..NetConfig::default() };
    let (server, _fleet, addr) = start(net);
    let mut raw = TcpStream::connect(&addr).expect("raw connect");
    raw.set_read_timeout(Some(Duration::from_millis(200))).expect("timeout");
    proto::write_client_hello(&mut raw).expect("hello");
    let mut r = std::io::BufReader::new(raw.try_clone().expect("clone"));
    proto::read_server_hello(&mut r).expect("server hello");

    // half a frame, then silence
    let mut frame = Vec::new();
    proto::write_request(&mut frame, 7, &Request::Stats).expect("serialize");
    raw.write_all(&frame[..frame.len() / 2]).expect("half frame");
    raw.flush().expect("flush");

    let deadline = Instant::now() + Duration::from_secs(5);
    let mut closed = false;
    let mut buf = [0u8; 64];
    while Instant::now() < deadline {
        match r.read(&mut buf) {
            Ok(0) => {
                closed = true;
                break;
            }
            Ok(_) => panic!("server answered a half frame"),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => {
                closed = true; // reset also counts as a disconnect
                break;
            }
        }
    }
    assert!(closed, "stalled writer kept its connection past the stall budget");
    drop(raw);
    stop(server, &addr);
}

/// Pipelining far past the multiplexing window: the reactor pauses
/// reading (backpressure) instead of buffering without bound, and once
/// the peer drains, every request is answered exactly once — the "zero
/// dropped acked requests" property under an aggressive client.
#[test]
fn firehose_pipelining_past_the_window_loses_nothing() {
    let net = NetConfig { inflight_window: 4, write_soft_cap: 2 * 1024, ..NetConfig::default() };
    let (server, _fleet, addr) = start(net);
    let mut raw = TcpStream::connect(&addr).expect("raw connect");
    raw.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");
    proto::write_client_hello(&mut raw).expect("hello");
    let mut r = std::io::BufReader::new(raw.try_clone().expect("clone"));
    proto::read_server_hello(&mut r).expect("server hello");

    // 100 requests up front, nothing read: 25× the inflight window
    const BURST: u64 = 100;
    let mut bytes = Vec::new();
    for id in 1..=BURST {
        proto::write_request(&mut bytes, id, &Request::Stats).expect("serialize");
    }
    raw.write_all(&bytes).expect("firehose");
    raw.flush().expect("flush");
    std::thread::sleep(Duration::from_millis(100)); // let backpressure engage

    let mut seen = std::collections::HashSet::new();
    for _ in 0..BURST {
        let (id, resp) = proto::read_response(&mut r).expect("response");
        assert!(matches!(resp, Response::Stats(_)), "id {id} got {resp:?}");
        assert!(seen.insert(id), "id {id} answered twice");
    }
    assert_eq!(seen.len() as u64, BURST);
    assert!(seen.iter().all(|id| (1..=BURST).contains(id)));
    drop((raw, r));
    stop(server, &addr);
}

/// A peer that never drains its responses: the bounded write buffer
/// absorbs up to the hard cap and the peer is then either cut off (a
/// typed disconnect) or stops being read from (backpressure all the way
/// to the peer's own sends) — never unbounded server memory.  The client
/// keeps asking for large bulk responses without ever reading; if the
/// server buffered everything, hundreds of megabytes of responses would
/// accumulate and every write here would keep succeeding.
#[test]
fn never_draining_reader_hits_the_bounded_write_buffer() {
    let net = NetConfig {
        inflight_window: 64,
        write_soft_cap: 64 * 1024,
        write_hard_cap: 256 * 1024,
        ..NetConfig::default()
    };
    let (server, _fleet, addr) = start(net);
    let mut raw = TcpStream::connect(&addr).expect("raw connect");
    raw.set_write_timeout(Some(Duration::from_secs(2))).expect("write timeout");
    raw.set_read_timeout(Some(Duration::from_secs(2))).expect("read timeout");
    proto::write_client_hello(&mut raw).expect("hello");
    proto::read_server_hello(&mut raw).expect("server hello");

    // Each frame asks for a ~13 KB response; 32k frames would owe the
    // client ~400 MB.  Long before that the server must either disconnect
    // us at the hard cap (EPIPE/reset here) or stop reading our socket
    // entirely (this write times out once the kernel buffers fill).
    let mut rng = Rng::seed_from_u64(31);
    let tags: Vec<BitVec> = TagDistribution::Uniform.sample_distinct(32, 256, &mut rng);
    let mut frame = Vec::new();
    proto::write_lookup_bulk_request(&mut frame, 1, &tags).expect("serialize bulk");
    let mut bounded = false;
    for _ in 0..32_768 {
        if raw.write_all(&frame).and_then(|()| raw.flush()).is_err() {
            bounded = true;
            break;
        }
    }
    assert!(bounded, "server absorbed ~400 MB of owed responses without pushing back");
    drop(raw);
    stop(server, &addr);
}

/// Over the connection cap every surplus connection gets a deterministic
/// `busy` hello — the old accept loop could silently drop one when its
/// handler-thread spawn failed; the reactor answers inline.
#[test]
fn over_cap_connections_all_get_a_deterministic_busy_hello() {
    let net = NetConfig { max_connections: 1, ..NetConfig::default() };
    let (server, _fleet, addr) = start(net);
    let holder = CamClient::connect(addr.clone()).expect("first connection");
    for i in 0..10 {
        match CamClient::connect(addr.clone()) {
            Err(WireError::Busy) => {}
            other => panic!(
                "surplus connection {i} must get the busy hello, got {:?}",
                other.map(|_| "connected")
            ),
        }
    }
    drop(holder);
    // the freed slot must come back
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut reconnected = false;
    while Instant::now() < deadline {
        if CamClient::connect(addr.clone()).is_ok() {
            reconnected = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(reconnected, "slot never freed after the holder disconnected");
    stop(server, &addr);
}

/// Connection-ramp mode end to end: `conns` multiplexed connections stay
/// open through the run, every lookup is answered, and the bench row is
/// tagged with the connection count so gating never mixes scenarios.
#[test]
fn loadgen_connection_ramp_holds_conns_open_and_tags_its_row() {
    let net = NetConfig { max_connections: 64, ..NetConfig::default() };
    let (server, _fleet, addr) = start(net);
    let driver = LoadGen {
        addr: addr.clone(),
        threads: 2,
        lookups: 2_000,
        chunk: 32,
        hit_ratio: 0.9,
        population: 120,
        rate: 0.0,
        conns: 32,
        seed: 17,
    };
    let report = driver.run().expect("ramp run");
    assert_eq!(report.conns, 32);
    assert_eq!(report.lookups + report.errors, 2_000);
    assert_eq!(report.errors, 0, "no lookup may be dropped or shed in the ramp");
    let rec = report.to_record();
    assert!(rec.name.contains("/conns32"), "ramp rows get their own scenario: {}", rec.name);
    let conns_metric =
        rec.metrics.iter().find(|(k, _)| k == "conns").map(|(_, v)| *v).expect("conns metric");
    assert_eq!(conns_metric, 32.0);
    stop(server, &addr);
}
