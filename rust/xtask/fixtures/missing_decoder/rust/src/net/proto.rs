// Fixture: OP_PONG has an encoder arm but no decoder arm.

pub const VERSION: u16 = 1;

pub const OP_PING: u8 = 1;
pub const OP_PONG: u8 = 2;

pub enum Request {
    Ping,
    Pong,
}

fn op_for(req: &Request) -> u8 {
    match req {
        Request::Ping => OP_PING,
        Request::Pong => OP_PONG,
    }
}

fn decode(op: u8) -> Option<Request> {
    match op {
        OP_PING => Some(Request::Ping),
        _ => None,
    }
}
