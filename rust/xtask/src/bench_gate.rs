//! `cargo xtask bench-gate` — throughput regression gate over the
//! committed `BENCH_*.json` trajectory.
//!
//! Compares a fresh trajectory file (what CI just measured) against a
//! baseline (the committed snapshot).  For every `(bench, name)` scenario
//! present in both, the *latest run* of each side is paired and the gate
//! fails when the fresh `throughput_lps` falls more than `--threshold`
//! percent (default 15) below the baseline.  Scenarios without a baseline
//! row only warn — a brand-new bench or an empty committed trajectory
//! must not block the build that introduces it.
//!
//! Open-loop load-generator rows (`open_loop: 1`) are skipped: their
//! throughput tracks the *offered* arrival rate, not the capacity of the
//! stack, so a "regression" there only means someone asked for a lower
//! rate.
//!
//! The parser is deliberately line-based: `bench_rows_json` (the only
//! writer of these files) emits exactly one `{"name": …}` object per
//! line with alphabetized keys, and this task is dependency-free, so a
//! flat-object scanner is both sufficient and honest about what it
//! accepts.  Lines that do not parse are ignored, like
//! `read_bench_rows`'s tolerance for foreign fields.

use std::collections::BTreeMap;

/// One trajectory row: scenario tags plus numeric metrics.
#[derive(Debug, Clone)]
pub struct Row {
    pub bench: String,
    pub name: String,
    pub run: u64,
    pub metrics: BTreeMap<String, f64>,
}

/// What the gate decided.
#[derive(Debug, Default)]
pub struct GateOutcome {
    /// Hard failures: scenario, baseline lps, fresh lps, drop %.
    pub failures: Vec<String>,
    /// Advisory notes: missing baselines, skipped rows, empty trajectory.
    pub warnings: Vec<String>,
    /// Scenario pairs actually compared.
    pub compared: usize,
}

impl GateOutcome {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Parse every row object out of a trajectory document.
pub fn parse_rows(text: &str) -> Vec<Row> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        if !line.starts_with('{') || !line.contains("\"name\"") {
            continue;
        }
        if let Some(fields) = parse_flat_object(line) {
            let mut row = Row {
                bench: String::new(),
                name: String::new(),
                run: 1,
                metrics: BTreeMap::new(),
            };
            for (k, v) in fields {
                match (k.as_str(), v) {
                    ("name", Field::Str(s)) => row.name = s,
                    ("bench", Field::Str(s)) => row.bench = s,
                    ("run", Field::Num(n)) if n.is_finite() => row.run = n as u64,
                    (_, Field::Num(n)) => {
                        row.metrics.insert(k, n);
                    }
                    (_, Field::Str(_)) => {}
                }
            }
            if !row.name.is_empty() {
                out.push(row);
            }
        }
    }
    out
}

/// Keep only the latest run of every `(bench, name)` scenario.  Ties on
/// the `run` tag resolve to the *last-appended* row: trajectory files are
/// append-only, so file order is time order, and a re-measured scenario
/// checked in under the same run number must shadow the stale row rather
/// than lose to it (which made re-runs silently gate against old data).
fn latest(rows: Vec<Row>) -> BTreeMap<(String, String), Row> {
    let mut out: BTreeMap<(String, String), Row> = BTreeMap::new();
    for row in rows {
        let key = (row.bench.clone(), row.name.clone());
        match out.get(&key) {
            Some(prev) if prev.run > row.run => {}
            _ => {
                out.insert(key, row);
            }
        }
    }
    out
}

/// Gate `fresh` against `baseline`: fail on a > `threshold_pct` percent
/// drop of `throughput_lps` for any scenario present in both.
pub fn gate(baseline: &str, fresh: &str, threshold_pct: f64) -> GateOutcome {
    let mut out = GateOutcome::default();
    let base = latest(parse_rows(baseline));
    let new = latest(parse_rows(fresh));

    let gateable_base = base.values().filter(|r| gateable(r)).count();
    if gateable_base == 0 {
        out.warnings.push(
            "baseline trajectory holds no throughput rows yet — gate is advisory only".into(),
        );
    }
    for (key, row) in &new {
        if row.metrics.get("open_loop").copied().unwrap_or(0.0) == 1.0 {
            out.warnings
                .push(format!("{}/{}: open-loop row, throughput not gated", key.0, key.1));
            continue;
        }
        let Some(fresh_lps) = finite(row.metrics.get("throughput_lps")) else {
            continue;
        };
        let Some(base_lps) = base.get(key).and_then(|b| finite(b.metrics.get("throughput_lps")))
        else {
            out.warnings.push(format!("{}/{}: no baseline row, not gated", key.0, key.1));
            continue;
        };
        out.compared += 1;
        if base_lps <= 0.0 {
            continue;
        }
        let drop_pct = 100.0 * (base_lps - fresh_lps) / base_lps;
        if drop_pct > threshold_pct {
            out.failures.push(format!(
                "{}/{}: throughput_lps {:.0} → {:.0} ({:.1} % drop > {:.1} % threshold)",
                key.0, key.1, base_lps, fresh_lps, drop_pct, threshold_pct
            ));
        }
    }
    out
}

fn gateable(r: &Row) -> bool {
    r.metrics.get("open_loop").copied().unwrap_or(0.0) != 1.0
        && finite(r.metrics.get("throughput_lps")).is_some()
}

fn finite(v: Option<&f64>) -> Option<f64> {
    v.copied().filter(|x| x.is_finite())
}

/// One metric value: the trajectory schema only holds strings and
/// numbers (`null` reads as NaN, mirroring `read_bench_rows`).
enum Field {
    Str(String),
    Num(f64),
}

/// Parse a single-line flat JSON object: `{"k": "v", "n": 1.5, "x": null}`.
/// Returns `None` on anything malformed — callers skip such lines.
fn parse_flat_object(line: &str) -> Option<Vec<(String, Field)>> {
    let mut chars = line.chars().peekable();
    let mut fields = Vec::new();
    skip_ws(&mut chars);
    if chars.next()? != '{' {
        return None;
    }
    loop {
        skip_ws(&mut chars);
        match chars.peek()? {
            '}' => {
                chars.next();
                break;
            }
            ',' => {
                chars.next();
                continue;
            }
            '"' => {
                let key = parse_string(&mut chars)?;
                skip_ws(&mut chars);
                if chars.next()? != ':' {
                    return None;
                }
                skip_ws(&mut chars);
                let value = match chars.peek()? {
                    '"' => Field::Str(parse_string(&mut chars)?),
                    _ => {
                        let mut raw = String::new();
                        while let Some(&c) = chars.peek() {
                            if c == ',' || c == '}' {
                                break;
                            }
                            raw.push(c);
                            chars.next();
                        }
                        let raw = raw.trim();
                        if raw == "null" {
                            Field::Num(f64::NAN)
                        } else {
                            Field::Num(raw.parse().ok()?)
                        }
                    }
                };
                fields.push((key, value));
            }
            _ => return None,
        }
    }
    Some(fields)
}

fn parse_string(chars: &mut std::iter::Peekable<std::str::Chars<'_>>) -> Option<String> {
    if chars.next()? != '"' {
        return None;
    }
    let mut out = String::new();
    loop {
        match chars.next()? {
            '"' => return Some(out),
            '\\' => match chars.next()? {
                '"' => out.push('"'),
                '\\' => out.push('\\'),
                'n' => out.push('\n'),
                't' => out.push('\t'),
                'u' => {
                    let code: String = (0..4).filter_map(|_| chars.next()).collect();
                    let n = u32::from_str_radix(&code, 16).ok()?;
                    out.push(char::from_u32(n)?);
                }
                other => out.push(other),
            },
            c => out.push(c),
        }
    }
}

fn skip_ws(chars: &mut std::iter::Peekable<std::str::Chars<'_>>) {
    while matches!(chars.peek(), Some(' ' | '\t')) {
        chars.next();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn fixture(name: &str) -> String {
        let path =
            Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures/bench_gate").join(name);
        std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("missing fixture {}: {e}", path.display()))
    }

    #[test]
    fn parses_schema2_rows_and_keeps_the_latest_run() {
        let rows = parse_rows(&fixture("baseline.json"));
        assert_eq!(rows.len(), 3, "{rows:?}");
        let last = latest(rows);
        let key = ("net".to_string(), "net/shards=2/threads=8/bulk256".to_string());
        assert_eq!(last[&key].run, 2, "run 2 shadows run 1");
        assert_eq!(last[&key].metrics["throughput_lps"], 200000.0);
    }

    #[test]
    fn duplicate_run_tags_resolve_to_the_last_appended_row() {
        let rows = parse_rows(&fixture("duplicate_runs.json"));
        assert_eq!(rows.len(), 5, "{rows:?}");
        let last = latest(rows);
        // two rows share run 3 → file order breaks the tie
        let coord = ("coordinator".to_string(), "coordinator/banks=4".to_string());
        assert_eq!(last[&coord].metrics["throughput_lps"], 520000.0);
        // three-way tie on run 1 → still the final row
        let hot = ("decode_hotpath".to_string(), "decode_hotpath/prefilter=on".to_string());
        assert_eq!(last[&hot].metrics["throughput_lps"], 930000.0);
        // determinism: gating a file against itself can never fail
        let text = fixture("duplicate_runs.json");
        let out = gate(&text, &text, 15.0);
        assert!(out.passed(), "{:?}", out.failures);
        assert_eq!(out.compared, 2);
    }

    #[test]
    fn passes_when_fresh_throughput_holds() {
        let out = gate(&fixture("baseline.json"), &fixture("fresh_ok.json"), 15.0);
        assert!(out.passed(), "{:?}", out.failures);
        assert_eq!(out.compared, 1);
    }

    #[test]
    fn fails_a_throughput_drop_beyond_the_threshold() {
        let out = gate(&fixture("baseline.json"), &fixture("fresh_regressed.json"), 15.0);
        assert_eq!(out.failures.len(), 1, "{:?}", out.failures);
        assert!(out.failures[0].contains("throughput_lps"), "{}", out.failures[0]);
        // a looser threshold lets the same drop through
        assert!(gate(&fixture("baseline.json"), &fixture("fresh_regressed.json"), 60.0).passed());
    }

    #[test]
    fn empty_baseline_only_warns() {
        let out = gate(&fixture("empty.json"), &fixture("fresh_ok.json"), 15.0);
        assert!(out.passed());
        assert_eq!(out.compared, 0);
        assert!(
            out.warnings.iter().any(|w| w.contains("advisory")),
            "{:?}",
            out.warnings
        );
    }

    #[test]
    fn open_loop_rows_are_never_gated() {
        let base = r#"{"schema": 2, "rows": [
            {"name": "net/a/open", "bench": "net", "run": 1, "open_loop": 1, "rate": 5000, "throughput_lps": 5000}
        ]}"#;
        let fresh = r#"{"schema": 2, "rows": [
            {"name": "net/a/open", "bench": "net", "run": 1, "open_loop": 1, "rate": 100, "throughput_lps": 100}
        ]}"#;
        let out = gate(base, fresh, 15.0);
        assert!(out.passed(), "{:?}", out.failures);
        assert!(out.warnings.iter().any(|w| w.contains("open-loop")), "{:?}", out.warnings);
    }

    #[test]
    fn malformed_lines_and_null_metrics_are_skipped() {
        let text = "{\"schema\": 2, \"rows\": [\n\
                    {\"name\": \"a\", \"bench\": \"net\", \"run\": 1, \"throughput_lps\": null},\n\
                    {\"name\": \"b\", \"bench\": \"net\", \"run\": oops},\n\
                    {\"name\": \"c\", \"bench\": \"net\", \"run\": 1, \"throughput_lps\": 10}\n\
                    ]}\n";
        let rows = parse_rows(text);
        assert_eq!(rows.len(), 2, "{rows:?}");
        assert!(rows[0].metrics["throughput_lps"].is_nan());
        // NaN baseline never produces a comparison, let alone a failure
        let out = gate(text, text, 15.0);
        assert!(out.passed());
        assert_eq!(out.compared, 1, "only row c is comparable");
    }

    #[test]
    fn escaped_names_round_trip() {
        let rows = parse_rows(r#"{"name": "a \"quoted\" A", "bench": "net", "run": 1, "x": 2}"#);
        assert_eq!(rows[0].name, "a \"quoted\" A");
    }
}
