//! Ablation benches for the crate's headline design choices:
//!
//!  A1  ζ sweep at the reference point — comparisons / energy / wiring
//!      trade-off (§III-B criteria 1 & 2);
//!  A2  q sweep (c at fixed l) — CNN complexity vs ambiguity (§II-B,
//!      the Fig. 3 trade-off priced in energy and area);
//!  A3  bit-selection policy on non-uniform (router/ACL) tags — the §II-B
//!      "select bits to reduce correlation" claim, measured;
//!  A4  NOR vs NAND match-lines inside the *proposed* sub-blocks — the
//!      §III-B argument for exploiting NOR's low latency once only ~2
//!      sub-blocks are active;
//!  A5  hit-ratio sensitivity — misses are cheaper than hits (zero-block
//!      decodes), the inverse of a conventional CAM.
//!
//! Run: `cargo bench --bench ablations`

use cscam::cam::MatchlineKind;
use cscam::cnn::Selection;
use cscam::config::DesignConfig;
use cscam::coordinator::LookupEngine;
use cscam::energy::{proposed_search_energy, CalibrationConstants};
use cscam::stats::OnlineStats;
use cscam::timing::{proposed_delay, DelayConstants};
use cscam::transistor::{overhead_vs_nand, TransistorAssumptions};
use cscam::util::Rng;
use cscam::workload::{AclTrace, QueryMix, TagDistribution};

fn main() {
    let calib = CalibrationConstants::reference_130nm();
    let delays = DelayConstants::reference();

    println!("# A1 — ζ sweep at M=512, N=128, q=9");
    println!(
        "{:>5} {:>6} {:>10} {:>16} {:>11} {:>10}",
        "ζ", "β", "E[cmp]", "E [fJ/bit/srch]", "cycle [ns]", "overhead"
    );
    for zeta in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let cfg = DesignConfig { zeta, ..DesignConfig::reference() };
        let e = proposed_search_energy(&cfg, &calib).per_bit(cfg.m, cfg.n);
        let d = proposed_delay(&cfg, &delays);
        let o = overhead_vs_nand(&cfg, &TransistorAssumptions::default());
        println!(
            "{:>5} {:>6} {:>10.2} {:>16.4} {:>11.3} {:>9.2}%",
            zeta,
            cfg.beta(),
            cfg.expected_comparisons(),
            e,
            d.cycle_ns,
            100.0 * o
        );
    }

    println!("\n# A2 — q sweep (l=8 fixed, c varies)");
    println!(
        "{:>4} {:>4} {:>10} {:>16} {:>11} {:>10}",
        "c", "q", "E[λ]", "E [fJ/bit/srch]", "cycle [ns]", "overhead"
    );
    for c in 1..=6usize {
        let cfg = DesignConfig { c, ..DesignConfig::reference() };
        let e = proposed_search_energy(&cfg, &calib).per_bit(cfg.m, cfg.n);
        let d = proposed_delay(&cfg, &delays);
        let o = overhead_vs_nand(&cfg, &TransistorAssumptions::default());
        println!(
            "{:>4} {:>4} {:>10.3} {:>16.4} {:>11.3} {:>9.2}%",
            c,
            cfg.q(),
            cfg.expected_lambda(),
            e,
            d.cycle_ns,
            100.0 * o
        );
    }

    println!("\n# A3 — bit selection on router/ACL tags (measured, 512 rules)");
    let cfg = DesignConfig::reference();
    let mut rng = Rng::seed_from_u64(33);
    let rules = AclTrace { n: cfg.n, prefixes: 6, prefix_len: 48 }.generate(cfg.m, &mut rng);
    println!("{:<30} {:>10} {:>12} {:>16}", "policy", "λ̄", "blocks̄", "E [fJ/bit/srch]");
    let policies: Vec<(&str, Selection)> = vec![
        (
            "high-bits (prefix, worst)",
            Selection::explicit((cfg.n - cfg.q()..cfg.n).collect(), cfg.k()),
        ),
        ("contiguous (low bits)", Selection::contiguous(cfg.c, cfg.k())),
        ("strided", Selection::strided(cfg.n, cfg.c, cfg.k())),
        ("entropy-greedy", Selection::entropy_greedy(&rules, cfg.n, cfg.c, cfg.k())),
    ];
    for (name, sel) in policies {
        let mut engine = LookupEngine::with_selection(cfg.clone(), sel);
        for r in &rules {
            engine.insert(r).unwrap();
        }
        let (mut lam, mut blk, mut en) =
            (OnlineStats::new(), OnlineStats::new(), OnlineStats::new());
        for r in &rules {
            let out = engine.lookup(r).unwrap();
            lam.push(out.lambda as f64);
            blk.push(out.enabled_blocks as f64);
            en.push(out.energy.per_bit(cfg.m, cfg.n));
        }
        println!("{:<30} {:>10.2} {:>12.2} {:>16.4}", name, lam.mean(), blk.mean(), en.mean());
    }

    println!("\n# A4 — match-line family inside the proposed sub-blocks");
    println!("{:>6} {:>16} {:>11} {:>13}", "ML", "E [fJ/bit/srch]", "cycle [ns]", "latency [ns]");
    for ml in [MatchlineKind::Nor, MatchlineKind::Nand] {
        let cfg = DesignConfig { ml_kind: ml, ..DesignConfig::reference() };
        let e = proposed_search_energy(&cfg, &calib).per_bit(cfg.m, cfg.n);
        let d = proposed_delay(&cfg, &delays);
        println!("{:>6} {:>16.4} {:>11.3} {:>13.3}", ml.name(), e, d.cycle_ns, d.latency_ns);
    }
    println!("(NAND-ML sub-blocks would save energy but blow the cycle time — §III-B's call)");

    println!("\n# A6 — churn: enable bloat vs rewrites/slot, and the retrain payoff");
    println!(
        "{:>14} {:>10} {:>10} {:>16}",
        "rewrites/slot", "λ̄", "blocks̄", "blocks̄ (retrained)"
    );
    {
        let small =
            DesignConfig { m: 256, n: 64, zeta: 8, c: 3, l: 8, ..DesignConfig::reference() };
        for mult in [0usize, 1, 2, 4, 8] {
            let r = cscam::cnn::capacity::simulate_churn(&small, mult * small.m, 17);
            println!(
                "{:>14.1} {:>10.2} {:>10.2} {:>16.2}",
                r.rewrites_per_slot, r.mean_lambda, r.mean_blocks, r.mean_blocks_after_retrain
            );
        }
        println!(
            "(theory: P(dead neuron fires) = d^c with d = 1−(1−1/l)^t; retrain restores blocks̄ → {:.2})",
            small.expected_active_blocks()
        );
    }

    println!("\n# A7 — wave-pipelining feasibility across array sizes (§IV)");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>8}",
        "M", "Dmax [ns]", "Tclk [ns]", "clk2 [ns]", "waves"
    );
    for m in [256usize, 512, 1024, 2048] {
        let c = DesignConfig { m, ..DesignConfig::reference() };
        let w = cscam::timing::wave::analyze(&c, &delays);
        println!(
            "{:>6} {:>12.3} {:>12.3} {:>12.3} {:>8}",
            m, w.d_max_ns, w.t_clk_min_ns, w.clk2_offset_ns, w.waves_in_flight
        );
    }

    println!("\n# A8 — silicon area (µm², 0.13 µm) and where the β budget goes");
    println!(
        "{:>5} {:>12} {:>14} {:>14} {:>10}",
        "ζ", "total [µm²]", "enable wiring", "CNN SRAM", "overhead"
    );
    let ka = cscam::transistor::area::AreaConstants::reference_130nm();
    for zeta in [1usize, 2, 4, 8, 16, 64] {
        let c = DesignConfig { zeta, ..DesignConfig::reference() };
        let a = cscam::transistor::area::proposed_area(&c, &ka);
        let o = cscam::transistor::area::area_overhead_vs_nand(&c, &ka);
        println!(
            "{:>5} {:>12.0} {:>14.0} {:>14.0} {:>9.1}%",
            zeta,
            a.total_um2(),
            a.enable_routing_um2,
            a.cnn_sram_um2,
            100.0 * o
        );
    }

    println!("\n# A5 — hit-ratio sensitivity (measured, 20k searches each)");
    println!("{:>10} {:>16} {:>10}", "hit ratio", "E [fJ/bit/srch]", "blocks̄");
    let mut engine = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(44);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &stored {
        engine.insert(t).unwrap();
    }
    for hit in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let mix = QueryMix { hit_ratio: hit, zipf_s: 0.0 };
        let (mut en, mut blk) = (OnlineStats::new(), OnlineStats::new());
        for _ in 0..20_000 {
            let (tag, _) = mix.sample(&stored, cfg.n, &mut rng);
            let out = engine.lookup(&tag).unwrap();
            en.push(out.energy.per_bit(cfg.m, cfg.n));
            blk.push(out.enabled_blocks as f64);
        }
        println!("{:>10.2} {:>16.4} {:>10.3}", hit, en.mean(), blk.mean());
    }
}
