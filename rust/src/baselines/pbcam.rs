//! Precomputation-based CAM (PB-CAM) — Lin, Chang & Liu [4]; Ruan et al. [5].
//!
//! The closest prior art to the paper's classifier: store, per entry, a
//! precomputed *parameter* (the ones-count of the tag, ⌈log2(N+1)⌉ bits);
//! a search first compares the query's parameter against all M stored
//! parameters in a small parallel CAM, then runs the full N-bit comparison
//! only on the entries whose parameter matched.
//!
//! The paper's two criticisms, both of which this model exhibits:
//!
//! 1. the parameter-extractor (a ones-counter over N bits) grows in delay
//!    and complexity with the tag length N, unlike the CNN whose input is
//!    the *reduced* tag (§I);
//! 2. the ones-count of random tags concentrates around N/2
//!    (Binomial(N, ½)), so the expected number of surviving comparisons is
//!    `1 + (M−1)·C(2N,N)/4^N` ≈ `1 + (M−1)/√(πN)` — for 512×128 that is
//!    ~27 comparisons, vs ~2 for the CNN (§I "unlike the PB-CAMs, the
//!    proposed architecture can potentially narrow down the search procedure
//!    to only two comparisons").

use crate::bits::BitVec;
use crate::energy::{CalibrationConstants, EnergyBreakdown};

/// Functional PB-CAM storing tags plus their ones-count parameters.
#[derive(Debug, Clone)]
pub struct PbCam {
    n: usize,
    tags: Vec<Option<BitVec>>,
    params: Vec<u16>,
}

/// One PB-CAM search outcome.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PbSearchResult {
    /// Matching entry addresses.
    pub matches: Vec<usize>,
    /// Entries whose parameter matched (second-stage full comparisons).
    pub full_comparisons: usize,
}

impl PbCam {
    pub fn new(m: usize, n: usize) -> Self {
        assert!(m > 0 && n > 0);
        PbCam { n, tags: vec![None; m], params: vec![0; m] }
    }

    pub fn m(&self) -> usize {
        self.tags.len()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Parameter bits: ⌈log2(N+1)⌉.
    pub fn param_bits(&self) -> usize {
        (usize::BITS - self.n.leading_zeros()) as usize
    }

    pub fn write(&mut self, addr: usize, tag: BitVec) {
        assert_eq!(tag.len(), self.n);
        self.params[addr] = tag.count_ones() as u16;
        self.tags[addr] = Some(tag);
    }

    pub fn erase(&mut self, addr: usize) {
        self.tags[addr] = None;
    }

    /// Two-phase search: parameter filter, then full comparison.
    pub fn search(&self, tag: &BitVec) -> PbSearchResult {
        assert_eq!(tag.len(), self.n);
        let p = tag.count_ones() as u16;
        let mut matches = Vec::new();
        let mut full = 0usize;
        for (addr, stored) in self.tags.iter().enumerate() {
            let Some(stored) = stored else { continue };
            if self.params[addr] != p {
                continue;
            }
            full += 1;
            if stored == tag {
                matches.push(addr);
            }
        }
        PbSearchResult { matches, full_comparisons: full }
    }

    /// Closed-form expected number of second-stage comparisons for uniform
    /// tags when the query equals a stored tag: 1 + (M−1)·E[P(count match)].
    ///
    /// E over the query's own count: Σ_k C(N,k)²/4^N ≈ 1/√(πN) — the
    /// *collision probability* of two Binomial(N, ½) draws.
    pub fn expected_full_comparisons(m: usize, n: usize) -> f64 {
        // Σ_k [C(n,k)/2^n]² computed in log space for big n.
        let mut sum = 0.0f64;
        let mut log_c = 0.0f64; // ln C(n,0)
        let ln2n = (n as f64) * std::f64::consts::LN_2;
        for k in 0..=n {
            let log_p = log_c - ln2n;
            sum += (2.0 * log_p).exp();
            // C(n,k+1) = C(n,k)·(n−k)/(k+1)
            if k < n {
                log_c += ((n - k) as f64).ln() - ((k + 1) as f64).ln();
            }
        }
        1.0 + (m as f64 - 1.0) * sum
    }

    /// Per-search energy of the PB-CAM under the same calibration as the
    /// other architectures: an M×param_bits parallel NOR mini-CAM (always
    /// fully active) plus `full_comparisons` N-bit NOR row compares, plus
    /// the ones-counter tree (≈N adder cells ≈ 2N gate events).
    pub fn search_energy(
        &self,
        full_comparisons: usize,
        calib: &CalibrationConstants,
    ) -> EnergyBreakdown {
        let pbits = self.param_bits();
        let per_cell = calib.e_sl_cell + calib.e_ml_nor + calib.e_global_wire;
        EnergyBreakdown {
            // stage 1: parameter mini-CAM, all M rows
            searchline_fj: (self.m() * pbits) as f64 * calib.e_sl_cell,
            matchline_fj: (self.m() * pbits) as f64 * calib.e_ml_nor
                + full_comparisons as f64 * self.n as f64 * per_cell,
            global_wire_fj: (self.m() * pbits) as f64 * calib.e_global_wire,
            // ones-counter tree as generic logic
            pii_logic_fj: 2.0 * self.n as f64 * calib.e_pii_logic_neuron * 20.0,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TagDistribution;
    use crate::util::Rng;

    #[test]
    fn functional_search_finds_entry() {
        let mut pb = PbCam::new(16, 32);
        pb.write(3, BitVec::from_u128(0xDEAD, 32));
        pb.write(9, BitVec::from_u128(0xBEEF, 32));
        let r = pb.search(&BitVec::from_u128(0xDEAD, 32));
        assert_eq!(r.matches, vec![3]);
        assert!(r.full_comparisons >= 1);
        pb.erase(3);
        assert!(pb.search(&BitVec::from_u128(0xDEAD, 32)).matches.is_empty());
    }

    #[test]
    fn parameter_filter_skips_different_counts() {
        let mut pb = PbCam::new(4, 8);
        pb.write(0, BitVec::from_u128(0b0000_0001, 8)); // count 1
        pb.write(1, BitVec::from_u128(0b0000_0011, 8)); // count 2
        pb.write(2, BitVec::from_u128(0b0000_0111, 8)); // count 3
        let r = pb.search(&BitVec::from_u128(0b0000_0100, 8)); // count 1
        assert!(r.matches.is_empty());
        assert_eq!(r.full_comparisons, 1, "only the count-1 entry is fully compared");
    }

    #[test]
    fn expected_comparisons_matches_simulation() {
        let (m, n) = (256usize, 64usize);
        let mut rng = Rng::seed_from_u64(11);
        let mut total = 0usize;
        let mut queries = 0usize;
        for _ in 0..8 {
            let tags = TagDistribution::Uniform.sample_distinct(n, m, &mut rng);
            let mut pb = PbCam::new(m, n);
            for (a, t) in tags.iter().enumerate() {
                pb.write(a, t.clone());
            }
            for t in tags.iter().step_by(4) {
                total += pb.search(t).full_comparisons;
                queries += 1;
            }
        }
        let sim = total as f64 / queries as f64;
        let exp = PbCam::expected_full_comparisons(m, n);
        let rel = (sim - exp).abs() / exp;
        assert!(rel < 0.1, "sim {sim} vs closed {exp}");
    }

    #[test]
    fn paper_claim_pbcam_narrows_far_less_than_cnn() {
        // §I: PB-CAM cannot approach the CNN's ~2 comparisons at 512×128.
        let pb = PbCam::expected_full_comparisons(512, 128);
        assert!(pb > 20.0, "PB-CAM expected comparisons = {pb}");
        let cnn = crate::stats::expected_lambda(512, 9);
        assert!(pb > 10.0 * cnn);
    }

    #[test]
    fn pbcam_energy_beats_conventional_but_not_proposed() {
        let cfg = crate::config::DesignConfig::reference();
        let calib = CalibrationConstants::reference_130nm();
        let pb = PbCam::new(cfg.m, cfg.n);
        let full = PbCam::expected_full_comparisons(cfg.m, cfg.n).round() as usize;
        let e_pb = pb.search_energy(full, &calib).per_bit(cfg.m, cfg.n);
        let e_nand = 1.30;
        let e_prop =
            crate::energy::proposed_search_energy(&cfg, &calib).per_bit(cfg.m, cfg.n);
        assert!(e_pb < e_nand, "PB-CAM {e_pb} should beat NAND {e_nand}");
        assert!(e_prop < e_pb, "proposed {e_prop} should beat PB-CAM {e_pb}");
    }

    #[test]
    fn param_bits_is_log2_n_plus_one() {
        assert_eq!(PbCam::new(4, 128).param_bits(), 8);
        assert_eq!(PbCam::new(4, 127).param_bits(), 7);
        assert_eq!(PbCam::new(4, 8).param_bits(), 4);
    }
}
