//! A minimal readiness poller over raw OS interfaces — the reactor's only
//! window onto the kernel, kept deliberately tiny so the event loop in
//! [`crate::net::server`] stays an ordinary single-threaded state machine.
//!
//! No async runtime and no FFI crate: every Rust binary on a Unix target
//! already links the platform C library, so the two syscall families this
//! module needs are declared directly.  Linux gets `epoll` (O(ready)
//! wakeups, the only shape that scales to tens of thousands of
//! connections); every other Unix falls back to `poll(2)` over the
//! registered set (O(registered) per wakeup, correct everywhere POSIX
//! is).  Both backends speak the same [`Poller`] surface:
//!
//! * [`Poller::add`]/[`Poller::modify`]/[`Poller::remove`] register a file
//!   descriptor with a caller-chosen `u64` token and a read/write interest
//!   pair (level-triggered: an event repeats while the condition holds,
//!   so a partial read/write can simply return to the loop);
//! * [`Poller::wait`] parks until something is ready, filling a reusable
//!   event buffer.
//!
//! [`wake_pair`] builds the reactor's cross-thread doorbell from a
//! nonblocking `UnixStream` pair: worker threads that complete a response
//! ring [`WakeHandle::wake`]; the read end lives in the poller like any
//! connection, so a wakeup is just one more readiness event.  The pair
//! saturates harmlessly — once the pipe's buffer is full every further
//! wake is a no-op `WouldBlock`, which is exactly the "a wakeup is
//! already pending" edge the reactor wants.

use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

/// Read/write interest for a registered descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub read: bool,
    pub write: bool,
}

impl Interest {
    pub const READ: Interest = Interest { read: true, write: false };
    pub const WRITE: Interest = Interest { read: false, write: true };
    pub const BOTH: Interest = Interest { read: true, write: true };
}

/// One readiness event out of [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// The token the descriptor was registered with.
    pub token: u64,
    /// Readable — includes hangup/error, so a closing peer always
    /// surfaces through the read path (where `read() == 0` names it).
    pub readable: bool,
    /// Writable — includes error, so a broken pipe surfaces through the
    /// write path.
    pub writable: bool,
    /// The peer hung up or the descriptor is in an error state.
    pub hangup: bool,
}

/// Clamp an optional timeout onto the millisecond `int` the syscalls
/// take: `None` parks forever (-1); sub-millisecond waits round *up* so a
/// short deadline cannot degenerate into a busy loop.
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis();
            if ms == 0 && !d.is_zero() {
                1
            } else {
                ms.min(i32::MAX as u128) as i32
            }
        }
    }
}

#[cfg(target_os = "linux")]
mod sys {
    use super::{timeout_ms, Event, Interest};
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    /// Mirror of the kernel's `struct epoll_event`.  x86-64 is the one
    /// ABI where the kernel declares it packed (no padding between the
    /// 32-bit event mask and the 64-bit data word).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    /// Level-triggered epoll instance.
    pub struct Poller {
        epfd: RawFd,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            // SAFETY: plain syscall, no pointers.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            let mut mask = EPOLLRDHUP;
            if interest.read {
                mask |= EPOLLIN;
            }
            if interest.write {
                mask |= EPOLLOUT;
            }
            let mut ev = EpollEvent { events: mask, data: token };
            let evp = if op == EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev };
            // SAFETY: `evp` is null (DEL, where the kernel ignores it) or a
            // live stack value; the kernel copies it before returning.
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, evp) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn add(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn remove(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, Interest { read: false, write: false })
        }

        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            const MAX_EVENTS: usize = 1024;
            let mut buf = [EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
            // SAFETY: `buf` outlives the call and `maxevents` matches its
            // length; the kernel writes at most that many entries.
            let n = unsafe {
                epoll_wait(self.epfd, buf.as_mut_ptr(), MAX_EVENTS as i32, timeout_ms(timeout))
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in buf.iter().take(n as usize) {
                // copy the (possibly unaligned) packed fields by value
                let bits = ev.events;
                let token = ev.data;
                out.push(Event {
                    token,
                    readable: bits & (EPOLLIN | EPOLLHUP | EPOLLRDHUP | EPOLLERR) != 0,
                    writable: bits & (EPOLLOUT | EPOLLERR) != 0,
                    hangup: bits & (EPOLLHUP | EPOLLRDHUP | EPOLLERR) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            // SAFETY: `epfd` is this instance's descriptor; nothing else
            // closes it.
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    use super::{timeout_ms, Event, Interest};
    use std::io;
    use std::os::raw::c_ulong;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;
    const POLLNVAL: i16 = 0x020;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: RawFd,
        events: i16,
        revents: i16,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: i32) -> i32;
    }

    /// POSIX `poll(2)` fallback: the registry lives in userspace and the
    /// whole set is handed to the kernel per wait — O(registered), fine
    /// for the connection counts a non-Linux dev box sees.
    pub struct Poller {
        registered: std::sync::Mutex<Vec<(RawFd, u64, Interest)>>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { registered: std::sync::Mutex::new(Vec::new()) })
        }

        fn with_registry<R>(
            &self,
            f: impl FnOnce(&mut Vec<(RawFd, u64, Interest)>) -> R,
        ) -> R {
            let mut g = self.registered.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            f(&mut g)
        }

        pub fn add(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.with_registry(|r| r.push((fd, token, interest)));
            Ok(())
        }

        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.with_registry(|r| {
                for e in r.iter_mut() {
                    if e.0 == fd {
                        *e = (fd, token, interest);
                    }
                }
            });
            Ok(())
        }

        pub fn remove(&self, fd: RawFd) -> io::Result<()> {
            self.with_registry(|r| r.retain(|e| e.0 != fd));
            Ok(())
        }

        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            let mut fds: Vec<PollFd> = self.with_registry(|r| {
                r.iter()
                    .map(|&(fd, _tok, i)| {
                        let mut mask = 0i16;
                        if i.read {
                            mask |= POLLIN;
                        }
                        if i.write {
                            mask |= POLLOUT;
                        }
                        PollFd { fd, events: mask, revents: 0 }
                    })
                    .collect()
            });
            let tokens: Vec<u64> = self.with_registry(|r| r.iter().map(|e| e.1).collect());
            // SAFETY: `fds` outlives the call and `nfds` matches its length.
            let n =
                unsafe { poll(fds.as_mut_ptr(), fds.len() as c_ulong, timeout_ms(timeout)) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for (pfd, &token) in fds.iter().zip(tokens.iter()) {
                let r = pfd.revents;
                if r == 0 {
                    continue;
                }
                out.push(Event {
                    token,
                    readable: r & (POLLIN | POLLHUP | POLLERR | POLLNVAL) != 0,
                    writable: r & (POLLOUT | POLLERR | POLLNVAL) != 0,
                    hangup: r & (POLLHUP | POLLERR | POLLNVAL) != 0,
                });
            }
            Ok(())
        }
    }
}

pub use sys::Poller;

// ------------------------------------------------------------- wake pair

use std::io::{Read as _, Write as _};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::Arc;

/// The write end of the reactor's doorbell; clone freely across worker
/// threads.
#[derive(Clone)]
pub struct WakeHandle {
    tx: Arc<UnixStream>,
}

impl WakeHandle {
    /// Ring the doorbell.  Never blocks: a full pipe means a wakeup is
    /// already pending, which is all a level-triggered reactor needs.
    pub fn wake(&self) {
        let _ = (&*self.tx).write(&[1u8]);
    }
}

/// The read end of the doorbell; lives inside the reactor's poller.
pub struct WakeReader {
    rx: UnixStream,
}

impl WakeReader {
    /// The descriptor to register (read interest) in the [`Poller`].
    pub fn fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }

    /// Swallow every pending ring so the level-triggered readiness clears.
    pub fn drain(&self) {
        let mut buf = [0u8; 256];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }
}

/// Build the doorbell: a nonblocking socketpair, write end shareable.
pub fn wake_pair() -> io::Result<(WakeHandle, WakeReader)> {
    let (tx, rx) = UnixStream::pair()?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    Ok((WakeHandle { tx: Arc::new(tx) }, WakeReader { rx }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn wake_pair_rings_and_drains() {
        let (tx, rx) = wake_pair().unwrap();
        let poller = Poller::new().unwrap();
        poller.add(rx.fd(), 42, Interest::READ).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(std::time::Duration::from_millis(10))).unwrap();
        assert!(events.is_empty(), "no wake yet");
        tx.wake();
        tx.wake();
        poller.wait(&mut events, Some(std::time::Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 42 && e.readable));
        rx.drain();
        events.clear();
        poller.wait(&mut events, Some(std::time::Duration::from_millis(10))).unwrap();
        assert!(events.is_empty(), "drained doorbell is quiet again");
    }

    #[test]
    fn poller_sees_accept_readiness_and_interest_changes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let poller = Poller::new().unwrap();
        poller.add(listener.as_raw_fd(), 7, Interest::READ).unwrap();

        let mut events = Vec::new();
        poller.wait(&mut events, Some(std::time::Duration::from_millis(10))).unwrap();
        assert!(events.is_empty(), "idle listener is not readable");

        let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        poller.wait(&mut events, Some(std::time::Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable), "pending accept is readable");
        let (conn, _) = listener.accept().unwrap();
        conn.set_nonblocking(true).unwrap();

        // A fresh connection with write interest reports writable at once.
        poller.add(conn.as_raw_fd(), 8, Interest::BOTH).unwrap();
        events.clear();
        poller.wait(&mut events, Some(std::time::Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 8 && e.writable));

        // Dropping write interest silences the writable stream.
        poller.modify(conn.as_raw_fd(), 8, Interest::READ).unwrap();
        events.clear();
        poller.wait(&mut events, Some(std::time::Duration::from_millis(20))).unwrap();
        assert!(!events.iter().any(|e| e.token == 8 && e.writable));

        // Peer hangup surfaces as readable (read() == 0 names it).
        drop(client);
        events.clear();
        poller.wait(&mut events, Some(std::time::Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 8 && e.readable && e.hangup));
        poller.remove(conn.as_raw_fd()).unwrap();
    }
}
