#![cfg_attr(feature = "simd", feature(portable_simd))]
//! # cscam — Low-power CAM based on clustered-sparse-networks
//!
//! Full-system reproduction of Jarollahi, Gripon, Onizawa & Gross,
//! *"A Low-Power Content-Addressable-Memory Based on Clustered-Sparse-Networks"*
//! (ASAP 2013).
//!
//! The paper couples a clustered sparse network (CNN) classifier to a CAM
//! array split into `β = M/ζ` independently compare-enabled sub-blocks: the
//! CNN decodes a reduced-length tag and enables, on average, only ~2
//! sub-blocks, eliminating most of the parallel match-line comparisons that
//! dominate CAM search energy.
//!
//! ## Layout (three-layer architecture, see rust/README.md)
//!
//! - [`cnn`] — the clustered-sparse-network classifier (bit-packed native
//!   implementation: training, global decode, tag-bit selection).
//! - [`cam`] — functional + circuit-level model of the sub-blocked CAM array
//!   (Fig. 5): XOR/NAND/NOR cells, match-lines, compare-enables.
//! - [`energy`], [`timing`], [`transistor`] — the SPECTRE-substitute circuit
//!   simulator: switched-capacitance energy, logical-effort delay, and
//!   structural transistor counting (calibration documented in
//!   [`energy::calib`]).
//! - [`tech`] — CMOS technology nodes and the scaling method of Huang &
//!   Hwang [6] used for the paper's 90 nm projection.
//! - [`baselines`] — conventional NAND/NOR references, the PB-CAM
//!   precomputation baseline, and the literature anchor rows of Table II.
//! - [`workload`] — tag/trace generators (uniform, correlated, Zipf,
//!   synthetic TLB and router/ACL traces).
//! - [`stats`] — estimators for the ambiguity statistics of Fig. 3.
//! - [`config`], [`sweep`] — design-point configuration and the Table I
//!   design-space exploration.
//! - [`runtime`] — PJRT bridge: loads the AOT-lowered HLO text artifacts
//!   produced by `python/compile/aot.py` and executes them on the request
//!   path (Python is build-time only).  The execution half sits behind the
//!   `pjrt` cargo feature; the default build is pure Rust.
//! - [`coordinator`] — the L3 serving system for one bank: the lookup
//!   engine split into an immutable shared `SearchState` (concurrent
//!   `&self` lookups with per-thread scratch) and a single writer that
//!   RCU-publishes after each acknowledged mutation; a sized reader pool,
//!   dynamic batcher (PJRT path), insert/delete paths, striped metrics.
//! - [`shard`] — the L4 scale-out layer: `S` independent CNN+CAM banks
//!   behind a scatter-gather router (tag-hash / learned-prefix / broadcast
//!   placement), with fleet-level metrics aggregation.
//! - [`net`] — the L5 network layer: a versioned length-prefixed wire
//!   protocol plus a `std::net` TCP server, client and load generator
//!   that put the sharded fleet on the network.
//! - [`store`] — the L6 durability layer: per-bank snapshot + write-ahead
//!   log with crash recovery, compaction and a fleet manifest, so a
//!   restarted fleet comes back bit-identical (`serve --data-dir`).
//! - [`obs`] — the L7 observability layer: Prometheus-text exposition of
//!   the serving metrics (wire op `OP_METRICS` and a plain-HTTP
//!   `GET /metrics` sidecar, `serve --metrics-addr`).
//! - [`repl`] — the L8 replication layer: log-shipping primary→replica
//!   streaming over wire v5 (`SubscribeLog`/`LogBatch`/`SnapshotTransfer`),
//!   read replicas applying through the same WAL/RCU path, and failover
//!   promotion with epoch fencing (`serve --replicate-from`, `promote`).

pub mod baselines;
pub mod bits;
pub mod cam;
pub mod cnn;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod net;
pub mod obs;
pub mod repl;
pub mod runtime;
pub mod shard;
pub mod stats;
pub mod store;
pub mod sweep;
pub mod tech;
pub mod timing;
pub mod transistor;
pub mod util;
pub mod workload;

pub use config::DesignConfig;
pub use coordinator::engine::{LookupEngine, LookupOutcome};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
