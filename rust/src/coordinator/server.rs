//! The serve loop: a single-owner engine thread fed by an mpsc channel,
//! with dynamic batching of the decode stage and per-request response
//! channels.
//!
//! Shape: `ServerHandle` (cheap to clone, one per client thread) → mpsc →
//! engine thread.  Lookups are queued into the [`Batcher`]; inserts /
//! deletes / metrics are *barriers* (they flush the pending batch first, so
//! a lookup never observes a half-applied mutation).  The decode stage runs
//! either natively (bit-packed CNN) or — with the `pjrt` cargo feature —
//! through the PJRT artifact ([`crate::runtime::ArtifactStore`]), the
//! three-layer configuration with Python strictly at build time.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::bits::BitVec;
use crate::config::DesignConfig;
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::engine::{EngineError, LookupEngine, LookupOutcome};
use crate::coordinator::metrics::Metrics;
use crate::runtime::DecodeOutput;
use crate::store::{BankStore, StoreError};
#[cfg(feature = "pjrt")]
use crate::runtime::ArtifactStore;

/// Owner of the PJRT artifact store for the trip onto the engine thread.
///
/// The unsafety is scoped to this newtype on purpose: blessing the whole
/// [`DecodeBackend`] enum would silently extend to any variant added later.
//
// SAFETY: the xla crate's PJRT handles are `!Send` only because
// `PjRtClient` wraps its FFI handle in an `Rc`.  `ArtifactStore` creates
// the client itself and owns every object cloned from it (executables,
// resident buffers), so all `Rc` clones live inside the one store.  The
// server moves the whole store onto its single engine thread at spawn and
// never aliases it afterwards — every clone crosses threads together,
// exactly once, which is the condition `Rc` needs.
#[cfg(feature = "pjrt")]
pub struct SendArtifactStore(pub Box<ArtifactStore>);

#[cfg(feature = "pjrt")]
unsafe impl Send for SendArtifactStore {}

/// Which implementation runs the CNN decode stage.
pub enum DecodeBackend {
    /// Bit-packed native decode (reference hot path).
    Native,
    /// AOT-compiled PJRT artifact (the three-layer stack).
    #[cfg(feature = "pjrt")]
    Pjrt(SendArtifactStore),
}

#[cfg(feature = "pjrt")]
impl DecodeBackend {
    /// Wrap an artifact store for the engine thread.
    pub fn pjrt(store: ArtifactStore) -> Self {
        DecodeBackend::Pjrt(SendArtifactStore(Box::new(store)))
    }
}

impl std::fmt::Debug for DecodeBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeBackend::Native => write!(f, "Native"),
            #[cfg(feature = "pjrt")]
            DecodeBackend::Pjrt(_) => write!(f, "Pjrt"),
        }
    }
}

type LookupResp = mpsc::SyncSender<Result<LookupOutcome, EngineError>>;

type BulkResp = mpsc::SyncSender<Vec<Result<LookupOutcome, EngineError>>>;

enum Request {
    Lookup { tag: BitVec, enqueued: Instant, resp: LookupResp },
    BulkLookup { tags: Vec<BitVec>, enqueued: Instant, resp: BulkResp },
    Insert { tag: BitVec, resp: mpsc::SyncSender<Result<usize, EngineError>> },
    Delete { addr: usize, resp: mpsc::SyncSender<Result<(), EngineError>> },
    Metrics { resp: mpsc::SyncSender<Box<Metrics>> },
    Drain { resp: mpsc::SyncSender<()> },
    /// Durability barrier: fsync the WAL (`snapshot: false`) or snapshot +
    /// truncate it (`snapshot: true`).  `Ok(false)` means the bank serves
    /// without a store attached (nothing to persist).
    Persist { snapshot: bool, resp: mpsc::SyncSender<Result<bool, StoreError>> },
}

/// Why a persistence request ([`ServerHandle::flush_store`] /
/// [`ServerHandle::snapshot_store`]) failed.
#[derive(Debug)]
pub enum PersistError {
    /// The engine thread is gone.
    Shutdown,
    /// The durability layer itself failed.
    Store(StoreError),
}

impl std::fmt::Display for PersistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PersistError::Shutdown => write!(f, "server has shut down"),
            PersistError::Store(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for PersistError {}

/// An enqueued persist barrier that has not been awaited yet — the scatter
/// half of a fleet-wide flush/snapshot: fire one per bank so the banks
/// fsync or snapshot *concurrently*, then wait (a sequential barrier per
/// bank would serialize S full-bank snapshots behind one connection).
pub struct PendingPersist {
    rx: mpsc::Receiver<Result<bool, StoreError>>,
}

impl PendingPersist {
    /// Block until the bank's engine thread finishes the persist barrier.
    pub fn wait(self) -> Result<bool, PersistError> {
        self.rx.recv().map_err(|_| PersistError::Shutdown)?.map_err(PersistError::Store)
    }
}

/// A lookup that has been enqueued but not yet answered — the scatter half
/// of a scatter-gather: fire one per bank, then [`PendingLookup::wait`] for
/// each (see [`crate::shard::ShardedServerHandle`]).
pub struct PendingLookup {
    rx: mpsc::Receiver<Result<LookupOutcome, EngineError>>,
}

impl PendingLookup {
    /// Block until the engine thread answers.
    pub fn wait(self) -> Result<LookupOutcome, EngineError> {
        self.rx.recv().map_err(|_| EngineError::Shutdown)?
    }
}

/// An enqueued bulk lookup (scatter half; see [`PendingLookup`]).
pub struct PendingBulk {
    rx: Option<mpsc::Receiver<Vec<Result<LookupOutcome, EngineError>>>>,
    n: usize,
}

impl PendingBulk {
    /// Block until the engine thread answers; one result per input tag, in
    /// order.  A dead engine yields [`EngineError::Shutdown`] per tag.
    pub fn wait(self) -> Vec<Result<LookupOutcome, EngineError>> {
        match self.rx {
            None => Vec::new(),
            Some(rx) => rx
                .recv()
                .unwrap_or_else(|_| (0..self.n).map(|_| Err(EngineError::Shutdown)).collect()),
        }
    }
}

/// Cloneable client handle to a running [`CamServer`].
///
/// All methods block the calling thread until the engine thread responds
/// (except `*_deferred`, which split enqueue from wait, and
/// [`Self::try_lookup`], which sheds instead of queueing when the server is
/// saturated); issue requests from multiple threads to exercise batching.
/// A send or receive failure means the engine thread is gone, reported as
/// [`EngineError::Shutdown`].
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
    /// Lookup tags enqueued but not yet dequeued by the engine thread
    /// (bulk requests count per tag).
    depth: Arc<AtomicUsize>,
    /// Admission cap for [`Self::try_lookup`].
    cap: usize,
}

impl ServerHandle {
    /// Count a lookup-class request into the admission queue and send it.
    /// `weight` is the number of tags the request carries, so bulk lookups
    /// count per tag, not per message.
    fn enqueue_lookup(&self, req: Request, weight: usize) -> Result<(), EngineError> {
        self.depth.fetch_add(weight, Ordering::Relaxed);
        self.tx.send(req).map_err(|_| {
            self.depth.fetch_sub(weight, Ordering::Relaxed);
            EngineError::Shutdown
        })
    }

    /// True when the admission queue is at capacity ([`Self::try_lookup`]
    /// would shed).
    pub fn is_saturated(&self) -> bool {
        self.depth.load(Ordering::Relaxed) >= self.cap
    }

    /// Lookup (dynamically batched with concurrent callers).
    pub fn lookup(&self, tag: BitVec) -> Result<LookupOutcome, EngineError> {
        self.lookup_deferred(tag)?.wait()
    }

    /// Non-blocking admission: like [`Self::lookup`], but returns
    /// [`EngineError::Full`] without queueing when the server already has
    /// `queue_capacity` tags pending (bulk requests count per tag) — the
    /// per-bank load-shedding hook for the sharded router.
    pub fn try_lookup(&self, tag: BitVec) -> Result<LookupOutcome, EngineError> {
        if self.is_saturated() {
            return Err(EngineError::Full);
        }
        self.lookup(tag)
    }

    /// Enqueue a lookup without waiting for the answer (scatter half).
    pub fn lookup_deferred(&self, tag: BitVec) -> Result<PendingLookup, EngineError> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.enqueue_lookup(Request::Lookup { tag, enqueued: Instant::now(), resp }, 1)?;
        Ok(PendingLookup { rx })
    }

    /// Bulk lookup: ship many tags in one request — one channel round-trip
    /// amortized over the whole slice.  The batch is decoded in
    /// `max_batch`-sized chunks, preserving order.
    pub fn lookup_many(&self, tags: Vec<BitVec>) -> Vec<Result<LookupOutcome, EngineError>> {
        let n = tags.len();
        match self.lookup_many_deferred(tags) {
            Ok(pending) => pending.wait(),
            Err(e) => (0..n).map(|_| Err(e.clone())).collect(),
        }
    }

    /// Enqueue a bulk lookup without waiting (scatter half).
    pub fn lookup_many_deferred(&self, tags: Vec<BitVec>) -> Result<PendingBulk, EngineError> {
        let n = tags.len();
        if n == 0 {
            return Ok(PendingBulk { rx: None, n: 0 });
        }
        let (resp, rx) = mpsc::sync_channel(1);
        self.enqueue_lookup(Request::BulkLookup { tags, enqueued: Instant::now(), resp }, n)?;
        Ok(PendingBulk { rx: Some(rx), n })
    }

    /// Insert a tag; returns once the CNN + CAM are updated.
    pub fn insert(&self, tag: BitVec) -> Result<usize, EngineError> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx.send(Request::Insert { tag, resp }).map_err(|_| EngineError::Shutdown)?;
        rx.recv().map_err(|_| EngineError::Shutdown)?
    }

    /// Delete by address.
    pub fn delete(&self, addr: usize) -> Result<(), EngineError> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx.send(Request::Delete { addr, resp }).map_err(|_| EngineError::Shutdown)?;
        rx.recv().map_err(|_| EngineError::Shutdown)?
    }

    /// Snapshot of the server metrics.
    pub fn metrics(&self) -> Option<Box<Metrics>> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx.send(Request::Metrics { resp }).ok()?;
        rx.recv().ok()
    }

    /// Flush pending work and wait for it to complete.
    pub fn drain(&self) {
        let (resp, rx) = mpsc::sync_channel(1);
        if self.tx.send(Request::Drain { resp }).is_ok() {
            let _ = rx.recv();
        }
    }

    /// Fsync the bank's WAL.  `Ok(true)` once everything acknowledged so
    /// far is on disk; `Ok(false)` when the bank serves without a store.
    /// Runs as a barrier, so it orders after every prior mutation.
    pub fn flush_store(&self) -> Result<bool, PersistError> {
        self.persist(false)
    }

    /// Force a compaction: snapshot the bank and truncate its WAL.
    /// `Ok(false)` when the bank serves without a store.
    pub fn snapshot_store(&self) -> Result<bool, PersistError> {
        self.persist(true)
    }

    /// Enqueue a persist barrier without waiting (scatter half; see
    /// [`PendingPersist`]).  `snapshot: false` fsyncs the WAL,
    /// `snapshot: true` compacts.
    pub fn persist_deferred(&self, snapshot: bool) -> Result<PendingPersist, PersistError> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Persist { snapshot, resp })
            .map_err(|_| PersistError::Shutdown)?;
        Ok(PendingPersist { rx })
    }

    fn persist(&self, snapshot: bool) -> Result<bool, PersistError> {
        self.persist_deferred(snapshot)?.wait()
    }
}

/// Default admission cap for [`ServerHandle::try_lookup`] — deep enough
/// that only a genuinely backed-up engine sheds.
pub const DEFAULT_QUEUE_CAPACITY: usize = 4096;

/// The serve-thread owner.
pub struct CamServer {
    engine: LookupEngine,
    backend: DecodeBackend,
    policy: BatchPolicy,
    metrics: Metrics,
    /// Lookup tags enqueued but not yet dequeued (shared with handles).
    queue_depth: Arc<AtomicUsize>,
    /// Admission cap handed to [`ServerHandle::try_lookup`].
    queue_cap: usize,
    /// Set on any mutation; the PJRT path re-uploads weights before the next
    /// batched decode.  (Only read by the `pjrt` decode path.)
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    weights_dirty: bool,
    /// Optional durability: mutations are logged here inside the same
    /// barrier that applies them, before the acknowledgement is sent.
    store: Option<BankStore>,
}

impl CamServer {
    /// Build a server around a fresh engine.
    pub fn new(cfg: DesignConfig, backend: DecodeBackend, policy: BatchPolicy) -> Self {
        Self::with_engine(LookupEngine::new(cfg), backend, policy)
    }

    /// Build around an existing (pre-populated) engine.
    pub fn with_engine(engine: LookupEngine, backend: DecodeBackend, policy: BatchPolicy) -> Self {
        CamServer {
            engine,
            backend,
            policy,
            metrics: Metrics::new(),
            queue_depth: Arc::new(AtomicUsize::new(0)),
            queue_cap: DEFAULT_QUEUE_CAPACITY,
            weights_dirty: true,
            store: None,
        }
    }

    /// Attach a durability store: every acknowledged insert/delete is
    /// logged to its WAL first, compaction runs automatically past the
    /// store's threshold, and the WAL is flushed when the serve loop
    /// exits.  The store must have been recovered against the same engine
    /// this server wraps (see [`crate::store::BankStore::open`]).
    pub fn with_store(mut self, store: BankStore) -> Self {
        self.store = Some(store);
        self
    }

    /// Cap the admission queue: [`ServerHandle::try_lookup`] sheds with
    /// [`EngineError::Full`] once this many lookups are pending.
    pub fn with_queue_capacity(mut self, cap: usize) -> Self {
        self.queue_cap = cap;
        self
    }

    /// Spawn the serve loop on a dedicated thread.  The thread exits when
    /// every [`ServerHandle`] clone has been dropped.
    pub fn spawn(self) -> ServerHandle {
        let (tx, rx) = mpsc::channel();
        let depth = Arc::clone(&self.queue_depth);
        let cap = self.queue_cap;
        std::thread::Builder::new()
            .name("cscam-server".into())
            .spawn(move || self.run(rx))
            .expect("spawn server thread");
        ServerHandle { tx, depth, cap }
    }

    /// Account a request leaving the channel queue (admission bookkeeping —
    /// mirrors the per-tag weights of `ServerHandle::enqueue_lookup`).
    fn note_dequeue(&self, req: &Request) {
        match req {
            Request::Lookup { .. } => {
                self.queue_depth.fetch_sub(1, Ordering::Relaxed);
            }
            Request::BulkLookup { tags, .. } => {
                self.queue_depth.fetch_sub(tags.len(), Ordering::Relaxed);
            }
            _ => {}
        }
    }

    fn run(mut self, rx: mpsc::Receiver<Request>) {
        self.serve_loop(&rx);
        // All handles are gone: whatever was acknowledged is already
        // written through to the OS, but honor the fsync contract one last
        // time so a clean exit leaves nothing pending a power cycle.
        if let Some(store) = self.store.as_mut() {
            if let Err(e) = store.flush() {
                eprintln!("cscam-server: WAL flush on exit failed: {e}");
            }
        }
    }

    fn serve_loop(&mut self, rx: &mpsc::Receiver<Request>) {
        let mut batcher: Batcher<(BitVec, Instant, LookupResp)> = Batcher::new(self.policy);
        loop {
            let req = match batcher.deadline() {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        let batch = batcher.flush();
                        self.run_batch(batch);
                        continue;
                    }
                    match rx.recv_timeout(d - now) {
                        Ok(r) => Some(r),
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            let batch = batcher.flush();
                            self.run_batch(batch);
                            continue;
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => None,
                    }
                }
                None => rx.recv().ok(),
            };
            if let Some(r) = &req {
                self.note_dequeue(r);
            }
            match req {
                Some(Request::Lookup { tag, enqueued, resp }) => {
                    if let Some(batch) = batcher.push((tag, enqueued, resp), Instant::now()) {
                        self.run_batch(batch);
                    }
                    // Greedy drain: batch everything already queued, then
                    // serve immediately instead of sleeping out max_wait —
                    // the classic "batch what's there" adaptive policy.  The
                    // deadline path above remains as the bound for requests
                    // that arrive while a batch is running.
                    loop {
                        match rx.try_recv() {
                            Ok(drained) => {
                                self.note_dequeue(&drained);
                                match drained {
                                    Request::Lookup { tag, enqueued, resp } => {
                                        if let Some(batch) =
                                            batcher.push((tag, enqueued, resp), Instant::now())
                                        {
                                            self.run_batch(batch);
                                        }
                                    }
                                    other => {
                                        let batch = batcher.flush();
                                        self.run_batch(batch);
                                        self.handle_barrier(other);
                                        break;
                                    }
                                }
                            }
                            Err(mpsc::TryRecvError::Empty) => {
                                let batch = batcher.flush();
                                self.run_batch(batch);
                                break;
                            }
                            Err(mpsc::TryRecvError::Disconnected) => {
                                let batch = batcher.flush();
                                self.run_batch(batch);
                                return;
                            }
                        }
                    }
                }
                Some(other) => {
                    // barrier: mutations and snapshots see a flushed queue
                    let batch = batcher.flush();
                    self.run_batch(batch);
                    self.handle_barrier(other);
                }
                None => {
                    // all handles dropped: drain and exit
                    let batch = batcher.flush();
                    self.run_batch(batch);
                    return;
                }
            }
        }
    }

    /// Handle a non-lookup request (the pending batch is already flushed).
    /// Mutations follow the one persist policy of
    /// [`crate::store::log_applied_insert`] /
    /// [`crate::store::log_applied_delete`] — shared with [`DurableBank`]
    /// so the threaded and synchronous paths cannot drift.
    ///
    /// [`DurableBank`]: crate::store::DurableBank
    fn handle_barrier(&mut self, req: Request) {
        match req {
            Request::Insert { tag, resp } => {
                let r = match self.engine.insert(&tag) {
                    Ok(addr) => {
                        // the engine mutated whether or not the log keeps
                        // up (a failed append rolls it back, which is a
                        // further mutation)
                        self.weights_dirty = true;
                        match self.store.as_mut() {
                            None => Ok(addr),
                            Some(store) => {
                                crate::store::log_applied_insert(
                                    store,
                                    &mut self.engine,
                                    addr,
                                    &tag,
                                )
                                .map(|()| addr)
                            }
                        }
                        .map(|addr| {
                            self.metrics.inserts += 1;
                            addr
                        })
                    }
                    Err(e) => Err(e),
                };
                let _ = resp.send(r);
            }
            Request::Delete { addr, resp } => {
                let r = match self.engine.delete(addr) {
                    Ok(()) => {
                        self.weights_dirty = true;
                        match self.store.as_mut() {
                            None => Ok(()),
                            Some(store) => {
                                crate::store::log_applied_delete(store, &self.engine, addr)
                            }
                        }
                        .map(|()| self.metrics.deletes += 1)
                    }
                    Err(e) => Err(e),
                };
                let _ = resp.send(r);
            }
            Request::BulkLookup { tags, enqueued, resp } => {
                let results = self.run_bulk(tags, enqueued);
                let _ = resp.send(results);
            }
            Request::Metrics { resp } => {
                let _ = resp.send(Box::new(self.metrics.clone()));
            }
            Request::Drain { resp } => {
                let _ = resp.send(());
            }
            Request::Persist { snapshot, resp } => {
                let r = match self.store.as_mut() {
                    None => Ok(false),
                    Some(store) => {
                        let res =
                            if snapshot { store.compact(&self.engine) } else { store.flush() };
                        res.map(|()| true)
                    }
                };
                if let Err(e) = &r {
                    eprintln!("cscam-server: persist barrier failed: {e}");
                }
                let _ = resp.send(r);
            }
            Request::Lookup { .. } => unreachable!("lookups are batched, not barriers"),
        }
    }

    /// Run the batched decode stage through the PJRT artifact; `None` falls
    /// back to the native per-query decode inside the engine.
    #[cfg(feature = "pjrt")]
    fn decode_stage<'a>(&mut self, tags: impl Iterator<Item = &'a BitVec>) -> Option<DecodeOutput> {
        match &mut self.backend {
            DecodeBackend::Native => None,
            DecodeBackend::Pjrt(store) => {
                if self.weights_dirty && store.0.set_weights(self.engine.weight_rows()).is_ok() {
                    self.weights_dirty = false;
                }
                if self.weights_dirty {
                    None // weight upload failed: fall back to native decode
                } else {
                    let idx: Vec<Vec<u16>> =
                        tags.map(|t| self.engine.cluster_indices(t)).collect();
                    store.0.decode(&idx).ok()
                }
            }
        }
    }

    #[cfg(not(feature = "pjrt"))]
    fn decode_stage<'a>(
        &mut self,
        _tags: impl Iterator<Item = &'a BitVec>,
    ) -> Option<DecodeOutput> {
        None
    }

    /// Serve a pre-assembled batch of tags in order, chunked to the batch
    /// policy (and thus to the compiled PJRT batch sizes).
    fn run_bulk(
        &mut self,
        tags: Vec<BitVec>,
        enqueued: Instant,
    ) -> Vec<Result<LookupOutcome, EngineError>> {
        let mut out = Vec::with_capacity(tags.len());
        for chunk in tags.chunks(self.policy.max_batch.max(1)) {
            self.metrics.record_batch(chunk.len());
            let decoded = self.decode_stage(chunk.iter());
            for (i, tag) in chunk.iter().enumerate() {
                let r = match &decoded {
                    Some(d) => {
                        self.engine.lookup_with_enables(tag, &d.enables[i], d.lambda[i] as usize)
                    }
                    None => self.engine.lookup(tag),
                };
                if let Ok(o) = &r {
                    self.metrics.record_lookup(o);
                }
                out.push(r);
            }
        }
        self.metrics.record_latency(enqueued.elapsed().as_nanos() as u64);
        out
    }

    fn run_batch(&mut self, batch: Vec<(BitVec, Instant, LookupResp)>) {
        if batch.is_empty() {
            return;
        }
        self.metrics.record_batch(batch.len());

        // PJRT path: one artifact call covers the whole batch's decode stage.
        let decoded = self.decode_stage(batch.iter().map(|(t, _, _)| t));

        for (i, (tag, enqueued, resp)) in batch.into_iter().enumerate() {
            let out = match &decoded {
                Some(d) => {
                    self.engine.lookup_with_enables(&tag, &d.enables[i], d.lambda[i] as usize)
                }
                None => self.engine.lookup(&tag),
            };
            if let Ok(o) = &out {
                self.metrics.record_lookup(o);
            }
            self.metrics.record_latency(enqueued.elapsed().as_nanos() as u64);
            let _ = resp.send(out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workload::TagDistribution;
    use std::time::Duration;

    fn policy() -> BatchPolicy {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) }
    }

    #[test]
    fn serve_native_roundtrip() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(1);
        let tags = TagDistribution::Uniform.sample_distinct(32, 20, &mut rng);
        for (i, t) in tags.iter().enumerate() {
            assert_eq!(h.insert(t.clone()).unwrap(), i);
        }
        for (i, t) in tags.iter().enumerate() {
            let out = h.lookup(t.clone()).unwrap();
            assert_eq!(out.addr, Some(i));
        }
        let m = h.metrics().unwrap();
        assert_eq!(m.lookups, 20);
        assert_eq!(m.hits, 20);
        assert_eq!(m.inserts, 20);
    }

    #[test]
    fn concurrent_lookups_batch_together() {
        let server = CamServer::new(
            DesignConfig::small_test(),
            DecodeBackend::Native,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) },
        );
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(2);
        let tags = TagDistribution::Uniform.sample_distinct(32, 32, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        let mut joins = Vec::new();
        for t in tags {
            let h = h.clone();
            joins.push(std::thread::spawn(move || h.lookup(t).unwrap().addr.is_some()));
        }
        let hits = joins.into_iter().map(|j| j.join().unwrap()).filter(|&b| b).count();
        assert_eq!(hits, 32);
        let m = h.metrics().unwrap();
        assert_eq!(m.lookups, 32);
        assert!(m.batches < 32, "some batching must occur: {} batches", m.batches);
        assert!(m.batch_size.mean() > 1.0);
    }

    #[test]
    fn delete_barrier_orders_before_following_lookups() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(3);
        let tags = TagDistribution::Uniform.sample_distinct(32, 4, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        h.delete(2).unwrap();
        let out = h.lookup(tags[2].clone()).unwrap();
        assert_eq!(out.addr, None);
    }

    #[test]
    fn drain_is_a_noop_on_idle_server() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        h.drain();
        assert_eq!(h.metrics().unwrap().lookups, 0);
    }

    #[test]
    fn lookup_many_matches_singles_and_preserves_order() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(8);
        let tags = TagDistribution::Uniform.sample_distinct(32, 30, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        let singles: Vec<_> = tags.iter().map(|t| h.lookup(t.clone()).unwrap().addr).collect();
        let bulk = h.lookup_many(tags.clone());
        assert_eq!(bulk.len(), 30);
        for (i, r) in bulk.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap().addr, singles[i], "order must be preserved");
        }
        assert!(h.lookup_many(Vec::new()).is_empty());
    }

    #[test]
    fn persist_without_a_store_is_a_no_op_ack() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        assert!(!h.flush_store().unwrap(), "no store: flush acks false");
        assert!(!h.snapshot_store().unwrap(), "no store: snapshot acks false");
    }

    #[test]
    fn persist_with_a_store_logs_before_the_ack() {
        let dir = std::env::temp_dir()
            .join(format!("cscam-coord-{}", std::process::id()))
            .join("persist");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = DesignConfig::small_test();
        let opts = crate::store::StoreOptions::default();
        let (bank, _) = crate::store::DurableBank::open(&dir, cfg.clone(), opts).unwrap();
        let (engine, store) = bank.into_parts();
        let h = CamServer::with_engine(engine, DecodeBackend::Native, policy())
            .with_store(store)
            .spawn();
        let mut rng = Rng::seed_from_u64(31);
        let tags = TagDistribution::Uniform.sample_distinct(32, 6, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        h.delete(1).unwrap();
        assert!(h.flush_store().unwrap());
        // acked mutations are already on disk: a reopen replays all of them
        let (bank, report) =
            crate::store::DurableBank::open(&dir, cfg, crate::store::StoreOptions::default())
                .unwrap();
        assert_eq!(report.wal_records, 7);
        assert_eq!(bank.occupancy(), 5);
        // a forced snapshot truncates the log
        assert!(h.snapshot_store().unwrap());
        drop(bank);
    }

    #[test]
    fn dropped_server_reports_persist_shutdown() {
        let (tx, rx) = mpsc::channel();
        drop(rx);
        let h = ServerHandle {
            tx,
            depth: Arc::new(AtomicUsize::new(0)),
            cap: DEFAULT_QUEUE_CAPACITY,
        };
        assert!(matches!(h.flush_store(), Err(PersistError::Shutdown)));
        assert!(matches!(h.snapshot_store(), Err(PersistError::Shutdown)));
    }

    #[test]
    fn server_exits_when_handles_drop() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let h2 = h.clone();
        drop(h);
        drop(h2);
        // nothing to assert directly; the thread exiting keeps the process
        // from hanging at test end (would deadlock `cargo test` otherwise)
    }

    #[test]
    fn dropped_server_yields_shutdown_not_full() {
        // A handle whose engine thread is gone must report Shutdown — Full
        // means "no free CAM slot" and would mislead capacity-aware callers.
        let (tx, rx) = mpsc::channel();
        drop(rx);
        let h = ServerHandle {
            tx,
            depth: Arc::new(AtomicUsize::new(0)),
            cap: DEFAULT_QUEUE_CAPACITY,
        };
        assert_eq!(h.lookup(BitVec::zeros(32)).unwrap_err(), EngineError::Shutdown);
        assert_eq!(h.try_lookup(BitVec::zeros(32)).unwrap_err(), EngineError::Shutdown);
        assert_eq!(h.depth.load(Ordering::Relaxed), 0, "failed sends must not leak depth");
        assert_eq!(h.insert(BitVec::zeros(32)).unwrap_err(), EngineError::Shutdown);
        assert_eq!(h.delete(0).unwrap_err(), EngineError::Shutdown);
        let bulk = h.lookup_many(vec![BitVec::zeros(32); 3]);
        assert_eq!(bulk.len(), 3);
        for r in bulk {
            assert_eq!(r.unwrap_err(), EngineError::Shutdown);
        }
        assert!(h.metrics().is_none());
        h.drain(); // must not hang or panic
    }

    #[test]
    fn try_lookup_sheds_at_capacity_while_lookup_blocks_through() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy())
            .with_queue_capacity(0);
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(21);
        let tags = TagDistribution::Uniform.sample_distinct(32, 4, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        // cap 0: the non-blocking path sheds every request with Full...
        assert_eq!(h.try_lookup(tags[0].clone()).unwrap_err(), EngineError::Full);
        // ...while the blocking path still serves (shedding is opt-in).
        assert_eq!(h.lookup(tags[0].clone()).unwrap().addr, Some(0));
        let m = h.metrics().unwrap();
        assert_eq!(m.lookups, 1, "shed requests never reach the engine");
    }

    #[test]
    fn try_lookup_admits_below_capacity() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(22);
        let tags = TagDistribution::Uniform.sample_distinct(32, 4, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        assert!(!h.is_saturated());
        for (i, t) in tags.iter().enumerate() {
            assert_eq!(h.try_lookup(t.clone()).unwrap().addr, Some(i));
        }
        // the queue drains as the engine answers: depth returns to zero
        h.drain();
        assert_eq!(h.depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn deferred_lookups_scatter_then_gather() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(23);
        let tags = TagDistribution::Uniform.sample_distinct(32, 8, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        let pending: Vec<_> =
            tags.iter().map(|t| h.lookup_deferred(t.clone()).unwrap()).collect();
        for (i, p) in pending.into_iter().enumerate() {
            assert_eq!(p.wait().unwrap().addr, Some(i));
        }
        let bulk = h.lookup_many_deferred(tags.clone()).unwrap().wait();
        for (i, r) in bulk.into_iter().enumerate() {
            assert_eq!(r.unwrap().addr, Some(i));
        }
        assert!(h.lookup_many_deferred(Vec::new()).unwrap().wait().is_empty());
    }

    #[test]
    fn bulk_admission_counts_per_tag() {
        // A bulk message of N tags must weigh N against the admission cap,
        // not 1 — otherwise chunked clients never shed.
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(24);
        let tags = TagDistribution::Uniform.sample_distinct(32, 6, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        let pending = h.lookup_many_deferred(tags.clone()).unwrap();
        // enqueue counted 6; it may already be partially dequeued, never more
        assert!(h.depth.load(Ordering::Relaxed) <= 6);
        let results = pending.wait();
        assert_eq!(results.len(), 6);
        h.drain();
        assert_eq!(h.depth.load(Ordering::Relaxed), 0, "per-tag weights must balance");
    }
}
