//! The multi-bank CAM: `S` full CNN+CAM instances behind one router.
//!
//! [`ShardedCam`] is the synchronous core — a [`LookupEngine`] per bank
//! plus the placement/merge logic, directly testable against a single
//! [`crate::cam::CamArray`] of the same total M.  The threaded serving
//! layer ([`crate::shard::server`]) stacks one engine thread per bank on
//! top of the same merge rules.
//!
//! Addressing is flat: entry `a` of bank `b` is global address
//! `b · M_bank + a`, so a fleet of `S × M_bank` banks is address-compatible
//! with one `M = S · M_bank` array.

use crate::bits::BitVec;
use crate::cam::SearchResult;
use crate::config::DesignConfig;
use crate::coordinator::engine::{EngineError, LookupEngine, LookupOutcome};
use crate::energy::{EnergyBreakdown, SearchActivity};
use crate::shard::placement::{PlacementMode, ShardRouter};
use crate::timing::DelayReport;

/// Merged outcome of one sharded lookup.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedOutcome {
    /// Matching flat global address (lowest on multi-match), if any.
    pub addr: Option<usize>,
    /// All matching flat global addresses, ascending.
    pub all_matches: Vec<usize>,
    /// Banks that actually searched (1 in owner modes, S in broadcast).
    pub banks_searched: usize,
    /// Σ λ across the searched banks.
    pub lambda: usize,
    /// Σ compare-enabled sub-blocks across the searched banks.
    pub enabled_blocks: usize,
    /// Σ full-row comparisons across the searched banks.
    pub comparisons: usize,
    /// Σ per-search energy across the searched banks (every searched bank
    /// burns its own decode + compare energy).
    pub energy: EnergyBreakdown,
    /// Worst-bank delay: parallel banks finish when the slowest does.
    pub delay: DelayReport,
}

/// Lift a single bank's outcome into fleet addressing.
pub(crate) fn globalize_outcome(out: LookupOutcome, bank: usize, bank_m: usize) -> ShardedOutcome {
    let off = bank * bank_m;
    ShardedOutcome {
        addr: out.addr.map(|a| a + off),
        all_matches: out.all_matches.iter().map(|a| a + off).collect(),
        banks_searched: 1,
        lambda: out.lambda,
        enabled_blocks: out.enabled_blocks,
        comparisons: out.comparisons,
        energy: out.energy,
        delay: out.delay,
    }
}

/// One step of the broadcast gather fold (shared by the synchronous core
/// and the threaded fleet so their merge rules cannot drift).
pub(crate) fn merge_fold(acc: Option<ShardedOutcome>, g: ShardedOutcome) -> ShardedOutcome {
    match acc {
        None => g,
        Some(a) => merge_outcomes(a, g),
    }
}

/// Ownerless-insert scan shared by the synchronous core and the threaded
/// fleet: try each bank round-robin from `start`, spilling past full banks
/// so [`EngineError::Full`] only propagates when the whole fleet is full.
/// Returns `(bank, local address)`.
pub(crate) fn spill_insert(
    shards: usize,
    start: usize,
    mut insert_into: impl FnMut(usize) -> Result<usize, EngineError>,
) -> Result<(usize, usize), EngineError> {
    for off in 0..shards {
        let b = (start + off) % shards;
        match insert_into(b) {
            Ok(a) => return Ok((b, a)),
            Err(EngineError::Full) => continue,
            Err(e) => return Err(e),
        }
    }
    Err(EngineError::Full)
}

/// Bounds-checked flat-address split shared by both delete paths.
pub(crate) fn split_global(
    global: usize,
    bank_m: usize,
    shards: usize,
) -> Result<(usize, usize), EngineError> {
    if global >= bank_m * shards {
        return Err(EngineError::BadAddress(global));
    }
    Ok((global / bank_m, global % bank_m))
}

/// Gather half of the broadcast path: fold a second bank's (already
/// globalized) outcome into an accumulator — activity sums, timing takes
/// the slowest bank.
pub(crate) fn merge_outcomes(mut acc: ShardedOutcome, other: ShardedOutcome) -> ShardedOutcome {
    acc.all_matches.extend(other.all_matches);
    acc.all_matches.sort_unstable();
    acc.addr = acc.all_matches.first().copied();
    acc.banks_searched += other.banks_searched;
    acc.lambda += other.lambda;
    acc.enabled_blocks += other.enabled_blocks;
    acc.comparisons += other.comparisons;
    acc.energy.add(&other.energy);
    acc.delay = DelayReport {
        cycle_ns: acc.delay.cycle_ns.max(other.delay.cycle_ns),
        latency_ns: acc.delay.latency_ns.max(other.delay.latency_ns),
    };
    acc
}

/// `S` independent banks (each a full [`LookupEngine`]: its own clustered
/// network, CAM array and energy model) behind a [`ShardRouter`].
#[derive(Debug)]
pub struct ShardedCam {
    banks: Vec<LookupEngine>,
    router: ShardRouter,
    bank_m: usize,
    /// Round-robin cursor for ownerless (broadcast) inserts.
    rr: usize,
}

impl ShardedCam {
    /// Build a fleet for a design point: `cfg.shards` banks of
    /// `cfg.m / cfg.shards` entries each.
    pub fn new(cfg: &DesignConfig, mode: PlacementMode) -> Self {
        // lint:allow(constructor precondition: a geometry that fails
        // validation cannot be served at all, so refuse loudly at build time)
        cfg.validate().expect("invalid design config");
        let router = ShardRouter::new(cfg.shards, mode);
        let bank_cfg = cfg.per_bank();
        let banks = (0..cfg.shards).map(|_| LookupEngine::new(bank_cfg.clone())).collect();
        ShardedCam { banks, router, bank_m: bank_cfg.m, rr: 0 }
    }

    /// Build around existing (pre-populated) banks of identical geometry.
    pub fn with_banks(banks: Vec<LookupEngine>, router: ShardRouter) -> Self {
        assert!(!banks.is_empty(), "need at least one bank");
        assert_eq!(banks.len(), router.shards(), "router/bank count mismatch");
        let bank_m = banks[0].config().m;
        let bank_n = banks[0].config().n;
        assert!(
            banks.iter().all(|b| b.config().m == bank_m && b.config().n == bank_n),
            "banks must share one geometry"
        );
        ShardedCam { banks, router, bank_m, rr: 0 }
    }

    pub fn shard_count(&self) -> usize {
        self.banks.len()
    }

    /// Entries per bank (M_bank).
    pub fn bank_m(&self) -> usize {
        self.bank_m
    }

    pub fn router(&self) -> &ShardRouter {
        &self.router
    }

    pub fn total_capacity(&self) -> usize {
        self.bank_m * self.banks.len()
    }

    pub fn occupancy(&self) -> usize {
        self.banks.iter().map(|b| b.occupancy()).sum()
    }

    pub fn banks(&self) -> &[LookupEngine] {
        &self.banks
    }

    pub fn bank_mut(&mut self, i: usize) -> &mut LookupEngine {
        &mut self.banks[i]
    }

    /// Flat global address of entry `local` in bank `bank`.
    pub fn global_addr(&self, bank: usize, local: usize) -> usize {
        bank * self.bank_m + local
    }

    /// `(bank, local)` of a flat global address.
    pub fn split_addr(&self, global: usize) -> (usize, usize) {
        (global / self.bank_m, global % self.bank_m)
    }

    /// Insert into the owning bank (or round-robin with fallback scan in
    /// broadcast mode, so [`EngineError::Full`] means the whole fleet is
    /// full); returns the flat global address.
    pub fn insert(&mut self, tag: &BitVec) -> Result<usize, EngineError> {
        match self.router.place(tag) {
            Some(b) => {
                let a = self.banks[b].insert(tag)?;
                Ok(self.global_addr(b, a))
            }
            None => {
                let s = self.banks.len();
                let start = self.rr;
                self.rr = (self.rr + 1) % s;
                let banks = &mut self.banks;
                let (b, a) = spill_insert(s, start, |b| banks[b].insert(tag))?;
                Ok(self.global_addr(b, a))
            }
        }
    }

    /// Delete by flat global address.
    pub fn delete(&mut self, global: usize) -> Result<(), EngineError> {
        let (b, local) = split_global(global, self.bank_m, self.banks.len())?;
        self.banks[b].delete(local)
    }

    /// Delete by tag (routed lookup + erase); `Ok(false)` if absent.
    pub fn delete_tag(&mut self, tag: &BitVec) -> Result<bool, EngineError> {
        match self.lookup(tag)?.addr {
            Some(g) => {
                self.delete(g)?;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// The sharded lookup: dispatch to the owning bank in hash/prefix
    /// modes, or scatter to every bank and gather-merge in broadcast mode.
    pub fn lookup(&mut self, tag: &BitVec) -> Result<ShardedOutcome, EngineError> {
        match self.router.place(tag) {
            Some(b) => {
                let out = self.banks[b].lookup(tag)?;
                Ok(globalize_outcome(out, b, self.bank_m))
            }
            None => {
                let bank_m = self.bank_m;
                let mut merged: Option<ShardedOutcome> = None;
                for (b, bank) in self.banks.iter_mut().enumerate() {
                    let out = bank.lookup(tag)?;
                    merged = Some(merge_fold(merged, globalize_outcome(out, b, bank_m)));
                }
                // lint:allow(infallible: constructors enforce >= 1 bank, so
                // the merge fold above ran at least once)
                Ok(merged.expect("at least one bank"))
            }
        }
    }

    /// Raw scatter-gather search with every sub-block of every bank enabled
    /// and no CNN stage: matches are globalized and the per-bank
    /// [`SearchActivity`] counters are summed.  Bit-for-bit identical to
    /// [`crate::cam::CamArray::search_all`] on one array of the same total
    /// M holding the same entries at the same flat addresses — the
    /// equivalence anchor of the property tests.
    pub fn search_unclassified(&self, tag: &BitVec) -> SearchResult {
        let mut matches = Vec::new();
        let mut activity = SearchActivity::default();
        let mut total_blocks = 0usize;
        for (b, bank) in self.banks.iter().enumerate() {
            let r = bank.search_unclassified(tag);
            total_blocks += r.activity.total_blocks;
            activity.accumulate(&r.activity);
            matches.extend(r.matches.into_iter().map(|a| self.global_addr(b, a)));
        }
        // accumulate() keeps the last bank's geometry; the fleet view is
        // the sum of the banks' sub-blocks.
        activity.total_blocks = total_blocks;
        matches.sort_unstable();
        SearchResult { matches, activity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workload::TagDistribution;

    fn fleet_cfg(shards: usize) -> DesignConfig {
        DesignConfig { m: 256, n: 32, zeta: 4, c: 3, l: 4, shards, ..DesignConfig::reference() }
    }

    #[test]
    fn capacity_and_addressing() {
        let cam = ShardedCam::new(&fleet_cfg(4), PlacementMode::TagHash);
        assert_eq!(cam.shard_count(), 4);
        assert_eq!(cam.bank_m(), 64);
        assert_eq!(cam.total_capacity(), 256);
        assert_eq!(cam.global_addr(2, 5), 133);
        assert_eq!(cam.split_addr(133), (2, 5));
    }

    #[test]
    fn hash_mode_roundtrip_with_global_addresses() {
        let mut cam = ShardedCam::new(&fleet_cfg(4), PlacementMode::TagHash);
        let mut rng = Rng::seed_from_u64(5);
        let tags = TagDistribution::Uniform.sample_distinct(32, 150, &mut rng);
        let mut addrs = Vec::new();
        for t in &tags {
            addrs.push(cam.insert(t).unwrap());
        }
        assert_eq!(cam.occupancy(), 150);
        for (t, &g) in tags.iter().zip(&addrs) {
            let out = cam.lookup(t).unwrap();
            assert_eq!(out.addr, Some(g));
            assert_eq!(out.banks_searched, 1, "owner dispatch touches one bank");
            let (b, _) = cam.split_addr(g);
            assert_eq!(cam.router().place(t), Some(b));
        }
    }

    #[test]
    fn broadcast_mode_roundtrip_searches_every_bank() {
        let mut cam = ShardedCam::new(&fleet_cfg(4), PlacementMode::Broadcast);
        let mut rng = Rng::seed_from_u64(6);
        let tags = TagDistribution::Uniform.sample_distinct(32, 100, &mut rng);
        for t in &tags {
            cam.insert(t).unwrap();
        }
        // round-robin inserts spread exactly
        for b in cam.banks() {
            assert_eq!(b.occupancy(), 25);
        }
        for t in &tags {
            let out = cam.lookup(t).unwrap();
            assert!(out.addr.is_some(), "tag lost");
            assert_eq!(out.banks_searched, 4, "broadcast touches the fleet");
        }
    }

    #[test]
    fn broadcast_insert_spills_to_free_banks_and_fleet_full_is_full() {
        let mut cam = ShardedCam::new(&fleet_cfg(2), PlacementMode::Broadcast);
        let mut rng = Rng::seed_from_u64(7);
        let tags = TagDistribution::Uniform.sample_distinct(32, 257, &mut rng);
        for t in tags.iter().take(256) {
            cam.insert(t).unwrap();
        }
        assert_eq!(cam.occupancy(), 256);
        assert_eq!(cam.insert(&tags[256]), Err(EngineError::Full));
    }

    #[test]
    fn delete_by_tag_and_by_address() {
        let mut cam = ShardedCam::new(&fleet_cfg(4), PlacementMode::TagHash);
        let mut rng = Rng::seed_from_u64(8);
        let tags = TagDistribution::Uniform.sample_distinct(32, 20, &mut rng);
        let mut addrs = Vec::new();
        for t in &tags {
            addrs.push(cam.insert(t).unwrap());
        }
        assert!(cam.delete_tag(&tags[3]).unwrap());
        assert_eq!(cam.lookup(&tags[3]).unwrap().addr, None);
        assert!(!cam.delete_tag(&tags[3]).unwrap(), "double delete is a no-op");
        cam.delete(addrs[7]).unwrap();
        assert_eq!(cam.lookup(&tags[7]).unwrap().addr, None);
        assert_eq!(cam.occupancy(), 18);
        assert!(matches!(cam.delete(10_000), Err(EngineError::BadAddress(_))));
    }

    #[test]
    fn single_bank_fleet_is_the_engine() {
        // S = 1 passthrough: the router is a no-op and every lookup outcome
        // (address, matches, λ, energy, delay) is bit-identical to driving
        // the one LookupEngine directly.
        let cfg = fleet_cfg(1);
        let mut fleet = ShardedCam::new(&cfg, PlacementMode::TagHash);
        let mut engine = LookupEngine::new(cfg.per_bank());
        let mut rng = Rng::seed_from_u64(9);
        let tags = TagDistribution::Uniform.sample_distinct(32, 50, &mut rng);
        for t in &tags {
            let g = fleet.insert(t).unwrap();
            assert_eq!(g, engine.insert(t).unwrap(), "global address == local address");
        }
        let mut probes = tags.clone();
        probes.extend(TagDistribution::Uniform.sample_distinct(32, 50, &mut rng));
        for t in &probes {
            let f = fleet.lookup(t).unwrap();
            let e = engine.lookup(t).unwrap();
            assert_eq!(f.banks_searched, 1);
            assert_eq!(f.addr, e.addr);
            assert_eq!(f.all_matches, e.all_matches);
            assert_eq!(f.lambda, e.lambda);
            assert_eq!(f.enabled_blocks, e.enabled_blocks);
            assert_eq!(f.comparisons, e.comparisons);
            assert_eq!(f.energy, e.energy);
            assert_eq!(f.delay, e.delay);
        }
    }

    #[test]
    fn learned_prefix_roundtrips_on_three_banks() {
        // Non-power-of-two shard count: the oversampled learned index is
        // folded with `% 3`, and insert→lookup must still resolve exactly.
        let cfg = DesignConfig {
            m: 192,
            n: 32,
            zeta: 4,
            c: 3,
            l: 4,
            shards: 3,
            ..DesignConfig::reference()
        };
        let mut rng = Rng::seed_from_u64(11);
        let tags = TagDistribution::Uniform.sample_distinct(32, 120, &mut rng);
        let mut cam = ShardedCam::new(&cfg, PlacementMode::learned(3, &tags, 32));
        let mut addrs = Vec::new();
        for t in &tags {
            addrs.push(cam.insert(t).unwrap());
        }
        for (t, &g) in tags.iter().zip(&addrs) {
            let out = cam.lookup(t).unwrap();
            assert_eq!(out.addr, Some(g));
            assert_eq!(out.banks_searched, 1, "learned placement owns exactly one bank");
        }
        // no bank monopolizes a uniform population
        for b in cam.banks() {
            assert!(b.occupancy() >= 20, "bank holds {} of 120", b.occupancy());
        }
    }

    #[test]
    fn broadcast_delete_then_lookup_misses() {
        // Broadcast mode stores ownerless: a delete must still erase the
        // entry wherever round-robin put it, and the scatter-gather lookup
        // must then miss while every other entry keeps hitting.
        let mut cam = ShardedCam::new(&fleet_cfg(4), PlacementMode::Broadcast);
        let mut rng = Rng::seed_from_u64(10);
        let tags = TagDistribution::Uniform.sample_distinct(32, 40, &mut rng);
        let mut addrs = Vec::new();
        for t in &tags {
            addrs.push(cam.insert(t).unwrap());
        }
        // delete one by flat address, one by tag (routed erase)
        cam.delete(addrs[5]).unwrap();
        assert!(cam.delete_tag(&tags[11]).unwrap());
        for (i, t) in tags.iter().enumerate() {
            let out = cam.lookup(t).unwrap();
            assert_eq!(out.banks_searched, 4, "broadcast always scatters");
            if i == 5 || i == 11 {
                assert_eq!(out.addr, None, "deleted tag {i} still matches");
            } else {
                assert_eq!(out.addr, Some(addrs[i]));
            }
        }
        // a deleted slot is reusable: the spilled re-insert hits again
        let g = cam.insert(&tags[5]).unwrap();
        assert_eq!(cam.lookup(&tags[5]).unwrap().addr, Some(g));
    }

    #[test]
    fn merge_sums_activity_and_takes_worst_delay() {
        let mk = |addr: Option<usize>, lambda: usize, cycle: f64| ShardedOutcome {
            addr,
            all_matches: addr.into_iter().collect(),
            banks_searched: 1,
            lambda,
            enabled_blocks: lambda,
            comparisons: 4 * lambda,
            energy: EnergyBreakdown { matchline_fj: 10.0, ..Default::default() },
            delay: DelayReport { cycle_ns: cycle, latency_ns: 2.0 * cycle },
        };
        let m = merge_outcomes(mk(None, 2, 0.7), mk(Some(9), 3, 0.9));
        assert_eq!(m.addr, Some(9));
        assert_eq!(m.banks_searched, 2);
        assert_eq!(m.lambda, 5);
        assert_eq!(m.enabled_blocks, 5);
        assert_eq!(m.comparisons, 20);
        assert!((m.energy.total_fj() - 20.0).abs() < 1e-12);
        assert!((m.delay.cycle_ns - 0.9).abs() < 1e-12);
        assert!((m.delay.latency_ns - 1.8).abs() < 1e-12);
    }
}
