//! The threaded fleet: one [`CamServer`] writer thread plus a reader pool
//! per bank behind a scatter-gather [`ShardedServerHandle`].
//!
//! Each bank keeps the full single-bank serving stack — its own
//! [`crate::coordinator::Batcher`], [`crate::coordinator::LookupEngine`]
//! and [`Metrics`] on a dedicated writer thread, plus `readers` threads
//! serving lookups from the bank's published
//! [`crate::coordinator::SearchState`] — so banks mutate independently and
//! lookups run concurrently both *across* banks and *within* one (bulk
//! slices are chunked over each bank's pool).  The handle routes by
//! [`ShardRouter`]: owner dispatch in hash/prefix modes,
//! scatter-then-gather (deferred sends, one wait per bank) in broadcast
//! mode, per-bank load shedding through
//! [`crate::coordinator::ServerHandle::try_lookup`]
//! ([`EngineError::Busy`]), and zero-queue direct reads
//! ([`ShardedServerHandle::lookup_direct`]) for callers that bring their
//! own thread, like the TCP connection handlers.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::bits::BitVec;
use crate::config::DesignConfig;
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::engine::{DecodeScratch, EngineError, LookupEngine};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::server::{CamServer, DecodeBackend, PersistError, ServerHandle};
use crate::shard::placement::{PlacementMode, ShardRouter};
use crate::shard::sharded::{
    globalize_outcome, merge_fold, merge_outcomes, spill_insert, split_global, ShardedOutcome,
};
use crate::store::{
    BankStore, FleetManifest, PlacementSpec, RecoveryReport, StoreError, StoreOptions,
};

/// Per-bank metrics snapshots plus the merged fleet view.
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    /// One snapshot per bank, in bank order.
    pub per_bank: Vec<Metrics>,
    /// Every bank merged ([`Metrics::merge`]).
    pub aggregate: Metrics,
}

impl FleetMetrics {
    /// The bank that served the most lookups (the hot shard).
    pub fn hottest_bank(&self) -> usize {
        self.per_bank
            .iter()
            .enumerate()
            .max_by_key(|(_, m)| m.lookups)
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Fraction of all lookups served by the hottest bank (1/S when the
    /// fleet is balanced, →1.0 under a hot-shard workload).
    pub fn hot_fraction(&self) -> f64 {
        if self.aggregate.lookups == 0 {
            return 0.0;
        }
        self.per_bank[self.hottest_bank()].lookups as f64 / self.aggregate.lookups as f64
    }

    /// Multi-line fleet summary (`bank_m`/`n` are the per-bank geometry).
    pub fn summary(&self, bank_m: usize, n: usize) -> String {
        let mut s = format!(
            "fleet of {} banks: {}",
            self.per_bank.len(),
            self.aggregate.summary(bank_m, n)
        );
        for (i, m) in self.per_bank.iter().enumerate() {
            s.push_str(&format!(
                "\n  bank {i}: lookups={} hits={} inserts={} λ̄={:.3}",
                m.lookups,
                m.hits,
                m.inserts,
                m.lambda.mean()
            ));
        }
        s
    }
}

/// What [`ShardedCamServer::open_durable`] recovered.
#[derive(Debug, Clone)]
pub struct FleetRecovery {
    /// The fleet manifest already existed (a restart) rather than being
    /// created by this open (first boot).
    pub manifest_loaded: bool,
    /// One recovery report per bank, in bank order.
    pub banks: Vec<RecoveryReport>,
}

impl FleetRecovery {
    /// WAL records replayed across all banks.
    pub fn total_records(&self) -> usize {
        self.banks.iter().map(|b| b.wal_records).sum()
    }

    /// Live entries recovered across all banks.
    pub fn total_occupancy(&self) -> usize {
        self.banks.iter().map(|b| b.occupancy).sum()
    }

    /// Banks whose WAL had a torn tail truncated.
    pub fn truncated_banks(&self) -> usize {
        self.banks.iter().filter(|b| b.truncated_bytes > 0).count()
    }

    /// One-line human summary for the serve log.
    pub fn summary(&self) -> String {
        format!(
            "{} the fleet manifest; recovered {} entries across {} banks \
             ({} WAL records, {} snapshot(s), {} torn tail(s) truncated)",
            if self.manifest_loaded { "validated against" } else { "created" },
            self.total_occupancy(),
            self.banks.len(),
            self.total_records(),
            self.banks.iter().filter(|b| b.snapshot_loaded).count(),
            self.truncated_banks()
        )
    }
}

/// Builder for the threaded fleet.
pub struct ShardedCamServer {
    servers: Vec<CamServer>,
    router: ShardRouter,
    bank_m: usize,
    bank_n: usize,
}

impl ShardedCamServer {
    /// `cfg.shards` fresh banks (native decode) of `cfg.m / cfg.shards`
    /// entries each, sharing one batch policy.
    pub fn new(cfg: &DesignConfig, mode: PlacementMode, policy: BatchPolicy) -> Self {
        // lint:allow(constructor precondition: a geometry that fails
        // validation cannot be served at all, so refuse loudly at build time)
        cfg.validate().expect("invalid design config");
        let router = ShardRouter::new(cfg.shards, mode);
        let bank_cfg = cfg.per_bank();
        let servers = (0..cfg.shards)
            .map(|_| CamServer::new(bank_cfg.clone(), DecodeBackend::Native, policy))
            .collect();
        ShardedCamServer { servers, router, bank_m: bank_cfg.m, bank_n: bank_cfg.n }
    }

    /// Wrap existing (pre-populated) banks of identical geometry.
    pub fn with_banks(banks: Vec<LookupEngine>, router: ShardRouter, policy: BatchPolicy) -> Self {
        assert!(!banks.is_empty(), "need at least one bank");
        assert_eq!(banks.len(), router.shards(), "router/bank count mismatch");
        let bank_m = banks[0].config().m;
        let bank_n = banks[0].config().n;
        assert!(
            banks.iter().all(|b| b.config().m == bank_m && b.config().n == bank_n),
            "banks must share one geometry"
        );
        let servers = banks
            .into_iter()
            .map(|e| CamServer::with_engine(e, DecodeBackend::Native, policy))
            .collect();
        ShardedCamServer { servers, router, bank_m, bank_n }
    }

    /// Cap every bank's admission queue (per-bank shedding for
    /// [`ShardedServerHandle::try_lookup`]).
    pub fn with_queue_capacity(mut self, cap: usize) -> Self {
        self.servers =
            self.servers.into_iter().map(|s| s.with_queue_capacity(cap)).collect();
        self
    }

    /// Size every bank's reader pool: `n` threads per bank serving lookups
    /// concurrently from the bank's published search state (`0` = the
    /// pre-pool engine-thread path).
    pub fn with_readers(mut self, n: usize) -> Self {
        self.servers = self.servers.into_iter().map(|s| s.with_readers(n)).collect();
        self
    }

    /// Open a *durable* fleet under `dir`: one [`crate::store::DurableBank`]
    /// recovery per bank (`dir/bank-<i>/` holds its snapshot + WAL), with a
    /// `fleet.kv` manifest recording shard count, geometry and placement so
    /// a restart refuses an incompatible layout instead of silently
    /// re-homing stored tags.
    ///
    /// On a restart of a learned-prefix fleet the manifest's recorded bit
    /// positions *replace* the freshly supplied selection — placement is an
    /// address-space contract and must not drift with the sample that
    /// happened to train it.  Returns the recovery report per bank.
    pub fn open_durable(
        cfg: &DesignConfig,
        mode: PlacementMode,
        policy: BatchPolicy,
        dir: &Path,
        opts: StoreOptions,
    ) -> Result<(Self, FleetRecovery), StoreError> {
        cfg.validate()
            .map_err(|e| StoreError::Incompatible(format!("invalid design config: {e}")))?;
        std::fs::create_dir_all(dir)?;
        let manifest_path_exists = dir.join(crate::store::MANIFEST_FILE).exists();
        let (manifest, manifest_loaded) = if manifest_path_exists {
            let manifest = FleetManifest::load(dir)?;
            manifest.check_compatible(cfg, &mode)?;
            (manifest, true)
        } else {
            let manifest = FleetManifest {
                cfg: cfg.clone(),
                placement: PlacementSpec::from_mode(&mode),
                epoch: 0,
            };
            manifest.store(dir)?;
            (manifest, false)
        };
        let effective_mode = manifest.placement.to_mode(cfg.n)?;
        let router = ShardRouter::new(cfg.shards, effective_mode);

        let bank_cfg = cfg.per_bank();
        let mut servers = Vec::with_capacity(cfg.shards);
        let mut banks = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let factory_cfg = bank_cfg.clone();
            let (store, engine, report) = BankStore::open(
                &dir.join(format!("bank-{i}")),
                opts,
                &bank_cfg,
                move || LookupEngine::new(factory_cfg),
            )?;
            banks.push(report);
            servers.push(
                CamServer::with_engine(engine, DecodeBackend::Native, policy).with_store(store),
            );
        }
        let fleet = ShardedCamServer {
            servers,
            router,
            bank_m: bank_cfg.m,
            bank_n: bank_cfg.n,
        };
        Ok((fleet, FleetRecovery { manifest_loaded, banks }))
    }

    /// Spawn one engine thread per bank.
    pub fn spawn(self) -> ShardedServerHandle {
        ShardedServerHandle {
            banks: self.servers.into_iter().map(|s| s.spawn()).collect(),
            router: Arc::new(self.router),
            bank_m: self.bank_m,
            bank_n: self.bank_n,
            rr: Arc::new(AtomicUsize::new(0)),
        }
    }
}

/// Cloneable client handle to a running fleet — the multi-bank analogue of
/// [`ServerHandle`], with flat global addressing and fleet-level metrics.
#[derive(Clone)]
pub struct ShardedServerHandle {
    banks: Vec<ServerHandle>,
    router: Arc<ShardRouter>,
    bank_m: usize,
    bank_n: usize,
    /// Round-robin cursor for ownerless (broadcast) inserts.
    rr: Arc<AtomicUsize>,
}

impl ShardedServerHandle {
    pub fn shard_count(&self) -> usize {
        self.banks.len()
    }

    /// Entries per bank (M_bank).
    pub fn bank_m(&self) -> usize {
        self.bank_m
    }

    /// Tag width N the fleet expects (the network hello announces it so a
    /// remote client can size its tags without a config file).
    pub fn tag_bits(&self) -> usize {
        self.bank_n
    }

    pub fn router(&self) -> &ShardRouter {
        &self.router
    }

    /// Direct handle to one bank (drains, per-bank probes).
    pub fn bank(&self, i: usize) -> &ServerHandle {
        &self.banks[i]
    }

    fn global(&self, bank: usize, local: usize) -> usize {
        bank * self.bank_m + local
    }

    /// Insert into the owning bank (round-robin with fallback scan in
    /// broadcast mode); returns the flat global address.
    pub fn insert(&self, tag: BitVec) -> Result<usize, EngineError> {
        match self.router.place(&tag) {
            Some(b) => Ok(self.global(b, self.banks[b].insert(tag)?)),
            None => {
                let s = self.banks.len();
                // lint:allow(relaxed: the round-robin cursor only spreads
                // ownerless inserts statistically; any interleaving of the
                // counter is an acceptable start bank, and the spill scan
                // below corrects for collisions — no other memory depends
                // on this ordering)
                let start = self.rr.fetch_add(1, Ordering::Relaxed) % s;
                let (b, a) = spill_insert(s, start, |b| self.banks[b].insert(tag.clone()))?;
                Ok(self.global(b, a))
            }
        }
    }

    /// Delete by flat global address.
    pub fn delete(&self, global: usize) -> Result<(), EngineError> {
        let (b, local) = split_global(global, self.bank_m, self.banks.len())?;
        self.banks[b].delete(local)
    }

    /// The scatter-gather lookup: owner dispatch in hash/prefix modes; in
    /// broadcast mode the request is scattered to every bank first (they
    /// decode in parallel) and the answers are gathered and merged.
    pub fn lookup(&self, tag: BitVec) -> Result<ShardedOutcome, EngineError> {
        match self.router.place(&tag) {
            Some(b) => Ok(globalize_outcome(self.banks[b].lookup(tag)?, b, self.bank_m)),
            None => {
                let pending: Result<Vec<_>, _> =
                    self.banks.iter().map(|h| h.lookup_deferred(tag.clone())).collect();
                let mut merged: Option<ShardedOutcome> = None;
                for (b, p) in pending?.into_iter().enumerate() {
                    let g = globalize_outcome(p.wait()?, b, self.bank_m);
                    merged = Some(merge_fold(merged, g));
                }
                // lint:allow(infallible: constructors enforce >= 1 bank, so
                // the gather fold above ran at least once)
                Ok(merged.expect("at least one bank"))
            }
        }
    }

    /// Non-blocking admission: sheds with [`EngineError::Busy`] when the
    /// owning bank is saturated (broadcast: when any bank is), without
    /// queueing anything.  [`EngineError::Full`] stays reserved for "no
    /// free CAM slot" on the insert path.
    pub fn try_lookup(&self, tag: BitVec) -> Result<ShardedOutcome, EngineError> {
        match self.router.place(&tag) {
            Some(b) => Ok(globalize_outcome(self.banks[b].try_lookup(tag)?, b, self.bank_m)),
            None => {
                if self.banks.iter().any(|h| h.is_saturated()) {
                    return Err(EngineError::Busy);
                }
                self.lookup(tag)
            }
        }
    }

    /// Run one lookup entirely *on the calling thread* against the owning
    /// bank's published search state (broadcast: against every bank's,
    /// gather-merged) — no queue, no channel hop, no engine thread.  This
    /// is the net worker pool's read path; results are bit-identical
    /// to [`Self::lookup`].  The caller owns the scratch (one per thread);
    /// bank geometry is uniform, so one scratch serves the whole fleet.
    pub fn lookup_direct(
        &self,
        tag: &BitVec,
        scratch: &mut DecodeScratch,
    ) -> Result<ShardedOutcome, EngineError> {
        if tag.len() != self.bank_n {
            // validate before routing: the learned-prefix router reads
            // fixed bit positions and would panic on a narrow tag
            return Err(EngineError::TagWidth { got: tag.len(), want: self.bank_n });
        }
        match self.router.place(tag) {
            Some(b) => {
                Ok(globalize_outcome(self.banks[b].lookup_direct(tag, scratch)?, b, self.bank_m))
            }
            None => {
                let mut merged: Option<ShardedOutcome> = None;
                for (b, h) in self.banks.iter().enumerate() {
                    let g = globalize_outcome(h.lookup_direct(tag, scratch)?, b, self.bank_m);
                    merged = Some(merge_fold(merged, g));
                }
                // lint:allow(infallible: constructors enforce >= 1 bank, so
                // the gather fold above ran at least once)
                Ok(merged.expect("at least one bank"))
            }
        }
    }

    /// Bulk [`Self::lookup_direct`]: every tag served on the calling
    /// thread, in order.  Parallelism across connections, not within one —
    /// in-process callers who want intra-slice fan-out use
    /// [`Self::lookup_many`], which spreads chunks over each bank's reader
    /// pool.
    pub fn lookup_many_direct(
        &self,
        tags: &[BitVec],
        scratch: &mut DecodeScratch,
    ) -> Vec<Result<ShardedOutcome, EngineError>> {
        tags.iter().map(|t| self.lookup_direct(t, scratch)).collect()
    }

    /// Bulk scatter-gather preserving input order: one bulk message per
    /// owning bank (broadcast mode ships the whole slice to every bank and
    /// merges element-wise), so channel round-trips amortize over the
    /// slice and the banks' engine threads run concurrently.
    pub fn lookup_many(&self, tags: Vec<BitVec>) -> Vec<Result<ShardedOutcome, EngineError>> {
        let n = tags.len();
        if n == 0 {
            return Vec::new();
        }
        let mut out: Vec<Option<Result<ShardedOutcome, EngineError>>> = vec![None; n];
        if self.router.is_broadcast() {
            let pendings: Vec<_> =
                self.banks.iter().map(|h| h.lookup_many_deferred(tags.clone())).collect();
            for (b, p) in pendings.into_iter().enumerate() {
                let results = match p {
                    Ok(p) => p.wait(),
                    Err(e) => (0..n).map(|_| Err(e.clone())).collect(),
                };
                for (i, r) in results.into_iter().enumerate() {
                    let g = r.map(|o| globalize_outcome(o, b, self.bank_m));
                    out[i] = Some(match out[i].take() {
                        None => g,
                        Some(Ok(acc)) => g.map(|o| merge_outcomes(acc, o)),
                        Some(err) => err,
                    });
                }
            }
        } else {
            let s = self.banks.len();
            let mut per_bank: Vec<Vec<BitVec>> = vec![Vec::new(); s];
            let mut pos: Vec<Vec<usize>> = vec![Vec::new(); s];
            for (i, t) in tags.into_iter().enumerate() {
                // lint:allow(infallible: this branch only runs in owner
                // placement modes, where place() is total)
                let b = self.router.place(&t).expect("owner placement");
                pos[b].push(i);
                per_bank[b].push(t);
            }
            let pendings: Vec<_> = per_bank
                .into_iter()
                .enumerate()
                .map(|(b, ts)| self.banks[b].lookup_many_deferred(ts))
                .collect();
            for (b, p) in pendings.into_iter().enumerate() {
                let results = match p {
                    Ok(p) => p.wait(),
                    Err(e) => (0..pos[b].len()).map(|_| Err(e.clone())).collect(),
                };
                for (&i, r) in pos[b].iter().zip(results) {
                    out[i] = Some(r.map(|o| globalize_outcome(o, b, self.bank_m)));
                }
            }
        }
        // lint:allow(infallible: both branches above visit every input index
        // exactly once, so no slot can remain None)
        out.into_iter().map(|r| r.expect("every slot filled")).collect()
    }

    /// Non-blocking bulk admission: sheds the whole slice with
    /// [`EngineError::Busy`] — without queueing anything — when any bank
    /// the slice would touch is saturated (the owning banks in owner
    /// modes, every bank in broadcast); otherwise exactly
    /// [`Self::lookup_many`].  One saturated bank must not shed traffic
    /// owned entirely by idle banks.
    pub fn try_lookup_many(
        &self,
        tags: Vec<BitVec>,
    ) -> Result<Vec<Result<ShardedOutcome, EngineError>>, EngineError> {
        let saturated = if self.router.is_broadcast() {
            self.banks.iter().any(|h| h.is_saturated())
        } else {
            tags.iter()
                .any(|t| self.router.place(t).is_some_and(|b| self.banks[b].is_saturated()))
        };
        if saturated {
            return Err(EngineError::Busy);
        }
        Ok(self.lookup_many(tags))
    }

    /// Snapshot every bank and merge into the fleet view; `None` if any
    /// engine thread is gone.
    pub fn fleet_metrics(&self) -> Option<FleetMetrics> {
        let mut per_bank = Vec::with_capacity(self.banks.len());
        for h in &self.banks {
            per_bank.push(*h.metrics()?);
        }
        let mut aggregate = Metrics::new();
        for m in &per_bank {
            aggregate.merge(m);
        }
        Some(FleetMetrics { per_bank, aggregate })
    }

    /// Flush every bank's pending work.
    pub fn drain(&self) {
        for h in &self.banks {
            h.drain();
        }
    }

    /// Scatter one persist barrier to every bank, then gather: the banks
    /// fsync/snapshot concurrently, so the fleet-wide cost is roughly one
    /// bank's latency instead of S of them in series.
    fn persist_all(&self, snapshot: bool) -> Result<bool, PersistError> {
        let pending: Result<Vec<_>, _> =
            self.banks.iter().map(|h| h.persist_deferred(snapshot)).collect();
        let mut any = false;
        for p in pending? {
            any |= p.wait()?;
        }
        Ok(any)
    }

    /// Fsync every bank's WAL.  `Ok(true)` once every acknowledged write
    /// in the fleet is on disk; `Ok(false)` when no bank has a store
    /// (the fleet serves without `--data-dir`).  Each bank's flush is a
    /// barrier on its engine thread, so it orders after every mutation
    /// that bank acknowledged; the banks run their barriers in parallel.
    pub fn flush_stores(&self) -> Result<bool, PersistError> {
        self.persist_all(false)
    }

    /// Force a fleet-wide compaction: every bank snapshots and truncates
    /// its WAL, concurrently.  `Ok(false)` when no bank has a store.
    pub fn snapshot_stores(&self) -> Result<bool, PersistError> {
        self.persist_all(true)
    }

    /// Orderly stop: drain every bank's pending work, then flush every
    /// bank's WAL — strictly in that order, so no acknowledged write can
    /// be left unlogged when the caller proceeds to drop the handles (the
    /// engine threads exit once every clone is gone and flush once more on
    /// their own way out).  After this returns, reopening the fleet's data
    /// directory recovers every acknowledged mutation.
    pub fn shutdown(&self) -> Result<bool, PersistError> {
        self.drain();
        self.flush_stores()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workload::TagDistribution;
    use std::time::Duration;

    fn fleet_cfg(shards: usize) -> DesignConfig {
        DesignConfig { m: 256, n: 32, zeta: 4, c: 3, l: 4, shards, ..DesignConfig::reference() }
    }

    fn policy() -> BatchPolicy {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) }
    }

    #[test]
    fn fleet_roundtrip_and_metrics_aggregate() {
        let h = ShardedCamServer::new(&fleet_cfg(4), PlacementMode::TagHash, policy()).spawn();
        let mut rng = Rng::seed_from_u64(31);
        let tags = TagDistribution::Uniform.sample_distinct(32, 120, &mut rng);
        let mut addrs = Vec::new();
        for t in &tags {
            addrs.push(h.insert(t.clone()).unwrap());
        }
        for (t, &g) in tags.iter().zip(&addrs) {
            assert_eq!(h.lookup(t.clone()).unwrap().addr, Some(g));
        }
        let fm = h.fleet_metrics().unwrap();
        assert_eq!(fm.per_bank.len(), 4);
        assert_eq!(fm.aggregate.lookups, 120);
        assert_eq!(fm.aggregate.hits, 120);
        assert_eq!(fm.aggregate.inserts, 120);
        let per_bank_sum: u64 = fm.per_bank.iter().map(|m| m.lookups).sum();
        assert_eq!(per_bank_sum, 120, "fleet view is the sum of the banks");
        assert!(fm.summary(64, 32).contains("fleet of 4 banks"));
    }

    #[test]
    fn broadcast_fleet_merges_all_banks() {
        let h = ShardedCamServer::new(&fleet_cfg(4), PlacementMode::Broadcast, policy()).spawn();
        let mut rng = Rng::seed_from_u64(32);
        let tags = TagDistribution::Uniform.sample_distinct(32, 40, &mut rng);
        let mut addrs = Vec::new();
        for t in &tags {
            addrs.push(h.insert(t.clone()).unwrap());
        }
        for (t, &g) in tags.iter().zip(&addrs) {
            let out = h.lookup(t.clone()).unwrap();
            assert_eq!(out.addr, Some(g));
            assert_eq!(out.banks_searched, 4);
        }
        // every bank saw every lookup
        let fm = h.fleet_metrics().unwrap();
        for m in &fm.per_bank {
            assert_eq!(m.lookups, 40);
        }
    }

    #[test]
    fn bulk_matches_singles_in_both_modes() {
        for mode in [PlacementMode::TagHash, PlacementMode::Broadcast] {
            let h = ShardedCamServer::new(&fleet_cfg(4), mode, policy()).spawn();
            let mut rng = Rng::seed_from_u64(33);
            let tags = TagDistribution::Uniform.sample_distinct(32, 60, &mut rng);
            for t in &tags {
                h.insert(t.clone()).unwrap();
            }
            let singles: Vec<_> =
                tags.iter().map(|t| h.lookup(t.clone()).unwrap().addr).collect();
            let bulk = h.lookup_many(tags.clone());
            assert_eq!(bulk.len(), 60);
            for (i, r) in bulk.into_iter().enumerate() {
                assert_eq!(r.unwrap().addr, singles[i], "order must be preserved");
            }
            assert!(h.lookup_many(Vec::new()).is_empty());
        }
    }

    #[test]
    fn try_lookup_sheds_busy_per_bank() {
        let h = ShardedCamServer::new(&fleet_cfg(4), PlacementMode::TagHash, policy())
            .with_queue_capacity(0)
            .spawn();
        let mut rng = Rng::seed_from_u64(34);
        let tags = TagDistribution::Uniform.sample_distinct(32, 8, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        // cap 0: every bank sheds the non-blocking path with Busy (the
        // queue condition, distinct from Full = no free CAM slot)...
        for t in &tags {
            assert_eq!(h.try_lookup(t.clone()).unwrap_err(), EngineError::Busy);
        }
        // ...bulk admission sheds the whole slice the same way...
        assert_eq!(h.try_lookup_many(tags.clone()).unwrap_err(), EngineError::Busy);
        // ...while blocking lookups still get through...
        assert!(h.lookup(tags[0].clone()).unwrap().addr.is_some());
        // ...and so do direct reads: they never queue, so the admission
        // cap cannot shed them.
        let mut scratch = DecodeScratch::new();
        assert!(h.lookup_direct(&tags[0], &mut scratch).unwrap().addr.is_some());
    }

    #[test]
    fn direct_reads_match_queued_lookups_in_all_modes() {
        for mode in [PlacementMode::TagHash, PlacementMode::Broadcast] {
            let h = ShardedCamServer::new(&fleet_cfg(4), mode, policy()).spawn();
            let mut rng = Rng::seed_from_u64(37);
            let tags = TagDistribution::Uniform.sample_distinct(32, 40, &mut rng);
            for t in &tags {
                h.insert(t.clone()).unwrap();
            }
            let mut probes = tags.clone();
            probes.extend(TagDistribution::Uniform.sample_distinct(32, 20, &mut rng));
            let mut scratch = DecodeScratch::new();
            for t in &probes {
                let queued = h.lookup(t.clone()).unwrap();
                let direct = h.lookup_direct(t, &mut scratch).unwrap();
                assert_eq!(queued, direct, "direct read diverged from the queued path");
            }
            let bulk_direct = h.lookup_many_direct(&probes, &mut scratch);
            let bulk_queued = h.lookup_many(probes.clone());
            for (d, q) in bulk_direct.iter().zip(&bulk_queued) {
                assert_eq!(d.as_ref().unwrap(), q.as_ref().unwrap());
            }
            // a narrow tag is a typed error, not a router panic
            let narrow = crate::bits::BitVec::zeros(8);
            assert!(matches!(
                h.lookup_direct(&narrow, &mut scratch),
                Err(EngineError::TagWidth { got: 8, want: 32 })
            ));
        }
    }

    #[test]
    fn shutdown_flushes_every_banks_wal_before_handles_drop() {
        // The drain-order contract: after shutdown() returns, every
        // acknowledged write must be recoverable from disk — even though
        // the engine threads are still alive behind the live handles (no
        // acknowledged-but-unlogged writes survive the drain + flush
        // barrier sequence).
        let dir = std::env::temp_dir()
            .join(format!("cscam-shard-{}", std::process::id()))
            .join("drain-order");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = fleet_cfg(4);
        let (fleet, rec) = ShardedCamServer::open_durable(
            &cfg,
            PlacementMode::TagHash,
            policy(),
            &dir,
            StoreOptions::default(),
        )
        .unwrap();
        assert!(!rec.manifest_loaded, "first boot creates the manifest");
        let h = fleet.spawn();
        let mut rng = Rng::seed_from_u64(36);
        let tags = TagDistribution::Uniform.sample_distinct(32, 48, &mut rng);
        let mut addrs = Vec::new();
        for t in &tags {
            addrs.push(h.insert(t.clone()).unwrap());
        }
        h.delete(addrs[0]).unwrap();
        assert!(h.shutdown().unwrap(), "a durable fleet reports flushed stores");

        // reopen FROM DISK while the original handles are still alive:
        // the recovered fleet must hold exactly the acknowledged state
        let (reopened, rec) = ShardedCamServer::open_durable(
            &cfg,
            PlacementMode::TagHash,
            policy(),
            &dir,
            StoreOptions::default(),
        )
        .unwrap();
        assert!(rec.manifest_loaded, "restart validates the manifest");
        assert_eq!(rec.total_records(), 49, "48 inserts + 1 delete all logged");
        assert_eq!(rec.total_occupancy(), 47);
        let h2 = reopened.spawn();
        for (t, &g) in tags.iter().zip(&addrs).skip(1) {
            assert_eq!(h2.lookup(t.clone()).unwrap().addr, Some(g));
        }
        assert_eq!(h2.lookup(tags[0].clone()).unwrap().addr, None, "delete recovered too");
        drop(h);
    }

    #[test]
    fn durable_fleet_refuses_incompatible_reopen() {
        let dir = std::env::temp_dir()
            .join(format!("cscam-shard-{}", std::process::id()))
            .join("incompatible");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = fleet_cfg(4);
        let (fleet, _) = ShardedCamServer::open_durable(
            &cfg,
            PlacementMode::TagHash,
            policy(),
            &dir,
            StoreOptions::default(),
        )
        .unwrap();
        drop(fleet);
        // different shard count
        let other = fleet_cfg(2);
        assert!(matches!(
            ShardedCamServer::open_durable(
                &other,
                PlacementMode::TagHash,
                policy(),
                &dir,
                StoreOptions::default(),
            ),
            Err(StoreError::Incompatible(_))
        ));
        // different placement kind
        assert!(matches!(
            ShardedCamServer::open_durable(
                &cfg,
                PlacementMode::Broadcast,
                policy(),
                &dir,
                StoreOptions::default(),
            ),
            Err(StoreError::Incompatible(_))
        ));
    }

    #[test]
    fn try_lookup_many_admits_below_capacity() {
        for mode in [PlacementMode::TagHash, PlacementMode::Broadcast] {
            let h = ShardedCamServer::new(&fleet_cfg(4), mode, policy()).spawn();
            let mut rng = Rng::seed_from_u64(35);
            let tags = TagDistribution::Uniform.sample_distinct(32, 24, &mut rng);
            for t in &tags {
                h.insert(t.clone()).unwrap();
            }
            let singles: Vec<_> =
                tags.iter().map(|t| h.lookup(t.clone()).unwrap().addr).collect();
            let bulk = h.try_lookup_many(tags.clone()).expect("unsaturated fleet admits");
            for (i, r) in bulk.into_iter().enumerate() {
                assert_eq!(r.unwrap().addr, singles[i]);
            }
        }
    }
}
