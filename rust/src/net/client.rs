//! Blocking TCP client for the CAM wire protocol.
//!
//! [`CamClient`] keeps one connection, performs the magic/version
//! handshake on connect, and exposes the fleet operations 1:1 — the
//! returned [`ShardedOutcome`] carries the matched global address, λ and
//! the energy/delay physics bit-identical to an in-process
//! [`crate::shard::ShardedServerHandle::lookup`].
//!
//! [`CamClient::lookup_bulk`] is *pipelined and multiplexed*: the tag
//! slice is split into chunks, a bounded window of chunk frames is kept in
//! flight, and responses are matched back up by request id — since
//! protocol v6 a server may answer them in *completion* order rather than
//! submission order (its hello advertises `multiplex`), and the re-match
//! makes that reordering invisible: per-tag results always come back in
//! input order.  The wire analogue of the in-process deferred scatter.
//!
//! Idempotent calls (`lookup`, `lookup_bulk`, `stats`, `metrics`, `drain`)
//! transparently **reconnect and retry once** when the transport drops;
//! mutations (`insert`, `delete`) and `shutdown` never auto-retry, because
//! replaying them could double-apply.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::bits::BitVec;
use crate::coordinator::engine::EngineError;
use crate::net::proto::{
    self, read_server_hello, write_client_hello, Request, Response, ServerHello, StatsReport,
    WireError, VERSION,
};
use crate::shard::ShardedOutcome;

/// Connect-phase bound.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// Per-call transport bound: no server response should take this long (a
/// full 4096-tag bulk frame is microseconds of engine work), so hitting it
/// means the peer is gone or wedged — the call fails with an I/O error and
/// the connection is poisoned rather than blocking the caller forever.
const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// A request-writing callback: receives the connection's writer and the
/// request id chosen for this call.  Lets the hot paths serialize straight
/// from borrowed tags ([`proto::write_tag_request`]) while the cold paths
/// go through an owned [`Request`].
type WriteReq<'a> = &'a dyn Fn(&mut BufWriter<TcpStream>, u64) -> std::io::Result<()>;

struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    hello: ServerHello,
}

impl Conn {
    fn open(addr: &str) -> Result<Conn, WireError> {
        let target = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| WireError::Protocol(format!("'{addr}' resolves to no address")))?;
        let stream = TcpStream::connect_timeout(&target, CONNECT_TIMEOUT)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(IO_TIMEOUT))?;
        stream.set_write_timeout(Some(IO_TIMEOUT))?;
        let read_half = stream.try_clone()?;
        let mut conn = Conn {
            reader: BufReader::new(read_half),
            writer: BufWriter::new(stream),
            hello: ServerHello {
                version: 0,
                busy: false,
                multiplex: false,
                shards: 0,
                bank_m: 0,
                tag_bits: 0,
            },
        };
        write_client_hello(&mut conn.writer)?;
        conn.writer.flush()?;
        conn.hello = read_server_hello(&mut conn.reader)?;
        if conn.hello.busy {
            return Err(WireError::Busy);
        }
        if conn.hello.version != VERSION {
            return Err(WireError::Protocol(format!(
                "server speaks version {}, this client speaks {}",
                conn.hello.version, VERSION
            )));
        }
        Ok(conn)
    }
}

/// Outcome of one [`CamClient::subscribe_log`] poll — the three answers a
/// replication feed can give a subscriber (see [`crate::repl`]).
#[derive(Debug)]
pub enum LogPoll {
    /// Framed WAL records past the requested offset.  `next_offset` is
    /// what the subscriber should request next (requesting it *is* the
    /// ack of everything before it); `remaining` is the records still
    /// unread behind this batch — the replica's lag.
    Batch { generation: u64, next_offset: u64, remaining: u64, frames: Vec<u8> },
    /// A full state transfer: either the bootstrap the subscriber asked
    /// for, or the requested `(generation, offset)` no longer exists
    /// (compaction retired that log) and the feed restarts the stream
    /// from its current snapshot.
    Snapshot { generation: u64, image: Vec<u8> },
    /// The subscriber's epoch is stale — the fleet was promoted past it
    /// and the old lineage is fenced off.  `server_epoch` is the epoch
    /// the feed is serving.
    Fenced { server_epoch: u64 },
}

/// A blocking wire-protocol client with reconnect.
pub struct CamClient {
    addr: String,
    conn: Option<Conn>,
    next_id: u64,
}

impl CamClient {
    /// Connect and handshake.
    pub fn connect(addr: impl Into<String>) -> Result<CamClient, WireError> {
        let addr = addr.into();
        let conn = Conn::open(&addr)?;
        Ok(CamClient { addr, conn: Some(conn), next_id: 1 })
    }

    /// What the server announced at handshake (fleet geometry); `None`
    /// while disconnected.
    pub fn server_info(&self) -> Option<&ServerHello> {
        self.conn.as_ref().map(|c| &c.hello)
    }

    /// Did the server advertise out-of-order (multiplexed) responses at
    /// handshake?  Purely informational — [`Self::lookup_bulk`] re-matches
    /// responses by request id either way.
    pub fn multiplexed(&self) -> bool {
        self.conn.as_ref().is_some_and(|c| c.hello.multiplex)
    }

    /// Drop the current connection (if any) and open a fresh one.
    pub fn reconnect(&mut self) -> Result<(), WireError> {
        self.conn = None;
        self.conn = Some(Conn::open(&self.addr)?);
        Ok(())
    }

    fn conn(&mut self) -> Result<&mut Conn, WireError> {
        if self.conn.is_none() {
            self.conn = Some(Conn::open(&self.addr)?);
        }
        // lint:allow(infallible: the branch above just set self.conn to Some
        // or returned the connect error)
        Ok(self.conn.as_mut().expect("just connected"))
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// One request/response exchange.  Transport failures poison the
    /// connection (the next call reconnects).
    fn call_once(&mut self, req: &Request) -> Result<Response, WireError> {
        self.call_with(&|w, id| proto::write_request(w, id, req))
    }

    /// Like [`Self::call_once`], but reconnect-and-retry once on a
    /// transport error — only safe for idempotent requests.
    fn call_idempotent(&mut self, req: &Request) -> Result<Response, WireError> {
        self.call_idempotent_with(&|w, id| proto::write_request(w, id, req))
    }

    fn call_with(&mut self, write: WriteReq<'_>) -> Result<Response, WireError> {
        let id = self.fresh_id();
        let result = self.exchange(id, write);
        if matches!(result, Err(WireError::Io(_)) | Err(WireError::Protocol(_))) {
            self.conn = None;
        }
        result
    }

    fn call_idempotent_with(&mut self, write: WriteReq<'_>) -> Result<Response, WireError> {
        match self.call_with(write) {
            Err(WireError::Io(_)) => {
                self.reconnect()?;
                self.call_with(write)
            }
            other => other,
        }
    }

    fn exchange(&mut self, id: u64, write: WriteReq<'_>) -> Result<Response, WireError> {
        let conn = self.conn()?;
        write(&mut conn.writer, id)?;
        conn.writer.flush()?;
        let (rid, resp) = proto::read_response(&mut conn.reader)?;
        if rid != id {
            return Err(WireError::Protocol(format!(
                "response id {rid} does not match request id {id}"
            )));
        }
        Ok(resp)
    }

    /// Insert a tag; returns its flat global address.  Not auto-retried.
    pub fn insert(&mut self, tag: &BitVec) -> Result<u64, WireError> {
        match self.call_with(&|w, id| proto::write_tag_request(w, id, proto::OP_INSERT, tag))? {
            Response::Inserted { addr } => Ok(addr),
            other => unexpected(other),
        }
    }

    /// Delete by flat global address.  Not auto-retried.
    pub fn delete(&mut self, addr: u64) -> Result<(), WireError> {
        match self.call_once(&Request::Delete { addr })? {
            Response::Deleted => Ok(()),
            other => unexpected(other),
        }
    }

    /// One lookup, served on the server's worker pool directly from the
    /// owning bank's published snapshot.  A server may answer
    /// [`EngineError::Busy`] (as [`WireError::Engine`]) under admission
    /// shedding; [`EngineError::Full`] strictly means "no free CAM slot".
    pub fn lookup(&mut self, tag: &BitVec) -> Result<ShardedOutcome, WireError> {
        let resp = self
            .call_idempotent_with(&|w, id| proto::write_tag_request(w, id, proto::OP_LOOKUP, tag))?;
        match resp {
            Response::Lookup(o) => Ok(*o),
            other => unexpected(other),
        }
    }

    /// Pipelined bulk lookup: `tags` is cut into `chunk`-sized frames
    /// (clamped to [`proto::MAX_BULK_TAGS`]) and streamed through a
    /// bounded window — several frames are in flight before the first
    /// response is read, but never so many that both sides could wedge on
    /// full socket buffers.  Per-tag results come back in input order.
    pub fn lookup_bulk(
        &mut self,
        tags: &[BitVec],
        chunk: usize,
    ) -> Result<Vec<Result<ShardedOutcome, EngineError>>, WireError> {
        if tags.is_empty() {
            return Ok(Vec::new());
        }
        let chunk = chunk.clamp(1, proto::MAX_BULK_TAGS);
        match self.bulk_once(tags, chunk) {
            Err(WireError::Io(_)) => {
                // lookups are idempotent: replay the whole burst once
                self.reconnect()?;
                self.bulk_once(tags, chunk)
            }
            other => other,
        }
    }

    fn bulk_once(
        &mut self,
        tags: &[BitVec],
        chunk: usize,
    ) -> Result<Vec<Result<ShardedOutcome, EngineError>>, WireError> {
        let chunks: Vec<&[BitVec]> = tags.chunks(chunk).collect();
        let ids: Vec<u64> = chunks.iter().map(|_| self.fresh_id()).collect();
        let result = self.bulk_exchange(&ids, &chunks, tags.len());
        if matches!(result, Err(WireError::Io(_)) | Err(WireError::Protocol(_))) {
            self.conn = None;
        }
        result
    }

    fn bulk_exchange(
        &mut self,
        ids: &[u64],
        chunks: &[&[BitVec]],
        total: usize,
    ) -> Result<Vec<Result<ShardedOutcome, EngineError>>, WireError> {
        let conn = self.conn()?;
        // Bounded pipelining: keep a window of frames in flight (≈1024
        // tags' worth) instead of writing the whole burst up front — an
        // unbounded scatter could fill both directions' socket buffers
        // with neither side reading, deadlocking the connection (and a
        // v6 server's per-connection backpressure would stop reading us
        // long before that).  Reading one response before sending frame
        // i+W keeps the response stream draining while frames overlap.
        //
        // Since protocol v6 the server executes a connection's requests on
        // a worker pool and answers in *completion* order, so a response
        // may belong to any outstanding frame of the window — each is
        // re-matched to its chunk by request id and the per-tag results
        // are reassembled in input order before returning.
        let chunk = chunks[0].len().max(1);
        let window = (1024 / chunk).clamp(1, 64).min(chunks.len());
        let mut slots: Vec<Option<Response>> = (0..chunks.len()).map(|_| None).collect();
        let mut next_send = window;
        for i in 0..window {
            proto::write_lookup_bulk_request(&mut conn.writer, ids[i], chunks[i])?;
        }
        conn.writer.flush()?;
        for _ in 0..chunks.len() {
            let (rid, resp) = proto::read_response(&mut conn.reader)?;
            let ci = match ids.iter().position(|&id| id == rid) {
                Some(ci) if ci < next_send => ci,
                _ => {
                    return Err(WireError::Protocol(format!(
                        "response id {rid} matches no outstanding bulk frame"
                    )))
                }
            };
            if slots[ci].replace(resp).is_some() {
                return Err(WireError::Protocol(format!("duplicate response for id {rid}")));
            }
            // slide the window: one response in, the next frame out
            if next_send < chunks.len() {
                let (id, chunk) = (ids[next_send], chunks[next_send]);
                proto::write_lookup_bulk_request(&mut conn.writer, id, chunk)?;
                conn.writer.flush()?;
                next_send += 1;
            }
        }
        // reassemble in input order, whatever order the answers arrived in
        let mut out = Vec::with_capacity(total);
        for (slot, c) in slots.into_iter().zip(chunks) {
            let Some(resp) = slot else {
                return Err(WireError::Protocol("bulk frame never answered".into()));
            };
            match resp {
                Response::LookupBulk(items) => {
                    if items.len() != c.len() {
                        return Err(WireError::Protocol(format!(
                            "bulk chunk answered {} of {} tags",
                            items.len(),
                            c.len()
                        )));
                    }
                    out.extend(items);
                }
                // whole-chunk shed: every tag of the chunk gets the error
                Response::Error { code, aux } => match proto::engine_error_from_code(code, aux) {
                    Some(e) => out.extend(c.iter().map(|_| Err(e.clone()))),
                    None => {
                        return Err(WireError::Protocol(format!(
                            "bulk chunk failed with protocol code {code}"
                        )))
                    }
                },
                other => {
                    return Err(WireError::Protocol(format!(
                        "unexpected bulk response {other:?}"
                    )))
                }
            }
        }
        Ok(out)
    }

    /// Fleet statistics snapshot.
    pub fn stats(&mut self) -> Result<StatsReport, WireError> {
        match self.call_idempotent(&Request::Stats)? {
            Response::Stats(s) => Ok(*s),
            other => unexpected(other),
        }
    }

    /// Fetch the fleet's Prometheus-text metrics exposition in-band — the
    /// same document the `--metrics-addr` HTTP sidecar serves on
    /// `GET /metrics` (see [`crate::obs`]).  Idempotent, auto-retried.
    pub fn metrics(&mut self) -> Result<String, WireError> {
        match self.call_idempotent(&Request::Metrics)? {
            Response::Metrics { text } => Ok(text),
            other => unexpected(other),
        }
    }

    /// Flush all pending work on every bank.
    pub fn drain(&mut self) -> Result<(), WireError> {
        match self.call_idempotent(&Request::Drain)? {
            Response::Drained => Ok(()),
            other => unexpected(other),
        }
    }

    /// Force a fleet-wide compaction: every bank snapshots its state and
    /// truncates its WAL.  Idempotent (compacting twice is a no-op), so
    /// transport failures auto-retry.  Acks (without snapshotting) on a
    /// fleet serving without `--data-dir`.
    pub fn snapshot(&mut self) -> Result<(), WireError> {
        match self.call_idempotent(&Request::Snapshot)? {
            Response::Snapshotted => Ok(()),
            other => unexpected(other),
        }
    }

    /// Fsync every bank's WAL: after the ack, every acknowledged mutation
    /// is on disk.  Idempotent, auto-retried.
    pub fn flush(&mut self) -> Result<(), WireError> {
        match self.call_idempotent(&Request::Flush)? {
            Response::Flushed => Ok(()),
            other => unexpected(other),
        }
    }

    /// One replication-log poll: ask the feed for the log of `bank` past
    /// `(generation, offset)`, identifying as `replica` at `epoch`.
    /// Requesting an offset acknowledges everything before it.  Pass
    /// [`proto::SUBSCRIBE_BOOTSTRAP`] as the offset to request a full
    /// state transfer, and [`proto::REPL_MANIFEST_BANK`] as the bank to
    /// fetch the fleet manifest instead of a bank's log.  Idempotent
    /// (re-asking for the same suffix re-ships it), auto-retried.
    pub fn subscribe_log(
        &mut self,
        replica: u64,
        epoch: u64,
        bank: u32,
        generation: u64,
        offset: u64,
    ) -> Result<LogPoll, WireError> {
        let req = Request::SubscribeLog { replica, epoch, bank, generation, offset };
        match self.call_idempotent(&req)? {
            Response::LogBatch { bank: b, generation, next_offset, remaining, frames } => {
                if b != bank {
                    return Err(WireError::Protocol(format!(
                        "log batch for bank {b}, subscribed to bank {bank}"
                    )));
                }
                Ok(LogPoll::Batch { generation, next_offset, remaining, frames })
            }
            Response::SnapshotTransfer { bank: b, generation, image } => {
                if b != bank {
                    return Err(WireError::Protocol(format!(
                        "snapshot transfer for bank {b}, subscribed to bank {bank}"
                    )));
                }
                Ok(LogPoll::Snapshot { generation, image })
            }
            // ERR_FENCED is a wire-level verdict, not an engine error —
            // surface it as data so the replica can stop chasing cleanly
            Response::Error { code: proto::ERR_FENCED, aux } => {
                Ok(LogPoll::Fenced { server_epoch: aux })
            }
            other => unexpected(other),
        }
    }

    /// Ask the server to drain and stop; the ack means all accepted work
    /// is done.  The connection is unusable afterwards.
    pub fn shutdown(&mut self) -> Result<(), WireError> {
        let r = match self.call_once(&Request::Shutdown)? {
            Response::ShutdownAck => Ok(()),
            other => unexpected(other),
        };
        self.conn = None;
        r
    }
}

/// Map a mismatched response onto the right error: typed engine errors
/// pass through, anything else is a protocol violation.
fn unexpected<T>(resp: Response) -> Result<T, WireError> {
    match resp {
        Response::Error { code, aux } => match proto::engine_error_from_code(code, aux) {
            Some(e) => Err(WireError::Engine(e)),
            None => Err(WireError::Protocol(format!("server error code {code} (aux {aux})"))),
        },
        other => Err(WireError::Protocol(format!("unexpected response {other:?}"))),
    }
}
