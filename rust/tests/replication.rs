//! Replication integration battery: primary→replica log shipping over the
//! wire protocol, end to end.
//!
//! Every test drives a *durable primary* served over TCP with a
//! [`ReplRole::Primary`] feed attached, mirrors the same history into a
//! never-crashed in-memory reference fleet, and asserts the replica's
//! answers are bit-identical field-for-field to that reference — the same
//! oracle discipline the durability battery uses for crash recovery.
//!
//! Covered here:
//!
//! * snapshot bootstrap + log chase converging on a live primary, with
//!   wire lookups served from the replica's own reader pools;
//! * writes through a replica front-end forwarding to the primary and
//!   returning to the replica through the log (never applied locally);
//! * a primary compaction mid-stream retiring the generation a replica is
//!   tailing, forcing a [`LogPoll::Snapshot`] restart;
//! * failover: primary dies, replica is promoted offline, serves every
//!   acked write, fences the stale epoch with [`LogPoll::Fenced`], and the
//!   ex-primary rejoins the new lineage as a subscriber;
//! * clean write errors (no false acks) while a replica's upstream is
//!   down, with reads still serving.
//!
//! The graceful-stop here is deliberate: acked writes are WAL
//! write-through on the primary, so a drained stop and a `kill -9` leave
//! the same acked prefix on disk.  The actual `kill -9` variant runs in
//! CI's `replication-smoke` job against real processes.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use cscam::bits::BitVec;
use cscam::config::DesignConfig;
use cscam::coordinator::BatchPolicy;
use cscam::net::proto::SUBSCRIBE_BOOTSTRAP;
use cscam::net::{CamClient, CamTcpServer, LogPoll, NetConfig, NetServerHandle};
use cscam::repl::{promote, ReplRole, ReplicaFeed, ReplicaOptions, ReplicaServer};
use cscam::shard::{PlacementMode, ShardedCamServer, ShardedServerHandle};
use cscam::store::StoreOptions;
use cscam::util::Rng;
use cscam::workload::TagDistribution;

fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("cscam-replication-{}", std::process::id()))
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn fleet_cfg() -> DesignConfig {
    // 2 banks × 64 entries = one 128-entry fleet
    DesignConfig { m: 128, n: 32, zeta: 4, c: 3, l: 4, shards: 2, ..DesignConfig::reference() }
}

fn policy() -> BatchPolicy {
    BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(100) }
}

fn replica_opts(id: u64) -> ReplicaOptions {
    ReplicaOptions {
        replica_id: id,
        poll_interval: Duration::from_millis(2),
        ..ReplicaOptions::default()
    }
}

/// Open a durable fleet at `dir`, spawn it, and serve it over TCP with a
/// primary replication role attached (SubscribeLog answered from `dir`).
fn start_primary(dir: &Path) -> (NetServerHandle, ShardedServerHandle, String) {
    let (fleet, _recovery) = ShardedCamServer::open_durable(
        &fleet_cfg(),
        PlacementMode::TagHash,
        policy(),
        dir,
        StoreOptions::default(),
    )
    .unwrap();
    let handle = fleet.spawn();
    let feed = ReplicaFeed::open(dir).unwrap();
    let server = CamTcpServer::bind(handle.clone(), "127.0.0.1:0", NetConfig::default())
        .unwrap()
        .with_repl(Arc::new(ReplRole::Primary(feed)));
    let addr = server.local_addr().unwrap().to_string();
    let net = server.spawn().unwrap();
    (net, handle, addr)
}

/// Bind a TCP front-end over a replica's local fleet: reads serve from the
/// replica's own banks, writes forward to its upstream primary.
fn start_replica_front(replica: &ReplicaServer) -> (NetServerHandle, String) {
    let server = CamTcpServer::bind(replica.fleet(), "127.0.0.1:0", NetConfig::default())
        .unwrap()
        .with_repl(Arc::new(ReplRole::Replica(replica.forwarder())));
    let addr = server.local_addr().unwrap().to_string();
    (server.spawn().unwrap(), addr)
}

/// Poll a fleet until `tag` resolves to `want` (the log is asynchronous;
/// convergence, not instant visibility, is the contract).
fn await_addr(
    fleet: &ShardedServerHandle,
    tag: &BitVec,
    want: Option<usize>,
    timeout: Duration,
) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        if fleet.lookup(tag.clone()).unwrap().addr == want {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn replica_bootstraps_chases_and_serves_bit_identical_wire_reads() {
    let dir_p = test_dir("serve-primary");
    let dir_r = test_dir("serve-replica");
    let (net_p, _handle_p, addr_p) = start_primary(&dir_p);
    let reference = ShardedCamServer::new(&fleet_cfg(), PlacementMode::TagHash, policy()).spawn();

    // a history exists before the replica is born: bootstrap must carry it
    let mut rng = Rng::seed_from_u64(901);
    let tags = TagDistribution::Uniform.sample_distinct(fleet_cfg().n, 40, &mut rng);
    let mut client = CamClient::connect(addr_p.clone()).unwrap();
    for t in &tags {
        let a = client.insert(t).unwrap();
        let b = reference.insert(t.clone()).unwrap();
        assert_eq!(a, b as u64, "wire primary and reference placement diverged");
    }

    let replica = ReplicaServer::start(&addr_p, &dir_r, replica_opts(1)).unwrap();
    assert_eq!(replica.epoch(), 0, "fresh lineage starts at epoch 0");
    assert!(replica.wait_caught_up(Duration::from_secs(10)), "replica never converged");
    assert!(replica.fenced().is_none());

    // wire reads through the replica front-end: every stored tag plus 40
    // random probes must answer field-for-field like the reference
    let (net_r, addr_r) = start_replica_front(&replica);
    let mut rclient = CamClient::connect(addr_r).unwrap();
    for t in &tags {
        assert_eq!(rclient.lookup(t).unwrap(), reference.lookup(t.clone()).unwrap());
    }
    for _ in 0..40 {
        let t = cscam::workload::random_tag(fleet_cfg().n, &mut rng);
        assert_eq!(rclient.lookup(&t).unwrap(), reference.lookup(t).unwrap());
    }

    // the primary's exposition carries this subscriber's progress rows
    let text = client.metrics().unwrap();
    assert!(text.contains("cscam_repl_epoch 0"), "missing epoch gauge:\n{text}");
    assert!(
        text.contains(r#"cscam_repl_acked_offset{replica="1",bank="0"}"#),
        "missing acked-offset row:\n{text}"
    );
    assert!(
        text.contains(r#"cscam_repl_lag_records{replica="1",bank="1"}"#),
        "missing lag row:\n{text}"
    );

    // the replica's own status mirrors the same shape, one row per bank
    let status = replica.status();
    assert_eq!(status.epoch, 0);
    assert_eq!(status.lags.len(), 2);
    assert!(status.lags.iter().all(|l| l.replica == 1));

    net_r.shutdown();
    net_r.join();
    // the front-end stop drained the replica's banks; the chaser stop may
    // find them already gone, which is fine here
    let _ = replica.shutdown();
    client.shutdown().unwrap();
    net_p.join();
}

#[test]
fn writes_through_a_replica_forward_to_the_primary_and_return_through_the_log() {
    let dir_p = test_dir("forward-primary");
    let dir_r = test_dir("forward-replica");
    let (net_p, _handle_p, addr_p) = start_primary(&dir_p);
    let reference = ShardedCamServer::new(&fleet_cfg(), PlacementMode::TagHash, policy()).spawn();
    let mut rng = Rng::seed_from_u64(904);
    let tags = TagDistribution::Uniform.sample_distinct(fleet_cfg().n, 8, &mut rng);

    let replica = ReplicaServer::start(&addr_p, &dir_r, replica_opts(5)).unwrap();
    let (net_rf, addr_rf) = start_replica_front(&replica);
    let mut rclient = CamClient::connect(addr_rf).unwrap();
    let mut pclient = CamClient::connect(addr_p.clone()).unwrap();

    // inserts through the replica's front door are acked by the primary
    // (same placement as the reference) and visible there immediately…
    let mut addrs = Vec::new();
    for t in &tags {
        let a = rclient.insert(t).unwrap();
        assert_eq!(a, reference.insert(t.clone()).unwrap() as u64, "forwarded placement diverged");
        assert_eq!(pclient.lookup(t).unwrap().addr, Some(a as usize));
        addrs.push(a);
    }
    // …and return to the replica through the log, never applied locally
    for (t, a) in tags.iter().zip(&addrs) {
        assert!(
            await_addr(&replica.fleet(), t, Some(*a as usize), Duration::from_secs(10)),
            "forwarded insert never arrived through the log"
        );
    }
    // forwarded deletes take the same round trip
    rclient.delete(addrs[0]).unwrap();
    reference.delete(addrs[0] as usize).unwrap();
    assert!(
        await_addr(&replica.fleet(), &tags[0], None, Duration::from_secs(10)),
        "forwarded delete never arrived through the log"
    );
    // converged: wire reads through the replica match the reference
    for t in &tags {
        assert_eq!(rclient.lookup(t).unwrap(), reference.lookup(t.clone()).unwrap());
    }

    net_rf.shutdown();
    net_rf.join();
    let _ = replica.shutdown();
    pclient.shutdown().unwrap();
    net_p.join();
}

#[test]
fn mid_stream_compaction_restarts_the_replica_from_a_snapshot_transfer() {
    let dir_p = test_dir("compact-primary");
    let dir_r = test_dir("compact-replica");
    let (net_p, _handle_p, addr_p) = start_primary(&dir_p);
    let reference = ShardedCamServer::new(&fleet_cfg(), PlacementMode::TagHash, policy()).spawn();

    let mut rng = Rng::seed_from_u64(902);
    let tags = TagDistribution::Uniform.sample_distinct(fleet_cfg().n, 45, &mut rng);
    let mut client = CamClient::connect(addr_p.clone()).unwrap();
    for t in tags.iter().take(20) {
        assert_eq!(client.insert(t).unwrap(), reference.insert(t.clone()).unwrap() as u64);
    }
    let replica = ReplicaServer::start(&addr_p, &dir_r, replica_opts(2)).unwrap();
    assert!(replica.wait_caught_up(Duration::from_secs(10)), "initial chase never converged");

    // compaction resets every bank's log to generation 1 while the
    // replica holds generation-0 cursors; writes land before and after,
    // so the stale cursors are unreachable and only the Snapshot restart
    // path can make the replica whole again
    for t in tags.iter().skip(20).take(10) {
        assert_eq!(client.insert(t).unwrap(), reference.insert(t.clone()).unwrap() as u64);
    }
    client.snapshot().unwrap();
    for t in tags.iter().skip(30) {
        assert_eq!(client.insert(t).unwrap(), reference.insert(t.clone()).unwrap() as u64);
    }

    // wait on actual state, not the caught-up flag (which may be stale
    // from before the burst): the last insert must arrive
    let last = tags.last().unwrap();
    let want = reference.lookup(last.clone()).unwrap().addr;
    assert!(
        await_addr(&replica.fleet(), last, want, Duration::from_secs(10)),
        "replica never crossed the generation bump"
    );
    assert!(replica.fenced().is_none());

    // bit-identical across the whole history plus random probes
    for t in &tags {
        assert_eq!(replica.fleet().lookup(t.clone()).unwrap(), reference.lookup(t.clone()).unwrap());
    }
    for _ in 0..40 {
        let t = cscam::workload::random_tag(fleet_cfg().n, &mut rng);
        assert_eq!(replica.fleet().lookup(t.clone()).unwrap(), reference.lookup(t).unwrap());
    }

    replica.shutdown().unwrap();
    client.shutdown().unwrap();
    net_p.join();
}

#[test]
fn failover_promotes_the_replica_without_losing_acked_writes_and_fences_the_old_epoch() {
    let dir_p = test_dir("failover-primary");
    let dir_r = test_dir("failover-replica");
    let (net_p, _handle_p, addr_p) = start_primary(&dir_p);
    let reference = ShardedCamServer::new(&fleet_cfg(), PlacementMode::TagHash, policy()).spawn();

    let mut rng = Rng::seed_from_u64(903);
    let tags = TagDistribution::Uniform.sample_distinct(fleet_cfg().n, 32, &mut rng);
    let mut client = CamClient::connect(addr_p.clone()).unwrap();

    // 30 acked writes: half before the replica exists, half while it is
    // chasing; plus a few acked deletes so failover carries those too
    let mut acked = Vec::new();
    for t in tags.iter().take(15) {
        let a = client.insert(t).unwrap();
        assert_eq!(a, reference.insert(t.clone()).unwrap() as u64);
        acked.push((t.clone(), a));
    }
    let replica = ReplicaServer::start(&addr_p, &dir_r, replica_opts(3)).unwrap();
    for t in tags.iter().skip(15).take(15) {
        let a = client.insert(t).unwrap();
        assert_eq!(a, reference.insert(t.clone()).unwrap() as u64);
        acked.push((t.clone(), a));
    }
    for (_, a) in acked.iter().take(3) {
        client.delete(*a).unwrap();
        reference.delete(*a as usize).unwrap();
    }

    // wait on state, not the flag: last insert present AND first delete
    // applied means the probed banks converged…
    let last = acked.last().unwrap();
    assert!(
        await_addr(&replica.fleet(), &last.0, Some(last.1 as usize), Duration::from_secs(10))
            && await_addr(&replica.fleet(), &acked[0].0, None, Duration::from_secs(10)),
        "replica never converged before the failover"
    );
    // …and every bank's reported lag draining to zero means the whole
    // acked history was read and applied (the cursor only advances past
    // records that applied)
    let deadline = Instant::now() + Duration::from_secs(10);
    while replica.status().lags.iter().any(|l| l.lag_records > 0) {
        assert!(Instant::now() < deadline, "per-bank lag never drained: {:?}", replica.status());
        std::thread::sleep(Duration::from_millis(2));
    }

    // the primary dies (drained stop — acked writes are WAL write-through,
    // so the on-disk acked prefix is the same as after a kill -9; CI's
    // replication-smoke job covers the literal kill)
    net_p.shutdown();
    net_p.join();

    // reads keep serving off the orphaned replica; a write with a dead
    // primary must fail cleanly, never false-ack
    let (net_rf, addr_rf) = start_replica_front(&replica);
    let mut rclient = CamClient::connect(addr_rf).unwrap();
    assert_eq!(rclient.lookup(&acked[5].0).unwrap(), reference.lookup(acked[5].0.clone()).unwrap());
    let orphan = cscam::workload::random_tag(fleet_cfg().n, &mut rng);
    assert!(rclient.insert(&orphan).is_err(), "a write with a dead primary must not be acked");
    net_rf.shutdown();
    net_rf.join();
    let _ = replica.shutdown();

    // offline promotion bumps the manifest epoch: 0 → 1
    assert_eq!(promote(&dir_r).unwrap(), 1);

    // the promoted directory serves as the new writable primary: every
    // acked write answers exactly like the never-crashed reference
    let (net_c, _handle_c, addr_c) = start_primary(&dir_r);
    let mut c = CamClient::connect(addr_c.clone()).unwrap();
    for (i, (t, a)) in acked.iter().enumerate() {
        let got = c.lookup(t).unwrap();
        assert_eq!(got, reference.lookup(t.clone()).unwrap(), "acked write {i} diverged");
        if i >= 3 {
            assert_eq!(got.addr, Some(*a as usize), "acked write {i} lost in failover");
        } else {
            assert_eq!(got.addr, None, "acked delete {i} lost in failover");
        }
    }
    // and accepts new writes on the new lineage
    let a31 = c.insert(&tags[31]).unwrap();
    assert_eq!(a31, reference.insert(tags[31].clone()).unwrap() as u64);

    // a subscriber still on epoch 0 — the crashed ex-primary rejoining in
    // its old role — is refused with the fence, which names the new epoch
    match c.subscribe_log(99, 0, 0, 0, SUBSCRIBE_BOOTSTRAP).unwrap() {
        LogPoll::Fenced { server_epoch } => assert_eq!(server_epoch, 1),
        other => panic!("stale-epoch subscriber answered {other:?} instead of Fenced"),
    }

    // the correct rejoin path: subscribe fresh, adopt epoch 1 through the
    // manifest, and converge on the new lineage — here straight into the
    // ex-primary's own directory, overwriting its fenced state
    let rejoin = ReplicaServer::start(&addr_c, &dir_p, replica_opts(4)).unwrap();
    assert_eq!(rejoin.epoch(), 1, "rejoin must adopt the promoted epoch");
    assert!(
        await_addr(&rejoin.fleet(), &tags[31], Some(a31 as usize), Duration::from_secs(10)),
        "rejoined ex-primary never converged on the new lineage"
    );
    assert!(rejoin.fenced().is_none());
    for (i, (t, _)) in acked.iter().enumerate() {
        assert_eq!(
            rejoin.fleet().lookup(t.clone()).unwrap(),
            reference.lookup(t.clone()).unwrap(),
            "rejoined replica diverged on acked write {i}"
        );
    }

    rejoin.shutdown().unwrap();
    c.shutdown().unwrap();
    net_c.join();
}
