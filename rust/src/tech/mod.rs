//! CMOS technology nodes and inter-node scaling.
//!
//! The paper evaluates at 0.13 µm / 1.2 V and projects the proposed design to
//! 90 nm / 1.0 V "using the method in [6]" (Huang & Hwang, JSSC 2011).  The
//! projected numbers in the paper (0.060 fJ/bit/search, 0.582 ns from
//! 0.124 fJ/bit/search, 0.70 ns) pin the method down exactly:
//!
//! ```text
//!   energy scale = (L / L0) · (V / V0)²      (switched capacitance C·V²,
//!                                             C ∝ feature size)
//!   delay  scale = (L / L0) · (V0 / V)       (gate delay ∝ C·V / I,
//!                                             I ∝ V² ⇒ t ∝ L / V)
//! ```
//!
//! `0.124 · (90/130) · (1.0/1.2)² = 0.0596 ≈ 0.060` and
//! `0.70 · (90/130) · (1.2/1.0) = 0.5815 ≈ 0.582` — both match the paper to
//! rounding. [`scale_energy`] / [`scale_delay`] implement these rules and are
//! unit-tested against the paper's projection.


/// A CMOS process node, the knobs the energy/delay models depend on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TechNode {
    /// Human name, e.g. "0.13um".
    pub name: &'static str,
    /// Drawn feature size in nanometres.
    pub feature_nm: f64,
    /// Nominal supply voltage in volts.
    pub vdd: f64,
    /// Fanout-of-4 inverter delay in picoseconds — the unit of the
    /// logical-effort delay model in [`crate::timing`].
    pub fo4_ps: f64,
}

/// 0.18 µm node (PF-CDPD [12] silicon).
pub const NODE_180NM: TechNode = TechNode {
    name: "0.18um",
    feature_nm: 180.0,
    vdd: 1.8,
    fo4_ps: 70.0,
};

/// 0.13 µm node — the paper's SPECTRE testbed (1.2 V).
pub const NODE_130NM: TechNode = TechNode {
    name: "0.13um",
    feature_nm: 130.0,
    vdd: 1.2,
    fo4_ps: 50.0,
};

/// 90 nm node at 1.0 V — the paper's projection target (as in [3]/[6]).
pub const NODE_90NM: TechNode = TechNode {
    name: "90nm",
    feature_nm: 90.0,
    vdd: 1.0,
    fo4_ps: 35.0,
};

/// 65 nm node (the [6] TCAM macro).
pub const NODE_65NM: TechNode = TechNode {
    name: "65nm",
    feature_nm: 65.0,
    vdd: 1.0,
    fo4_ps: 25.0,
};

/// 32 nm node (HS-WA [1] silicon).
pub const NODE_32NM: TechNode = TechNode {
    name: "32nm",
    feature_nm: 32.0,
    vdd: 0.9,
    fo4_ps: 14.0,
};

/// All nodes known to the simulator, coarsest first.
pub const ALL_NODES: [TechNode; 5] = [NODE_180NM, NODE_130NM, NODE_90NM, NODE_65NM, NODE_32NM];

/// Look a node up by name ("0.13um", "90nm", …).
pub fn node_by_name(name: &str) -> Option<TechNode> {
    ALL_NODES.iter().copied().find(|n| {
        n.name.eq_ignore_ascii_case(name)
            || n.name.trim_end_matches("um").trim_end_matches("nm") == name
    })
}

/// Scale a dynamic energy measured at `from` to node `to`
/// (method of [6]: E ∝ L·V²).
pub fn scale_energy(energy: f64, from: TechNode, to: TechNode) -> f64 {
    energy * (to.feature_nm / from.feature_nm) * (to.vdd / from.vdd).powi(2)
}

/// Scale a delay measured at `from` to node `to`
/// (method of [6]: t ∝ L/V).
pub fn scale_delay(delay: f64, from: TechNode, to: TechNode) -> f64 {
    delay * (to.feature_nm / from.feature_nm) * (from.vdd / to.vdd)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_90nm_energy_projection() {
        // §IV: 0.124 fJ/bit/search @ 0.13 µm/1.2 V → 0.060 @ 90 nm/1.0 V.
        let e = scale_energy(0.124, NODE_130NM, NODE_90NM);
        assert!((e - 0.060).abs() < 0.001, "got {e}");
    }

    #[test]
    fn paper_90nm_delay_projection() {
        // §IV: 0.70 ns @ 0.13 µm/1.2 V → 0.582 ns @ 90 nm/1.0 V.
        let d = scale_delay(0.70, NODE_130NM, NODE_90NM);
        assert!((d - 0.582).abs() < 0.001, "got {d}");
    }

    #[test]
    fn scaling_is_identity_on_same_node() {
        assert_eq!(scale_energy(1.3, NODE_130NM, NODE_130NM), 1.3);
        assert_eq!(scale_delay(2.3, NODE_130NM, NODE_130NM), 2.3);
    }

    #[test]
    fn scaling_composes() {
        // 0.13µm → 90nm → 65nm equals 0.13µm → 65nm.
        let direct = scale_energy(1.0, NODE_130NM, NODE_65NM);
        let via = scale_energy(scale_energy(1.0, NODE_130NM, NODE_90NM), NODE_90NM, NODE_65NM);
        assert!((direct - via).abs() < 1e-12);
    }

    #[test]
    fn node_lookup() {
        assert_eq!(node_by_name("90nm"), Some(NODE_90NM));
        assert_eq!(node_by_name("0.13um"), Some(NODE_130NM));
        assert_eq!(node_by_name("7nm"), None);
    }

    #[test]
    fn smaller_nodes_are_cheaper_and_faster() {
        for pair in ALL_NODES.windows(2) {
            let (a, b) = (pair[0], pair[1]);
            assert!(scale_energy(1.0, a, b) < 1.0, "{} -> {}", a.name, b.name);
            // delay also shrinks whenever V doesn't drop too fast; true for our ladder
            assert!(scale_delay(1.0, a, b) < 1.1, "{} -> {}", a.name, b.name);
        }
    }
}
