//! Shard placement: which bank owns a tag.
//!
//! Inserts and deletes must land deterministically so later lookups find
//! them; lookups either go straight to the owner (one bank burns energy —
//! the scale-out analogue of the paper's compare-enable gating) or fan out
//! to every bank when no owner exists.  Three modes:
//!
//! * [`PlacementMode::TagHash`] — stable FNV-1a over the packed tag words;
//!   uniform populations balance automatically;
//! * [`PlacementMode::LearnedPrefix`] — the bank index is read from a
//!   data-driven bit selection ([`Selection`], reusing `cnn/bitselect`):
//!   high-entropy, low-correlation bits keep *skewed* tag populations
//!   balanced where hashing a handful of fixed fields would not, and the
//!   placement stays a trivial hardware function (a k-bit mux);
//! * [`PlacementMode::Broadcast`] — no owner: inserts round-robin across
//!   banks, lookups scatter-gather over the whole fleet.

use crate::bits::BitVec;
use crate::cnn::Selection;

/// How the router maps tags to banks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlacementMode {
    /// Stable FNV-1a tag-hash; lookups touch exactly one bank.
    TagHash,
    /// Bank index decoded from a learned bit selection; lookups touch
    /// exactly one bank.
    LearnedPrefix(Selection),
    /// No owner: inserts round-robin, lookups fan out to every bank.
    Broadcast,
}

impl PlacementMode {
    /// Learn a placement prefix from a tag sample: pick
    /// `ceil(log2(shards)) + 2` bits maximizing marginal entropy
    /// (penalizing correlation with bits already picked), so banks stay
    /// balanced even on low-entropy populations such as
    /// [`crate::workload::TagDistribution::Correlated`].  The two extra
    /// bits oversample the index: `value % shards` is exact for
    /// power-of-two shard counts and within ~10 % of uniform otherwise
    /// (a bare `ceil(log2(S))`-bit value would send double traffic to the
    /// low banks when `S` is not a power of two).
    pub fn learned(shards: usize, sample: &[BitVec], n: usize) -> Self {
        let k = ((shards.max(2) as f64).log2().ceil() as usize + 2).min(n).min(16);
        PlacementMode::LearnedPrefix(Selection::entropy_greedy(sample, n, 1, k))
    }
}

/// Places inserts/deletes/lookups on banks: the routing front-end of the
/// sharded fleet.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardRouter {
    shards: usize,
    mode: PlacementMode,
}

impl ShardRouter {
    pub fn new(shards: usize, mode: PlacementMode) -> Self {
        assert!(shards > 0, "need at least one shard");
        ShardRouter { shards, mode }
    }

    /// Stable tag-hash placement.
    pub fn tag_hash(shards: usize) -> Self {
        Self::new(shards, PlacementMode::TagHash)
    }

    /// Broadcast (ownerless) placement.
    pub fn broadcast(shards: usize) -> Self {
        Self::new(shards, PlacementMode::Broadcast)
    }

    /// Learned-prefix placement (see [`PlacementMode::learned`]).
    pub fn learned(shards: usize, sample: &[BitVec], n: usize) -> Self {
        Self::new(shards, PlacementMode::learned(shards, sample, n))
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn mode(&self) -> &PlacementMode {
        &self.mode
    }

    pub fn is_broadcast(&self) -> bool {
        matches!(self.mode, PlacementMode::Broadcast)
    }

    /// The owning bank of a tag, or `None` in broadcast mode.
    pub fn place(&self, tag: &BitVec) -> Option<usize> {
        match &self.mode {
            PlacementMode::TagHash => Some((fnv1a(tag) % self.shards as u64) as usize),
            PlacementMode::LearnedPrefix(sel) => Some(sel.apply(tag)[0] as usize % self.shards),
            PlacementMode::Broadcast => None,
        }
    }

    /// Partition a tag population by owning bank (broadcast: round-robin),
    /// e.g. to build per-bank query pools for the hot-shard workload.
    pub fn partition(&self, tags: &[BitVec]) -> Vec<Vec<BitVec>> {
        let mut out = vec![Vec::new(); self.shards];
        for (i, t) in tags.iter().enumerate() {
            let b = self.place(t).unwrap_or(i % self.shards);
            out[b].push(t.clone());
        }
        out
    }
}

// The hash itself lives in `util::hash` (the wire protocol checksums
// frames with the same definition); re-exported here because placement is
// where its stability contract bites hardest.
pub use crate::util::hash::fnv1a;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workload::TagDistribution;

    #[test]
    fn tag_hash_is_deterministic_and_roughly_balanced() {
        let r = ShardRouter::tag_hash(4);
        let mut rng = Rng::seed_from_u64(1);
        let tags = TagDistribution::Uniform.sample_distinct(32, 200, &mut rng);
        let mut counts = [0usize; 4];
        for t in &tags {
            let b = r.place(t).unwrap();
            assert_eq!(r.place(t), Some(b), "placement must be stable");
            counts[b] += 1;
        }
        for c in counts {
            assert!((20..90).contains(&c), "imbalanced: {counts:?}");
        }
    }

    #[test]
    fn learned_prefix_balances_correlated_tags() {
        // Constant high field + mirrored low bits: a placement reading fixed
        // positions could land everything on one bank; the entropy-greedy
        // selection picks varying, uncorrelated bits instead.
        let mut rng = Rng::seed_from_u64(2);
        let dist = TagDistribution::Correlated { fixed_bits: 12, mirror_span: 8 };
        let tags = dist.sample_distinct(32, 240, &mut rng);
        let r = ShardRouter::learned(4, &tags, 32);
        let counts = r.partition(&tags);
        for (b, pool) in counts.iter().enumerate() {
            assert!(
                (24..140).contains(&pool.len()),
                "bank {b} holds {} of 240",
                pool.len()
            );
        }
        // deterministic
        let t = &tags[17];
        assert_eq!(r.place(t), r.place(t));
    }

    #[test]
    fn learned_prefix_stays_balanced_for_non_power_of_two_shards() {
        // 3 banks from a 4-bit oversampled index: 16 % 3 leaves at most a
        // 6/16-vs-5/16 skew, nothing like the 2x bias of a bare 2-bit index.
        let mut rng = Rng::seed_from_u64(5);
        let tags = TagDistribution::Uniform.sample_distinct(32, 300, &mut rng);
        let r = ShardRouter::learned(3, &tags, 32);
        let parts = r.partition(&tags);
        for (b, pool) in parts.iter().enumerate() {
            assert!((60..=145).contains(&pool.len()), "bank {b}: {}", pool.len());
        }
    }

    #[test]
    fn single_shard_router_is_a_passthrough() {
        // S = 1: every owner mode must resolve to bank 0 for every tag (a
        // degenerate fleet is just the monolith), and broadcast stays
        // ownerless — its scatter path then touches the one bank.
        let mut rng = Rng::seed_from_u64(9);
        let tags = TagDistribution::Uniform.sample_distinct(32, 40, &mut rng);
        let hash = ShardRouter::tag_hash(1);
        let learned = ShardRouter::learned(1, &tags, 32);
        for t in &tags {
            assert_eq!(hash.place(t), Some(0));
            assert_eq!(learned.place(t), Some(0));
        }
        assert_eq!(hash.partition(&tags)[0].len(), 40);
        let bcast = ShardRouter::broadcast(1);
        assert_eq!(bcast.place(&tags[0]), None, "broadcast never names an owner");
        assert_eq!(bcast.partition(&tags).len(), 1);
        assert_eq!(bcast.partition(&tags)[0].len(), 40);
    }

    #[test]
    fn broadcast_has_no_owner_and_partitions_round_robin() {
        let r = ShardRouter::broadcast(3);
        let mut rng = Rng::seed_from_u64(3);
        let tags = TagDistribution::Uniform.sample_distinct(32, 9, &mut rng);
        assert!(r.is_broadcast());
        assert_eq!(r.place(&tags[0]), None);
        let parts = r.partition(&tags);
        assert_eq!(parts.iter().map(Vec::len).collect::<Vec<_>>(), vec![3, 3, 3]);
    }

    #[test]
    fn fnv_differs_across_tags() {
        let mut rng = Rng::seed_from_u64(4);
        let tags = TagDistribution::Uniform.sample_distinct(64, 50, &mut rng);
        let mut hashes = std::collections::HashSet::new();
        for t in &tags {
            hashes.insert(fnv1a(t));
        }
        assert_eq!(hashes.len(), 50, "50 distinct tags should not collide");
    }
}
