//! cscam maintenance tasks, invoked as `cargo xtask <command>`.
//!
//! * `lint` — run the cross-file invariant analyzer over the working tree
//!   and exit non-zero if any invariant is broken.  See [`lint`] for what
//!   is checked and for the `// lint:allow(reason)` escape hatch.
//! * `bench-gate` — compare a freshly measured `BENCH_*.json` trajectory
//!   against the committed baseline and exit non-zero on a throughput
//!   regression beyond the threshold.  See [`bench_gate`].

mod bench_gate;
mod lint;

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: cargo xtask <lint [--root <dir>] | \
                     bench-gate --baseline <file> --fresh <file> [--threshold <pct>]>";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => run_lint(&args[1..]),
        Some("bench-gate") => run_bench_gate(&args[1..]),
        _ => {
            eprintln!("{USAGE}");
            ExitCode::from(2)
        }
    }
}

fn run_lint(args: &[String]) -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--root" => match it.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    eprintln!("xtask lint: --root needs a directory");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("xtask lint: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
    }
    if !root.join("rust/src").is_dir() {
        eprintln!(
            "xtask lint: `{}` does not look like the repo root (no rust/src); \
             run from the workspace root or pass --root",
            root.display()
        );
        return ExitCode::from(2);
    }
    let violations = lint::run(&root);
    for v in &violations {
        eprintln!("{v}");
    }
    if violations.is_empty() {
        eprintln!("xtask lint: all cross-file invariants hold");
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

fn run_bench_gate(args: &[String]) -> ExitCode {
    let mut baseline = None;
    let mut fresh = None;
    let mut threshold = 15.0_f64;
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        let mut take = |what: &str| match it.next() {
            Some(v) => Some(v.clone()),
            None => {
                eprintln!("xtask bench-gate: {what} needs a value");
                None
            }
        };
        match arg.as_str() {
            "--baseline" => match take("--baseline") {
                Some(v) => baseline = Some(v),
                None => return ExitCode::from(2),
            },
            "--fresh" => match take("--fresh") {
                Some(v) => fresh = Some(v),
                None => return ExitCode::from(2),
            },
            "--threshold" => match take("--threshold").map(|v| v.parse::<f64>()) {
                Some(Ok(v)) if v.is_finite() && v >= 0.0 => threshold = v,
                _ => {
                    eprintln!("xtask bench-gate: --threshold takes a percentage >= 0");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("xtask bench-gate: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
    }
    let (Some(baseline), Some(fresh)) = (baseline, fresh) else {
        eprintln!("xtask bench-gate: --baseline and --fresh are both required\n{USAGE}");
        return ExitCode::from(2);
    };
    let read = |path: &str| match std::fs::read_to_string(path) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("xtask bench-gate: cannot read {path}: {e}");
            None
        }
    };
    let (Some(base_text), Some(fresh_text)) = (read(&baseline), read(&fresh)) else {
        return ExitCode::from(2);
    };
    let out = bench_gate::gate(&base_text, &fresh_text, threshold);
    for w in &out.warnings {
        eprintln!("xtask bench-gate: warning: {w}");
    }
    for f in &out.failures {
        eprintln!("xtask bench-gate: FAIL: {f}");
    }
    if out.passed() {
        eprintln!(
            "xtask bench-gate: {} scenario(s) compared, none regressed beyond {threshold} %",
            out.compared
        );
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask bench-gate: {} regression(s)", out.failures.len());
        ExitCode::FAILURE
    }
}
