//! TLB simulation — the paper's first motivating application (§I: "this
//! power inefficiency has constrained TLBs to be limited to no more than
//! 512 entries in current processors").
//!
//! Simulates a 512-entry proposed-architecture TLB in front of a synthetic
//! process address stream (working set + sequential strides + cold pages),
//! with FIFO replacement on miss, and compares the per-access CAM energy
//! against conventional NAND and NOR TLBs serving the identical stream.
//!
//! Run: `cargo run --release --example tlb_simulation`

use cscam::cam::MatchlineKind;
use cscam::config::DesignConfig;
use cscam::coordinator::LookupEngine;
use cscam::energy::{conventional_search_energy, CalibrationConstants};
use cscam::stats::OnlineStats;
use cscam::util::Rng;
use cscam::workload::TlbTrace;

fn main() -> anyhow::Result<()> {
    // 52-bit VPN tags (x86-64 4 KiB pages), zero-extended into a 128-bit
    // tag CAM.  §II-B in practice: the default strided selection would pick
    // reduced-tag bits from the always-zero upper half (massive correlation
    // → every stored page becomes an ambiguity), so the q bits are strided
    // across the *valid* 52-bit window instead.
    let cfg = DesignConfig { n: 128, ..DesignConfig::reference() };
    let vpn_bits = 52usize;
    let sel = cscam::cnn::Selection::explicit(
        (0..cfg.q()).map(|i| i * vpn_bits / cfg.q()).collect(),
        cfg.k(),
    );
    let mut engine = LookupEngine::with_selection(cfg.clone(), sel);

    let mut rng = Rng::seed_from_u64(86);
    let accesses = 50_000;
    let (trace, _) = TlbTrace {
        n: vpn_bits,
        working_set: 400,
        p_sequential: 0.25,
        p_new: 0.004,
    }
    .generate(accesses, &mut rng);

    let widen = |vpn: &cscam::bits::BitVec| {
        let mut t = cscam::bits::BitVec::zeros(cfg.n);
        for i in vpn.iter_ones() {
            t.set(i, true);
        }
        t
    };

    let mut resident: Vec<Option<cscam::bits::BitVec>> = vec![None; cfg.m];
    let mut victim = 0usize;
    let (mut hits, mut misses) = (0u64, 0u64);
    let mut energy = OnlineStats::new();
    let mut lambda = OnlineStats::new();
    let mut comparisons = OnlineStats::new();

    for vpn in &trace {
        let tag = widen(vpn);
        let out = engine.lookup(&tag)?;
        energy.push(out.energy.total_fj());
        lambda.push(out.lambda as f64);
        comparisons.push(out.comparisons as f64);
        match out.addr {
            Some(_) => hits += 1,
            None => {
                misses += 1;
                engine.insert_at(victim, &tag)?;
                resident[victim] = Some(tag);
                victim = (victim + 1) % cfg.m;
            }
        }
    }

    let calib = CalibrationConstants::reference_130nm();
    let e_nand =
        conventional_search_energy(cfg.m, cfg.n, MatchlineKind::Nand, &calib).total_fj();
    let e_nor = conventional_search_energy(cfg.m, cfg.n, MatchlineKind::Nor, &calib).total_fj();

    println!("# TLB simulation — {} accesses, {}-entry proposed-architecture TLB", accesses, cfg.m);
    println!("hit ratio          : {:.1} %", 100.0 * hits as f64 / (hits + misses) as f64);
    println!("mean λ             : {:.3}", lambda.mean());
    println!("mean comparisons   : {:.2} of {} rows", comparisons.mean(), cfg.m);
    println!(
        "mean search energy : {:.1} fJ  ({:.4} fJ/bit/search)",
        energy.mean(),
        energy.mean() / (cfg.m * cfg.n) as f64
    );
    println!("\n# per-access CAM energy on the identical stream");
    println!("proposed : {:>10.1} fJ   (1.00×)", energy.mean());
    println!("Ref NAND : {:>10.1} fJ   ({:.2}×)", e_nand, e_nand / energy.mean());
    println!("Ref NOR  : {:>10.1} fJ   ({:.2}×)", e_nor, e_nor / energy.mean());
    println!(
        "\nTLB energy saved vs NAND: {:.1} %  (paper's headline: 90.5 %)",
        100.0 * (1.0 - energy.mean() / e_nand)
    );
    Ok(())
}
