// Fixture error-code map: only Full is wired up.

pub const ERR_FULL: u16 = 1;

pub fn engine_error_code(e: &EngineError) -> (u16, u64) {
    match e {
        EngineError::Full => (ERR_FULL, 0),
        _ => (0, 0),
    }
}

pub fn engine_error_from_code(code: u16, _aux: u64) -> Option<EngineError> {
    match code {
        ERR_FULL => Some(EngineError::Full),
        _ => None,
    }
}
