//! Silicon-area estimation (µm²) — makes §III-B's "not too many sub-blocks
//! to expand the layout and complicate the interconnections" quantitative.
//!
//! Transistor counts alone miss the cost the paper's criterion 1 is about:
//! each sub-block adds a compare-enable line that must be *routed* across
//! the array width, and the block decoder/driver column grows with β.  This
//! module prices cells by layout area and wiring by track length × pitch,
//! which is what actually limits β in a real floorplan.
//!
//! All areas at the reference node (0.13 µm); scale with the square of the
//! feature-size ratio for other nodes.

use crate::cam::CellKind;
use crate::config::DesignConfig;
use crate::tech::TechNode;

/// Layout constants at 0.13 µm (standard-cell / compiled-macro ballparks).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaConstants {
    /// CAM cell footprint, µm² (9T XOR ≈ 10T NAND to first order).
    pub cam_cell_um2: f64,
    /// 6T SRAM bit, µm².
    pub sram_bit_um2: f64,
    /// Generic logic per transistor, µm² (routed standard cell).
    pub logic_per_t_um2: f64,
    /// Metal routing pitch, µm (one track's width+space).
    pub wire_pitch_um: f64,
    /// CAM cell pitch, µm (row height ≈ column width for a square-ish cell).
    pub cell_pitch_um: f64,
}

impl AreaConstants {
    pub const fn reference_130nm() -> Self {
        AreaConstants {
            cam_cell_um2: 5.5,
            sram_bit_um2: 2.5,
            logic_per_t_um2: 0.9,
            wire_pitch_um: 0.41,
            cell_pitch_um: 2.4,
        }
    }
}

impl Default for AreaConstants {
    fn default() -> Self {
        Self::reference_130nm()
    }
}

/// Area report, µm².
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AreaReport {
    /// CAM tag array.
    pub cam_array_um2: f64,
    /// Output data SRAM.
    pub data_sram_um2: f64,
    /// CNN weight SRAM.
    pub cnn_sram_um2: f64,
    /// CNN + CAM peripheral logic.
    pub logic_um2: f64,
    /// Compare-enable distribution: β horizontal lines spanning the array
    /// width plus the vertical enable trunk spanning the array height.
    pub enable_routing_um2: f64,
}

impl AreaReport {
    pub fn total_um2(&self) -> f64 {
        self.cam_array_um2
            + self.data_sram_um2
            + self.cnn_sram_um2
            + self.logic_um2
            + self.enable_routing_um2
    }
}

/// Area of the proposed design at the reference node.
pub fn proposed_area(cfg: &DesignConfig, k: &AreaConstants) -> AreaReport {
    let t = super::proposed_count(cfg, &super::TransistorAssumptions::default());
    // array width spans N tag bits (+ data), height spans M rows
    let array_width_um = cfg.n as f64 * k.cell_pitch_um;
    let array_height_um = cfg.m as f64 * k.cell_pitch_um;
    AreaReport {
        cam_array_um2: (cfg.m * cfg.n) as f64 * k.cam_cell_um2,
        data_sram_um2: t.data_sram as f64 / 6.0 * k.sram_bit_um2,
        cnn_sram_um2: (cfg.c * cfg.l * cfg.m) as f64 * k.sram_bit_um2,
        logic_um2: (t.cam_periphery + t.cnn_logic) as f64 * k.logic_per_t_um2,
        // β horizontal enable lines across the array width + one vertical
        // trunk per block column down the array height
        enable_routing_um2: cfg.beta() as f64 * array_width_um * k.wire_pitch_um
            + array_height_um * k.wire_pitch_um,
    }
}

/// Area of the conventional design (no CNN, no enable routing).
pub fn conventional_area(cfg: &DesignConfig, cell: CellKind, k: &AreaConstants) -> AreaReport {
    let t = super::conventional_count(cfg.m, cfg.n, cell, &super::TransistorAssumptions::default());
    AreaReport {
        cam_array_um2: (cfg.m * cfg.n) as f64 * k.cam_cell_um2,
        data_sram_um2: t.data_sram as f64 / 6.0 * k.sram_bit_um2,
        cnn_sram_um2: 0.0,
        logic_um2: t.cam_periphery as f64 * k.logic_per_t_um2,
        enable_routing_um2: 0.0,
    }
}

/// Area overhead of the proposed design vs the conventional NAND macro.
pub fn area_overhead_vs_nand(cfg: &DesignConfig, k: &AreaConstants) -> f64 {
    proposed_area(cfg, k).total_um2() / conventional_area(cfg, CellKind::Nand10T, k).total_um2()
        - 1.0
}

/// Scale a report to another node (area ∝ L²).
pub fn scale_area(report: &AreaReport, from: TechNode, to: TechNode) -> AreaReport {
    let s = (to.feature_nm / from.feature_nm).powi(2);
    AreaReport {
        cam_array_um2: report.cam_array_um2 * s,
        data_sram_um2: report.data_sram_um2 * s,
        cnn_sram_um2: report.cnn_sram_um2 * s,
        logic_um2: report.logic_um2 * s,
        enable_routing_um2: report.enable_routing_um2 * s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DesignConfig;

    fn k() -> AreaConstants {
        AreaConstants::reference_130nm()
    }

    #[test]
    fn reference_area_overhead_is_single_digit_percent() {
        // Consistent with the transistor-count picture (paper: +3.4 %).
        let ovh = area_overhead_vs_nand(&DesignConfig::reference(), &k());
        assert!((0.01..0.12).contains(&ovh), "area overhead {ovh}");
    }

    #[test]
    fn enable_routing_grows_linearly_with_beta() {
        let a8 = proposed_area(&DesignConfig { zeta: 8, ..DesignConfig::reference() }, &k());
        let a4 = proposed_area(&DesignConfig { zeta: 4, ..DesignConfig::reference() }, &k());
        let a2 = proposed_area(&DesignConfig { zeta: 2, ..DesignConfig::reference() }, &k());
        // halving ζ doubles β and (asymptotically) the horizontal routing
        let d84 = a4.enable_routing_um2 - a8.enable_routing_um2;
        let d42 = a2.enable_routing_um2 - a4.enable_routing_um2;
        assert!(d84 > 0.0 && (d42 / d84 - 2.0).abs() < 0.05, "d84={d84} d42={d42}");
    }

    #[test]
    fn routing_cost_is_why_beta_is_capped() {
        // §III-B criterion 1, quantified: at β = 512 (ζ = 1) the enable
        // routing alone exceeds the entire CNN SRAM area.
        let fine = proposed_area(&DesignConfig { zeta: 1, ..DesignConfig::reference() }, &k());
        assert!(fine.enable_routing_um2 > fine.cnn_sram_um2);
        // while at the Table I point it is a small fraction
        let ref_pt = proposed_area(&DesignConfig::reference(), &k());
        assert!(ref_pt.enable_routing_um2 < 0.3 * ref_pt.cnn_sram_um2);
    }

    #[test]
    fn area_scales_quadratically() {
        let a = proposed_area(&DesignConfig::reference(), &k());
        let s = scale_area(&a, crate::tech::NODE_130NM, crate::tech::NODE_65NM);
        let ratio = s.total_um2() / a.total_um2();
        assert!((ratio - (65.0f64 / 130.0).powi(2)).abs() < 1e-9);
    }

    #[test]
    fn totals_are_component_sums() {
        let a = proposed_area(&DesignConfig::reference(), &k());
        let sum = a.cam_array_um2
            + a.data_sram_um2
            + a.cnn_sram_um2
            + a.logic_um2
            + a.enable_routing_um2;
        assert!((a.total_um2() - sum).abs() < 1e-9);
    }
}
