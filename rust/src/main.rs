//! `cscam` — CLI for the clustered-sparse-network CAM reproduction.
//!
//! Subcommands map one-to-one onto the paper's artifacts:
//! * `fig3`   — E(#comparisons) vs q Monte-Carlo sweep (Fig. 3);
//! * `table2` — energy/delay comparison table (Table II + headline ratios);
//! * `sweep`  — the 15-point design-space exploration behind Table I;
//! * `serve`  — run the lookup engine on a synthetic workload through the
//!   threaded coordinator (native or PJRT decode backend), or — with
//!   `--listen` — expose the sharded fleet over TCP (`cscam::net`);
//! * `loadgen` — drive a listening server over the wire protocol and
//!   report throughput/p50/p99 into the bench JSON trajectory;
//! * `promote` — failover: bump a replica directory's fleet epoch so it
//!   serves as the writable primary and the old lineage is fenced;
//! * `info`   — print the resolved design point and model predictions.
//!
//! Global option: `--config <file>` loads a `key = value` design point
//! (defaults to the Table I reference).

use anyhow::{bail, Result};

use cscam::baselines::{anchor_rows, PbCam};
use cscam::cam::MatchlineKind;
use cscam::config::DesignConfig;
use cscam::coordinator::{BatchPolicy, CamServer, DecodeBackend};
use cscam::energy::{conventional_search_energy, proposed_search_energy, CalibrationConstants};
use cscam::stats::{expected_comparisons, simulate_lambda};
use cscam::sweep::{run_sweep, SweepConstraints};
use cscam::tech;
use cscam::timing::{conventional_delay, proposed_delay, scaled_delay, DelayConstants};
use cscam::transistor::{overhead_vs_nand, TransistorAssumptions};
use cscam::util::cli::Args;
use cscam::util::Rng;
use cscam::workload::{QueryMix, TagDistribution};

const USAGE: &str = "\
cscam — low-power CAM via clustered-sparse-networks (ASAP 2013 reproduction)

USAGE: cscam [--config FILE] <COMMAND> [OPTIONS]

COMMANDS:
  fig3    reproduce Fig. 3      --sizes 256,512,1024  --trials N  --seed S
  table2  reproduce Table II    --node 90nm (optional projection)
  sweep   reproduce Table I     --m 512 --n 128
  serve   run the coordinator   --lookups N --hit-ratio R --pjrt --max-batch B
                                --threads T --seed S --readers R
          (--readers sizes each bank's lookup reader pool; 0 routes reads
           through the engine thread; --pjrt forces 0 and needs a binary
           built with `--features pjrt`)
          sharded fleet:        --shards S --placement hash|prefix|broadcast
                                --hot-fraction F --hot-shard B
          (S > 1 spawns one engine thread per bank; --hot-fraction > 0
           hammers one bank through the hot-shard stream)
          network serving:      --listen ADDR (e.g. 127.0.0.1:4242, port 0
           picks an ephemeral port) --max-conns N --port-file PATH
          (starts empty; clients insert over the wire; blocks until a
           wire Shutdown request arrives)
          durability:           --data-dir PATH (per-bank snapshot + WAL;
           a restart recovers every acknowledged write bit-identically)
           --fsync never|always|N (N = fsync every N appends; default never)
           --compact-bytes N (snapshot + truncate past N WAL bytes)
          observability:        --metrics-addr ADDR (HTTP sidecar answering
           GET /metrics with the Prometheus-text exposition; port 0 picks
           an ephemeral port, printed at startup and appended as a second
           line to --port-file)
          replication:          --replicate-from ADDR (serve as a read
           replica of the primary at ADDR: bootstrap a state transfer
           into --data-dir, chase the primary's log, forward writes
           upstream; geometry, placement and epoch are adopted from the
           primary's manifest, so --shards/--placement are ignored)
           --replica-id N (subscriber id in the primary's cscam_repl_*
           series; default: this process id)
          (a primary with --data-dir answers SubscribeLog automatically)
  promote bump the fleet epoch  --data-dir PATH
          (offline failover: run against the chosen replica's directory
           while no process is serving it; the directory then serves as
           a writable primary and subscribers still on the old epoch —
           including the crashed ex-primary — are fenced with ERR_FENCED)
  loadgen drive a listening server over the wire protocol
                                --connect ADDR --lookups N --threads T
                                --chunk C --hit-ratio R --population P
                                --rate Q --conns N --seed S --json PATH
                                --shutdown
          (--json appends a 'net'-tagged row to the bench trajectory;
           --rate Q paces arrivals open-loop at Q lookups/s, measuring
           latency from each frame's intended start — 0 = closed-loop;
           --conns N holds N multiplexed connections open, spread over
           the threads, with the same offered load — the c10k ramp;
           --shutdown stops the server after the run)
  info    print the design point and all model predictions
";

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(raw, &["pjrt", "help", "shutdown"])?;
    if args.flag("help") || args.positional().is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cfg = match args.get("config") {
        Some(p) => DesignConfig::from_kv_file(std::path::Path::new(p))?,
        None => DesignConfig::reference(),
    };
    match args.positional()[0].as_str() {
        "fig3" => fig3(&args),
        "table2" => table2(&cfg, &args),
        "sweep" => sweep_cmd(&args),
        "serve" => serve(&cfg, &args),
        "promote" => promote_cmd(&args),
        "loadgen" => loadgen(&args),
        "info" => info(&cfg),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn fig3(args: &Args) -> Result<()> {
    let sizes: Vec<usize> = args.get_list("sizes", vec![256, 512, 1024])?;
    let trials: usize = args.get_parse("trials", 1_000_000)?;
    let seed: u64 = args.get_parse("seed", 42)?;

    println!("# Fig. 3 — E[#comparisons] vs reduced-tag bits q (ζ=1 view)");
    print!("{:>4}", "q");
    for m in &sizes {
        print!("{:>12}", format!("M={m}"));
    }
    println!();
    let mut rng = Rng::seed_from_u64(seed);
    let qmax = sizes.iter().map(|m| (*m as f64).log2() as usize + 6).max().unwrap();
    let qmin = 4;
    let per_point = (trials / (qmax - qmin + 1)).max(1000);
    for q in qmin..=qmax {
        print!("{q:>4}");
        for &m in &sizes {
            let est = simulate_lambda(m, q, 1, per_point, &mut rng);
            print!("{:>12.4}", est.mean_lambda);
        }
        println!();
    }
    println!(
        "\nclosed form: E[λ] = 1 + (M−1)/2^q; Table I point (M=512, q=9): {:.4}",
        cscam::stats::expected_lambda(512, 9)
    );
    Ok(())
}

fn table2(cfg: &DesignConfig, args: &Args) -> Result<()> {
    let calib = CalibrationConstants::reference_130nm();
    let delays = DelayConstants::reference();
    let n130 = tech::NODE_130NM;

    println!("# Table II — result comparisons (512×128 for our rows)");
    println!(
        "{:<12} {:>11} {:>8} {:>10} {:>15} {:>20}",
        "design", "config", "tech", "delay[ns]", "E[fJ/bit/srch]", "source"
    );
    for r in anchor_rows() {
        println!(
            "{:<12} {:>11} {:>8} {:>10.3} {:>15.3} {:>20}",
            r.name,
            format!("{}x{}", r.config.0, r.config.1),
            r.node.name,
            r.delay_ns,
            r.energy_fj_bit,
            "published"
        );
    }

    let nand_e =
        conventional_search_energy(cfg.m, cfg.n, MatchlineKind::Nand, &calib).per_bit(cfg.m, cfg.n);
    let nor_e =
        conventional_search_energy(cfg.m, cfg.n, MatchlineKind::Nor, &calib).per_bit(cfg.m, cfg.n);
    let prop_e = proposed_search_energy(cfg, &calib).per_bit(cfg.m, cfg.n);
    let nand_d = conventional_delay(cfg.m, cfg.n, MatchlineKind::Nand, &delays, n130);
    let nor_d = conventional_delay(cfg.m, cfg.n, MatchlineKind::Nor, &delays, n130);
    let prop_d = proposed_delay(cfg, &delays);

    for (name, d, e) in [
        ("Ref. NAND", nand_d.cycle_ns, nand_e),
        ("Ref. NOR", nor_d.cycle_ns, nor_e),
        ("Proposed", prop_d.cycle_ns, prop_e),
    ] {
        println!(
            "{:<12} {:>11} {:>8} {:>10.3} {:>15.3} {:>20}",
            name,
            format!("{}x{}", cfg.m, cfg.n),
            "0.13um",
            d,
            e,
            "model (this work)"
        );
    }

    // PB-CAM comparison row (functional baseline, §I)
    let pb_full = PbCam::expected_full_comparisons(cfg.m, cfg.n);
    let pb = PbCam::new(cfg.m, cfg.n);
    let pb_e = pb.search_energy(pb_full.round() as usize, &calib).per_bit(cfg.m, cfg.n);
    println!(
        "{:<12} {:>11} {:>8} {:>10} {:>15.3} {:>20}",
        "PB-CAM [4]",
        format!("{}x{}", cfg.m, cfg.n),
        "0.13um",
        "-",
        pb_e,
        "model (this work)"
    );

    println!("\n# headline ratios vs Ref. NAND (paper: energy 9.5 %, delay 30.4 %, +3.4 % transistors)");
    println!("energy  : {:.1} %", 100.0 * prop_e / nand_e);
    println!("delay   : {:.1} %", 100.0 * prop_d.cycle_ns / nand_d.cycle_ns);
    let ovh = overhead_vs_nand(cfg, &TransistorAssumptions::default());
    println!("trans.  : +{:.1} %", 100.0 * ovh);
    println!("E[comparisons]/search: {:.2} (of {})", cfg.expected_comparisons(), cfg.m);

    if let Some(name) = args.get("node") {
        let Some(target) = tech::node_by_name(name) else { bail!("unknown node {name}") };
        let e90 = tech::scale_energy(prop_e, n130, target);
        let d90 = scaled_delay(prop_d, n130, target);
        println!(
            "\n# projected to {} / {:.1} V (method of [6]; paper @90nm: 0.060 fJ/bit/search, 0.582 ns)",
            target.name, target.vdd
        );
        println!("proposed: {:.3} fJ/bit/search, {:.3} ns", e90, d90.cycle_ns);
    }
    Ok(())
}

fn sweep_cmd(args: &Args) -> Result<()> {
    let m: usize = args.get_parse("m", 512)?;
    let n: usize = args.get_parse("n", 128)?;
    let constraints = SweepConstraints::default();
    println!("# Table I design-space exploration: M={m}, N={n}");
    println!(
        "{:<4} {:<4} {:<5} {:<4} {:<5} {:>15} {:>10} {:>9} {:>8} {:>9}",
        "c",
        "l",
        "zeta",
        "q",
        "beta",
        "E[fJ/bit/srch]",
        "cycle[ns]",
        "overhead",
        "E[cmp]",
        "feasible"
    );
    for p in run_sweep(m, n, &constraints) {
        println!(
            "{:<4} {:<4} {:<5} {:<4} {:<5} {:>15.4} {:>10.3} {:>8.1}% {:>8.2} {:>9}",
            p.cfg.c,
            p.cfg.l,
            p.cfg.zeta,
            p.cfg.q(),
            p.cfg.beta(),
            p.energy_fj_bit,
            p.cycle_ns,
            100.0 * p.overhead,
            p.comparisons,
            if p.feasible { "yes" } else { "no" }
        );
    }
    if let Some(best) = cscam::sweep::select_design(m, n, &constraints) {
        println!(
            "\nselected: c={} l={} ζ={} (q={}, β={}) — Table I: c=3 l=8 ζ=8 (q=9, β=64)",
            best.cfg.c,
            best.cfg.l,
            best.cfg.zeta,
            best.cfg.q(),
            best.cfg.beta()
        );
    }
    Ok(())
}

/// Build the PJRT decode backend from the on-disk artifacts.
#[cfg(feature = "pjrt")]
fn pjrt_backend(cfg: &DesignConfig) -> Result<DecodeBackend> {
    let dir = cscam::runtime::default_artifact_dir();
    let store = cscam::runtime::ArtifactStore::load(&dir)?;
    anyhow::ensure!(
        store.manifest().config.m == cfg.m,
        "artifact geometry (M={}) != config (M={}); re-run `make artifacts`",
        store.manifest().config.m,
        cfg.m
    );
    Ok(DecodeBackend::pjrt(store))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_cfg: &DesignConfig) -> Result<DecodeBackend> {
    bail!("this binary was built without the `pjrt` feature; rebuild with `--features pjrt`")
}

fn serve(cfg: &DesignConfig, args: &Args) -> Result<()> {
    if args.get("listen").is_some() {
        return serve_listen(cfg, args);
    }
    let lookups: usize = args.get_parse("lookups", 10_000)?;
    let hit_ratio: f64 = args.get_parse("hit-ratio", 0.9)?;
    let pjrt = args.flag("pjrt");
    let max_batch: usize = args.get_parse("max-batch", 64)?;
    let threads: usize = args.get_parse("threads", 8)?;
    let seed: u64 = args.get_parse("seed", 7)?;
    let shards: usize = args.get_parse("shards", cfg.shards)?;
    let readers: usize = args.get_parse("readers", cscam::coordinator::DEFAULT_READERS)?;

    let policy = BatchPolicy { max_batch, ..Default::default() };
    if shards > 1 {
        if pjrt {
            bail!(
                "--pjrt serves a single bank (the artifacts are AOT-compiled \
                 for one geometry); drop --shards or --pjrt"
            );
        }
        return serve_sharded(cfg, args, shards, policy, readers);
    }

    let backend = if pjrt { pjrt_backend(cfg)? } else { DecodeBackend::Native };
    let h = CamServer::new(cfg.clone(), backend, policy).with_readers(readers).spawn();

    let mut rng = Rng::seed_from_u64(seed);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &stored {
        h.insert(t.clone()).expect("insert");
    }
    let mix = QueryMix { hit_ratio, zipf_s: 0.0 };

    // pre-draw queries, then fire from `threads` client threads
    let mut queries: Vec<Vec<cscam::bits::BitVec>> = vec![Vec::new(); threads];
    for i in 0..lookups {
        let (tag, _) = mix.sample(&stored, cfg.n, &mut rng);
        queries[i % threads].push(tag);
    }
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for qs in queries {
        let h = h.clone();
        joins.push(std::thread::spawn(move || {
            let mut hits = 0usize;
            for t in qs {
                hits += h.lookup(t).expect("lookup").addr.is_some() as usize;
            }
            hits
        }));
    }
    let hits: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let wall = t0.elapsed();

    let m = h.metrics().expect("metrics");
    println!(
        "# serve — backend={}, {threads} client threads",
        if pjrt { "pjrt" } else { "native" }
    );
    println!("{}", m.summary(cfg.m, cfg.n));
    println!(
        "hits: {hits}/{lookups}; throughput: {:.0} lookups/s (wall {:.3} s), mean batch {:.1}",
        lookups as f64 / wall.as_secs_f64(),
        wall.as_secs_f64(),
        m.batch_size.mean()
    );
    Ok(())
}

/// The sharded serve path: one engine thread per bank behind the
/// scatter-gather router, with an optional hot-shard stream.
fn serve_sharded(
    cfg: &DesignConfig,
    args: &Args,
    shards: usize,
    policy: BatchPolicy,
    readers: usize,
) -> Result<()> {
    use cscam::shard::{PlacementMode, ShardedCamServer};
    use cscam::workload::HotShardMix;

    let lookups: usize = args.get_parse("lookups", 10_000)?;
    let hit_ratio: f64 = args.get_parse("hit-ratio", 0.9)?;
    let threads: usize = args.get_parse("threads", 8)?;
    let seed: u64 = args.get_parse("seed", 7)?;
    let hot_fraction: f64 = args.get_parse("hot-fraction", 0.0)?;
    let placement = args.get("placement").unwrap_or("hash");

    let mut fleet_cfg = cfg.clone();
    fleet_cfg.shards = shards;
    fleet_cfg.validate()?;

    // ~70 % fill: hash placement is binomial across banks, leave headroom
    let mut rng = Rng::seed_from_u64(seed);
    let candidates =
        TagDistribution::Uniform.sample_distinct(fleet_cfg.n, fleet_cfg.m * 7 / 10, &mut rng);
    let mode = match placement {
        "hash" => PlacementMode::TagHash,
        "prefix" => PlacementMode::learned(shards, &candidates, fleet_cfg.n),
        "broadcast" => PlacementMode::Broadcast,
        other => bail!("unknown --placement '{other}' (hash|prefix|broadcast)"),
    };
    let h = ShardedCamServer::new(&fleet_cfg, mode, policy).with_readers(readers).spawn();
    let mut stored = Vec::new();
    for t in &candidates {
        if h.insert(t.clone()).is_ok() {
            stored.push(t.clone());
        }
    }

    // pre-draw queries: plain mix, or the hot-shard stream
    if hot_fraction > 0.0 && placement == "broadcast" {
        bail!(
            "--hot-fraction is meaningless with --placement broadcast \
             (every lookup touches every bank); use hash or prefix placement"
        );
    }
    let by_bank = h.router().partition(&stored);
    let hot_bank: usize = args.get_parse(
        "hot-shard",
        (0..by_bank.len()).max_by_key(|&b| by_bank[b].len()).unwrap_or(0),
    )?;
    if hot_bank >= shards {
        bail!("--hot-shard {hot_bank} out of range: the fleet has {shards} banks");
    }
    let mix = QueryMix { hit_ratio, zipf_s: 0.0 };
    let hot = HotShardMix { hot_bank, hot_fraction, hit_ratio };
    let mut queries: Vec<Vec<cscam::bits::BitVec>> = vec![Vec::new(); threads];
    for i in 0..lookups {
        let tag = if hot_fraction > 0.0 {
            hot.sample(&by_bank, fleet_cfg.n, &mut rng).0
        } else {
            mix.sample(&stored, fleet_cfg.n, &mut rng).0
        };
        queries[i % threads].push(tag);
    }

    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for qs in queries {
        let h = h.clone();
        joins.push(std::thread::spawn(move || {
            let mut hits = 0usize;
            for t in qs {
                hits += h.lookup(t).expect("lookup").addr.is_some() as usize;
            }
            hits
        }));
    }
    let hits: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let wall = t0.elapsed();

    let fm = h.fleet_metrics().expect("metrics");
    println!(
        "# serve — sharded fleet: {shards} banks × {} entries, placement={placement}, \
         {threads} client threads",
        fleet_cfg.per_bank().m
    );
    if hot_fraction > 0.0 {
        println!("# hot-shard stream: bank {hot_bank} draws {:.0} % of hits", 100.0 * hot_fraction);
    }
    println!("{}", fm.summary(fleet_cfg.per_bank().m, fleet_cfg.n));
    println!(
        "hits: {hits}/{lookups}; throughput: {:.0} lookups/s (wall {:.3} s); hottest bank {} \
         ({:.1} % of lookups)",
        lookups as f64 / wall.as_secs_f64(),
        wall.as_secs_f64(),
        fm.hottest_bank(),
        100.0 * fm.hot_fraction()
    );
    Ok(())
}

/// `serve --listen`: expose an (initially empty) sharded fleet over TCP.
/// Blocks until a wire `Shutdown` request drains the banks and stops the
/// accept loop.
fn serve_listen(cfg: &DesignConfig, args: &Args) -> Result<()> {
    use cscam::net::{CamTcpServer, NetConfig};
    use cscam::shard::{PlacementMode, ShardedCamServer};
    use cscam::store::{FsyncPolicy, StoreOptions};

    let listen = args.get("listen").expect("checked by caller");
    let shards: usize = args.get_parse("shards", cfg.shards)?;
    let max_batch: usize = args.get_parse("max-batch", 64)?;
    let max_conns: usize = args.get_parse("max-conns", 64)?;
    let readers: usize = args.get_parse("readers", cscam::coordinator::DEFAULT_READERS)?;
    let seed: u64 = args.get_parse("seed", 7)?;
    let placement = args.get("placement").unwrap_or("hash");
    let data_dir = args.get("data-dir");
    let fsync = match args.get("fsync").unwrap_or("never") {
        "never" => FsyncPolicy::Never,
        "always" => FsyncPolicy::Always,
        n => FsyncPolicy::EveryN(
            n.parse().map_err(|_| anyhow::anyhow!("--fsync takes never|always|N, got '{n}'"))?,
        ),
    };
    let store_opts =
        StoreOptions { fsync, compact_bytes: args.get_parse("compact-bytes", 4 << 20)? };

    // the replica path diverges early: geometry, placement and epoch are
    // adopted from the primary's manifest, never from the local flags
    if let Some(upstream) = args.get("replicate-from") {
        let policy = BatchPolicy { max_batch, ..Default::default() };
        return serve_replica(args, upstream, store_opts, policy, max_conns, readers);
    }

    let mut fleet_cfg = cfg.clone();
    fleet_cfg.shards = shards;
    fleet_cfg.validate()?;

    let mode = match placement {
        "hash" => PlacementMode::TagHash,
        "broadcast" => PlacementMode::Broadcast,
        "prefix" => {
            // the selection only decides ownership, so any deterministic
            // sample works; --seed keeps server and tooling reproducible
            let mut rng = Rng::seed_from_u64(seed);
            let sample = TagDistribution::Uniform.sample_distinct(
                fleet_cfg.n,
                (fleet_cfg.m / 2).max(16),
                &mut rng,
            );
            PlacementMode::learned(shards, &sample, fleet_cfg.n)
        }
        other => bail!("unknown --placement '{other}' (hash|prefix|broadcast)"),
    };

    let policy = BatchPolicy { max_batch, ..Default::default() };
    let mut recovered = None;
    let fleet = match data_dir {
        Some(dir) => {
            let dir = std::path::Path::new(dir);
            let (server, recovery) =
                ShardedCamServer::open_durable(&fleet_cfg, mode, policy, dir, store_opts)
                    .map_err(|e| anyhow::anyhow!("opening --data-dir {}: {e}", dir.display()))?;
            println!("# data-dir {}: {}", dir.display(), recovery.summary());
            recovered = Some(recovery);
            server.with_readers(readers).spawn()
        }
        None => ShardedCamServer::new(&fleet_cfg, mode, policy).with_readers(readers).spawn(),
    };
    // a durable primary answers SubscribeLog: attach the replication
    // feed over its own data directory (the Arc is shared with the
    // metrics sidecar so both render the same subscriber progress)
    let repl_role = match data_dir {
        Some(dir) => {
            let feed = cscam::repl::ReplicaFeed::open(std::path::Path::new(dir))
                .map_err(|e| anyhow::anyhow!("opening replication feed over {dir}: {e}"))?;
            println!("# replication feed at epoch {} (SubscribeLog served)", feed.epoch());
            Some(std::sync::Arc::new(cscam::repl::ReplRole::Primary(feed)))
        }
        None => None,
    };
    let server = CamTcpServer::bind(
        fleet.clone(),
        listen,
        NetConfig { max_connections: max_conns, ..Default::default() },
    )?;
    let server = match &repl_role {
        Some(role) => server.with_repl(std::sync::Arc::clone(role)),
        None => server,
    };
    let addr = server.local_addr()?;
    let handle = server.spawn()?;
    println!(
        "# cscam serving {} banks x {} entries (N={}, placement={placement}) on {addr}",
        shards,
        fleet_cfg.per_bank().m,
        fleet_cfg.n
    );
    // Prometheus scrape sidecar: a second listener serving the same
    // exposition `OP_METRICS` returns in-band (see `cscam::obs`).
    let metrics_http = match args.get("metrics-addr") {
        Some(maddr) => {
            let scrape_fleet = fleet.clone();
            let bank_m = fleet_cfg.per_bank().m;
            let tag_bits = fleet_cfg.n;
            let scrape_role = repl_role.clone();
            let render: cscam::obs::RenderFn = std::sync::Arc::new(move || {
                match scrape_fleet.fleet_metrics() {
                    Some(fm) => {
                        let repl = match scrape_role.as_deref() {
                            Some(cscam::repl::ReplRole::Primary(feed)) => Some(feed.status()),
                            _ => None,
                        };
                        cscam::obs::render_prometheus(
                            &fm,
                            bank_m,
                            tag_bits,
                            recovered.as_ref(),
                            repl.as_ref(),
                        )
                    }
                    // fleet already shutting down: an empty exposition
                    None => String::new(),
                }
            });
            let sidecar = cscam::obs::MetricsHttpServer::spawn(maddr, render)
                .map_err(|e| anyhow::anyhow!("binding --metrics-addr {maddr}: {e}"))?;
            println!("# metrics on http://{}/metrics", sidecar.local_addr());
            Some(sidecar)
        }
        None => None,
    };
    if let Some(path) = args.get("port-file") {
        match metrics_http.as_ref() {
            // second line so smoke scripts can find the scrape port too
            Some(s) => std::fs::write(path, format!("{addr}\n{}", s.local_addr()))?,
            None => std::fs::write(path, addr.to_string())?,
        }
        println!("# wrote address to {path}");
    }
    handle.join();
    if let Some(sidecar) = metrics_http {
        sidecar.shutdown();
    }

    if let Some(fm) = fleet.fleet_metrics() {
        println!("# shut down after draining:");
        println!("{}", fm.summary(fleet_cfg.per_bank().m, fleet_cfg.n));
    }
    Ok(())
}

/// `serve --listen --replicate-from`: bootstrap a read replica of the
/// primary at `upstream` into `--data-dir`, serve wire lookups from the
/// local fleet (the chaser keeps it converged with the primary's log),
/// and forward `Insert`/`Delete` upstream.  Geometry, placement and
/// epoch all come from the primary's manifest.
fn serve_replica(
    args: &Args,
    upstream: &str,
    store: cscam::store::StoreOptions,
    policy: BatchPolicy,
    max_conns: usize,
    readers: usize,
) -> Result<()> {
    use cscam::net::{CamTcpServer, NetConfig};
    use cscam::repl::{ReplRole, ReplicaOptions, ReplicaServer};
    use std::sync::Arc;

    let listen = args.get("listen").expect("checked by caller");
    let Some(dir) = args.get("data-dir") else {
        bail!("--replicate-from needs --data-dir PATH (the replica's own durable directory)");
    };
    let mut opts = ReplicaOptions { store, policy, readers, ..Default::default() };
    opts.replica_id = args.get_parse("replica-id", opts.replica_id)?;

    let replica = ReplicaServer::start(upstream, std::path::Path::new(dir), opts)
        .map_err(|e| anyhow::anyhow!("replicating from {upstream}: {e}"))?;
    println!(
        "# replica {} of {upstream} at epoch {}; {dir}: {}",
        args.get("replica-id").unwrap_or("(pid)"),
        replica.epoch(),
        replica.recovery().summary()
    );

    let fleet = replica.fleet();
    let server = CamTcpServer::bind(
        fleet.clone(),
        listen,
        NetConfig { max_connections: max_conns, ..Default::default() },
    )?
    .with_repl(Arc::new(ReplRole::Replica(replica.forwarder())));
    let addr = server.local_addr()?;
    let handle = server.spawn()?;
    println!("# cscam replica serving reads on {addr} (writes forwarded to {upstream})");

    let metrics_http = match args.get("metrics-addr") {
        Some(maddr) => {
            let scrape_fleet = fleet.clone();
            let bank_m = fleet.bank_m();
            let tag_bits = fleet.tag_bits();
            let recovery = replica.recovery().clone();
            let status = replica.status_fn();
            let render: cscam::obs::RenderFn =
                Arc::new(move || match scrape_fleet.fleet_metrics() {
                    Some(fm) => cscam::obs::render_prometheus(
                        &fm,
                        bank_m,
                        tag_bits,
                        Some(&recovery),
                        Some(&status()),
                    ),
                    // fleet already shutting down: an empty exposition
                    None => String::new(),
                });
            let sidecar = cscam::obs::MetricsHttpServer::spawn(maddr, render)
                .map_err(|e| anyhow::anyhow!("binding --metrics-addr {maddr}: {e}"))?;
            println!("# metrics on http://{}/metrics", sidecar.local_addr());
            Some(sidecar)
        }
        None => None,
    };
    if let Some(path) = args.get("port-file") {
        match metrics_http.as_ref() {
            // second line so smoke scripts can find the scrape port too
            Some(s) => std::fs::write(path, format!("{addr}\n{}", s.local_addr()))?,
            None => std::fs::write(path, addr.to_string())?,
        }
        println!("# wrote address to {path}");
    }
    handle.join();
    if let Some(sidecar) = metrics_http {
        sidecar.shutdown();
    }
    // a wire Shutdown already drained the local fleet; the chaser being
    // stopped afterwards may find it closed, which is fine
    if let Err(e) = replica.shutdown() {
        eprintln!("# replica shutdown: {e}");
    }
    Ok(())
}

/// `promote`: offline failover.  Bump the manifest epoch of the chosen
/// replica's data directory so it serves as the writable primary; every
/// subscriber still on the old epoch — including the crashed ex-primary,
/// should it rejoin — is refused with `ERR_FENCED`.
fn promote_cmd(args: &Args) -> Result<()> {
    let Some(dir) = args.get("data-dir") else {
        bail!("promote needs --data-dir PATH (the replica directory taking over)");
    };
    let epoch = cscam::repl::promote(std::path::Path::new(dir))
        .map_err(|e| anyhow::anyhow!("promoting {dir}: {e}"))?;
    println!("promoted {dir}: fleet epoch is now {epoch}");
    println!("subscribers still on epoch {} (including the ex-primary) will be fenced", epoch - 1);
    Ok(())
}

/// `loadgen`: drive a listening server over the wire and report into the
/// bench trajectory.
fn loadgen(args: &Args) -> Result<()> {
    use cscam::net::{CamClient, LoadGen};
    use cscam::util::bench::write_bench_json;

    let Some(addr) = args.get("connect") else {
        bail!("loadgen needs --connect ADDR (see `cscam serve --listen`)");
    };
    let driver = LoadGen {
        addr: addr.to_string(),
        threads: args.get_parse("threads", 4)?,
        lookups: args.get_parse("lookups", 20_000)?,
        chunk: args.get_parse("chunk", 64)?,
        hit_ratio: args.get_parse("hit-ratio", 0.9)?,
        population: args.get_parse("population", 256)?,
        rate: args.get_parse("rate", 0.0)?,
        conns: args.get_parse("conns", 0)?,
        seed: args.get_parse("seed", 7)?,
    };
    let report = driver.run().map_err(|e| anyhow::anyhow!("loadgen failed: {e}"))?;
    println!("# loadgen against {addr}");
    println!("{}", report.summary());

    if let Some(path) = args.get("json") {
        write_bench_json(std::path::Path::new(path), "net", &[report.to_record()])?;
        println!("appended 1 'net' row to {path}");
    }
    if args.flag("shutdown") {
        let mut c = CamClient::connect(addr.to_string())
            .map_err(|e| anyhow::anyhow!("shutdown connect failed: {e}"))?;
        c.shutdown().map_err(|e| anyhow::anyhow!("shutdown failed: {e}"))?;
        println!("server asked to shut down (banks drained)");
    }
    Ok(())
}

fn info(cfg: &DesignConfig) -> Result<()> {
    let calib = CalibrationConstants::reference_130nm();
    let delays = DelayConstants::reference();
    println!("design point:\n{}", cfg.to_kv());
    println!("q = {} bits, β = {} sub-blocks, k = {}", cfg.q(), cfg.beta(), cfg.k());
    println!(
        "E[λ] = {:.4}, E[blocks] = {:.4}, E[comparisons] = {:.2}",
        cfg.expected_lambda(),
        cfg.expected_active_blocks(),
        cfg.expected_comparisons()
    );
    let e = proposed_search_energy(cfg, &calib);
    println!(
        "energy/search = {:.1} fJ ({:.4} fJ/bit/search)",
        e.total_fj(),
        e.per_bit(cfg.m, cfg.n)
    );
    println!("  CNN share: {:.1} fJ, CAM share: {:.1} fJ", e.cnn_fj(), e.cam_fj());
    let d = proposed_delay(cfg, &delays);
    println!("cycle = {:.3} ns, latency = {:.3} ns", d.cycle_ns, d.latency_ns);
    let ovh = overhead_vs_nand(cfg, &TransistorAssumptions::default());
    println!("transistor overhead vs Ref. NAND: +{:.2} %", 100.0 * ovh);
    println!(
        "closed-form comparisons check: {:.3}",
        expected_comparisons(cfg.m, cfg.q(), cfg.zeta)
    );
    Ok(())
}
