//! `cam_client` — walk the wire protocol end to end.
//!
//! With `--connect ADDR` it drives an already-running `cscam serve
//! --listen` server; without it, it spins up its own 4-bank fleet on a
//! loopback ephemeral port so the demo is self-contained:
//!
//! ```sh
//! cargo run --release --example cam_client
//! cargo run --release --example cam_client -- --connect 127.0.0.1:4242
//! ```

use cscam::config::DesignConfig;
use cscam::coordinator::BatchPolicy;
use cscam::net::{CamClient, CamTcpServer, NetConfig};
use cscam::shard::{PlacementMode, ShardedCamServer};
use cscam::util::cli::Args;
use cscam::util::Rng;
use cscam::workload::TagDistribution;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    args.check_known(&["connect"])?;

    // No --connect: host a small fleet ourselves on an ephemeral port.
    let (addr, local_server) = match args.get("connect") {
        Some(a) => (a.to_string(), None),
        None => {
            let cfg = DesignConfig {
                m: 256,
                n: 32,
                zeta: 4,
                c: 3,
                l: 4,
                shards: 4,
                ..DesignConfig::reference()
            };
            let fleet = ShardedCamServer::new(&cfg, PlacementMode::TagHash, BatchPolicy::default())
                .spawn();
            let server = CamTcpServer::bind(fleet, "127.0.0.1:0", NetConfig::default())?;
            let addr = server.local_addr()?.to_string();
            println!("(no --connect given: hosting a 4-bank fleet on {addr})");
            (addr, Some(server.spawn()?))
        }
    };

    let mut client = CamClient::connect(addr.clone())
        .map_err(|e| anyhow::anyhow!("connect to {addr}: {e}"))?;
    let hello = *client.server_info().expect("hello after connect");
    println!(
        "connected: protocol v{}, {} banks x {} entries, N = {} tag bits",
        hello.version, hello.shards, hello.bank_m, hello.tag_bits
    );

    // Insert a handful of tags and read their global addresses back.
    let mut rng = Rng::seed_from_u64(2013);
    let tags = TagDistribution::Uniform.sample_distinct(hello.tag_bits as usize, 16, &mut rng);
    let mut addrs = Vec::new();
    for t in &tags {
        addrs.push(client.insert(t).map_err(|e| anyhow::anyhow!("insert: {e}"))?);
    }
    println!("\ninserted {} tags; global addresses {:?}…", tags.len(), &addrs[..4]);

    // One lookup: the paper's physics arrive over the wire.
    let out = client.lookup(&tags[3]).map_err(|e| anyhow::anyhow!("lookup: {e}"))?;
    println!("\nlookup tags[3]:");
    println!("  matched address   : {:?} (expected {})", out.addr, addrs[3]);
    println!(
        "  λ / blocks / cmp  : {} / {} / {}",
        out.lambda, out.enabled_blocks, out.comparisons
    );
    println!("  banks searched    : {}", out.banks_searched);
    println!("  energy            : {:.1} fJ", out.energy.total_fj());
    println!("  cycle / latency   : {:.3} / {:.3} ns", out.delay.cycle_ns, out.delay.latency_ns);

    // Pipelined bulk: all frames go out before the first response is read.
    let bulk = client
        .lookup_bulk(&tags, 4)
        .map_err(|e| anyhow::anyhow!("lookup_bulk: {e}"))?;
    let hits = bulk.iter().filter(|r| matches!(r, Ok(o) if o.addr.is_some())).count();
    println!("\nbulk lookup of {} tags in frames of 4: {hits} hits", tags.len());

    // Delete, then show the miss.
    client.delete(addrs[3]).map_err(|e| anyhow::anyhow!("delete: {e}"))?;
    let gone = client.lookup(&tags[3]).map_err(|e| anyhow::anyhow!("lookup: {e}"))?;
    println!("after delete: lookup tags[3] → {:?}", gone.addr);

    // Fleet statistics over the wire.
    let stats = client.stats().map_err(|e| anyhow::anyhow!("stats: {e}"))?;
    println!(
        "\nfleet stats: {} lookups, {} hits, λ̄ {:.3}, Ē {:.1} fJ, hottest bank {} ({:.0} %)",
        stats.lookups,
        stats.hits,
        stats.mean_lambda,
        stats.mean_energy_fj,
        stats.hottest_bank,
        100.0 * stats.hot_fraction
    );
    println!("per-bank lookups: {:?}", stats.per_bank_lookups);

    // The same metrics as a Prometheus-text exposition, fetched in-band
    // over the wire (`OP_METRICS`) — what the `serve --metrics-addr` HTTP
    // sidecar serves on GET /metrics.
    let exposition = client.metrics().map_err(|e| anyhow::anyhow!("metrics: {e}"))?;
    let shown: Vec<&str> = exposition
        .lines()
        .filter(|l| {
            !l.starts_with('#')
                && (l.starts_with("cscam_lookups_total")
                    || l.starts_with("cscam_hit_ratio")
                    || l.starts_with("cscam_hot_fraction")
                    || l.starts_with("cscam_shed_total"))
        })
        .collect();
    println!(
        "\nprometheus exposition: {} lines; headline series:\n  {}",
        exposition.lines().count(),
        shown.join("\n  ")
    );

    // Clean shutdown (drains the banks) when we own the server.
    if let Some(server) = local_server {
        client.shutdown().map_err(|e| anyhow::anyhow!("shutdown: {e}"))?;
        server.join();
        println!("\nlocal server drained and stopped");
    }
    Ok(())
}
