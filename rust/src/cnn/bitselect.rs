//! Tag-length reduction: choosing which q of the N tag bits feed the CNN.
//!
//! §II-B: "it is possible to select the bits in the reduced length tag in
//! such a way to reduce correlations."  Uniformly random tags make any
//! selection equally good; real workloads (TLB VPNs, router prefixes) have
//! low-entropy regions (high-order bits nearly constant, strides in the low
//! bits), and a bad selection inflates E(λ) — more enabled sub-blocks, more
//! energy, never wrong results.
//!
//! Three policies:
//! * [`Selection::contiguous`] — naive truncation (the strawman);
//! * [`Selection::strided`] — spread evenly across the tag;
//! * [`Selection::entropy_greedy`] — data-driven: greedily pick the bit with
//!   the highest marginal entropy, penalized by correlation with the bits
//!   already picked (the paper's "according to a pattern to reduce the tag
//!   correlation", made concrete).


use crate::bits::BitVec;

/// An ordered choice of q bit positions within an N-bit tag, plus the
/// cluster geometry used to map them to P_I neuron indices.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Selection {
    positions: Vec<usize>,
    k: usize,
}

impl Selection {
    /// The first `c·k` bits of the tag, in order (naive truncation).
    pub fn contiguous(c: usize, k: usize) -> Self {
        Selection { positions: (0..c * k).collect(), k }
    }

    /// `c·k` positions spread evenly across an `n`-bit tag.
    pub fn strided(n: usize, c: usize, k: usize) -> Self {
        let q = c * k;
        assert!(q <= n, "q={q} exceeds tag width {n}");
        let positions = (0..q).map(|i| i * n / q).collect();
        Selection { positions, k }
    }

    /// Explicit positions (must be in-range and distinct; length must be c·k).
    pub fn explicit(positions: Vec<usize>, k: usize) -> Self {
        assert!(k > 0 && positions.len() % k == 0, "positions must fill whole clusters");
        Selection { positions, k }
    }

    /// Data-driven greedy selection from a tag sample: repeatedly take the
    /// position maximizing `H(bit) − μ·mean|corr(bit, chosen)|`.
    pub fn entropy_greedy(sample: &[BitVec], n: usize, c: usize, k: usize) -> Self {
        let q = c * k;
        assert!(q <= n);
        assert!(!sample.is_empty(), "need a non-empty sample");
        let s = sample.len() as f64;

        // per-bit means
        let p: Vec<f64> = (0..n)
            .map(|b| sample.iter().filter(|t| t.get(b)).count() as f64 / s)
            .collect();
        let entropy = |pb: f64| {
            if pb <= 0.0 || pb >= 1.0 {
                0.0
            } else {
                -(pb * pb.log2() + (1.0 - pb) * (1.0 - pb).log2())
            }
        };
        let corr = |a: usize, b: usize| -> f64 {
            let pab = sample.iter().filter(|t| t.get(a) && t.get(b)).count() as f64 / s;
            let cov = pab - p[a] * p[b];
            let va = p[a] * (1.0 - p[a]);
            let vb = p[b] * (1.0 - p[b]);
            if va <= 0.0 || vb <= 0.0 {
                0.0
            } else {
                (cov / (va * vb).sqrt()).abs()
            }
        };

        const MU: f64 = 0.5; // correlation penalty weight
        let mut chosen: Vec<usize> = Vec::with_capacity(q);
        let mut remaining: Vec<usize> = (0..n).collect();
        for _ in 0..q {
            let (pos_i, _best) = remaining
                .iter()
                .enumerate()
                .map(|(i, &b)| {
                    let pen = if chosen.is_empty() {
                        0.0
                    } else {
                        chosen.iter().map(|&a| corr(a, b)).sum::<f64>() / chosen.len() as f64
                    };
                    (i, entropy(p[b]) - MU * pen)
                })
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .expect("remaining non-empty");
            chosen.push(remaining.swap_remove(pos_i));
        }
        Selection { positions: chosen, k }
    }

    /// Reduced-tag width q.
    pub fn q(&self) -> usize {
        self.positions.len()
    }

    /// Number of clusters this selection feeds.
    pub fn c(&self) -> usize {
        self.positions.len() / self.k
    }

    /// Bits per cluster.
    pub fn k(&self) -> usize {
        self.k
    }

    /// The chosen positions (cluster-major: positions[i·k..(i+1)·k] feed
    /// cluster i, LSB first).
    pub fn positions(&self) -> &[usize] {
        &self.positions
    }

    /// Apply to a full tag: produce the c cluster indices (LD inputs).
    pub fn apply(&self, tag: &BitVec) -> Vec<u16> {
        let mut out = Vec::with_capacity(self.c());
        self.apply_into(tag, &mut out);
        out
    }

    /// Allocation-free apply (hot path).
    #[inline]
    pub fn apply_into(&self, tag: &BitVec, out: &mut Vec<u16>) {
        out.clear();
        for cluster in self.positions.chunks(self.k) {
            let mut v: u16 = 0;
            for (bit, &pos) in cluster.iter().enumerate() {
                if tag.get(pos) {
                    v |= 1 << bit;
                }
            }
            out.push(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn contiguous_is_truncation() {
        let sel = Selection::contiguous(3, 3);
        assert_eq!(sel.q(), 9);
        assert_eq!(sel.c(), 3);
        assert_eq!(sel.positions(), &[0, 1, 2, 3, 4, 5, 6, 7, 8]);
        // §II-A example: tag bits '101110' (LSB-first here) split 3+3.
        let tag = BitVec::from_u128(0b101110, 32);
        let idx = Selection::contiguous(2, 3).apply(&tag);
        assert_eq!(idx, vec![0b110, 0b101]);
    }

    #[test]
    fn strided_spreads_positions() {
        let sel = Selection::strided(128, 3, 3);
        assert_eq!(sel.q(), 9);
        let pos = sel.positions();
        assert_eq!(pos[0], 0);
        assert!(pos.windows(2).all(|w| w[1] > w[0]));
        assert!(*pos.last().unwrap() >= 100, "spread to the high bits");
    }

    #[test]
    fn apply_is_binary_to_integer_mapping() {
        let sel = Selection::explicit(vec![0, 2, 4, 1, 3, 5], 3);
        let tag = BitVec::from_bools(&[true, false, true, true, false, false]);
        // cluster 0 reads bits 0,2,4 → 1,1,0 → 0b011 = 3
        // cluster 1 reads bits 1,3,5 → 0,1,0 → 0b010 = 2
        assert_eq!(sel.apply(&tag), vec![3, 2]);
    }

    #[test]
    fn entropy_greedy_avoids_constant_bits() {
        //

        // Tags whose upper half is constant: the greedy picker must choose
        // only positions from the varying lower half.
        let mut rng = Rng::seed_from_u64(1);
        let n = 32;
        let sample: Vec<BitVec> = (0..400)
            .map(|_| BitVec::from_u128((rng.gen_u64() as u16) as u128, n))
            .collect();
        let sel = Selection::entropy_greedy(&sample, n, 3, 3);
        assert!(sel.positions().iter().all(|&p| p < 16), "picked {:?}", sel.positions());
    }

    #[test]
    fn entropy_greedy_penalizes_duplicated_bits() {
        // Bit 1 mirrors bit 0; a correlation-aware picker choosing 2 bits
        // from {0,1,2,3} must not take both 0 and 1.
        let mut rng = Rng::seed_from_u64(2);
        let sample: Vec<BitVec> = (0..500)
            .map(|_| {
                let b0 = rng.gen_bool(0.5);
                let b2 = rng.gen_bool(0.5);
                let b3 = rng.gen_bool(0.5);
                BitVec::from_bools(&[b0, b0, b2, b3])
            })
            .collect();
        let sel = Selection::entropy_greedy(&sample, 4, 2, 1);
        let pos = sel.positions();
        assert!(
            !(pos.contains(&0) && pos.contains(&1)),
            "correlated pair picked: {pos:?}"
        );
    }

    #[test]
    fn apply_into_reuses_buffer() {
        let sel = Selection::contiguous(3, 3);
        let mut buf = Vec::new();
        let tag = BitVec::from_u128(0x1FF, 16);
        sel.apply_into(&tag, &mut buf);
        assert_eq!(buf, vec![7, 7, 7]);
        sel.apply_into(&BitVec::zeros(16), &mut buf);
        assert_eq!(buf, vec![0, 0, 0]);
    }
}
