//! One CAM macro + its CNN classifier — the Fig. 1 system as an engine.
//!
//! The engine is split along the read/write boundary so searches can run
//! on every core at once:
//!
//! * [`SearchState`] — everything a *search* reads (bit selection, CNN
//!   weight rows, CAM tags + valid bits, energy/delay constants), immutable
//!   and shared behind an `Arc`.  [`SearchState::lookup`] is a pure
//!   function of `(state, tag, scratch)` and takes `&self`.
//! * [`DecodeScratch`] — the per-thread reusable buffers (`idx`, `act`,
//!   `enables`) the decode stage writes into.  One per reader thread, no
//!   allocation on the hot path.
//! * [`LookupEngine`] — the single writer: owns the mutation-side state
//!   (`live` associations, stale-delete counter, insert cursor) plus the
//!   current `Arc<SearchState>`.  Mutations copy-on-write the state
//!   (`Arc::make_mut`) and the serving layer re-publishes the new `Arc`
//!   through a [`SharedSearch`] slot RCU-style — readers never block the
//!   writer and never observe a half-applied mutation.

use std::sync::Arc;

use crate::bits::BitVec;
use crate::cam::{BankFilter, CamArray};
use crate::cnn::{ClusteredNetwork, Selection};
use crate::config::DesignConfig;
use crate::energy::{EnergyBreakdown, EnergyModel, SearchActivity};
use crate::timing::{proposed_delay, DelayConstants, DelayReport};

/// Engine errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// The CAM is full — no free slot for an insert.  This is a *capacity*
    /// condition; transient overload is [`EngineError::Busy`].
    Full,
    /// Admission shedding: the server's lookup queue is at capacity, the
    /// request was not enqueued — returned by
    /// [`crate::coordinator::ServerHandle::try_lookup`] and the fleet-level
    /// non-blocking admission.  Retry later; the CAM itself may have free
    /// slots (that condition is [`EngineError::Full`]).
    Busy,
    /// Address out of range.
    BadAddress(usize),
    /// Tag width does not match the configured N.
    TagWidth { got: usize, want: usize },
    /// The serving thread is gone (its channel disconnected) — reported by
    /// [`crate::coordinator::ServerHandle`] when the engine cannot answer.
    Shutdown,
    /// The durability layer failed to log the mutation (disk full, I/O
    /// error).  A failed insert is rolled back out of the in-memory engine
    /// (so it cannot resurface via a later snapshot and a retry cannot
    /// duplicate it); a failed delete may have applied in memory, but
    /// deletes are idempotent so a retry converges.
    Persist(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Full => write!(f, "CAM is full"),
            EngineError::Busy => write!(f, "server admission queue at capacity"),
            EngineError::BadAddress(a) => write!(f, "address {a} out of range"),
            EngineError::TagWidth { got, want } => {
                write!(f, "tag width {got}, expected {want}")
            }
            EngineError::Shutdown => write!(f, "server has shut down"),
            EngineError::Persist(m) => write!(f, "durability layer failed: {m}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// Outcome of one lookup, with the physics the paper reports.
#[derive(Debug, Clone, PartialEq)]
pub struct LookupOutcome {
    /// Matching address, if any (lowest address on multi-match, like a
    /// priority encoder).
    pub addr: Option<usize>,
    /// All matching addresses.
    pub all_matches: Vec<usize>,
    /// λ — P_II neurons activated by the CNN.
    pub lambda: usize,
    /// Sub-blocks compare-enabled.
    pub enabled_blocks: usize,
    /// Full-row comparisons performed (enabled rows).
    pub comparisons: usize,
    /// Per-search energy at the configured node.
    pub energy: EnergyBreakdown,
    /// Cycle/latency of this design point (constant per config).
    pub delay: DelayReport,
}

/// Per-thread reusable decode buffers — the mutable half of a lookup.
///
/// A scratch is geometry-agnostic: it resizes itself lazily the first time
/// a [`SearchState`] of a new geometry uses it, then stays allocation-free.
/// One per reader thread (or per connection); never shared.
#[derive(Debug, Clone)]
pub struct DecodeScratch {
    act: BitVec,
    enables: BitVec,
    idx: Vec<u16>,
    /// Lookups answered by the bloom pre-filter without running decode,
    /// accumulated here (the scratch is the only per-thread mutable state a
    /// lookup touches) and drained into the serving metrics by
    /// [`Self::take_prefilter_rejects`].
    prefilter_rejects: u64,
}

impl Default for DecodeScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl DecodeScratch {
    /// An empty scratch; buffers are sized on first use.
    pub fn new() -> Self {
        DecodeScratch {
            act: BitVec::zeros(0),
            enables: BitVec::zeros(0),
            idx: Vec::new(),
            prefilter_rejects: 0,
        }
    }

    /// Pre-size for a design point (avoids the first-use allocation).
    pub fn for_config(cfg: &DesignConfig) -> Self {
        DecodeScratch {
            act: BitVec::zeros(cfg.m),
            enables: BitVec::zeros(cfg.beta()),
            idx: Vec::with_capacity(cfg.c),
            prefilter_rejects: 0,
        }
    }

    /// Resize the buffers to a state's geometry, reusing the allocations.
    ///
    /// Shrinking **truncates and zeroes** the reclaimed region
    /// ([`BitVec::resize`]): the word-level winner-take-all reads whole
    /// word slices, so a bank-split/retrain shrink that merely adjusted the
    /// length while leaving stale high words would feed garbage into the
    /// AND-reduce.  The regression test
    /// `scratch_shrink_leaves_no_stale_words` pins this down.
    #[inline]
    fn ensure(&mut self, m: usize, beta: usize) {
        if self.act.len() != m {
            self.act.resize(m);
        }
        if self.enables.len() != beta {
            self.enables.resize(beta);
        }
    }

    /// Drain the pre-filter reject counter (serving layers feed it into
    /// `cscam_prefilter_rejects_total`).
    pub fn take_prefilter_rejects(&mut self) -> u64 {
        std::mem::take(&mut self.prefilter_rejects)
    }
}

/// The immutable search half of an engine: everything a lookup reads.
///
/// Shared behind an `Arc` by the serving layers; [`Self::lookup`] takes
/// `&self` plus a caller-owned [`DecodeScratch`], so any number of threads
/// can search one published state concurrently, each with its own scratch.
/// Bit-for-bit identical to driving [`LookupEngine::lookup`] on the same
/// state — it *is* the same code.
#[derive(Debug, Clone)]
pub struct SearchState {
    cfg: DesignConfig,
    selection: Selection,
    net: ClusteredNetwork,
    cam: CamArray,
    /// Counting-bloom pre-filter over the valid tags: a negative answer
    /// short-circuits [`Self::lookup`] before decode (the software analog
    /// of SMLE-CAM's match-line pre-screening).  Maintained by the single
    /// writer on insert/delete; rebuilt deterministically from the CAM when
    /// a restore source carries no filter section.
    filter: BankFilter,
    energy: EnergyModel,
    delay: DelayReport,
}

impl SearchState {
    fn new(cfg: DesignConfig, selection: Selection, net: ClusteredNetwork, cam: CamArray) -> Self {
        let filter = Self::rebuild_filter(&cam);
        Self::with_filter(cfg, selection, net, cam, filter)
    }

    fn with_filter(
        cfg: DesignConfig,
        selection: Selection,
        net: ClusteredNetwork,
        cam: CamArray,
        filter: BankFilter,
    ) -> Self {
        let energy = EnergyModel::new(cfg.clone());
        let delay = proposed_delay(&cfg, &DelayConstants::reference());
        SearchState { cfg, selection, net, cam, filter, energy, delay }
    }

    /// The filter a CAM's valid tags deterministically imply — what the
    /// writer-maintained filter always equals (asserted by the decode-kernel
    /// battery) and what restore uses when no filter section is present.
    pub fn rebuild_filter(cam: &CamArray) -> BankFilter {
        let mut f = BankFilter::new(cam.m());
        for addr in cam.valid_bits().iter_ones() {
            f.add(&cam.slab().row(addr));
        }
        f
    }

    pub fn config(&self) -> &DesignConfig {
        &self.cfg
    }

    pub fn selection(&self) -> &Selection {
        &self.selection
    }

    /// The clustered network (weight rows for the PJRT artifact upload).
    pub fn network(&self) -> &ClusteredNetwork {
        &self.net
    }

    /// The CAM array (snapshot encoding reads tags + valid bits off it).
    pub fn cam(&self) -> &CamArray {
        &self.cam
    }

    /// The bloom pre-filter (snapshot encoding serializes its cells).
    pub fn filter(&self) -> &BankFilter {
        &self.filter
    }

    pub fn occupancy(&self) -> usize {
        self.cam.occupancy()
    }

    /// The full proposed-architecture lookup — pure: `&self` state, caller
    /// scratch, no interior mutability.  This is the concurrent hot path.
    ///
    /// The bloom pre-filter runs first: a negative answer is definitive
    /// (no false negatives), so the lookup returns a miss with zero
    /// compared rows and zero enabled blocks — the accounting of a decode
    /// that activated nothing (λ = 0), mirroring a match-line that was
    /// never energized.  For any tag the filter passes — every stored tag,
    /// plus the ~5 % false positives — the outcome is bit-identical to
    /// [`Self::lookup_unfiltered`], because it *is* that code.
    pub fn lookup(
        &self,
        tag: &BitVec,
        scratch: &mut DecodeScratch,
    ) -> Result<LookupOutcome, EngineError> {
        if tag.len() != self.cfg.n {
            return Err(EngineError::TagWidth { got: tag.len(), want: self.cfg.n });
        }
        if !self.filter.may_contain(tag) {
            scratch.prefilter_rejects += 1;
            return Ok(self.rejected_outcome());
        }
        self.lookup_unfiltered(tag, scratch)
    }

    /// The lookup with the pre-filter bypassed: always runs the CNN decode
    /// and the enabled-block compare.  This is the reference the
    /// bit-identity battery checks the filtered path against, and the
    /// baseline side of the `decode_hotpath` bench.
    pub fn lookup_unfiltered(
        &self,
        tag: &BitVec,
        scratch: &mut DecodeScratch,
    ) -> Result<LookupOutcome, EngineError> {
        if tag.len() != self.cfg.n {
            return Err(EngineError::TagWidth { got: tag.len(), want: self.cfg.n });
        }
        scratch.ensure(self.cfg.m, self.cfg.beta());
        // Stage 1 (CNN): tag reduction + LD + GD → compare enables.
        self.selection.apply_into(tag, &mut scratch.idx);
        let lambda = self.net.decode_into(&scratch.idx, &mut scratch.act, &mut scratch.enables);

        // Stage 2 (CAM): search only the enabled sub-blocks.
        let result = self.cam.search(tag, &scratch.enables);
        let energy = self.energy.proposed_measured(&result.activity, 1);

        Ok(LookupOutcome {
            addr: result.matches.first().copied(),
            all_matches: result.matches,
            lambda,
            enabled_blocks: result.activity.enabled_blocks,
            comparisons: result.activity.enabled_rows,
            energy,
            delay: self.delay,
        })
    }

    /// The outcome of a pre-filter reject: exactly what
    /// [`Self::lookup_unfiltered`] reports when the decode activates no
    /// P_II neuron — λ = 0, no enabled blocks, no compared rows, and the
    /// modelled energy of that all-quiet search.
    fn rejected_outcome(&self) -> LookupOutcome {
        let activity = SearchActivity {
            total_blocks: self.cfg.beta(),
            tag_bits: self.cfg.n,
            ..SearchActivity::default()
        };
        LookupOutcome {
            addr: None,
            all_matches: Vec::new(),
            lambda: 0,
            enabled_blocks: 0,
            comparisons: 0,
            energy: self.energy.proposed_measured(&activity, 1),
            delay: self.delay,
        }
    }

    /// Lookup with an externally computed enable mask (the PJRT decode
    /// path: the batcher ships cluster indices to the artifact and feeds
    /// the resulting masks back here for the CAM stage).
    pub fn lookup_with_enables(
        &self,
        tag: &BitVec,
        enables: &BitVec,
        lambda: usize,
    ) -> Result<LookupOutcome, EngineError> {
        if tag.len() != self.cfg.n {
            return Err(EngineError::TagWidth { got: tag.len(), want: self.cfg.n });
        }
        let result = self.cam.search(tag, enables);
        let energy = self.energy.proposed_measured(&result.activity, 1);
        Ok(LookupOutcome {
            addr: result.matches.first().copied(),
            all_matches: result.matches,
            lambda,
            enabled_blocks: result.activity.enabled_blocks,
            comparisons: result.activity.enabled_rows,
            energy,
            delay: self.delay,
        })
    }

    /// Baseline: conventional full-array search (all blocks enabled), with
    /// the conventional energy model — used by the Table II harness.
    pub fn lookup_conventional(
        &self,
        tag: &BitVec,
        ml: crate::cam::MatchlineKind,
    ) -> Result<LookupOutcome, EngineError> {
        if tag.len() != self.cfg.n {
            return Err(EngineError::TagWidth { got: tag.len(), want: self.cfg.n });
        }
        let result = self.cam.search_all(tag);
        let energy = self.energy.conventional(ml);
        let delay = crate::timing::conventional_delay(
            self.cfg.m,
            self.cfg.n,
            ml,
            &DelayConstants::reference(),
            self.cfg.tech(),
        );
        Ok(LookupOutcome {
            addr: result.matches.first().copied(),
            all_matches: result.matches,
            lambda: self.cfg.m, // no classifier: every row is a candidate
            enabled_blocks: result.activity.enabled_blocks,
            comparisons: result.activity.enabled_rows,
            energy,
            delay,
        })
    }

    /// Raw functional search with every sub-block enabled and no CNN stage:
    /// the pure content of the array.  This is the anchor the sharded
    /// scatter-gather path ([`crate::shard::ShardedCam`]) is checked
    /// against bit-for-bit.  Panics on a tag-width mismatch (the callers
    /// validate widths at the API boundary).
    pub fn search_unclassified(&self, tag: &BitVec) -> crate::cam::SearchResult {
        self.cam.search_all(tag)
    }

    /// Cluster indices for a tag (what the PJRT decode path ships).
    pub fn cluster_indices(&self, tag: &BitVec) -> Vec<u16> {
        self.selection.apply(tag)
    }
}

/// The RCU publish slot: single writer, any number of snapshot readers.
///
/// The serving layer's writer thread publishes a fresh `Arc<SearchState>`
/// after every acknowledged mutation (strictly *after* the WAL ack, so a
/// reader can never observe un-logged state); readers grab the current
/// `Arc` with one brief read-lock and then search entirely lock-free.  A
/// snapshot stays valid (and consistent) for as long as the reader holds
/// the `Arc`, even across concurrent publishes.
///
/// This is a domain-typed wrapper around the generic
/// [`crate::util::sync::PublishSlot`] — the primitive the loom battery
/// model-checks (`rust/tests/loom_models.rs`).
#[derive(Debug, Clone)]
pub struct SharedSearch {
    slot: Arc<crate::util::sync::PublishSlot<SearchState>>,
}

impl SharedSearch {
    /// A slot holding `initial` until the first publish.
    pub fn new(initial: Arc<SearchState>) -> Self {
        SharedSearch { slot: Arc::new(crate::util::sync::PublishSlot::new(initial)) }
    }

    /// The current published state.  O(1): clones the `Arc`, not the state.
    pub fn snapshot(&self) -> Arc<SearchState> {
        self.slot.snapshot()
    }

    /// Publish a new state (single-writer discipline: only the engine
    /// thread of the owning server calls this).
    pub fn publish(&self, state: Arc<SearchState>) {
        self.slot.publish(state)
    }
}

/// The proposed architecture, end to end: tag-bit selection → CNN decode →
/// sub-block compare-enabled CAM search → priority encode, with energy and
/// delay accounting per search.
///
/// This is the *writer* handle: mutations (`insert`/`delete`/`retrain`)
/// copy-on-write the shared [`SearchState`]; reads go through the state
/// (the `&mut self` convenience [`Self::lookup`] just reuses an internal
/// scratch).  Concurrent readers hold `Arc<SearchState>` snapshots from
/// [`Self::search_state`] and never touch the engine.
#[derive(Debug, Clone)]
pub struct LookupEngine {
    state: Arc<SearchState>,
    /// Associations currently live (addr → cluster indices), for retrains.
    live: Vec<Option<Vec<u16>>>,
    /// Deletes since the last retrain leave stale weights (superposition);
    /// they only cost energy, never correctness.
    stale_deletes: usize,
    /// Insert cursor: every slot below this index is occupied, so the
    /// lowest-free-slot scan of [`Self::insert`] starts here instead of at
    /// zero.  The hint is conservative (it may lag behind the true
    /// frontier after a WAL replay), which never changes which address an
    /// insert picks — only how far it scans.  Persisted by the snapshot
    /// codec ([`crate::store::snapshot`]).
    first_free: usize,
    /// Retrain when stale deletes exceed this fraction of M (0 disables).
    pub retrain_threshold: f64,
    /// Writer-local scratch for the `&mut self` lookup convenience.
    scratch: DecodeScratch,
}

impl LookupEngine {
    /// Build an empty engine for a design point with an explicit bit
    /// selection.
    pub fn with_selection(cfg: DesignConfig, selection: Selection) -> Self {
        cfg.validate().expect("invalid design config");
        assert_eq!(selection.q(), cfg.q(), "selection width must equal q");
        assert_eq!(selection.c(), cfg.c, "selection clusters must equal c");
        let net = ClusteredNetwork::from_config(&cfg);
        let cam = CamArray::new(cfg.m, cfg.n, cfg.zeta);
        let m = cfg.m;
        let scratch = DecodeScratch::for_config(&cfg);
        LookupEngine {
            state: Arc::new(SearchState::new(cfg, selection, net, cam)),
            live: vec![None; m],
            stale_deletes: 0,
            first_free: 0,
            retrain_threshold: 0.25,
            scratch,
        }
    }

    /// Rebuild an engine from persisted state — the restore half of the
    /// snapshot codec ([`crate::store::snapshot::BankImage`]).  All inputs
    /// are validated (they may come from a corrupt file); on success the
    /// engine is field-for-field identical to the one the image was taken
    /// from: same matches, λ, energy and delay for every tag.
    /// `filter` is the serialized pre-filter when the source image carried
    /// one (snapshot v2+); `None` — a v1 image, or any older producer —
    /// rebuilds it from the CAM's valid tags, which yields the exact same
    /// filter the writer would have maintained (rebuild is deterministic).
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        cfg: DesignConfig,
        selection: Selection,
        net: ClusteredNetwork,
        cam: CamArray,
        filter: Option<BankFilter>,
        stale_deletes: usize,
        retrain_threshold: f64,
        insert_cursor: usize,
    ) -> Result<Self, String> {
        cfg.validate().map_err(|e| format!("invalid design config: {e}"))?;
        if selection.q() != cfg.q() || selection.c() != cfg.c || selection.k() != cfg.k() {
            return Err(format!(
                "selection geometry (q={}, c={}, k={}) does not match the config (q={}, c={}, k={})",
                selection.q(),
                selection.c(),
                selection.k(),
                cfg.q(),
                cfg.c,
                cfg.k()
            ));
        }
        if let Some(&p) = selection.positions().iter().find(|&&p| p >= cfg.n) {
            return Err(format!("selection position {p} out of range for N={}", cfg.n));
        }
        if net.c() != cfg.c || net.l() != cfg.l || net.m() != cfg.m || net.zeta() != cfg.zeta {
            return Err(format!(
                "network geometry ({}x{} rows of {} bits, ζ={}) does not match the config",
                net.c(),
                net.l(),
                net.m(),
                net.zeta()
            ));
        }
        if cam.m() != cfg.m || cam.n() != cfg.n || cam.zeta() != cfg.zeta {
            return Err(format!(
                "CAM geometry ({}x{}, ζ={}) does not match the config",
                cam.m(),
                cam.n(),
                cam.zeta()
            ));
        }
        if insert_cursor > cfg.m {
            return Err(format!("insert cursor {insert_cursor} past M={}", cfg.m));
        }
        if let Some(free) = (0..insert_cursor).find(|&a| cam.read(a).is_none()) {
            return Err(format!(
                "insert cursor {insert_cursor} claims slot {free} is occupied, but it is free"
            ));
        }
        if !retrain_threshold.is_finite() || retrain_threshold < 0.0 {
            return Err(format!("retrain threshold {retrain_threshold} out of range"));
        }
        let filter = match filter {
            Some(f) => {
                let expected = BankFilter::new(cfg.m).len();
                if f.len() != expected {
                    return Err(format!(
                        "filter has {} cells, expected {expected} for M={}",
                        f.len(),
                        cfg.m
                    ));
                }
                if f.keys() != cam.occupancy() as u64 {
                    return Err(format!(
                        "filter covers {} keys but the CAM holds {} valid entries",
                        f.keys(),
                        cam.occupancy()
                    ));
                }
                f
            }
            None => SearchState::rebuild_filter(&cam),
        };
        // `live` is derived state: valid slot ⇔ live association, and the
        // cluster indices are a pure function of the stored tag.
        let live: Vec<Option<Vec<u16>>> =
            (0..cfg.m).map(|a| cam.read(a).map(|t| selection.apply(&t))).collect();
        let scratch = DecodeScratch::for_config(&cfg);
        Ok(LookupEngine {
            state: Arc::new(SearchState::with_filter(cfg, selection, net, cam, filter)),
            live,
            stale_deletes,
            first_free: insert_cursor,
            retrain_threshold,
            scratch,
        })
    }

    /// Build with the default strided bit selection (§II-B: spread the q
    /// bits across the tag to reduce correlation).
    pub fn new(cfg: DesignConfig) -> Self {
        let sel = Selection::strided(cfg.n, cfg.c, cfg.k());
        Self::with_selection(cfg, sel)
    }

    /// The current search state behind its `Arc` — O(1).  The serving
    /// layer publishes this through a [`SharedSearch`] slot after every
    /// acknowledged mutation; tests and benches use it to run concurrent
    /// lookups without a server.
    pub fn search_state(&self) -> Arc<SearchState> {
        Arc::clone(&self.state)
    }

    pub fn config(&self) -> &DesignConfig {
        self.state.config()
    }

    pub fn selection(&self) -> &Selection {
        self.state.selection()
    }

    /// The CNN's weight rows, materialized from the slab (to ship to the
    /// PJRT decode artifact).
    pub fn weight_rows(&self) -> Vec<BitVec> {
        self.state.network().weight_rows()
    }

    pub fn occupancy(&self) -> usize {
        self.state.cam().occupancy()
    }

    /// The CAM array (snapshot encoding reads tags + valid bits off it).
    pub fn cam(&self) -> &CamArray {
        self.state.cam()
    }

    /// The clustered network (snapshot encoding reads the weight rows).
    pub fn network(&self) -> &ClusteredNetwork {
        self.state.network()
    }

    /// Deletes since the last retrain (persisted so a recovered engine
    /// triggers its next retrain at exactly the same point).
    pub fn stale_delete_count(&self) -> usize {
        self.stale_deletes
    }

    /// The lowest-free-slot scan hint (see [`Self::insert`]).
    pub fn insert_cursor(&self) -> usize {
        self.first_free
    }

    /// Insert a tag into the lowest free slot; returns the address.  The
    /// scan starts at the insert cursor (every lower slot is occupied), so
    /// sequential fills are O(1) per insert instead of O(M).
    pub fn insert(&mut self, tag: &BitVec) -> Result<usize, EngineError> {
        let addr = (self.first_free..self.state.cfg.m)
            .find(|&a| self.live[a].is_none() && self.state.cam.read(a).is_none())
            .ok_or(EngineError::Full)?;
        self.insert_at(addr, tag)?;
        self.first_free = addr + 1;
        Ok(addr)
    }

    /// Insert a tag at a specific address (TLB-style replacement).
    pub fn insert_at(&mut self, addr: usize, tag: &BitVec) -> Result<(), EngineError> {
        if tag.len() != self.state.cfg.n {
            return Err(EngineError::TagWidth { got: tag.len(), want: self.state.cfg.n });
        }
        if addr >= self.state.cfg.m {
            return Err(EngineError::BadAddress(addr));
        }
        // Replacing a live entry leaves its old weights stale (superposed);
        // its old tag leaves the pre-filter with it (read before overwrite).
        let replaced = if self.live[addr].is_some() {
            self.stale_deletes += 1;
            self.state.cam.read(addr)
        } else {
            None
        };
        let mut idx = Vec::with_capacity(self.state.cfg.c);
        self.state.selection.apply_into(tag, &mut idx);
        // Copy-on-write: clones the state only when a published snapshot
        // (or another engine clone) still shares it.  Behind a serving
        // publish slot that is exactly once per acknowledged mutation —
        // the RCU trade: writes pay an O(bank) copy so reads never take a
        // lock.  Bulk loads that mutate many times between publishes
        // (recovery replay, pre-population before `spawn`) clone at most
        // once, because only the first `make_mut` after a publish copies.
        let st = Arc::make_mut(&mut self.state);
        st.net.train(&idx, addr);
        st.cam.write(addr, tag.clone());
        if let Some(old) = replaced {
            st.filter.remove(&old);
        }
        st.filter.add(tag);
        self.live[addr] = Some(idx);
        self.maybe_retrain();
        Ok(())
    }

    /// Delete by address.  The CAM row is invalidated immediately; the CNN
    /// weights stay until the staleness threshold triggers a retrain
    /// (weights are superposed — stale ones cost energy, not correctness).
    pub fn delete(&mut self, addr: usize) -> Result<(), EngineError> {
        if addr >= self.state.cfg.m {
            return Err(EngineError::BadAddress(addr));
        }
        if self.live[addr].take().is_some() {
            // Read the tag before invalidating the row: the filter tracks
            // tag contents, the valid bit only gates the compare.
            let old = self.state.cam.read(addr);
            let st = Arc::make_mut(&mut self.state);
            st.cam.erase(addr);
            if let Some(old) = old {
                st.filter.remove(&old);
            }
            self.first_free = self.first_free.min(addr);
            self.stale_deletes += 1;
            self.maybe_retrain();
        }
        Ok(())
    }

    fn maybe_retrain(&mut self) {
        if self.retrain_threshold > 0.0
            && self.stale_deletes as f64 > self.retrain_threshold * self.state.cfg.m as f64
        {
            self.retrain();
        }
    }

    /// Rebuild the CNN from the live associations (drops stale weights).
    pub fn retrain(&mut self) {
        let entries: Vec<(Vec<u16>, usize)> = self
            .live
            .iter()
            .enumerate()
            .filter_map(|(a, idx)| idx.clone().map(|i| (i, a)))
            .collect();
        Arc::make_mut(&mut self.state)
            .net
            .retrain_from(entries.iter().map(|(i, a)| (i.as_slice(), *a)));
        self.stale_deletes = 0;
    }

    /// Fraction of trained weights that are stale.
    pub fn stale_fraction(&self) -> f64 {
        self.stale_deletes as f64 / self.state.cfg.m as f64
    }

    /// The full proposed-architecture lookup.  `&mut self` only for the
    /// writer-local scratch — semantically read-only, and bit-identical to
    /// [`SearchState::lookup`] on [`Self::search_state`] (the concurrent
    /// equivalence tests assert exactly that).
    pub fn lookup(&mut self, tag: &BitVec) -> Result<LookupOutcome, EngineError> {
        self.state.lookup(tag, &mut self.scratch)
    }

    /// Lookup with the pre-filter bypassed — see
    /// [`SearchState::lookup_unfiltered`].  The decode always runs, so
    /// stale superposed weights still fire the classifier; the bit-identity
    /// battery and the `decode_hotpath` bench baseline use this path.
    pub fn lookup_unfiltered(&mut self, tag: &BitVec) -> Result<LookupOutcome, EngineError> {
        self.state.lookup_unfiltered(tag, &mut self.scratch)
    }

    /// Drain the writer-scratch pre-filter reject counter (the engine-thread
    /// serving path feeds it into the bank metrics).
    pub fn take_prefilter_rejects(&mut self) -> u64 {
        self.scratch.take_prefilter_rejects()
    }

    /// Lookup with an externally computed enable mask (the PJRT decode
    /// path).  Pure read: shared references suffice.
    pub fn lookup_with_enables(
        &self,
        tag: &BitVec,
        enables: &BitVec,
        lambda: usize,
    ) -> Result<LookupOutcome, EngineError> {
        self.state.lookup_with_enables(tag, enables, lambda)
    }

    /// Cluster indices for a tag (what the PJRT decode path ships).
    pub fn cluster_indices(&self, tag: &BitVec) -> Vec<u16> {
        self.state.cluster_indices(tag)
    }

    /// Raw functional search with every sub-block enabled and no CNN
    /// stage — see [`SearchState::search_unclassified`].
    pub fn search_unclassified(&self, tag: &BitVec) -> crate::cam::SearchResult {
        self.state.search_unclassified(tag)
    }

    /// Baseline: conventional full-array search — used by the Table II
    /// harness.  Pure read: shared references suffice.
    pub fn lookup_conventional(
        &self,
        tag: &BitVec,
        ml: crate::cam::MatchlineKind,
    ) -> Result<LookupOutcome, EngineError> {
        self.state.lookup_conventional(tag, ml)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TagDistribution;
    use crate::util::Rng;

    fn small_engine() -> LookupEngine {
        LookupEngine::new(DesignConfig::small_test())
    }

    fn fill(engine: &mut LookupEngine, count: usize, seed: u64) -> Vec<BitVec> {
        let mut rng = Rng::seed_from_u64(seed);
        let tags =
            TagDistribution::Uniform.sample_distinct(engine.config().n, count, &mut rng);
        for t in &tags {
            engine.insert(t).unwrap();
        }
        tags
    }

    #[test]
    fn insert_then_lookup_hits() {
        let mut e = small_engine();
        let tags = fill(&mut e, 32, 1);
        for (i, t) in tags.iter().enumerate() {
            let out = e.lookup(t).unwrap();
            assert_eq!(out.addr, Some(i), "tag {i}");
            assert!(out.lambda >= 1);
            assert!(out.enabled_blocks >= 1);
        }
    }

    #[test]
    fn miss_returns_none_often_with_zero_comparisons() {
        let mut e = small_engine();
        fill(&mut e, 16, 2);
        let mut rng = Rng::seed_from_u64(99);
        let mut zero_comparison_misses = 0;
        for _ in 0..200 {
            let t = crate::workload::random_tag(e.config().n, &mut rng);
            let out = e.lookup(&t).unwrap();
            assert!(out.addr.is_none() || e.cam_tag_equal(&t, out.addr.unwrap()));
            if out.addr.is_none() && out.comparisons == 0 {
                zero_comparison_misses += 1;
            }
        }
        // with q=6 and 16 entries most random queries decode to nothing
        assert!(zero_comparison_misses > 100, "got {zero_comparison_misses}");
    }

    #[test]
    fn lookup_energy_is_far_below_conventional() {
        let mut e = LookupEngine::new(DesignConfig::reference());
        let tags = fill(&mut e, 512, 3);
        let mut prop = 0.0;
        let mut conv = 0.0;
        for t in tags.iter().take(64) {
            prop += e.lookup(t).unwrap().energy.total_fj();
            conv += e
                .lookup_conventional(t, crate::cam::MatchlineKind::Nand)
                .unwrap()
                .energy
                .total_fj();
        }
        let ratio = prop / conv;
        // headline: ~9.5 % (band reflects workload variance)
        assert!((0.05..0.20).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn delete_then_lookup_misses_but_costs_energy_until_retrain() {
        let mut e = small_engine();
        e.retrain_threshold = 0.0; // manual retrain only
        let tags = fill(&mut e, 8, 4);
        e.delete(3).unwrap();
        // The deleted tag left the pre-filter with the delete, so the
        // filtered path answers the miss without decoding at all…
        let out = e.lookup(&tags[3]).unwrap();
        assert_eq!(out.addr, None);
        assert_eq!(out.lambda, 0, "pre-filter rejects the deleted tag before decode");
        assert_eq!(out.comparisons, 0);
        // …while the unfiltered reference path still pays for the stale
        // superposed weights until a retrain clears them.
        let out = e.lookup_unfiltered(&tags[3]).unwrap();
        assert_eq!(out.addr, None);
        assert!(out.lambda >= 1, "stale weights still fire the classifier");
        e.retrain();
        let out = e.lookup_unfiltered(&tags[3]).unwrap();
        assert_eq!(out.addr, None);
        assert_eq!(out.lambda, 0, "retrain clears stale weights");
    }

    #[test]
    fn prefilter_reject_matches_lambda_zero_accounting() {
        // A rejected lookup must be indistinguishable from an unfiltered
        // decode that activated nothing: same energy, delay and counters.
        let mut e = small_engine();
        e.retrain_threshold = 0.0;
        let tags = fill(&mut e, 8, 4);
        e.delete(3).unwrap();
        e.retrain(); // now the unfiltered path also decodes to λ=0
        let filtered = e.lookup(&tags[3]).unwrap();
        let unfiltered = e.lookup_unfiltered(&tags[3]).unwrap();
        assert_eq!(filtered, unfiltered, "reject == λ=0 decode, field for field");
    }

    #[test]
    fn prefilter_never_rejects_stored_tags_and_counts_rejects() {
        let mut e = small_engine();
        let tags = fill(&mut e, 32, 21);
        let state = e.search_state();
        let mut scratch = DecodeScratch::new();
        for t in &tags {
            assert_eq!(state.lookup(t, &mut scratch).unwrap(), e.lookup_unfiltered(t).unwrap());
        }
        assert_eq!(scratch.take_prefilter_rejects(), 0, "stored tags never reject");
        let mut rng = Rng::seed_from_u64(22);
        let mut rejects = 0u64;
        for _ in 0..200 {
            let t = crate::workload::random_tag(e.config().n, &mut rng);
            let out = state.lookup(&t, &mut scratch).unwrap();
            assert!(out.addr.is_none() || e.cam_tag_equal(&t, out.addr.unwrap()));
            rejects += scratch.take_prefilter_rejects();
        }
        assert!(rejects > 150, "random 32-bit probes should mostly reject, got {rejects}");
        assert_eq!(scratch.take_prefilter_rejects(), 0, "take drains the counter");
    }

    #[test]
    fn automatic_retrain_after_threshold() {
        let mut e = small_engine();
        e.retrain_threshold = 0.1;
        let _tags = fill(&mut e, 32, 5);
        for a in 0..8 {
            e.delete(a).unwrap();
        }
        assert!(e.stale_fraction() < 0.1, "retrain must have fired");
    }

    #[test]
    fn replacement_at_same_address_updates_mapping() {
        let mut e = small_engine();
        let tags = fill(&mut e, 4, 6);
        let mut rng = Rng::seed_from_u64(77);
        let newt = crate::workload::random_tag(e.config().n, &mut rng);
        e.insert_at(2, &newt).unwrap();
        assert_eq!(e.lookup(&newt).unwrap().addr, Some(2));
        assert_eq!(e.lookup(&tags[2]).unwrap().addr, None, "old tag gone from CAM");
    }

    #[test]
    fn full_cam_rejects_insert() {
        let mut e = small_engine();
        fill(&mut e, 64, 7);
        let mut rng = Rng::seed_from_u64(123);
        let t = crate::workload::random_tag(e.config().n, &mut rng);
        assert_eq!(e.insert(&t), Err(EngineError::Full));
    }

    #[test]
    fn insert_cursor_still_picks_lowest_free_slot() {
        let mut e = small_engine();
        fill(&mut e, 10, 9);
        assert_eq!(e.insert_cursor(), 10);
        e.delete(7).unwrap();
        e.delete(3).unwrap();
        assert_eq!(e.insert_cursor(), 3, "delete lowers the hint to the freed slot");
        let mut rng = Rng::seed_from_u64(55);
        let t1 = crate::workload::random_tag(e.config().n, &mut rng);
        let t2 = crate::workload::random_tag(e.config().n, &mut rng);
        assert_eq!(e.insert(&t1).unwrap(), 3, "lowest free slot first");
        assert_eq!(e.insert(&t2).unwrap(), 7);
    }

    #[test]
    fn from_parts_rebuilds_a_bit_identical_engine() {
        let mut e = small_engine();
        e.retrain_threshold = 0.0;
        let tags = fill(&mut e, 20, 10);
        e.delete(5).unwrap();
        let mut rebuilt = LookupEngine::from_parts(
            e.config().clone(),
            e.selection().clone(),
            e.network().clone(),
            e.cam().clone(),
            Some(e.search_state().filter().clone()),
            e.stale_delete_count(),
            e.retrain_threshold,
            e.insert_cursor(),
        )
        .unwrap();
        assert_eq!(rebuilt.search_state().filter(), e.search_state().filter());
        assert_eq!(rebuilt.occupancy(), e.occupancy());
        assert_eq!(rebuilt.insert_cursor(), e.insert_cursor());
        for t in &tags {
            assert_eq!(e.lookup(t).unwrap(), rebuilt.lookup(t).unwrap());
        }
    }

    #[test]
    fn from_parts_rejects_inconsistent_state() {
        let e = small_engine();
        let cfg = e.config().clone();
        // a cursor claiming occupancy over free slots must be rejected
        assert!(LookupEngine::from_parts(
            cfg.clone(),
            e.selection().clone(),
            e.network().clone(),
            e.cam().clone(),
            None,
            0,
            0.25,
            5,
        )
        .is_err());
        // mismatched CAM geometry
        let wrong_cam = CamArray::new(cfg.m * 2, cfg.n, cfg.zeta);
        assert!(LookupEngine::from_parts(
            cfg.clone(),
            e.selection().clone(),
            e.network().clone(),
            wrong_cam,
            None,
            0,
            0.25,
            0,
        )
        .is_err());
        // a filter whose key count disagrees with the CAM occupancy
        let stale_filter = crate::cam::BankFilter::new(cfg.m);
        let mut full = small_engine();
        fill(&mut full, 4, 33);
        assert!(LookupEngine::from_parts(
            cfg,
            full.selection().clone(),
            full.network().clone(),
            full.cam().clone(),
            Some(stale_filter),
            0,
            0.25,
            4,
        )
        .is_err());
    }

    #[test]
    fn wrong_tag_width_rejected() {
        let mut e = small_engine();
        let t = BitVec::zeros(16);
        assert!(matches!(e.lookup(&t), Err(EngineError::TagWidth { .. })));
        assert!(matches!(e.insert(&t), Err(EngineError::TagWidth { .. })));
        let mut scratch = DecodeScratch::new();
        assert!(matches!(
            e.search_state().lookup(&t, &mut scratch),
            Err(EngineError::TagWidth { .. })
        ));
    }

    #[test]
    fn pjrt_style_external_enables_path_agrees_with_native() {
        let mut e = small_engine();
        let tags = fill(&mut e, 24, 8);
        for t in &tags {
            let idx = e.cluster_indices(t);
            let native = e.lookup(t).unwrap();
            // recompute enables via the network directly (stand-in for the
            // PJRT artifact; the real cross-check lives in rust/tests/)
            let act = e.network().decode(&idx);
            let ext = e.lookup_with_enables(t, &act.enables, act.lambda).unwrap();
            assert_eq!(native.addr, ext.addr);
            assert_eq!(native.lambda, ext.lambda);
            assert_eq!(native.enabled_blocks, ext.enabled_blocks);
        }
    }

    #[test]
    fn search_state_lookup_is_bit_identical_to_engine_lookup() {
        // the tentpole invariant: a snapshot + per-thread scratch answers
        // exactly what the engine answers, field for field, hits and misses
        let mut e = small_engine();
        let tags = fill(&mut e, 40, 14);
        let state = e.search_state();
        let mut scratch = DecodeScratch::new();
        let mut rng = Rng::seed_from_u64(15);
        let mut probes = tags.clone();
        probes.extend((0..40).map(|_| crate::workload::random_tag(e.config().n, &mut rng)));
        for t in &probes {
            assert_eq!(state.lookup(t, &mut scratch).unwrap(), e.lookup(t).unwrap());
        }
    }

    #[test]
    fn snapshots_are_immune_to_later_mutations() {
        // RCU semantics: a snapshot taken before a mutation keeps answering
        // from the old state; a snapshot taken after sees the new one.
        let mut e = small_engine();
        let tags = fill(&mut e, 8, 16);
        let before = e.search_state();
        e.delete(3).unwrap();
        let after = e.search_state();
        let mut scratch = DecodeScratch::new();
        assert_eq!(before.lookup(&tags[3], &mut scratch).unwrap().addr, Some(3));
        assert_eq!(after.lookup(&tags[3], &mut scratch).unwrap().addr, None);
    }

    #[test]
    fn one_scratch_serves_many_geometries() {
        let mut small = small_engine();
        let mut big = LookupEngine::new(DesignConfig::reference());
        let ts = fill(&mut small, 4, 17);
        let tb = fill(&mut big, 4, 18);
        let mut scratch = DecodeScratch::new();
        assert_eq!(
            small.search_state().lookup(&ts[0], &mut scratch).unwrap().addr,
            Some(0)
        );
        assert_eq!(big.search_state().lookup(&tb[1], &mut scratch).unwrap().addr, Some(1));
        assert_eq!(
            small.search_state().lookup(&ts[2], &mut scratch).unwrap().addr,
            Some(2)
        );
    }

    #[test]
    fn scratch_shrink_leaves_no_stale_words() {
        // Regression: a scratch warmed on a big geometry then reused on a
        // small one must behave exactly like a fresh scratch — the resize
        // has to truncate AND zero, or stale high words from the big
        // bank would sit where the word-level kernels can see them.
        let mut big = LookupEngine::new(DesignConfig::reference());
        let mut small = small_engine();
        let tb = fill(&mut big, 64, 23);
        let ts = fill(&mut small, 16, 24);
        let mut reused = DecodeScratch::new();
        let big_state = big.search_state();
        for t in &tb {
            big_state.lookup(t, &mut reused).unwrap();
        }
        let small_state = small.search_state();
        let mut rng = Rng::seed_from_u64(25);
        let mut probes = ts.clone();
        probes.extend((0..32).map(|_| crate::workload::random_tag(small.config().n, &mut rng)));
        for t in &probes {
            let mut fresh = DecodeScratch::new();
            assert_eq!(
                small_state.lookup(t, &mut reused).unwrap(),
                small_state.lookup(t, &mut fresh).unwrap()
            );
        }
    }

    #[test]
    fn shared_search_publish_and_snapshot() {
        let mut e = small_engine();
        let shared = SharedSearch::new(e.search_state());
        let tags = fill(&mut e, 4, 19);
        let mut scratch = DecodeScratch::new();
        // not yet published: the slot still answers from the empty state
        assert_eq!(shared.snapshot().lookup(&tags[0], &mut scratch).unwrap().addr, None);
        shared.publish(e.search_state());
        assert_eq!(
            shared.snapshot().lookup(&tags[0], &mut scratch).unwrap().addr,
            Some(0)
        );
    }

    #[test]
    fn busy_and_full_are_distinct_errors() {
        assert_ne!(EngineError::Busy, EngineError::Full);
        assert!(EngineError::Full.to_string().contains("full"));
        assert!(EngineError::Busy.to_string().contains("queue"));
    }

    impl LookupEngine {
        fn cam_tag_equal(&self, tag: &BitVec, addr: usize) -> bool {
            self.cam().read(addr).map(|t| &t == tag).unwrap_or(false)
        }
    }
}
