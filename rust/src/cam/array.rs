//! Functional CAM array with per-search switching-activity accounting.

use crate::bits::{kernel, BitSlab, BitVec};
use crate::energy::SearchActivity;

/// One search's outcome: the matching addresses plus the switching activity
/// the energy/timing models consume.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchResult {
    /// Addresses of valid entries that matched the full tag, ascending.
    pub matches: Vec<usize>,
    /// Switching-activity counters for the energy model.
    pub activity: SearchActivity,
}

/// A binary CAM of `m` entries × `n` tag bits, split into `m/ζ` sub-blocks
/// with independent compare enables (Fig. 5).
#[derive(Debug, Clone)]
pub struct CamArray {
    n: usize,
    zeta: usize,
    /// `M` rows of `N` bits in one contiguous slab — a whole ζ-row
    /// sub-block is one cache-friendly word run, which is what the
    /// word-parallel compare in [`Self::search`] sweeps.
    tags: BitSlab,
    valid: BitVec,
}

impl CamArray {
    /// Empty array. `m` must be a positive multiple of `zeta`.
    pub fn new(m: usize, n: usize, zeta: usize) -> Self {
        assert!(m > 0 && n > 0, "M and N must be positive");
        assert!(zeta > 0 && m % zeta == 0, "ζ must divide M");
        CamArray { n, zeta, tags: BitSlab::zeros(m, n), valid: BitVec::zeros(m) }
    }

    /// Rebuild from persisted rows + valid bits (the snapshot restore
    /// path).  Returns an error instead of panicking — the inputs may come
    /// from a corrupt file, and the store layer turns the message into a
    /// typed `StoreError::Corrupt`.
    pub fn from_parts(
        n: usize,
        zeta: usize,
        tags: Vec<BitVec>,
        valid: BitVec,
    ) -> Result<Self, String> {
        let m = tags.len();
        if m == 0 || n == 0 {
            return Err("M and N must be positive".into());
        }
        if zeta == 0 || m % zeta != 0 {
            return Err(format!("ζ={zeta} must divide M={m}"));
        }
        if valid.len() != m {
            return Err(format!("valid bits length {} != M={m}", valid.len()));
        }
        if let Some((a, t)) = tags.iter().enumerate().find(|(_, t)| t.len() != n) {
            return Err(format!("tag at address {a} is {} bits, expected N={n}", t.len()));
        }
        Ok(CamArray { n, zeta, tags: BitSlab::from_rows(&tags, n), valid })
    }

    /// All stored rows materialized, including residual contents of
    /// invalidated slots (the snapshot encoder dumps them verbatim; invalid
    /// rows never influence a search result).  Cold path — the hot compare
    /// reads the slab words directly.
    pub fn tag_rows(&self) -> Vec<BitVec> {
        self.tags.to_rows()
    }

    /// The backing tag slab (row `addr` ↦ the stored tag bits).
    pub fn slab(&self) -> &BitSlab {
        &self.tags
    }

    /// The valid bits, one per entry.
    pub fn valid_bits(&self) -> &BitVec {
        &self.valid
    }

    /// Number of entries (M).
    pub fn m(&self) -> usize {
        self.tags.rows()
    }

    /// Tag width in bits (N).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Rows per sub-block (ζ).
    pub fn zeta(&self) -> usize {
        self.zeta
    }

    /// Number of sub-blocks (β = M/ζ).
    pub fn beta(&self) -> usize {
        self.m() / self.zeta
    }

    /// Number of valid (occupied) entries.
    pub fn occupancy(&self) -> usize {
        self.valid.count_ones()
    }

    /// Store `tag` at `addr`, marking it valid.
    pub fn write(&mut self, addr: usize, tag: BitVec) {
        assert_eq!(tag.len(), self.n, "tag width mismatch");
        assert!(addr < self.m(), "address out of range");
        tag.ensure_tail_clear();
        self.tags.row_words_mut(addr).copy_from_slice(tag.words());
        self.valid.set(addr, true);
    }

    /// Invalidate `addr`.
    pub fn erase(&mut self, addr: usize) {
        assert!(addr < self.m(), "address out of range");
        self.valid.set(addr, false);
    }

    /// Read back the stored tag, if valid.  Materializes a fresh `BitVec`
    /// from the slab row — fine for the write-path callers this serves.
    pub fn read(&self, addr: usize) -> Option<BitVec> {
        if addr < self.m() && self.valid.get(addr) {
            Some(self.tags.row(addr))
        } else {
            None
        }
    }

    /// The sub-block index of an entry.
    pub fn block_of(&self, addr: usize) -> usize {
        addr / self.zeta
    }

    /// Search with all sub-blocks enabled — the conventional CAM behaviour.
    pub fn search_all(&self, tag: &BitVec) -> SearchResult {
        self.search(tag, &BitVec::ones(self.beta()))
    }

    /// Search with only the sub-blocks set in `enables` compare-enabled —
    /// the proposed architecture's behaviour. `enables` has β bits (the
    /// compare-enable lines the CNN drives in Fig. 4/5).
    ///
    /// Every *valid* row of an enabled block burns compare energy; disabled
    /// blocks keep their search-lines and match-lines quiet.  The activity
    /// counters record exactly what switched.
    pub fn search(&self, tag: &BitVec, enables: &BitVec) -> SearchResult {
        assert_eq!(tag.len(), self.n, "tag width mismatch");
        assert_eq!(enables.len(), self.beta(), "enable mask width mismatch");

        tag.ensure_tail_clear();
        let mut matches = Vec::new();
        let mut activity = SearchActivity {
            total_blocks: self.beta(),
            tag_bits: self.n,
            ..SearchActivity::default()
        };

        let tag_words = tag.words();
        for block in enables.iter_ones() {
            activity.enabled_blocks += 1;
            let base = block * self.zeta;
            // One enabled block = ζ consecutive slab rows = one contiguous
            // word run; the XOR-popcount compare streams straight through it.
            for row in base..base + self.zeta {
                activity.enabled_rows += 1;
                if !self.valid.get(row) {
                    // Invalid rows are compare-enabled (the enable line is
                    // per block) but their MLs are held by the valid bit:
                    // they precharge and immediately discharge — count as a
                    // full mismatch row, no bit comparisons resolved.
                    activity.mismatched_rows += 1;
                    activity.mismatch_bits += self.n / 2; // paper's half-bit assumption
                    continue;
                }
                activity.compared_rows += 1;
                activity.compared_bits += self.n;
                let dist = kernel::xor_popcount(self.tags.row_words(row), tag_words);
                if dist == 0 {
                    activity.matched_rows += 1;
                    matches.push(row);
                } else {
                    activity.mismatched_rows += 1;
                    activity.mismatch_bits += dist;
                }
            }
        }
        SearchResult { matches, activity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tag(v: u128, n: usize) -> BitVec {
        BitVec::from_u128(v, n)
    }

    #[test]
    fn write_search_roundtrip() {
        let mut cam = CamArray::new(16, 32, 4);
        cam.write(5, tag(0xDEAD, 32));
        cam.write(9, tag(0xBEEF, 32));
        let r = cam.search_all(&tag(0xDEAD, 32));
        assert_eq!(r.matches, vec![5]);
        let r = cam.search_all(&tag(0xBEEF, 32));
        assert_eq!(r.matches, vec![9]);
        let r = cam.search_all(&tag(0x1234, 32));
        assert!(r.matches.is_empty());
    }

    #[test]
    fn disabled_blocks_hide_matches_and_burn_nothing() {
        let mut cam = CamArray::new(16, 32, 4);
        cam.write(5, tag(0xDEAD, 32)); // block 1
        let mut en = BitVec::zeros(4);
        en.set(0, true); // only block 0 enabled
        let r = cam.search(&tag(0xDEAD, 32), &en);
        assert!(r.matches.is_empty());
        assert_eq!(r.activity.enabled_blocks, 1);
        assert_eq!(r.activity.enabled_rows, 4);

        en.set(1, true);
        let r = cam.search(&tag(0xDEAD, 32), &en);
        assert_eq!(r.matches, vec![5]);
        assert_eq!(r.activity.enabled_blocks, 2);
    }

    #[test]
    fn erase_invalidates() {
        let mut cam = CamArray::new(8, 16, 2);
        cam.write(3, tag(0xAB, 16));
        assert_eq!(cam.search_all(&tag(0xAB, 16)).matches, vec![3]);
        cam.erase(3);
        assert!(cam.search_all(&tag(0xAB, 16)).matches.is_empty());
        assert!(cam.read(3).is_none());
        assert_eq!(cam.occupancy(), 0);
    }

    #[test]
    fn duplicate_tags_all_match() {
        let mut cam = CamArray::new(8, 16, 2);
        cam.write(1, tag(0x7, 16));
        cam.write(6, tag(0x7, 16));
        assert_eq!(cam.search_all(&tag(0x7, 16)).matches, vec![1, 6]);
    }

    #[test]
    fn activity_counts_mismatch_bits_exactly() {
        let mut cam = CamArray::new(4, 8, 4);
        cam.write(0, tag(0b0000_0000, 8));
        cam.write(1, tag(0b0000_0111, 8)); // 3 bits from query 0
        let r = cam.search_all(&tag(0, 8));
        assert_eq!(r.matches, vec![0]);
        assert_eq!(r.activity.compared_rows, 2);
        assert_eq!(r.activity.matched_rows, 1);
        // rows 2,3 invalid → half-bit assumption: 2 × 8/2 = 8; row 1: 3 bits
        assert_eq!(r.activity.mismatch_bits, 3 + 8);
        assert_eq!(r.activity.compared_bits, 16);
    }

    #[test]
    fn overwrite_replaces_tag() {
        let mut cam = CamArray::new(4, 16, 2);
        cam.write(2, tag(0x11, 16));
        cam.write(2, tag(0x22, 16));
        assert!(cam.search_all(&tag(0x11, 16)).matches.is_empty());
        assert_eq!(cam.search_all(&tag(0x22, 16)).matches, vec![2]);
    }

    #[test]
    fn block_of_maps_rows_to_blocks() {
        let cam = CamArray::new(16, 8, 4);
        assert_eq!(cam.block_of(0), 0);
        assert_eq!(cam.block_of(3), 0);
        assert_eq!(cam.block_of(4), 1);
        assert_eq!(cam.block_of(15), 3);
    }

    #[test]
    #[should_panic(expected = "ζ must divide M")]
    fn bad_geometry_panics() {
        CamArray::new(10, 8, 4);
    }
}
