//! Literature anchor rows of Table II — other groups' silicon, reproduced
//! as published and cross-checked against our technology-scaling module.
//!
//! These rows are *citations*, not our measurements: PF-CDPD [12],
//! Hybrid [13], STOS [3] and HS-WA [1] report their own process, supply and
//! configuration.  The table harness prints them verbatim next to the three
//! rows our simulator produces (Ref. NAND / Ref. NOR / Proposed), and
//! [`AnchorRow::scaled_to`] normalizes them to a common node with the same
//! method of [6] the paper uses, so the cross-design comparison is
//! apples-to-apples.


use crate::tech::{self, TechNode};

/// One published comparison row.
#[derive(Debug, Clone, PartialEq)]
pub struct AnchorRow {
    /// Short name used in Table II.
    pub name: &'static str,
    /// Citation key in the paper's reference list.
    pub reference: &'static str,
    /// entries × tag bits.
    pub config: (usize, usize),
    /// Cell family as published.
    pub cell_type: &'static str,
    /// Process node.
    pub node: TechNode,
    /// Search delay in nanoseconds, as published.
    pub delay_ns: f64,
    /// Energy metric in fJ/bit/search, as published.
    pub energy_fj_bit: f64,
}

impl AnchorRow {
    /// This row's delay/energy scaled to `target` by the method of [6].
    pub fn scaled_to(&self, target: TechNode) -> (f64, f64) {
        (
            tech::scale_delay(self.delay_ns, self.node, target),
            tech::scale_energy(self.energy_fj_bit, self.node, target),
        )
    }
}

/// The four external rows of Table II, as published.
pub fn anchor_rows() -> Vec<AnchorRow> {
    vec![
        AnchorRow {
            name: "PF-CDPD",
            reference: "[12] Wang et al., ISSCC 2005",
            config: (256, 128),
            cell_type: "NAND",
            node: tech::NODE_180NM,
            delay_ns: 2.10,
            energy_fj_bit: 2.33,
        },
        AnchorRow {
            name: "Hybrid",
            reference: "[13] Chang & Liao, TVLSI 2008",
            config: (128, 32),
            cell_type: "NAND-NOR",
            node: tech::NODE_130NM,
            delay_ns: 0.60,
            energy_fj_bit: 1.3,
        },
        AnchorRow {
            name: "STOS",
            reference: "[3] Onizawa et al., ASYNC 2012",
            config: (256, 144),
            cell_type: "NAND",
            node: tech::NODE_90NM,
            delay_ns: 0.26,
            energy_fj_bit: 0.162,
        },
        AnchorRow {
            name: "HS-WA",
            reference: "[1] Agarwal et al., ESSCIRC 2011",
            config: (128, 128),
            cell_type: "NAND-NOR",
            node: tech::NODE_32NM,
            delay_ns: 0.145,
            energy_fj_bit: 1.07,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values_as_published() {
        let rows = anchor_rows();
        assert_eq!(rows.len(), 4);
        let by_name = |n: &str| rows.iter().find(|r| r.name == n).unwrap().clone();
        assert_eq!(by_name("PF-CDPD").energy_fj_bit, 2.33);
        assert_eq!(by_name("Hybrid").delay_ns, 0.60);
        assert_eq!(by_name("STOS").config, (256, 144));
        assert_eq!(by_name("HS-WA").node.feature_nm, 32.0);
    }

    #[test]
    fn scaling_to_own_node_is_identity() {
        for r in anchor_rows() {
            let (d, e) = r.scaled_to(r.node);
            assert!((d - r.delay_ns).abs() < 1e-12);
            assert!((e - r.energy_fj_bit).abs() < 1e-12);
        }
    }

    #[test]
    fn proposed_beats_every_anchor_on_energy_at_common_node() {
        // The paper's Table II conclusion: 0.124 fJ/bit/search is the lowest
        // energy row.  Normalize all anchors to 0.13 µm and compare against
        // our model's proposed-design prediction.
        let cfg = crate::config::DesignConfig::reference();
        let calib = crate::energy::CalibrationConstants::reference_130nm();
        let ours = crate::energy::proposed_search_energy(&cfg, &calib).per_bit(cfg.m, cfg.n);
        for r in anchor_rows() {
            let (_, e) = r.scaled_to(tech::NODE_130NM);
            assert!(ours < e, "proposed {ours} vs {} {e}", r.name);
        }
    }

    #[test]
    fn stos_remains_fastest_even_scaled() {
        // STOS is the delay outlier in Table II; scaling preserves that.
        let rows = anchor_rows();
        let at_130 = |n: &str| {
            rows.iter().find(|r| r.name == n).unwrap().scaled_to(tech::NODE_130NM).0
        };
        assert!(at_130("STOS") < at_130("PF-CDPD"));
        assert!(at_130("STOS") < at_130("Hybrid"));
    }
}
