//! Calibrated energy primitives at the reference node (0.13 µm, 1.2 V).
//!
//! # Fitting procedure (documented substitution)
//!
//! Per-cell search energy in a CAM decomposes into three physically distinct
//! components (Pagiamtzis & Sheikholeslami's survey [7]):
//!
//! 1. **search-line (SL)** — charging the differential search-line pair's
//!    gate + local-wire capacitance through the cell's compare transistors;
//! 2. **match-line (ML)** — precharging the ML and discharging it on a
//!    mismatch (NOR) / evaluating the series chain (NAND);
//! 3. **global search-data wire** — the un-gateable vertical broadcast wire
//!    that spans the array height regardless of which sub-blocks are enabled
//!    (hierarchical search-line schemes buffer the *local* SLs per block but
//!    still drive the global wire).
//!
//! Anchors (Table II, our own SPECTRE rows in the paper):
//!
//! ```text
//!   e_sl_cell + e_ml_nor  + e_global_wire = 2.39 fJ   (Ref. NOR, all enabled)
//!   e_sl_cell + e_ml_nand + e_global_wire = 1.30 fJ   (Ref. NAND)
//! ```
//!
//! with the ML share of a NOR cell's energy set to 60 % per [7] and the
//! global wire at 0.01 fJ/row/bit (extracted-wire ballpark for 0.13 µm, a
//! ~0.4 % effect on the conventional designs but the dominant *floor* of the
//! proposed one).  Solving: `e_ml_nor = 1.43`, `e_sl_cell = 0.95`,
//! `e_ml_nand = 0.34`.  The CNN-side primitives (SRAM read, decoder, logic)
//! are standard 0.13 µm ballparks and are *not* fitted to any proposed-design
//! number.


/// Energy primitives (all femtojoules per event, at 0.13 µm / 1.2 V).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalibrationConstants {
    /// SL energy per *enabled* CAM cell per search (gate + local wire).
    pub e_sl_cell: f64,
    /// NOR-type ML precharge+evaluate energy per cell per search.
    pub e_ml_nor: f64,
    /// NAND-type ML energy per cell per search (series chain, low swing).
    pub e_ml_nand: f64,
    /// Global search-data broadcast wire, per row per bit, un-gateable.
    pub e_global_wire: f64,
    /// CNN weight-SRAM read energy per bit (word-line + bit-line precharge
    /// amortized over the M-bit row).
    pub e_sram_read_bit: f64,
    /// One-hot decoder energy per output line per decode.
    pub e_decoder_line: f64,
    /// P_II logic (c-input AND + ζ-group OR) switching energy per neuron per
    /// decode, activity-weighted (most gates don't toggle).
    pub e_pii_logic_neuron: f64,
    /// Compare-enable line driver energy per *activated* sub-block (drives ζ
    /// rows' enable gating).
    pub e_enable_driver_block: f64,
    /// ML precharge-control overhead per enabled row (enable gating adds one
    /// pass device on the precharge path).
    pub e_enable_gate_row: f64,
}

impl CalibrationConstants {
    /// The reference calibration at 0.13 µm / 1.2 V (see module docs).
    pub const fn reference_130nm() -> Self {
        CalibrationConstants {
            e_sl_cell: 0.95,
            e_ml_nor: 1.43,
            e_ml_nand: 0.34,
            e_global_wire: 0.01,
            e_sram_read_bit: 1.5,
            e_decoder_line: 2.0,
            e_pii_logic_neuron: 0.05,
            e_enable_driver_block: 5.0,
            e_enable_gate_row: 0.5,
        }
    }

    /// Per-cell search energy of a fully-enabled conventional cell with the
    /// given match-line architecture.
    pub fn conventional_cell_energy(&self, ml: crate::cam::MatchlineKind) -> f64 {
        let ml_e = match ml {
            crate::cam::MatchlineKind::Nor => self.e_ml_nor,
            crate::cam::MatchlineKind::Nand => self.e_ml_nand,
        };
        self.e_sl_cell + ml_e + self.e_global_wire
    }
}

impl Default for CalibrationConstants {
    fn default() -> Self {
        Self::reference_130nm()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cam::MatchlineKind;

    #[test]
    fn anchors_reproduce_table2_conventional_rows() {
        let c = CalibrationConstants::reference_130nm();
        // Ref. NOR: 2.39 fJ/bit/search, Ref. NAND: 1.30 fJ/bit/search.
        assert!((c.conventional_cell_energy(MatchlineKind::Nor) - 2.39).abs() < 1e-9);
        assert!((c.conventional_cell_energy(MatchlineKind::Nand) - 1.30).abs() < 1e-9);
    }

    #[test]
    fn ml_share_of_nor_cell_is_about_60_percent() {
        // The [7]-survey split used in the fit.
        let c = CalibrationConstants::reference_130nm();
        let share = c.e_ml_nor / c.conventional_cell_energy(MatchlineKind::Nor);
        assert!((0.55..0.65).contains(&share), "share = {share}");
    }

    #[test]
    fn global_wire_is_a_small_fraction_of_conventional() {
        let c = CalibrationConstants::reference_130nm();
        assert!(c.e_global_wire / c.conventional_cell_energy(MatchlineKind::Nor) < 0.01);
    }
}
