//! Shard routing across multiple CAM macros.
//!
//! The paper notes power inefficiency has kept TLBs under 512 entries; the
//! system answer to bigger tables is horizontal scaling — several proposed
//! macros behind a deterministic tag-hash router (the same shape as a
//! multi-bank TLB or a router line card with several CAM chips).  Lookups
//! touch exactly one shard; total capacity is `shards × M`.

use crate::bits::BitVec;
use crate::config::DesignConfig;
use crate::coordinator::engine::{EngineError, LookupEngine, LookupOutcome};

/// A set of lookup engines behind a tag-hash.
#[derive(Debug)]
pub struct ShardRouter {
    shards: Vec<LookupEngine>,
}

impl ShardRouter {
    /// `shards` identical engines of the given design point.
    pub fn new(cfg: DesignConfig, shards: usize) -> Self {
        assert!(shards > 0);
        ShardRouter { shards: (0..shards).map(|_| LookupEngine::new(cfg.clone())).collect() }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn total_capacity(&self) -> usize {
        self.shards.iter().map(|s| s.config().m).sum()
    }

    pub fn occupancy(&self) -> usize {
        self.shards.iter().map(|s| s.occupancy()).sum()
    }

    /// Deterministic shard for a tag (FNV-1a over the packed words).
    pub fn shard_of(&self, tag: &BitVec) -> usize {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &w in tag.words() {
            for b in w.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        }
        (h % self.shards.len() as u64) as usize
    }

    /// Insert into the owning shard; returns (shard, local address).
    pub fn insert(&mut self, tag: &BitVec) -> Result<(usize, usize), EngineError> {
        let s = self.shard_of(tag);
        let addr = self.shards[s].insert(tag)?;
        Ok((s, addr))
    }

    /// Lookup in the owning shard; returns (shard, outcome).
    pub fn lookup(&mut self, tag: &BitVec) -> Result<(usize, LookupOutcome), EngineError> {
        let s = self.shard_of(tag);
        let out = self.shards[s].lookup(tag)?;
        Ok((s, out))
    }

    /// Delete from the owning shard by tag (lookup + erase).
    pub fn delete(&mut self, tag: &BitVec) -> Result<bool, EngineError> {
        let s = self.shard_of(tag);
        let out = self.shards[s].lookup(tag)?;
        match out.addr {
            Some(a) => {
                self.shards[s].delete(a)?;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Access a shard (metrics, retrain, …).
    pub fn shard_mut(&mut self, i: usize) -> &mut LookupEngine {
        &mut self.shards[i]
    }

    pub fn shards(&self) -> &[LookupEngine] {
        &self.shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TagDistribution;
    use crate::util::Rng;

    fn router(shards: usize) -> ShardRouter {
        ShardRouter::new(DesignConfig::small_test(), shards)
    }

    #[test]
    fn routing_is_deterministic() {
        let r = router(4);
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..50 {
            let t = crate::workload::random_tag(32, &mut rng);
            assert_eq!(r.shard_of(&t), r.shard_of(&t));
        }
    }

    #[test]
    fn inserted_tags_are_found_in_their_shard() {
        let mut r = router(4);
        let mut rng = Rng::seed_from_u64(2);
        let tags = TagDistribution::Uniform.sample_distinct(32, 100, &mut rng);
        for t in &tags {
            r.insert(t).unwrap();
        }
        assert_eq!(r.occupancy(), 100);
        for t in &tags {
            let (s, out) = r.lookup(t).unwrap();
            assert_eq!(s, r.shard_of(t));
            assert!(out.addr.is_some(), "tag lost");
        }
    }

    #[test]
    fn shards_balance_roughly() {
        let mut r = router(4);
        let mut rng = Rng::seed_from_u64(3);
        let tags = TagDistribution::Uniform.sample_distinct(32, 200, &mut rng);
        let mut counts = [0usize; 4];
        for t in &tags {
            counts[r.shard_of(t)] += 1;
        }
        for c in counts {
            assert!((20..90).contains(&c), "imbalanced: {counts:?}");
        }
        let _ = &mut r;
    }

    #[test]
    fn delete_by_tag() {
        let mut r = router(2);
        let mut rng = Rng::seed_from_u64(4);
        let tags = TagDistribution::Uniform.sample_distinct(32, 10, &mut rng);
        for t in &tags {
            r.insert(t).unwrap();
        }
        assert!(r.delete(&tags[5]).unwrap());
        let (_, out) = r.lookup(&tags[5]).unwrap();
        assert_eq!(out.addr, None);
        assert!(!r.delete(&tags[5]).unwrap(), "double delete is a no-op");
        assert_eq!(r.occupancy(), 9);
    }

    #[test]
    fn capacity_scales_with_shards() {
        assert_eq!(router(1).total_capacity(), 64);
        assert_eq!(router(8).total_capacity(), 512);
    }
}
