# Convenience targets for the cscam workspace.

.PHONY: build test lint artifacts

# Tier-1 gate.
build:
	cargo build --release

test:
	cargo test -q

# Cross-file invariant analyzer (rust/xtask) plus workspace-wide clippy —
# the same pair the CI static-analysis job runs.
lint:
	cargo xtask lint
	cargo clippy --workspace --all-targets -- -D warnings

# Lower the JAX decode/train graphs to HLO text artifacts for the PJRT
# backend (build-time Python; the Rust request path never runs Python).
# Consumed by `cargo run --features pjrt -- serve --pjrt` and the
# pjrt_roundtrip tests.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
