//! Bench/regeneration harness for **Table II** (+ the headline ratios and
//! the 90 nm projection): prints the published anchor rows, our calibrated
//! conventional rows, and the *predicted* proposed row — then validates the
//! analytic prediction against a measured 100k-search workload through the
//! functional simulator, which is what a SPECTRE testbench would do.
//!
//! Run: `cargo bench --bench table2_energy_delay`

use cscam::baselines::{anchor_rows, PbCam};
use cscam::cam::MatchlineKind;
use cscam::config::DesignConfig;
use cscam::coordinator::LookupEngine;
use cscam::energy::{conventional_search_energy, proposed_search_energy, CalibrationConstants};
use cscam::stats::OnlineStats;
use cscam::tech::{self, NODE_130NM, NODE_90NM};
use cscam::timing::{conventional_delay, proposed_delay, scaled_delay, DelayConstants};
use cscam::transistor::{overhead_vs_nand, TransistorAssumptions};
use cscam::util::Rng;
use cscam::workload::{QueryMix, TagDistribution};

fn main() {
    let cfg = DesignConfig::reference();
    let calib = CalibrationConstants::reference_130nm();
    let delays = DelayConstants::reference();

    println!("# Table II — result comparisons");
    println!(
        "{:<12} {:>9} {:>8} {:>10} {:>15}  {}",
        "design", "config", "tech", "delay[ns]", "E[fJ/bit/srch]", "source"
    );
    for r in anchor_rows() {
        println!(
            "{:<12} {:>9} {:>8} {:>10.3} {:>15.3}  published {}",
            r.name,
            format!("{}x{}", r.config.0, r.config.1),
            r.node.name,
            r.delay_ns,
            r.energy_fj_bit,
            r.reference
        );
    }
    let nand_e = conventional_search_energy(cfg.m, cfg.n, MatchlineKind::Nand, &calib);
    let nor_e = conventional_search_energy(cfg.m, cfg.n, MatchlineKind::Nor, &calib);
    let prop_e = proposed_search_energy(&cfg, &calib);
    let nand_d = conventional_delay(cfg.m, cfg.n, MatchlineKind::Nand, &delays, NODE_130NM);
    let nor_d = conventional_delay(cfg.m, cfg.n, MatchlineKind::Nor, &delays, NODE_130NM);
    let prop_d = proposed_delay(&cfg, &delays);
    for (name, d, e) in [
        ("Ref. NAND", nand_d.cycle_ns, nand_e.per_bit(cfg.m, cfg.n)),
        ("Ref. NOR", nor_d.cycle_ns, nor_e.per_bit(cfg.m, cfg.n)),
        ("Proposed", prop_d.cycle_ns, prop_e.per_bit(cfg.m, cfg.n)),
    ] {
        println!(
            "{:<12} {:>9} {:>8} {:>10.3} {:>15.3}  model (this work)",
            name,
            format!("{}x{}", cfg.m, cfg.n),
            "0.13um",
            d,
            e
        );
    }
    let pb = PbCam::expected_full_comparisons(cfg.m, cfg.n);
    println!(
        "{:<12} {:>9} {:>8} {:>10} {:>15.3}  model — {:.1} expected full comparisons",
        "PB-CAM [4]",
        format!("{}x{}", cfg.m, cfg.n),
        "0.13um",
        "-",
        PbCam::new(cfg.m, cfg.n).search_energy(pb.round() as usize, &calib).per_bit(cfg.m, cfg.n),
        pb
    );

    println!("\n# headline (paper: energy 9.5 %, delay 30.4 %, +3.4 % transistors)");
    println!("energy : {:.2} %", 100.0 * prop_e.per_bit(cfg.m, cfg.n) / 1.30);
    println!("delay  : {:.2} %", 100.0 * prop_d.cycle_ns / nand_d.cycle_ns);
    println!(
        "trans. : +{:.2} %",
        100.0 * overhead_vs_nand(&cfg, &TransistorAssumptions::default())
    );

    let e90 = tech::scale_energy(prop_e.per_bit(cfg.m, cfg.n), NODE_130NM, NODE_90NM);
    let d90 = scaled_delay(prop_d, NODE_130NM, NODE_90NM);
    println!("\n# 90 nm projection (paper: 0.060 fJ/bit/search, 0.582 ns)");
    println!("energy : {:.4} fJ/bit/search", e90);
    println!("delay  : {:.3} ns", d90.cycle_ns);

    // Validation: measured energy over a real workload through the
    // functional simulator vs the closed-form prediction.
    println!("\n# measured-workload validation (100k searches, 90 % hits, full CAM)");
    let mut engine = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(22);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &stored {
        engine.insert(t).unwrap();
    }
    let mix = QueryMix { hit_ratio: 0.9, zipf_s: 0.0 };
    let mut energy = OnlineStats::new();
    let mut blocks = OnlineStats::new();
    let t0 = std::time::Instant::now();
    let searches = 100_000;
    for _ in 0..searches {
        let (tag, _) = mix.sample(&stored, cfg.n, &mut rng);
        let out = engine.lookup(&tag).unwrap();
        energy.push(out.energy.per_bit(cfg.m, cfg.n));
        blocks.push(out.enabled_blocks as f64);
    }
    let wall = t0.elapsed();
    println!(
        "measured: {:.4} ± {:.4} fJ/bit/search (analytic {:.4}); blocks̄ {:.3} (analytic {:.3})",
        energy.mean(),
        energy.sem(),
        prop_e.per_bit(cfg.m, cfg.n),
        blocks.mean(),
        cfg.expected_active_blocks()
    );
    println!(
        "simulator rate: {:.2} M searches/s ({} searches in {:.2} s)",
        searches as f64 / wall.as_secs_f64() / 1e6,
        searches,
        wall.as_secs_f64()
    );
}
