//! The serve loop: a single-owner *writer* thread fed by an mpsc channel,
//! plus a sized pool of *reader* threads that serve lookups from the
//! published [`SearchState`] snapshot — reads never round-trip through the
//! mutation thread.
//!
//! Shape: `ServerHandle` (cheap to clone, one per client thread) splits
//! traffic by kind:
//!
//! * **mutations / barriers** (insert, delete, metrics, drain, persist) →
//!   mpsc → the engine thread, which owns the [`LookupEngine`] writer.
//!   After applying (and, with a store attached, logging) a mutation it
//!   re-publishes the engine's `Arc<SearchState>` through the bank's
//!   [`SharedSearch`] slot — *after* the WAL ack, *before* the client ack,
//!   so an acknowledged write is always visible to subsequent lookups and
//!   an unacknowledged one never is.
//! * **lookups** → the reader pool's lock-free
//!   [`crate::util::sync::BatchChannel`] ring; each reader thread holds
//!   its own [`DecodeScratch`], pops jobs in batches (one wakeup amortized
//!   over several under load), snapshots the published state per job and
//!   searches lock-free.  Bulk lookups are split into chunks so one big
//!   slice fans out across the pool.  With `readers = 0` — or with the
//!   PJRT decode backend, whose artifact store lives on the engine
//!   thread — lookups fall back to the classic batched engine-thread path
//!   ([`Batcher`]).
//! * **direct reads** ([`ServerHandle::lookup_direct`]) skip even the pool
//!   queue: the calling thread snapshots and searches itself.  This is
//!   what the net reactor's worker threads use.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::bits::BitVec;
use crate::config::DesignConfig;
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::engine::{
    DecodeScratch, EngineError, LookupEngine, LookupOutcome, SearchState, SharedSearch,
};
use crate::coordinator::metrics::Metrics;
use crate::runtime::DecodeOutput;
use crate::store::{BankImage, BankStore, StoreError, WalRecord};
use crate::util::sync::{lock_recover, AdmissionGauge, BatchChannel, JobGuard, Mutex};
#[cfg(feature = "pjrt")]
use crate::runtime::ArtifactStore;

/// Owner of the PJRT artifact store for the trip onto the engine thread.
///
/// The unsafety is scoped to this newtype on purpose: blessing the whole
/// [`DecodeBackend`] enum would silently extend to any variant added later.
//
// SAFETY: the xla crate's PJRT handles are `!Send` only because
// `PjRtClient` wraps its FFI handle in an `Rc`.  `ArtifactStore` creates
// the client itself and owns every object cloned from it (executables,
// resident buffers), so all `Rc` clones live inside the one store.  The
// server moves the whole store onto its single engine thread at spawn and
// never aliases it afterwards — every clone crosses threads together,
// exactly once, which is the condition `Rc` needs.
#[cfg(feature = "pjrt")]
pub struct SendArtifactStore(pub Box<ArtifactStore>);

#[cfg(feature = "pjrt")]
unsafe impl Send for SendArtifactStore {}

/// Which implementation runs the CNN decode stage.
pub enum DecodeBackend {
    /// Bit-packed native decode (reference hot path).
    Native,
    /// AOT-compiled PJRT artifact (the three-layer stack).
    #[cfg(feature = "pjrt")]
    Pjrt(SendArtifactStore),
}

impl DecodeBackend {
    /// Whether lookups may run on shared-state reader threads.  The PJRT
    /// artifact store is pinned to the engine thread, so its decode stage
    /// cannot leave it.
    fn supports_shared_readers(&self) -> bool {
        match self {
            DecodeBackend::Native => true,
            #[cfg(feature = "pjrt")]
            DecodeBackend::Pjrt(_) => false,
        }
    }
}

#[cfg(feature = "pjrt")]
impl DecodeBackend {
    /// Wrap an artifact store for the engine thread.
    pub fn pjrt(store: ArtifactStore) -> Self {
        DecodeBackend::Pjrt(SendArtifactStore(Box::new(store)))
    }
}

impl std::fmt::Debug for DecodeBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeBackend::Native => write!(f, "Native"),
            #[cfg(feature = "pjrt")]
            DecodeBackend::Pjrt(_) => write!(f, "Pjrt"),
        }
    }
}

type LookupResp = mpsc::SyncSender<Result<LookupOutcome, EngineError>>;

type BulkResp = mpsc::SyncSender<Vec<Result<LookupOutcome, EngineError>>>;

enum Request {
    Lookup { tag: BitVec, enqueued: Instant, resp: LookupResp },
    BulkLookup { tags: Vec<BitVec>, enqueued: Instant, resp: BulkResp },
    Insert { tag: BitVec, resp: mpsc::SyncSender<Result<usize, EngineError>> },
    Delete { addr: usize, resp: mpsc::SyncSender<Result<(), EngineError>> },
    Metrics { resp: mpsc::SyncSender<Box<Metrics>> },
    Drain { resp: mpsc::SyncSender<()> },
    /// Durability barrier: fsync the WAL (`snapshot: false`) or snapshot +
    /// truncate it (`snapshot: true`).  `Ok(false)` means the bank serves
    /// without a store attached (nothing to persist).
    Persist { snapshot: bool, resp: mpsc::SyncSender<Result<bool, StoreError>> },
    /// Replication barrier: apply shipped WAL records in order at their
    /// recorded addresses, log them locally, publish (see [`crate::repl`]).
    Apply { records: Vec<WalRecord>, resp: mpsc::SyncSender<Result<u64, StoreError>> },
    /// Replication barrier: replace the bank's whole state with a
    /// transferred snapshot image and persist it as the new local base.
    InstallImage { image: Box<BankImage>, resp: mpsc::SyncSender<Result<(), StoreError>> },
}

// ----------------------------------------------------------- reader pool

/// A lookup job bound for a reader thread.
enum ReadJob {
    Lookup { tag: BitVec, enqueued: Instant, resp: LookupResp },
    /// One part of a chunked bulk.  Every part of a bulk carries the SAME
    /// snapshot, taken once at enqueue time — the whole bulk answers from
    /// one consistent state even when its parts run on different readers
    /// interleaved with concurrent publishes (the pre-pool engine-thread
    /// path had this property because mutations were barriers; splitting
    /// must not silently lose it).
    Bulk { state: Arc<SearchState>, tags: Vec<BitVec>, enqueued: Instant, resp: BulkResp },
}

/// Sender side of the pool queue, with handle-count semantics: each
/// [`ServerHandle`] clone holds one; when the last drops, the reader
/// threads finish the queued jobs and exit.
///
/// The queue itself is the bounded lock-free MPMC
/// [`crate::util::sync::BatchChannel`] (std mpsc receivers cannot be
/// shared across reader threads; the drain barrier rides on its
/// enqueued/completed counters, and readers pop in batches) — extracted
/// behind the sync facade so the loom battery can model-check
/// push/pop/complete/barrier exhaustively.
struct ReadPoolHandle {
    queue: Arc<BatchChannel<ReadJob>>,
}

impl Clone for ReadPoolHandle {
    fn clone(&self) -> Self {
        self.queue.add_sender();
        ReadPoolHandle { queue: Arc::clone(&self.queue) }
    }
}

impl Drop for ReadPoolHandle {
    fn drop(&mut self) {
        self.queue.remove_sender();
    }
}

/// Striped serving metrics shared by every thread that answers lookups for
/// one bank (reader pool threads, direct-read callers).  Each thread
/// hashes to a stripe by its thread id, so recording is uncontended in the
/// steady state; [`Self::merge_into`] folds the stripes into a snapshot.
pub(crate) struct BankMetrics {
    stripes: Vec<Mutex<Metrics>>,
}

/// Stripe count: comfortably above the typical reader-pool size so
/// thread-id hashing rarely collides.
const METRIC_STRIPES: usize = 16;

impl BankMetrics {
    pub(crate) fn new() -> Self {
        BankMetrics { stripes: (0..METRIC_STRIPES).map(|_| Mutex::new(Metrics::new())).collect() }
    }

    fn stripe(&self) -> &Mutex<Metrics> {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        std::thread::current().id().hash(&mut h);
        &self.stripes[(h.finish() as usize) % self.stripes.len()]
    }

    /// Record under this thread's stripe lock (held only inside `f`).
    /// Poison recovery: a stripe is a bag of monotonic counters, valid at
    /// every panic point, so a stripe poisoned by a panicking reader keeps
    /// serving instead of cascading the panic into every later lookup.
    fn with<R>(&self, f: impl FnOnce(&mut Metrics) -> R) -> R {
        f(&mut lock_recover(self.stripe()))
    }

    /// Fold every stripe into `target` (non-atomic across stripes, like
    /// any metrics snapshot under concurrent load).
    pub(crate) fn merge_into(&self, target: &mut Metrics) {
        for s in &self.stripes {
            target.merge(&lock_recover(s));
        }
    }
}

/// Ring capacity of the reader-pool channel, in *jobs* (a bulk chunk is
/// one job).  A momentarily full ring makes `push` spin-wait, it never
/// drops — the admission gauge is what bounds how far ahead of the pool
/// callers can run.
const READ_RING_CAPACITY: usize = 1024;

fn spawn_reader_pool(
    readers: usize,
    shared: SharedSearch,
    metrics: Arc<BankMetrics>,
    depth: Arc<AdmissionGauge>,
    max_batch: usize,
) -> ReadPoolHandle {
    let queue = Arc::new(BatchChannel::with_capacity(READ_RING_CAPACITY));
    for i in 0..readers {
        let queue = Arc::clone(&queue);
        let shared = shared.clone();
        let metrics = Arc::clone(&metrics);
        let depth = Arc::clone(&depth);
        std::thread::Builder::new()
            .name(format!("cscam-reader-{i}"))
            .spawn(move || reader_loop(&queue, &shared, &metrics, &depth, max_batch))
            // lint:allow(a bank that cannot spawn its reader threads cannot
            // serve at all; failing spawn() loudly at startup is the contract)
            .expect("spawn reader thread");
    }
    ReadPoolHandle { queue }
}

/// Jobs a reader takes per channel round-trip: under load one park/unpark
/// cycle is amortized over a whole batch; when the queue runs shallow,
/// `pop_batch` degrades gracefully to singles.
const READER_POP_BATCH: usize = 16;

fn reader_loop(
    queue: &BatchChannel<ReadJob>,
    shared: &SharedSearch,
    metrics: &BankMetrics,
    depth: &AdmissionGauge,
    max_batch: usize,
) {
    let mut scratch = DecodeScratch::new();
    let mut jobs: Vec<ReadJob> = Vec::with_capacity(READER_POP_BATCH);
    loop {
        jobs.clear();
        if queue.pop_batch(READER_POP_BATCH, &mut jobs) == 0 {
            return; // all senders gone and the backlog is drained
        }
        for job in jobs.drain(..) {
            let _guard = JobGuard::new(queue);
            match job {
                ReadJob::Lookup { tag, enqueued, resp } => {
                    depth.retire(1);
                    let state = shared.snapshot();
                    let out = state.lookup(&tag, &mut scratch);
                    let rejects = scratch.take_prefilter_rejects();
                    metrics.with(|m| {
                        // a pool single is one decode dispatch of one tag
                        m.record_batch(1);
                        if let Ok(o) = &out {
                            m.record_lookup(o);
                        }
                        m.prefilter_rejects += rejects;
                        m.record_latency(enqueued.elapsed().as_nanos() as u64);
                    });
                    let _ = resp.send(out);
                }
                ReadJob::Bulk { state, tags, enqueued, resp } => {
                    depth.retire(tags.len());
                    // `state` was snapshotted once at enqueue time and is
                    // shared by every part of the bulk (whole-bulk consistency)
                    let mut out = Vec::with_capacity(tags.len());
                    for chunk in tags.chunks(max_batch.max(1)) {
                        for tag in chunk {
                            out.push(state.lookup(tag, &mut scratch));
                        }
                        let rejects = scratch.take_prefilter_rejects();
                        metrics.with(|m| {
                            m.record_batch(chunk.len());
                            for r in &out[out.len() - chunk.len()..] {
                                if let Ok(o) = r {
                                    m.record_lookup(o);
                                }
                            }
                            m.prefilter_rejects += rejects;
                        });
                    }
                    metrics.with(|m| m.record_latency(enqueued.elapsed().as_nanos() as u64));
                    let _ = resp.send(out);
                }
            }
        }
    }
}

// ------------------------------------------------------------- handles

/// Why a persistence request ([`ServerHandle::flush_store`] /
/// [`ServerHandle::snapshot_store`]) failed.
#[derive(Debug)]
pub enum PersistError {
    /// The engine thread is gone.
    Shutdown,
    /// The durability layer itself failed.
    Store(StoreError),
}

impl std::fmt::Display for PersistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PersistError::Shutdown => write!(f, "server has shut down"),
            PersistError::Store(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for PersistError {}

/// An enqueued persist barrier that has not been awaited yet — the scatter
/// half of a fleet-wide flush/snapshot: fire one per bank so the banks
/// fsync or snapshot *concurrently*, then wait (a sequential barrier per
/// bank would serialize S full-bank snapshots behind one connection).
pub struct PendingPersist {
    rx: mpsc::Receiver<Result<bool, StoreError>>,
}

impl PendingPersist {
    /// Block until the bank's engine thread finishes the persist barrier.
    pub fn wait(self) -> Result<bool, PersistError> {
        self.rx.recv().map_err(|_| PersistError::Shutdown)?.map_err(PersistError::Store)
    }
}

/// A lookup that has been enqueued but not yet answered — the scatter half
/// of a scatter-gather: fire one per bank, then [`PendingLookup::wait`] for
/// each (see [`crate::shard::ShardedServerHandle`]).
pub struct PendingLookup {
    rx: mpsc::Receiver<Result<LookupOutcome, EngineError>>,
}

impl PendingLookup {
    /// Block until a serving thread answers.
    pub fn wait(self) -> Result<LookupOutcome, EngineError> {
        self.rx.recv().map_err(|_| EngineError::Shutdown)?
    }
}

/// One in-flight part of a chunked bulk: its response channel plus the
/// number of tags it carries (for per-tag `Shutdown` expansion).
type BulkPart = (mpsc::Receiver<Vec<Result<LookupOutcome, EngineError>>>, usize);

/// An enqueued bulk lookup (scatter half; see [`PendingLookup`]).  With a
/// reader pool the slice is split into several chunk jobs so it fans out
/// across the readers; `wait` re-concatenates the parts in input order.
pub struct PendingBulk {
    parts: Vec<BulkPart>,
}

impl PendingBulk {
    /// Block until every part is answered; one result per input tag, in
    /// order.  A dead serving thread yields [`EngineError::Shutdown`] per
    /// tag of its part.
    pub fn wait(self) -> Vec<Result<LookupOutcome, EngineError>> {
        let mut out = Vec::new();
        for (rx, n) in self.parts {
            match rx.recv() {
                Ok(v) => out.extend(v),
                Err(_) => out.extend((0..n).map(|_| Err(EngineError::Shutdown))),
            }
        }
        out
    }
}

/// Cloneable client handle to a running [`CamServer`].
///
/// All methods block the calling thread until a serving thread responds
/// (except `*_deferred`, which split enqueue from wait,
/// [`Self::try_lookup`], which sheds instead of queueing when the server is
/// saturated, and [`Self::lookup_direct`], which runs the search on the
/// calling thread).  A send or receive failure means the serving thread is
/// gone, reported as [`EngineError::Shutdown`].
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
    /// Lookup tags enqueued but not yet dequeued by a serving thread
    /// (bulk requests count per tag).
    depth: Arc<AdmissionGauge>,
    /// Admission cap for [`Self::try_lookup`].
    cap: usize,
    /// The bank's published search state (direct reads, net layer).
    shared: SharedSearch,
    /// Reader pool, when the server runs one (`readers > 0`, native
    /// decode); `None` routes lookups through the engine thread.
    pool: Option<ReadPoolHandle>,
    /// Striped lookup metrics shared with the readers.
    bank_metrics: Arc<BankMetrics>,
    /// Bulk chunking floor (the server's batch policy).
    max_batch: usize,
    /// Pool size (≥ 1; used to split bulks).
    readers: usize,
}

impl ServerHandle {
    /// Count a lookup-class request into the admission queue and send it
    /// to the engine thread.  `weight` is the number of tags the request
    /// carries, so bulk lookups count per tag, not per message.
    fn enqueue_lookup(&self, req: Request, weight: usize) -> Result<(), EngineError> {
        self.depth.admit(weight);
        self.tx.send(req).map_err(|_| {
            self.depth.retire(weight);
            EngineError::Shutdown
        })
    }

    /// True when the admission queue is at capacity ([`Self::try_lookup`]
    /// would shed).
    pub fn is_saturated(&self) -> bool {
        self.depth.load() >= self.cap
    }

    /// Lookup, served by the reader pool (or, with `readers = 0` / PJRT,
    /// dynamically batched on the engine thread).
    pub fn lookup(&self, tag: BitVec) -> Result<LookupOutcome, EngineError> {
        self.lookup_deferred(tag)?.wait()
    }

    /// Non-blocking admission: like [`Self::lookup`], but returns
    /// [`EngineError::Busy`] without queueing when the server already has
    /// `queue_capacity` tags pending (bulk requests count per tag) — the
    /// per-bank load-shedding hook for the sharded router.  `Busy` is
    /// transient overload; [`EngineError::Full`] means the CAM has no free
    /// slot.
    pub fn try_lookup(&self, tag: BitVec) -> Result<LookupOutcome, EngineError> {
        if self.is_saturated() {
            self.bank_metrics.with(|m| m.shed_busy += 1);
            return Err(EngineError::Busy);
        }
        self.lookup(tag)
    }

    /// The bank's current published search state (O(1)).  Combine with a
    /// caller-owned [`DecodeScratch`] for zero-queue reads.
    pub fn search_snapshot(&self) -> Arc<SearchState> {
        self.shared.snapshot()
    }

    /// Run one lookup *on the calling thread* against the published
    /// snapshot — no queue, no channel, no other thread involved.  This is
    /// the net worker pool's read path.  Observes every mutation
    /// acknowledged before the call; records into the bank's metrics.
    pub fn lookup_direct(
        &self,
        tag: &BitVec,
        scratch: &mut DecodeScratch,
    ) -> Result<LookupOutcome, EngineError> {
        let t0 = Instant::now();
        let out = self.shared.snapshot().lookup(tag, scratch)?;
        let rejects = scratch.take_prefilter_rejects();
        self.bank_metrics.with(|m| {
            // keep the "every lookup belongs to a dispatch" invariant the
            // batch stats are read under
            m.record_batch(1);
            m.record_lookup(&out);
            m.prefilter_rejects += rejects;
            m.record_latency(t0.elapsed().as_nanos() as u64);
        });
        Ok(out)
    }

    /// Enqueue a lookup without waiting for the answer (scatter half).
    pub fn lookup_deferred(&self, tag: BitVec) -> Result<PendingLookup, EngineError> {
        let (resp, rx) = mpsc::sync_channel(1);
        match &self.pool {
            Some(pool) => {
                self.depth.admit(1);
                pool.queue.push(ReadJob::Lookup { tag, enqueued: Instant::now(), resp });
            }
            None => {
                self.enqueue_lookup(Request::Lookup { tag, enqueued: Instant::now(), resp }, 1)?;
            }
        }
        Ok(PendingLookup { rx })
    }

    /// Bulk lookup: ship many tags in one request — with a reader pool the
    /// slice is chunked so it runs on several readers concurrently, while
    /// results still come back in input order.
    pub fn lookup_many(&self, tags: Vec<BitVec>) -> Vec<Result<LookupOutcome, EngineError>> {
        let n = tags.len();
        match self.lookup_many_deferred(tags) {
            Ok(pending) => pending.wait(),
            Err(e) => (0..n).map(|_| Err(e.clone())).collect(),
        }
    }

    /// Enqueue a bulk lookup without waiting (scatter half).
    pub fn lookup_many_deferred(&self, tags: Vec<BitVec>) -> Result<PendingBulk, EngineError> {
        let n = tags.len();
        if n == 0 {
            return Ok(PendingBulk { parts: Vec::new() });
        }
        match &self.pool {
            Some(pool) => {
                // split across the pool, but never below the batch-policy
                // chunk (tiny fragments would pay more queue overhead than
                // the fan-out wins back)
                let chunk = n.div_ceil(self.readers.max(1)).max(self.max_batch.max(1));
                // one snapshot for the WHOLE bulk: parts running on
                // different readers interleaved with concurrent publishes
                // must still answer from one consistent state
                let state = self.shared.snapshot();
                let mut parts = Vec::with_capacity(n.div_ceil(chunk));
                let mut tags = tags;
                while !tags.is_empty() {
                    let rest = tags.split_off(tags.len().min(chunk));
                    let part = std::mem::replace(&mut tags, rest);
                    let (resp, rx) = mpsc::sync_channel(1);
                    let len = part.len();
                    self.depth.admit(len);
                    pool.queue.push(ReadJob::Bulk {
                        state: Arc::clone(&state),
                        tags: part,
                        enqueued: Instant::now(),
                        resp,
                    });
                    parts.push((rx, len));
                }
                Ok(PendingBulk { parts })
            }
            None => {
                let (resp, rx) = mpsc::sync_channel(1);
                self.enqueue_lookup(
                    Request::BulkLookup { tags, enqueued: Instant::now(), resp },
                    n,
                )?;
                Ok(PendingBulk { parts: vec![(rx, n)] })
            }
        }
    }

    /// Insert a tag; returns once the CNN + CAM are updated, logged (with
    /// a store attached) and the new state is published to readers.
    pub fn insert(&self, tag: BitVec) -> Result<usize, EngineError> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx.send(Request::Insert { tag, resp }).map_err(|_| EngineError::Shutdown)?;
        rx.recv().map_err(|_| EngineError::Shutdown)?
    }

    /// Delete by address.
    pub fn delete(&self, addr: usize) -> Result<(), EngineError> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx.send(Request::Delete { addr, resp }).map_err(|_| EngineError::Shutdown)?;
        rx.recv().map_err(|_| EngineError::Shutdown)?
    }

    /// Snapshot of the server metrics: the engine thread's view (inserts,
    /// deletes, engine-side batches) merged with every reader's stripe.
    pub fn metrics(&self) -> Option<Box<Metrics>> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx.send(Request::Metrics { resp }).ok()?;
        let mut m = rx.recv().ok()?;
        self.bank_metrics.merge_into(&mut m);
        Some(m)
    }

    /// Flush pending work and wait: a barrier over both serving halves —
    /// every lookup enqueued to the pool before this call is served, and
    /// the engine thread passes a FIFO `Drain`.  Bounded even under a
    /// sustained lookup stream from other handles (later arrivals are not
    /// waited for).
    pub fn drain(&self) {
        if let Some(pool) = &self.pool {
            pool.queue.barrier();
        }
        let (resp, rx) = mpsc::sync_channel(1);
        if self.tx.send(Request::Drain { resp }).is_ok() {
            let _ = rx.recv();
        }
    }

    /// Fsync the bank's WAL.  `Ok(true)` once everything acknowledged so
    /// far is on disk; `Ok(false)` when the bank serves without a store.
    /// Runs as a barrier, so it orders after every prior mutation.
    pub fn flush_store(&self) -> Result<bool, PersistError> {
        self.persist(false)
    }

    /// Force a compaction: snapshot the bank and truncate its WAL.
    /// `Ok(false)` when the bank serves without a store.
    pub fn snapshot_store(&self) -> Result<bool, PersistError> {
        self.persist(true)
    }

    /// Enqueue a persist barrier without waiting (scatter half; see
    /// [`PendingPersist`]).  `snapshot: false` fsyncs the WAL,
    /// `snapshot: true` compacts.
    pub fn persist_deferred(&self, snapshot: bool) -> Result<PendingPersist, PersistError> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Persist { snapshot, resp })
            .map_err(|_| PersistError::Shutdown)?;
        Ok(PendingPersist { rx })
    }

    fn persist(&self, snapshot: bool) -> Result<bool, PersistError> {
        self.persist_deferred(snapshot)?.wait()
    }

    /// Apply shipped WAL records at their recorded addresses — the replica
    /// write path ([`crate::repl`]).  Runs as a barrier on the engine
    /// thread: records are applied in order, logged to the local store,
    /// and the new state is published before the ack, exactly like a
    /// client insert.  Returns how many records were applied; an error
    /// means the batch stopped mid-way and the caller must not advance
    /// its replication cursor.
    pub fn apply_replicated(&self, records: Vec<WalRecord>) -> Result<u64, PersistError> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Apply { records, resp })
            .map_err(|_| PersistError::Shutdown)?;
        rx.recv().map_err(|_| PersistError::Shutdown)?.map_err(PersistError::Store)
    }

    /// Replace the bank's whole state with a transferred snapshot image
    /// (replica bootstrap / re-bootstrap after the primary compacted).
    /// The image becomes the local on-disk base too, so a replica restart
    /// recovers from it.  Published before the ack.
    pub fn install_image(&self, image: BankImage) -> Result<(), PersistError> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::InstallImage { image: Box::new(image), resp })
            .map_err(|_| PersistError::Shutdown)?;
        rx.recv().map_err(|_| PersistError::Shutdown)?.map_err(PersistError::Store)
    }
}

/// Default admission cap for [`ServerHandle::try_lookup`] — deep enough
/// that only a genuinely backed-up server sheds.
pub const DEFAULT_QUEUE_CAPACITY: usize = 4096;

/// Default reader-pool size: enough to prove concurrent reads everywhere
/// (tests, fleets) without spawning a thread herd per bank; benches and
/// servers size it explicitly ([`CamServer::with_readers`]).
pub const DEFAULT_READERS: usize = 2;

/// The serve-thread owner.
pub struct CamServer {
    engine: LookupEngine,
    backend: DecodeBackend,
    policy: BatchPolicy,
    metrics: Metrics,
    /// Lookup tags enqueued but not yet dequeued (shared with handles).
    queue_depth: Arc<AdmissionGauge>,
    /// Admission cap handed to [`ServerHandle::try_lookup`].
    queue_cap: usize,
    /// Reader-pool size ([`Self::with_readers`]); 0 = engine-thread reads.
    readers: usize,
    /// The bank's publish slot (created with the engine, shared with every
    /// handle and reader).
    shared: SharedSearch,
    /// Striped lookup metrics shared with readers and direct-read callers.
    bank_metrics: Arc<BankMetrics>,
    /// Set on any mutation; the PJRT path re-uploads weights before the next
    /// batched decode.  (Only read by the `pjrt` decode path.)
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    weights_dirty: bool,
    /// Optional durability: mutations are logged here inside the same
    /// barrier that applies them, before the acknowledgement is sent.
    store: Option<BankStore>,
}

impl CamServer {
    /// Build a server around a fresh engine.
    pub fn new(cfg: DesignConfig, backend: DecodeBackend, policy: BatchPolicy) -> Self {
        Self::with_engine(LookupEngine::new(cfg), backend, policy)
    }

    /// Build around an existing (pre-populated) engine.
    pub fn with_engine(engine: LookupEngine, backend: DecodeBackend, policy: BatchPolicy) -> Self {
        let shared = SharedSearch::new(engine.search_state());
        CamServer {
            engine,
            backend,
            policy,
            metrics: Metrics::new(),
            queue_depth: Arc::new(AdmissionGauge::new()),
            queue_cap: DEFAULT_QUEUE_CAPACITY,
            readers: DEFAULT_READERS,
            shared,
            bank_metrics: Arc::new(BankMetrics::new()),
            weights_dirty: true,
            store: None,
        }
    }

    /// Attach a durability store: every acknowledged insert/delete is
    /// logged to its WAL first, compaction runs automatically past the
    /// store's threshold, and the WAL is flushed when the serve loop
    /// exits.  The store must have been recovered against the same engine
    /// this server wraps (see [`crate::store::BankStore::open`]).
    pub fn with_store(mut self, store: BankStore) -> Self {
        self.store = Some(store);
        self
    }

    /// Cap the admission queue: [`ServerHandle::try_lookup`] sheds with
    /// [`EngineError::Busy`] once this many lookups are pending.
    pub fn with_queue_capacity(mut self, cap: usize) -> Self {
        self.queue_cap = cap;
        self
    }

    /// Size the reader pool: `n` threads serving lookups from the
    /// published snapshot.  `0` routes every lookup through the engine
    /// thread (the pre-pool behaviour; also forced by the PJRT backend,
    /// whose artifact store cannot leave that thread).
    pub fn with_readers(mut self, n: usize) -> Self {
        self.readers = n;
        self
    }

    /// Spawn the serve loop on a dedicated writer thread, plus the reader
    /// pool.  All threads exit when every [`ServerHandle`] clone has been
    /// dropped.
    pub fn spawn(self) -> ServerHandle {
        let (tx, rx) = mpsc::channel();
        let depth = Arc::clone(&self.queue_depth);
        let cap = self.queue_cap;
        let shared = self.shared.clone();
        let bank_metrics = Arc::clone(&self.bank_metrics);
        let max_batch = self.policy.max_batch;
        let readers = if self.backend.supports_shared_readers() { self.readers } else { 0 };
        let pool = (readers > 0).then(|| {
            spawn_reader_pool(
                readers,
                shared.clone(),
                Arc::clone(&bank_metrics),
                Arc::clone(&depth),
                max_batch,
            )
        });
        std::thread::Builder::new()
            .name("cscam-server".into())
            .spawn(move || self.run(rx))
            // lint:allow(no engine thread means no bank at all; failing
            // spawn() loudly at startup is the contract)
            .expect("spawn server thread");
        ServerHandle {
            tx,
            depth,
            cap,
            shared,
            pool,
            bank_metrics,
            max_batch,
            readers: readers.max(1),
        }
    }

    /// Account a request leaving the channel queue (admission bookkeeping —
    /// mirrors the per-tag weights of `ServerHandle::enqueue_lookup`).
    fn note_dequeue(&self, req: &Request) {
        match req {
            Request::Lookup { .. } => {
                self.queue_depth.retire(1);
            }
            Request::BulkLookup { tags, .. } => {
                self.queue_depth.retire(tags.len());
            }
            _ => {}
        }
    }

    fn run(mut self, rx: mpsc::Receiver<Request>) {
        self.serve_loop(&rx);
        // All handles are gone: whatever was acknowledged is already
        // written through to the OS, but honor the fsync contract one last
        // time so a clean exit leaves nothing pending a power cycle.
        if let Some(store) = self.store.as_mut() {
            if let Err(e) = store.flush() {
                eprintln!("cscam-server: WAL flush on exit failed: {e}");
            }
        }
    }

    fn serve_loop(&mut self, rx: &mpsc::Receiver<Request>) {
        let mut batcher: Batcher<(BitVec, Instant, LookupResp)> = Batcher::new(self.policy);
        loop {
            let req = match batcher.deadline() {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        let batch = batcher.flush();
                        self.run_batch(batch);
                        continue;
                    }
                    match rx.recv_timeout(d - now) {
                        Ok(r) => Some(r),
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            let batch = batcher.flush();
                            self.run_batch(batch);
                            continue;
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => None,
                    }
                }
                None => rx.recv().ok(),
            };
            if let Some(r) = &req {
                self.note_dequeue(r);
            }
            match req {
                Some(Request::Lookup { tag, enqueued, resp }) => {
                    if let Some(batch) = batcher.push((tag, enqueued, resp), Instant::now()) {
                        self.run_batch(batch);
                    }
                    // Greedy drain: batch everything already queued, then
                    // serve immediately instead of sleeping out max_wait —
                    // the classic "batch what's there" adaptive policy.  The
                    // deadline path above remains as the bound for requests
                    // that arrive while a batch is running.
                    loop {
                        match rx.try_recv() {
                            Ok(drained) => {
                                self.note_dequeue(&drained);
                                match drained {
                                    Request::Lookup { tag, enqueued, resp } => {
                                        if let Some(batch) =
                                            batcher.push((tag, enqueued, resp), Instant::now())
                                        {
                                            self.run_batch(batch);
                                        }
                                    }
                                    other => {
                                        let batch = batcher.flush();
                                        self.run_batch(batch);
                                        self.handle_barrier(other);
                                        break;
                                    }
                                }
                            }
                            Err(mpsc::TryRecvError::Empty) => {
                                let batch = batcher.flush();
                                self.run_batch(batch);
                                break;
                            }
                            Err(mpsc::TryRecvError::Disconnected) => {
                                let batch = batcher.flush();
                                self.run_batch(batch);
                                return;
                            }
                        }
                    }
                }
                Some(other) => {
                    // barrier: mutations and snapshots see a flushed queue
                    let batch = batcher.flush();
                    self.run_batch(batch);
                    self.handle_barrier(other);
                }
                None => {
                    // all handles dropped: drain and exit
                    let batch = batcher.flush();
                    self.run_batch(batch);
                    return;
                }
            }
        }
    }

    /// Publish the engine's current state to the bank's [`SharedSearch`]
    /// slot.  Called after a mutation is applied *and* logged (the store
    /// ack) but before the client ack — the RCU ordering contract: a
    /// lookup issued after an acknowledged mutation always observes it, a
    /// lookup can never observe an un-logged mutation.
    fn publish(&self) {
        self.shared.publish(self.engine.search_state());
    }

    /// Handle a non-lookup request (the pending batch is already flushed).
    /// Mutations follow the one persist policy of
    /// [`crate::store::log_applied_insert`] /
    /// [`crate::store::log_applied_delete`] — shared with [`DurableBank`]
    /// so the threaded and synchronous paths cannot drift.
    ///
    /// [`DurableBank`]: crate::store::DurableBank
    fn handle_barrier(&mut self, req: Request) {
        match req {
            Request::Insert { tag, resp } => {
                let r = match self.engine.insert(&tag) {
                    Ok(addr) => {
                        // the engine mutated whether or not the log keeps
                        // up (a failed append rolls it back, which is a
                        // further mutation)
                        self.weights_dirty = true;
                        match self.store.as_mut() {
                            None => Ok(addr),
                            Some(store) => {
                                crate::store::log_applied_insert(
                                    store,
                                    &mut self.engine,
                                    addr,
                                    &tag,
                                )
                                .map(|()| addr)
                            }
                        }
                        .map(|addr| {
                            self.metrics.inserts += 1;
                            addr
                        })
                    }
                    Err(e) => {
                        if e == EngineError::Full {
                            self.metrics.shed_full += 1;
                        }
                        Err(e)
                    }
                };
                // publish after the log verdict (a rolled-back insert
                // publishes the rollback), before the ack
                self.publish();
                let _ = resp.send(r);
            }
            Request::Delete { addr, resp } => {
                let r = match self.engine.delete(addr) {
                    Ok(()) => {
                        self.weights_dirty = true;
                        match self.store.as_mut() {
                            None => Ok(()),
                            Some(store) => {
                                crate::store::log_applied_delete(store, &self.engine, addr)
                            }
                        }
                        .map(|()| self.metrics.deletes += 1)
                    }
                    Err(e) => Err(e),
                };
                self.publish();
                let _ = resp.send(r);
            }
            Request::BulkLookup { tags, enqueued, resp } => {
                let results = self.run_bulk(tags, enqueued);
                let _ = resp.send(results);
            }
            Request::Metrics { resp } => {
                let mut m = self.metrics.clone();
                if let Some(store) = self.store.as_ref() {
                    m.absorb_wal(store.wal_stats());
                }
                let _ = resp.send(Box::new(m));
            }
            Request::Drain { resp } => {
                let _ = resp.send(());
            }
            Request::Persist { snapshot, resp } => {
                let r = match self.store.as_mut() {
                    None => Ok(false),
                    Some(store) => {
                        let res =
                            if snapshot { store.compact(&self.engine) } else { store.flush() };
                        res.map(|()| true)
                    }
                };
                if let Err(e) = &r {
                    eprintln!("cscam-server: persist barrier failed: {e}");
                }
                let _ = resp.send(r);
            }
            Request::Apply { records, resp } => {
                let r = self.apply_replicated_records(records);
                if let Err(e) = &r {
                    eprintln!("cscam-server: replicated apply failed: {e}");
                }
                // publish whatever prefix applied — every applied record
                // is already logged, so visibility follows the WAL ack
                // exactly as it does for client mutations
                self.publish();
                let _ = resp.send(r);
            }
            Request::InstallImage { image, resp } => {
                let r = self.install_transferred_image(*image);
                if let Err(e) = &r {
                    eprintln!("cscam-server: snapshot install failed: {e}");
                }
                self.publish();
                let _ = resp.send(r);
            }
            // lint:allow(the serve loop routes every Lookup into the batcher
            // before calling handle_barrier; reaching this arm is a local
            // logic error, not an input-dependent state)
            Request::Lookup { .. } => unreachable!("lookups are batched, not barriers"),
        }
    }

    /// Apply shipped WAL records in order ([`ServerHandle::apply_replicated`]):
    /// each record mutates the engine via the shared
    /// [`crate::store::apply_record`] definition (identical to recovery
    /// replay), then is appended to the local WAL so a replica restart can
    /// recover it.  Stops at the first failure — the unapplied suffix is
    /// simply re-shipped once the subscriber retries from its old cursor.
    fn apply_replicated_records(&mut self, records: Vec<WalRecord>) -> Result<u64, StoreError> {
        let mut applied = 0u64;
        for rec in &records {
            crate::store::apply_record(&mut self.engine, rec)?;
            self.weights_dirty = true;
            match rec {
                WalRecord::Insert { addr, tag } => {
                    self.metrics.inserts += 1;
                    if let Some(store) = self.store.as_mut() {
                        store.record_insert(*addr as usize, tag)?;
                    }
                }
                WalRecord::Delete { addr } => {
                    self.metrics.deletes += 1;
                    if let Some(store) = self.store.as_mut() {
                        store.record_delete(*addr as usize)?;
                    }
                }
            }
            applied += 1;
        }
        // local compaction policy is the bank's own affair — the shipped
        // cursor tracks the PRIMARY's log, not this one
        if let Some(store) = self.store.as_mut() {
            if let Err(e) = store.maybe_compact(&self.engine) {
                eprintln!(
                    "cscam-server: compaction failure (replicated records already logged): {e}"
                );
            }
        }
        Ok(applied)
    }

    /// Swap in a transferred snapshot ([`ServerHandle::install_image`]):
    /// decode the image into a fresh engine, persist it as the local base
    /// (snapshot + WAL reset to the image's generation), then replace the
    /// serving engine.  Geometry must match the bank being replaced.
    fn install_transferred_image(&mut self, image: BankImage) -> Result<(), StoreError> {
        if &image.cfg != self.engine.config() {
            return Err(StoreError::Incompatible(format!(
                "transferred snapshot geometry (M={}, N={}) does not match this bank \
                 (M={}, N={})",
                image.cfg.m,
                image.cfg.n,
                self.engine.config().m,
                self.engine.config().n
            )));
        }
        let generation = image.wal_generation;
        let fresh = image.into_engine()?;
        if let Some(store) = self.store.as_mut() {
            let mut img = BankImage::from_engine(&fresh);
            img.wal_generation = generation;
            store.install_image(&img)?;
        }
        self.engine = fresh;
        self.weights_dirty = true;
        Ok(())
    }

    /// Run the batched decode stage through the PJRT artifact; `None` falls
    /// back to the native per-query decode inside the engine.
    #[cfg(feature = "pjrt")]
    fn decode_stage<'a>(&mut self, tags: impl Iterator<Item = &'a BitVec>) -> Option<DecodeOutput> {
        match &mut self.backend {
            DecodeBackend::Native => None,
            DecodeBackend::Pjrt(store) => {
                if self.weights_dirty && store.0.set_weights(&self.engine.weight_rows()).is_ok() {
                    self.weights_dirty = false;
                }
                if self.weights_dirty {
                    None // weight upload failed: fall back to native decode
                } else {
                    let idx: Vec<Vec<u16>> =
                        tags.map(|t| self.engine.cluster_indices(t)).collect();
                    store.0.decode(&idx).ok()
                }
            }
        }
    }

    #[cfg(not(feature = "pjrt"))]
    fn decode_stage<'a>(
        &mut self,
        _tags: impl Iterator<Item = &'a BitVec>,
    ) -> Option<DecodeOutput> {
        None
    }

    /// Serve a pre-assembled batch of tags in order, chunked to the batch
    /// policy (and thus to the compiled PJRT batch sizes).
    fn run_bulk(
        &mut self,
        tags: Vec<BitVec>,
        enqueued: Instant,
    ) -> Vec<Result<LookupOutcome, EngineError>> {
        let mut out = Vec::with_capacity(tags.len());
        for chunk in tags.chunks(self.policy.max_batch.max(1)) {
            self.metrics.record_batch(chunk.len());
            let decoded = self.decode_stage(chunk.iter());
            for (i, tag) in chunk.iter().enumerate() {
                let r = match &decoded {
                    Some(d) => {
                        self.engine.lookup_with_enables(tag, &d.enables[i], d.lambda[i] as usize)
                    }
                    None => self.engine.lookup(tag),
                };
                if let Ok(o) = &r {
                    self.metrics.record_lookup(o);
                }
                out.push(r);
            }
            self.metrics.prefilter_rejects += self.engine.take_prefilter_rejects();
        }
        self.metrics.record_latency(enqueued.elapsed().as_nanos() as u64);
        out
    }

    fn run_batch(&mut self, batch: Vec<(BitVec, Instant, LookupResp)>) {
        if batch.is_empty() {
            return;
        }
        self.metrics.record_batch(batch.len());

        // PJRT path: one artifact call covers the whole batch's decode stage.
        let decoded = self.decode_stage(batch.iter().map(|(t, _, _)| t));

        for (i, (tag, enqueued, resp)) in batch.into_iter().enumerate() {
            let out = match &decoded {
                Some(d) => {
                    self.engine.lookup_with_enables(&tag, &d.enables[i], d.lambda[i] as usize)
                }
                None => self.engine.lookup(&tag),
            };
            if let Ok(o) = &out {
                self.metrics.record_lookup(o);
            }
            self.metrics.prefilter_rejects += self.engine.take_prefilter_rejects();
            self.metrics.record_latency(enqueued.elapsed().as_nanos() as u64);
            let _ = resp.send(out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workload::TagDistribution;
    use std::time::Duration;

    fn policy() -> BatchPolicy {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) }
    }

    /// A handle whose engine thread is already gone (and no reader pool).
    fn dead_handle() -> ServerHandle {
        let (tx, rx) = mpsc::channel();
        drop(rx);
        ServerHandle {
            tx,
            depth: Arc::new(AdmissionGauge::new()),
            cap: DEFAULT_QUEUE_CAPACITY,
            shared: SharedSearch::new(
                LookupEngine::new(DesignConfig::small_test()).search_state(),
            ),
            pool: None,
            bank_metrics: Arc::new(BankMetrics::new()),
            max_batch: 8,
            readers: 1,
        }
    }

    #[test]
    fn serve_native_roundtrip() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(1);
        let tags = TagDistribution::Uniform.sample_distinct(32, 20, &mut rng);
        for (i, t) in tags.iter().enumerate() {
            assert_eq!(h.insert(t.clone()).unwrap(), i);
        }
        for (i, t) in tags.iter().enumerate() {
            let out = h.lookup(t.clone()).unwrap();
            assert_eq!(out.addr, Some(i));
        }
        let m = h.metrics().unwrap();
        assert_eq!(m.lookups, 20);
        assert_eq!(m.hits, 20);
        assert_eq!(m.inserts, 20);
    }

    #[test]
    fn concurrent_lookups_batch_together_on_the_engine_thread_path() {
        // readers = 0 exercises the legacy engine-thread path, where the
        // dynamic batcher still coalesces concurrent singles (the PJRT
        // backend depends on this path).
        let server = CamServer::new(
            DesignConfig::small_test(),
            DecodeBackend::Native,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) },
        )
        .with_readers(0);
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(2);
        let tags = TagDistribution::Uniform.sample_distinct(32, 32, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        let mut joins = Vec::new();
        for t in tags {
            let h = h.clone();
            joins.push(std::thread::spawn(move || h.lookup(t).unwrap().addr.is_some()));
        }
        let hits = joins.into_iter().map(|j| j.join().unwrap()).filter(|&b| b).count();
        assert_eq!(hits, 32);
        let m = h.metrics().unwrap();
        assert_eq!(m.lookups, 32);
        assert!(m.batches < 32, "some batching must occur: {} batches", m.batches);
        assert!(m.batch_size.mean() > 1.0);
    }

    #[test]
    fn reader_pool_answers_concurrent_lookups_bit_identically() {
        // the pool path: 4 readers, 16 client threads, every outcome must
        // equal the reference engine's, field for field
        let cfg = DesignConfig::small_test();
        let mut reference = LookupEngine::new(cfg.clone());
        let server =
            CamServer::new(cfg, DecodeBackend::Native, policy()).with_readers(4);
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(41);
        let tags = TagDistribution::Uniform.sample_distinct(32, 30, &mut rng);
        for t in &tags {
            let a = h.insert(t.clone()).unwrap();
            assert_eq!(a, reference.insert(t).unwrap());
        }
        let want: Vec<LookupOutcome> =
            tags.iter().map(|t| reference.lookup(t).unwrap()).collect();
        let mut joins = Vec::new();
        for _ in 0..16 {
            let h = h.clone();
            let tags = tags.clone();
            let want = want.clone();
            joins.push(std::thread::spawn(move || {
                for (t, w) in tags.iter().zip(&want) {
                    assert_eq!(&h.lookup(t.clone()).unwrap(), w);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = h.metrics().unwrap();
        assert_eq!(m.lookups, 16 * 30, "every pool lookup is metered");
        assert_eq!(m.hits, 16 * 30);
    }

    #[test]
    fn direct_reads_observe_acked_mutations() {
        // publish-before-ack: after insert() returns, a direct read on any
        // thread sees the entry; after delete() returns, it is gone
        let server =
            CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(42);
        let tags = TagDistribution::Uniform.sample_distinct(32, 20, &mut rng);
        let mut scratch = DecodeScratch::new();
        for (i, t) in tags.iter().enumerate() {
            assert_eq!(h.insert(t.clone()).unwrap(), i);
            assert_eq!(h.lookup_direct(t, &mut scratch).unwrap().addr, Some(i));
        }
        h.delete(3).unwrap();
        assert_eq!(h.lookup_direct(&tags[3], &mut scratch).unwrap().addr, None);
        let m = h.metrics().unwrap();
        assert_eq!(m.lookups, 21, "direct reads are metered too");
    }

    #[test]
    fn delete_barrier_orders_before_following_lookups() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(3);
        let tags = TagDistribution::Uniform.sample_distinct(32, 4, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        h.delete(2).unwrap();
        let out = h.lookup(tags[2].clone()).unwrap();
        assert_eq!(out.addr, None);
    }

    #[test]
    fn drain_is_a_noop_on_idle_server() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        h.drain();
        assert_eq!(h.metrics().unwrap().lookups, 0);
    }

    #[test]
    fn lookup_many_matches_singles_and_preserves_order() {
        for readers in [0usize, 1, 4] {
            let server =
                CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy())
                    .with_readers(readers);
            let h = server.spawn();
            let mut rng = Rng::seed_from_u64(8);
            let tags = TagDistribution::Uniform.sample_distinct(32, 30, &mut rng);
            for t in &tags {
                h.insert(t.clone()).unwrap();
            }
            let singles: Vec<_> =
                tags.iter().map(|t| h.lookup(t.clone()).unwrap().addr).collect();
            let bulk = h.lookup_many(tags.clone());
            assert_eq!(bulk.len(), 30);
            for (i, r) in bulk.iter().enumerate() {
                assert_eq!(
                    r.as_ref().unwrap().addr,
                    singles[i],
                    "readers={readers}: order must be preserved"
                );
            }
            assert!(h.lookup_many(Vec::new()).is_empty());
        }
    }

    #[test]
    fn persist_without_a_store_is_a_no_op_ack() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        assert!(!h.flush_store().unwrap(), "no store: flush acks false");
        assert!(!h.snapshot_store().unwrap(), "no store: snapshot acks false");
    }

    #[test]
    fn persist_with_a_store_logs_before_the_ack() {
        let dir = std::env::temp_dir()
            .join(format!("cscam-coord-{}", std::process::id()))
            .join("persist");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = DesignConfig::small_test();
        let opts = crate::store::StoreOptions::default();
        let (bank, _) = crate::store::DurableBank::open(&dir, cfg.clone(), opts).unwrap();
        let (engine, store) = bank.into_parts();
        let h = CamServer::with_engine(engine, DecodeBackend::Native, policy())
            .with_store(store)
            .spawn();
        let mut rng = Rng::seed_from_u64(31);
        let tags = TagDistribution::Uniform.sample_distinct(32, 6, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        h.delete(1).unwrap();
        assert!(h.flush_store().unwrap());
        // acked mutations are already on disk: a reopen replays all of them
        let (bank, report) =
            crate::store::DurableBank::open(&dir, cfg, crate::store::StoreOptions::default())
                .unwrap();
        assert_eq!(report.wal_records, 7);
        assert_eq!(bank.occupancy(), 5);
        // a forced snapshot truncates the log
        assert!(h.snapshot_store().unwrap());
        drop(bank);
    }

    #[test]
    fn dropped_server_reports_persist_shutdown() {
        let h = dead_handle();
        assert!(matches!(h.flush_store(), Err(PersistError::Shutdown)));
        assert!(matches!(h.snapshot_store(), Err(PersistError::Shutdown)));
    }

    #[test]
    fn server_exits_when_handles_drop() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let h2 = h.clone();
        drop(h);
        drop(h2);
        // nothing to assert directly; the engine and reader threads exiting
        // keeps the process from hanging at test end (would deadlock
        // `cargo test` otherwise)
    }

    #[test]
    fn dropped_server_yields_shutdown_not_full() {
        // A handle whose engine thread is gone must report Shutdown — Full
        // means "no free CAM slot" and would mislead capacity-aware callers.
        let h = dead_handle();
        assert_eq!(h.lookup(BitVec::zeros(32)).unwrap_err(), EngineError::Shutdown);
        assert_eq!(h.try_lookup(BitVec::zeros(32)).unwrap_err(), EngineError::Shutdown);
        assert_eq!(h.depth.load(), 0, "failed sends must not leak depth");
        assert_eq!(h.insert(BitVec::zeros(32)).unwrap_err(), EngineError::Shutdown);
        assert_eq!(h.delete(0).unwrap_err(), EngineError::Shutdown);
        let bulk = h.lookup_many(vec![BitVec::zeros(32); 3]);
        assert_eq!(bulk.len(), 3);
        for r in bulk {
            assert_eq!(r.unwrap_err(), EngineError::Shutdown);
        }
        assert!(h.metrics().is_none());
        h.drain(); // must not hang or panic
    }

    #[test]
    fn replicated_apply_and_install_mirror_a_reference_engine() {
        let cfg = DesignConfig::small_test();
        let mut reference = LookupEngine::new(cfg.clone());
        let h = CamServer::new(cfg.clone(), DecodeBackend::Native, policy()).spawn();
        let mut rng = Rng::seed_from_u64(77);
        let tags = TagDistribution::Uniform.sample_distinct(32, 12, &mut rng);
        let mut records = Vec::new();
        for t in &tags {
            let addr = reference.insert(t).unwrap();
            records.push(WalRecord::Insert { addr: addr as u64, tag: t.clone() });
        }
        reference.delete(2).unwrap();
        records.push(WalRecord::Delete { addr: 2 });
        assert_eq!(h.apply_replicated(records).unwrap(), 13);
        // publish-before-ack holds for replicated applies: a direct read
        // issued after the ack sees the state, field-for-field identical
        // to an engine that executed the same history locally
        let mut scratch = DecodeScratch::new();
        for t in &tags {
            let want = reference.lookup(t).unwrap();
            assert_eq!(h.lookup_direct(t, &mut scratch).unwrap(), want);
        }
        let m = h.metrics().unwrap();
        assert_eq!(m.inserts, 12, "replicated mutations are metered");
        assert_eq!(m.deletes, 1);

        // installing a transferred image replaces the whole state
        let mut donor = LookupEngine::new(cfg);
        let extra = TagDistribution::Uniform.sample_distinct(32, 5, &mut rng);
        for t in &extra {
            donor.insert(t).unwrap();
        }
        let want: Vec<_> = extra.iter().map(|t| donor.lookup(t).unwrap()).collect();
        h.install_image(BankImage::from_engine(&donor)).unwrap();
        for (t, w) in extra.iter().zip(&want) {
            assert_eq!(&h.lookup_direct(t, &mut scratch).unwrap(), w);
        }
        for t in tags.iter().filter(|t| !extra.contains(t)) {
            assert_eq!(
                h.lookup_direct(t, &mut scratch).unwrap().addr,
                None,
                "pre-install state must be gone"
            );
        }
        // geometry mismatch is refused, state untouched
        let other = DesignConfig { m: DesignConfig::small_test().m * 2, ..DesignConfig::small_test() };
        let wrong = BankImage::from_engine(&LookupEngine::new(other));
        assert!(matches!(
            h.install_image(wrong),
            Err(PersistError::Store(StoreError::Incompatible(_)))
        ));
        assert!(h.lookup_direct(&extra[0], &mut scratch).unwrap().addr.is_some());
    }

    #[test]
    fn try_lookup_sheds_busy_at_capacity_while_lookup_blocks_through() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy())
            .with_queue_capacity(0);
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(21);
        let tags = TagDistribution::Uniform.sample_distinct(32, 4, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        // cap 0: the non-blocking path sheds every request with Busy (a
        // queue condition — Full stays reserved for "no free CAM slot")...
        assert_eq!(h.try_lookup(tags[0].clone()).unwrap_err(), EngineError::Busy);
        // ...while the blocking path still serves (shedding is opt-in).
        assert_eq!(h.lookup(tags[0].clone()).unwrap().addr, Some(0));
        let m = h.metrics().unwrap();
        assert_eq!(m.lookups, 1, "shed requests never reach a serving thread");
        assert_eq!(m.shed_busy, 1, "the shed itself is metered");
        assert_eq!(m.shed_full, 0);
    }

    #[test]
    fn full_cam_inserts_count_as_full_sheds() {
        let cfg = DesignConfig::small_test();
        let capacity = cfg.m;
        let server = CamServer::new(cfg, DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(26);
        let tags = TagDistribution::Uniform.sample_distinct(32, capacity + 2, &mut rng);
        let mut fulls = 0;
        for t in &tags {
            if h.insert(t.clone()) == Err(EngineError::Full) {
                fulls += 1;
            }
        }
        assert_eq!(fulls, 2, "the CAM holds exactly M entries");
        let m = h.metrics().unwrap();
        assert_eq!(m.shed_full, 2);
        assert_eq!(m.shed_busy, 0);
    }

    #[test]
    fn try_lookup_admits_below_capacity() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(22);
        let tags = TagDistribution::Uniform.sample_distinct(32, 4, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        assert!(!h.is_saturated());
        for (i, t) in tags.iter().enumerate() {
            assert_eq!(h.try_lookup(t.clone()).unwrap().addr, Some(i));
        }
        // the queue drains as the readers answer: depth returns to zero
        h.drain();
        assert_eq!(h.depth.load(), 0);
    }

    #[test]
    fn deferred_lookups_scatter_then_gather() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(23);
        let tags = TagDistribution::Uniform.sample_distinct(32, 8, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        let pending: Vec<_> =
            tags.iter().map(|t| h.lookup_deferred(t.clone()).unwrap()).collect();
        for (i, p) in pending.into_iter().enumerate() {
            assert_eq!(p.wait().unwrap().addr, Some(i));
        }
        let bulk = h.lookup_many_deferred(tags.clone()).unwrap().wait();
        for (i, r) in bulk.into_iter().enumerate() {
            assert_eq!(r.unwrap().addr, Some(i));
        }
        assert!(h.lookup_many_deferred(Vec::new()).unwrap().wait().is_empty());
    }

    #[test]
    fn bulk_admission_counts_per_tag() {
        // A bulk message of N tags must weigh N against the admission cap,
        // not 1 — otherwise chunked clients never shed.
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(24);
        let tags = TagDistribution::Uniform.sample_distinct(32, 6, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        let pending = h.lookup_many_deferred(tags.clone()).unwrap();
        // enqueue counted 6; it may already be partially dequeued, never more
        assert!(h.depth.load() <= 6);
        let results = pending.wait();
        assert_eq!(results.len(), 6);
        h.drain();
        assert_eq!(h.depth.load(), 0, "per-tag weights must balance");
    }

    #[test]
    fn big_bulks_fan_out_across_the_pool() {
        // 4 readers, one 256-tag bulk with max_batch 8: the slice must be
        // split (order still preserved) rather than land on one reader
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy())
            .with_readers(4);
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(25);
        let tags = TagDistribution::Uniform.sample_distinct(32, 60, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        let mut queries = Vec::new();
        for _ in 0..4 {
            queries.extend(tags.iter().cloned());
        }
        let out = h.lookup_many(queries.clone());
        assert_eq!(out.len(), 240);
        for (i, r) in out.into_iter().enumerate() {
            assert_eq!(r.unwrap().addr, Some(i % 60), "order across parts");
        }
        h.drain();
        assert_eq!(h.depth.load(), 0);
    }
}
