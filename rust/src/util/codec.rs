//! Shared binary codec primitives: little-endian scalar writers and a
//! bounds-checked payload reader.
//!
//! Two subsystems serialize structured records into checksummed binary
//! frames and MUST agree on the primitive encodings: the wire protocol
//! ([`crate::net::proto`] frames requests/responses over TCP) and the
//! durability layer ([`crate::store`] writes snapshots and WAL records to
//! disk).  Both build on exactly these helpers so the byte-level
//! conventions — little-endian scalars, `f64` as IEEE-754 bit patterns,
//! bit vectors as a `u32` length plus packed words — live in one place.
//!
//! Decoding is *total*: every reader returns a typed [`CodecError`] on
//! malformed input, and count-prefixed allocations are bounded by the
//! bytes actually present (see [`Cursor::remaining`]) so corrupt or
//! hostile input can never trigger an oversized allocation, let alone a
//! panic.

use crate::bits::BitVec;

/// A typed decode failure: the input bytes violate the encoding contract.
///
/// Wraps a human-readable description; the wire layer lifts it into
/// `WireError::Protocol`, the store layer into `StoreError::Corrupt`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodecError(pub String);

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CodecError {}

// ------------------------------------------------------------- writers

pub fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// IEEE-754 bit pattern: the decode side reproduces the value exactly.
pub fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// `u32` bit length + the packed little-endian words
/// ([`BitVec::to_bytes`]).
pub fn put_bitvec(buf: &mut Vec<u8>, v: &BitVec) {
    put_u32(buf, v.len() as u32);
    buf.extend_from_slice(&v.to_bytes());
}

// -------------------------------------------------------------- reader

/// Bounds-checked payload reader.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    /// Bytes left — the bound for any count-prefixed allocation: a count
    /// that claims more elements than the remaining bytes could possibly
    /// encode is rejected *before* `Vec::with_capacity` reserves for it.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if n > self.buf.len() - self.pos {
            return Err(CodecError(format!(
                "truncated payload: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn take_u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    pub fn take_u16(&mut self) -> Result<u16, CodecError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn take_u32(&mut self) -> Result<u32, CodecError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn take_u64(&mut self) -> Result<u64, CodecError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn take_f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    /// Inverse of [`put_bitvec`]: the word count is derived from the bit
    /// length and bounded by the remaining bytes before anything is read,
    /// and set bits past the length are rejected (strict tail validation —
    /// see [`BitVec::from_bytes`]).
    pub fn take_bitvec(&mut self) -> Result<BitVec, CodecError> {
        let len = self.take_u32()? as usize;
        let nbytes = len.div_ceil(64) * 8;
        if nbytes > self.remaining() {
            return Err(CodecError(format!(
                "bit vector of {len} bits needs {nbytes} bytes, have {}",
                self.remaining()
            )));
        }
        let bytes = self.take(nbytes)?;
        BitVec::from_bytes(bytes, len).map_err(|e| CodecError(format!("bit vector: {e}")))
    }

    /// Reject trailing garbage after a complete decode.
    pub fn finish(&self) -> Result<(), CodecError> {
        if self.pos != self.buf.len() {
            return Err(CodecError(format!(
                "{} trailing payload bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_roundtrip() {
        let mut buf = Vec::new();
        put_u16(&mut buf, 0xBEEF);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX - 1);
        put_f64(&mut buf, -0.125);
        let mut c = Cursor::new(&buf);
        assert_eq!(c.take_u16().unwrap(), 0xBEEF);
        assert_eq!(c.take_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(c.take_u64().unwrap(), u64::MAX - 1);
        assert_eq!(c.take_f64().unwrap().to_bits(), (-0.125f64).to_bits());
        c.finish().unwrap();
    }

    #[test]
    fn truncated_reads_are_typed_errors() {
        let mut c = Cursor::new(&[1, 2, 3]);
        assert!(c.take_u32().is_err());
        let mut c = Cursor::new(&[]);
        assert!(c.take_u8().is_err());
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut buf = Vec::new();
        put_u16(&mut buf, 7);
        buf.push(0xAB);
        let mut c = Cursor::new(&buf);
        c.take_u16().unwrap();
        assert!(c.finish().is_err());
    }

    #[test]
    fn bitvec_roundtrips_and_bounds_allocation() {
        for len in [0usize, 1, 63, 64, 65, 127, 128, 200] {
            let mut v = BitVec::zeros(len);
            for i in (0..len).step_by(3) {
                v.set(i, true);
            }
            let mut buf = Vec::new();
            put_bitvec(&mut buf, &v);
            let mut c = Cursor::new(&buf);
            assert_eq!(c.take_bitvec().unwrap(), v, "len={len}");
            c.finish().unwrap();
        }
        // a length claiming gigabytes is rejected before any allocation
        let mut buf = Vec::new();
        put_u32(&mut buf, u32::MAX);
        buf.extend_from_slice(&[0u8; 16]);
        assert!(Cursor::new(&buf).take_bitvec().is_err());
    }

    #[test]
    fn bitvec_tail_garbage_is_rejected() {
        // 70-bit vector: bits 70..127 of the word image are slack and must
        // decode to an error when set (the store contract is strict; the
        // wire's tag reader masks instead — see net/proto).
        let mut buf = Vec::new();
        put_u32(&mut buf, 70);
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(Cursor::new(&buf).take_bitvec().is_err());
    }
}
