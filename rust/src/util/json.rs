//! Minimal JSON parser — enough for `artifacts/manifest.json`.
//!
//! Supports the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null); rejects trailing garbage.  Not
//! performance-critical: the manifest is parsed once at startup.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<JsonValue> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// Required object field.
    pub fn req(&self, key: &str) -> Result<&JsonValue> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn as_object(&self) -> Result<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Object(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    pub fn as_array(&self) -> Result<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            JsonValue::String(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            JsonValue::Number(x) if *x >= 0.0 && x.fract() == 0.0 => Ok(*x as usize),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected '{}' at byte {}, got '{}'", b as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn literal(&mut self, text: &str, v: JsonValue) -> Result<JsonValue> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<JsonValue> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(JsonValue::String(self.string()?)),
            b't' => self.literal("true", JsonValue::Bool(true)),
            b'f' => self.literal("false", JsonValue::Bool(false)),
            b'n' => self.literal("null", JsonValue::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected '{}' at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(JsonValue::Object(map)),
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(JsonValue::Array(items)),
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x20 => bail!("raw control character in string"),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        self.pos = start + len;
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| anyhow!("invalid utf-8 in string"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let x: f64 = s.parse().map_err(|_| anyhow!("bad number '{s}'"))?;
        Ok(JsonValue::Number(x))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_document() {
        let doc = r#"{
            "config": {"m": 512, "c": 3, "l": 8, "zeta": 8, "q": 9, "beta": 64},
            "artifacts": {
                "gd_decode_b1": {
                    "kind": "decode", "batch": 1,
                    "inputs": [{"name": "idx", "dtype": "s32", "shape": [1, 3]}],
                    "outputs": [{"name": "enables", "dtype": "f32", "shape": [1, 64]}]
                }
            }
        }"#;
        let v = JsonValue::parse(doc).unwrap();
        assert_eq!(v.req("config").unwrap().req("m").unwrap().as_usize().unwrap(), 512);
        let art = v.req("artifacts").unwrap().as_object().unwrap();
        let dec = &art["gd_decode_b1"];
        assert_eq!(dec.req("kind").unwrap().as_str().unwrap(), "decode");
        let shape = dec.req("outputs").unwrap().as_array().unwrap()[0]
            .req("shape")
            .unwrap()
            .as_array()
            .unwrap();
        assert_eq!(shape[1].as_usize().unwrap(), 64);
    }

    #[test]
    fn scalars_and_arrays() {
        assert_eq!(JsonValue::parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(JsonValue::parse("null").unwrap(), JsonValue::Null);
        assert_eq!(JsonValue::parse("-2.5e2").unwrap(), JsonValue::Number(-250.0));
        assert_eq!(
            JsonValue::parse("[1, 2, 3]").unwrap(),
            JsonValue::Array(vec![
                JsonValue::Number(1.0),
                JsonValue::Number(2.0),
                JsonValue::Number(3.0)
            ])
        );
    }

    #[test]
    fn string_escapes() {
        let v = JsonValue::parse(r#""a\n\"b\"A π""#).unwrap();
        assert_eq!(v, JsonValue::String("a\n\"b\"A π".to_string()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(JsonValue::parse("{").is_err());
        assert!(JsonValue::parse("[1,]").is_err());
        assert!(JsonValue::parse("{} extra").is_err());
        assert!(JsonValue::parse(r#"{"a" 1}"#).is_err());
        assert!(JsonValue::parse("tru").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(JsonValue::parse("{}").unwrap(), JsonValue::Object(BTreeMap::new()));
        assert_eq!(JsonValue::parse("[]").unwrap(), JsonValue::Array(vec![]));
        assert_eq!(JsonValue::parse("  { }  ").unwrap(), JsonValue::Object(BTreeMap::new()));
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert!(JsonValue::Number(1.5).as_usize().is_err());
        assert!(JsonValue::Number(-1.0).as_usize().is_err());
        assert_eq!(JsonValue::Number(7.0).as_usize().unwrap(), 7);
    }
}
