// Fixture: Busy never gets a wire error code.

pub enum EngineError {
    Full,
    Busy,
}
