// Fixture: one bare Relaxed, one justified.

use std::sync::atomic::{AtomicUsize, Ordering};

pub fn bump(counter: &AtomicUsize) -> usize {
    counter.fetch_add(1, Ordering::Relaxed)
}

pub fn bump_justified(counter: &AtomicUsize) -> usize {
    // lint:allow(relaxed: advisory counter, nothing orders against it)
    counter.fetch_add(1, Ordering::Relaxed)
}
