//! Multi-threaded wire-protocol load generator.
//!
//! Reuses the [`crate::workload`] streams: a setup client inserts a
//! uniform tag population, then `threads` clients (one connection each)
//! fire [`QueryMix`]-drawn lookups in pipelined bulk frames and record the
//! round-trip of every frame into a log-linear
//! [`Histogram`](crate::stats::Histogram) (≤ one sub-bucket of quantile
//! error, no per-frame allocation).  The report carries throughput and
//! p50/p99 frame latency plus the paper's metrics (mean λ, mean energy)
//! read off the wire outcomes, and converts to a [`BenchRecord`] so the
//! run lands in the same `BENCH_*.json` trajectory schema as the
//! in-process bench ([`crate::util::bench::write_bench_json`] with the
//! `net` tag).
//!
//! Two pacing modes:
//!
//! * **Closed-loop** (`rate == 0`, the default): every thread fires its
//!   next frame the moment the previous one is answered.  Throughput
//!   measures the *capacity* of the stack, but latency hides queueing —
//!   a slow response delays the next arrival (coordinated omission).
//! * **Open-loop** (`rate > 0` lookups/s across all threads): each frame
//!   has an *intended start* on a fixed arrival schedule; threads sleep
//!   until that instant and measure latency from the intended start, so a
//!   stalled server accrues queue delay in the histogram instead of
//!   silently thinning the arrival stream.
//!
//! And a **connection-ramp mode** (`conns > 0`): instead of one
//! connection per thread, the generator opens `conns` multiplexed
//! connections total (each thread owns an equal share and round-robins
//! its frames across them) while the offered load stays whatever the
//! pacing mode says.  Most connections are idle-ish at any instant —
//! exactly the c10k shape the reactor front-end exists for — and the
//! bench row records `conns` so a 5k-connection run is never gated
//! against a 64-connection one.

use std::time::{Duration, Instant};

use crate::bits::BitVec;
use crate::net::client::CamClient;
use crate::net::proto::WireError;
use crate::stats::Histogram;
use crate::util::bench::BenchRecord;
use crate::util::Rng;
use crate::workload::{QueryMix, TagDistribution};

/// Upper bound of the latency histogram: ~1.07 s in nanoseconds; frames
/// slower than this all land in the saturating top bucket.
const LATENCY_CEILING_NS: u64 = 1 << 30;

/// Load-generator parameters.
#[derive(Debug, Clone)]
pub struct LoadGen {
    /// Server address, e.g. `127.0.0.1:4242`.
    pub addr: String,
    /// Client threads (one TCP connection each).
    pub threads: usize,
    /// Total lookups across all threads.
    pub lookups: usize,
    /// Tags per pipelined bulk frame.
    pub chunk: usize,
    /// Fraction of queries drawn from the stored population.
    pub hit_ratio: f64,
    /// Tags inserted before the run (capped by fleet capacity).
    pub population: usize,
    /// Open-loop arrival rate in lookups/s summed over all threads;
    /// `0.0` selects closed-loop pacing.
    pub rate: f64,
    /// Connection-ramp mode: total multiplexed connections to hold open,
    /// spread evenly over the threads (each thread round-robins its
    /// frames across its share).  `0` keeps the legacy shape of one
    /// connection per thread; values below `threads` are raised to one
    /// connection per thread.
    pub conns: usize,
    pub seed: u64,
}

impl Default for LoadGen {
    fn default() -> Self {
        LoadGen {
            addr: String::new(),
            threads: 4,
            lookups: 20_000,
            chunk: 64,
            hit_ratio: 0.9,
            population: 256,
            rate: 0.0,
            conns: 0,
            seed: 7,
        }
    }
}

/// What one load-generator run measured.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Lookups that produced a wire result (hit or miss).
    pub lookups: usize,
    pub hits: usize,
    /// Lookups answered with a typed engine error (sheds) — still counted
    /// toward throughput, not toward the hit ratio.
    pub errors: usize,
    pub wall_s: f64,
    pub throughput_lps: f64,
    /// Frame latency quantiles in nanoseconds (a frame carries up to
    /// `chunk` lookups).  Closed-loop: send→answer round-trip.  Open-loop:
    /// intended-start→answer, so schedule slip counts as latency.
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub mean_lambda: f64,
    pub mean_energy_fj: f64,
    pub threads: usize,
    pub chunk: usize,
    /// Concurrent connections actually held open for the run (equals
    /// `threads` outside connection-ramp mode).
    pub conns: usize,
    /// Shard count the server announced at handshake.
    pub shards: u32,
    /// `true` when frames were paced on a fixed arrival schedule.
    pub open_loop: bool,
    /// Offered arrival rate in lookups/s (`0.0` on closed-loop runs).
    pub rate: f64,
}

impl LoadReport {
    /// Hit ratio over answered lookups.
    pub fn hit_ratio(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let pacing = if self.open_loop {
            format!("open-loop @ {:.0}/s", self.rate)
        } else {
            "closed-loop".into()
        };
        format!(
            "{} lookups in {:.3} s — {:.0} lookups/s {pacing}, hits {:.1} %, λ̄ {:.3}, \
             Ē {:.1} fJ, frame p50 {} ns p99 {} ns ({} threads × bulk {}, {} conns, \
             {} errors)",
            self.lookups,
            self.wall_s,
            self.throughput_lps,
            100.0 * self.hit_ratio(),
            self.mean_lambda,
            self.mean_energy_fj,
            self.p50_ns,
            self.p99_ns,
            self.threads,
            self.chunk,
            self.conns,
            self.errors
        )
    }

    /// The trajectory row for `write_bench_json(path, "net", …)`.
    /// Open-loop rows get their own name suffix so regression gating never
    /// compares an offered-rate run against a capacity run, and
    /// connection-ramp rows (`conns > threads`) carry the connection
    /// count in the name for the same reason.
    pub fn to_record(&self) -> BenchRecord {
        let pacing = if self.open_loop { "/open" } else { "" };
        let ramp = if self.conns > self.threads {
            format!("/conns{}", self.conns)
        } else {
            String::new()
        };
        let mut rec = BenchRecord::new(format!(
            "net/shards={}/threads={}/bulk{}{}{}",
            self.shards, self.threads, self.chunk, ramp, pacing
        ));
        rec.push("shards", self.shards as f64);
        rec.push("threads", self.threads as f64);
        rec.push("chunk", self.chunk as f64);
        rec.push("conns", self.conns as f64);
        rec.push("lookups", self.lookups as f64);
        rec.push("throughput_lps", self.throughput_lps);
        rec.push("p50_ns", self.p50_ns as f64);
        rec.push("p99_ns", self.p99_ns as f64);
        rec.push("hit_ratio", self.hit_ratio());
        rec.push("mean_lambda", self.mean_lambda);
        rec.push("mean_energy_fj", self.mean_energy_fj);
        rec.push("errors", self.errors as f64);
        rec.push("open_loop", if self.open_loop { 1.0 } else { 0.0 });
        rec.push("rate", self.rate);
        rec
    }
}

/// Intended start of the lookup with global arrival index `idx`, in
/// nanoseconds after the run's `t0`, on the fleet-wide schedule of
/// `rate` lookups/s.
///
/// Computed from the *global* index, not from a per-thread period: a
/// rounded per-thread period (`1e9 * threads / rate`) silently drops the
/// residual arrival rate whenever `rate` does not divide evenly over the
/// threads, and starts every thread's schedule in phase (arrivals come in
/// bursts of `threads`).  The global schedule keeps the offered rate
/// exact and interleaves the threads' slots.
fn intended_start_ns(idx: u64, rate: f64) -> u64 {
    (idx as f64 * 1e9 / rate).round() as u64
}

/// Per-thread tallies merged into the report.
struct Tally {
    lookups: usize,
    hits: usize,
    errors: usize,
    lambda_sum: u64,
    energy_sum_fj: f64,
    latency_ns: Histogram,
}

impl Tally {
    fn new() -> Tally {
        Tally {
            lookups: 0,
            hits: 0,
            errors: 0,
            lambda_sum: 0,
            energy_sum_fj: 0.0,
            latency_ns: Histogram::log_linear(LATENCY_CEILING_NS),
        }
    }
}

impl LoadGen {
    /// Populate the fleet (through the wire) and run the generator.
    pub fn run(&self) -> Result<LoadReport, WireError> {
        let mut setup = CamClient::connect(self.addr.clone())?;
        // lint:allow(infallible: connect() just succeeded, so the client
        // holds the handshake hello; a failed connect returned above)
        let hello = *setup.server_info().expect("connected client has a hello");
        let n = hello.tag_bits as usize;
        let capacity = (hello.shards as usize) * (hello.bank_m as usize);

        // Store a uniform population, leaving hash-placement headroom.
        let mut rng = Rng::seed_from_u64(self.seed);
        let want = self.population.min(capacity * 7 / 10).max(1);
        let candidates = TagDistribution::Uniform.sample_distinct(n, want, &mut rng);
        let mut stored = Vec::new();
        for t in &candidates {
            match setup.insert(t) {
                Ok(_) => stored.push(t.clone()),
                Err(WireError::Engine(_)) => {} // bank full: keep going
                Err(e) => return Err(e),
            }
        }
        // Pre-draw every thread's query stream so the timed region is pure
        // wire traffic.
        let threads = self.threads.max(1);
        let mix = QueryMix { hit_ratio: self.hit_ratio, zipf_s: 0.0 };
        let mut streams: Vec<Vec<BitVec>> = vec![Vec::new(); threads];
        for i in 0..self.lookups {
            streams[i % threads].push(mix.sample(&stored, n, &mut rng).0);
        }
        // Open-loop: one fleet-wide arrival schedule; the round-robin
        // stream split means thread `i` owns global arrival indices
        // `i, i + threads, i + 2·threads, …` (see `intended_start_ns`).
        let open_loop = self.rate > 0.0;
        let rate = self.rate;
        // Connection-ramp mode: `conns` connections total, split evenly
        // (the first `conns % threads` threads take the remainder).
        let conns_total = if self.conns == 0 { threads } else { self.conns.max(threads) };

        // Every connection is opened before the clock starts: the ramp
        // measures the reactor *holding* `conns` live connections, not
        // the client's serial connect cost — and an open-loop schedule
        // that began during setup would book the connect backlog as
        // request latency.
        let barrier = std::sync::Arc::new(std::sync::Barrier::new(threads + 1));
        let t0_cell = std::sync::Arc::new(std::sync::OnceLock::new());
        let mut joins = Vec::new();
        for (thread_idx, stream) in streams.into_iter().enumerate() {
            let addr = self.addr.clone();
            let chunk = self.chunk.max(1);
            let threads_u = threads as u64;
            let conns_here =
                conns_total / threads + usize::from(thread_idx < conns_total % threads);
            let barrier = std::sync::Arc::clone(&barrier);
            let t0_cell = std::sync::Arc::clone(&t0_cell);
            joins.push(std::thread::spawn(move || -> Result<Tally, WireError> {
                let mut clients = Vec::with_capacity(conns_here);
                let mut connect_err = None;
                for _ in 0..conns_here {
                    match CamClient::connect(addr.clone()) {
                        Ok(c) => clients.push(c),
                        Err(e) => {
                            connect_err = Some(e);
                            break;
                        }
                    }
                }
                // reach the barrier even on a failed connect: the other
                // threads (and the caller) are parked on it
                barrier.wait();
                let t0 = *t0_cell.get_or_init(Instant::now);
                if let Some(e) = connect_err {
                    return Err(e);
                }
                let mut next_conn = 0usize;
                let mut t = Tally::new();
                // Lookups this thread has already scheduled; its next
                // frame starts at the global slot of its first lookup.
                let mut sent: u64 = 0;
                for frame in stream.chunks(chunk) {
                    let started = if open_loop {
                        let global = sent * threads_u + thread_idx as u64;
                        let intended =
                            Duration::from_nanos(intended_start_ns(global, rate));
                        let now = t0.elapsed();
                        if now < intended {
                            std::thread::sleep(intended - now);
                        }
                        sent += frame.len() as u64;
                        intended
                    } else {
                        t0.elapsed()
                    };
                    // round-robin the share: every connection sees traffic,
                    // so the ramp measures the reactor holding them all
                    // live, not one hot connection among idle ones
                    let client = &mut clients[next_conn];
                    next_conn = (next_conn + 1) % conns_here.max(1);
                    let results = client.lookup_bulk(frame, chunk)?;
                    // Open-loop latency runs from the *intended* start, so
                    // time a late frame spent queued behind schedule counts.
                    let lat = t0.elapsed().saturating_sub(started);
                    t.latency_ns.record(lat.as_nanos() as u64);
                    for r in results {
                        match r {
                            Ok(o) => {
                                t.lookups += 1;
                                t.hits += o.addr.is_some() as usize;
                                t.lambda_sum += o.lambda as u64;
                                t.energy_sum_fj += o.energy.total_fj();
                            }
                            Err(_) => t.errors += 1,
                        }
                    }
                }
                Ok(t)
            }));
        }
        barrier.wait();
        let t0 = *t0_cell.get_or_init(Instant::now);
        let mut total = Tally::new();
        for j in joins {
            let t = j.join().map_err(|_| {
                WireError::Protocol("load-generator thread panicked".into())
            })??;
            total.lookups += t.lookups;
            total.hits += t.hits;
            total.errors += t.errors;
            total.lambda_sum += t.lambda_sum;
            total.energy_sum_fj += t.energy_sum_fj;
            total.latency_ns.merge(&t.latency_ns);
        }
        let wall_s = t0.elapsed().as_secs_f64();

        let served = total.lookups + total.errors;
        Ok(LoadReport {
            lookups: total.lookups,
            hits: total.hits,
            errors: total.errors,
            wall_s,
            throughput_lps: if wall_s > 0.0 { served as f64 / wall_s } else { 0.0 },
            p50_ns: total.latency_ns.quantile(0.5),
            p99_ns: total.latency_ns.quantile(0.99),
            mean_lambda: if total.lookups > 0 {
                total.lambda_sum as f64 / total.lookups as f64
            } else {
                0.0
            },
            mean_energy_fj: if total.lookups > 0 {
                total.energy_sum_fj / total.lookups as f64
            } else {
                0.0
            },
            threads,
            chunk: self.chunk.max(1),
            conns: conns_total,
            shards: hello.shards,
            open_loop,
            rate: self.rate,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_arrival_schedule_keeps_the_full_offered_rate() {
        // 700/s does not divide over common thread counts; the rounded
        // per-thread period this replaced shipped fewer arrivals/s
        let rate = 700.0;
        let in_first_second = (0..10_000u64)
            .take_while(|&i| intended_start_ns(i, rate) < 1_000_000_000)
            .count();
        assert_eq!(in_first_second, 700, "no residual QPS may be dropped");
        // consecutive arrivals sit one inter-arrival gap apart (rounding
        // moves a boundary by at most a nanosecond)
        let gap = 1e9 / rate;
        for i in 0..1_000u64 {
            let d = intended_start_ns(i + 1, rate) - intended_start_ns(i, rate);
            assert!((d as f64 - gap).abs() <= 1.0, "gap {d} ns at index {i}");
        }
    }

    #[test]
    fn thread_slot_reconstruction_tiles_the_global_schedule() {
        // the round-robin stream split (`i % threads`) and the in-thread
        // reconstruction (`sent * threads + thread_idx`) must agree: every
        // global index is claimed exactly once
        let (threads, lookups) = (3usize, 11usize);
        let mut seen = vec![false; lookups];
        for thread_idx in 0..threads {
            let mut sent = 0u64;
            for i in 0..lookups {
                if i % threads == thread_idx {
                    let global = sent * threads as u64 + thread_idx as u64;
                    assert_eq!(global, i as u64);
                    assert!(!seen[i]);
                    seen[i] = true;
                    sent += 1;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "schedule has holes");
    }
}
