//! Dynamic batching for the decode stage.
//!
//! The PJRT decode artifact is compiled for fixed batch sizes at AOT time
//! (the paper's analogue: the CNN is a fixed-width datapath), so the serve
//! loop accumulates requests and flushes either when the largest compiled
//! batch fills or when the oldest request has waited `max_wait` — the
//! classic size-or-deadline policy of serving systems.
//!
//! The batcher is a *pure state machine* (no threads, no clocks of its own):
//! the server drives it with `push`/`due`/`flush`, which makes the policy
//! unit-testable without spinning up the serve thread.

use std::time::{Duration, Instant};

/// Flush policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush when the oldest queued request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(200) }
    }
}

/// A size/deadline batcher over opaque items.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    queue: Vec<T>,
    oldest: Option<Instant>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch > 0);
        Batcher { policy, queue: Vec::with_capacity(policy.max_batch), oldest: None }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Queue an item at `now`; returns a full batch if the size trigger
    /// fired.
    pub fn push(&mut self, item: T, now: Instant) -> Option<Vec<T>> {
        if self.queue.is_empty() {
            self.oldest = Some(now);
        }
        self.queue.push(item);
        if self.queue.len() >= self.policy.max_batch {
            Some(self.flush())
        } else {
            None
        }
    }

    /// The instant at which the deadline trigger will fire, if any.
    pub fn deadline(&self) -> Option<Instant> {
        self.oldest.map(|t| t + self.policy.max_wait)
    }

    /// True if the deadline has passed at `now`.
    pub fn due(&self, now: Instant) -> bool {
        matches!(self.deadline(), Some(d) if now >= d)
    }

    /// Take everything queued.
    pub fn flush(&mut self) -> Vec<T> {
        self.oldest = None;
        std::mem::take(&mut self.queue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn size_trigger_flushes_exactly_at_max() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(1) });
        let now = t0();
        assert!(b.push(1, now).is_none());
        assert!(b.push(2, now).is_none());
        let batch = b.push(3, now).expect("size trigger");
        assert_eq!(batch, vec![1, 2, 3]);
        assert!(b.is_empty());
        assert_eq!(b.deadline(), None);
    }

    #[test]
    fn deadline_trigger_counts_from_oldest() {
        let mut b =
            Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5) });
        let now = t0();
        b.push('a', now);
        b.push('b', now + Duration::from_millis(4));
        assert!(!b.due(now + Duration::from_millis(4)));
        assert!(b.due(now + Duration::from_millis(5)));
        assert_eq!(b.flush(), vec!['a', 'b']);
        assert!(!b.due(now + Duration::from_secs(9)), "empty batcher is never due");
    }

    #[test]
    fn deadline_resets_after_flush() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(1) });
        let now = t0();
        b.push(1, now);
        b.flush();
        b.push(2, now + Duration::from_millis(10));
        let d = b.deadline().unwrap();
        assert_eq!(d, now + Duration::from_millis(11));
    }

    #[test]
    fn single_item_batches_allowed() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 1, max_wait: Duration::ZERO });
        assert_eq!(b.push(42, t0()), Some(vec![42]));
    }
}
