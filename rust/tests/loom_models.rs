//! Loom models of the concurrency kernel behind the serving path.
//!
//! `util::sync` is the only module in the tree that owns raw
//! synchronization: the `PublishSlot` RCU swap that `SharedSearch`
//! readers snapshot, the lock-free MPMC `BatchChannel` the reader pools
//! and the net reactor's worker pool drain, and the `AdmissionGauge` the
//! coordinator uses to decide when a drain has settled.  These models run
//! those primitives under loom, which exhaustively permutes every thread
//! interleaving the memory model allows — including the weak-ordering
//! reorderings a real machine only exhibits under load.
//!
//! For the channel that means the properties the serving path leans on:
//! exactly-once delivery under racing consumers, FIFO per producer,
//! shutdown draining the backlog instead of dropping it, and the
//! completion barrier observing the worker's side effects.
//!
//! Compiled only with the `loom` feature, which swaps the facade onto
//! loom's instrumented primitives:
//!
//! ```text
//! cargo test -p cscam --test loom_models --features loom --release
//! ```
//!
//! Every model stays at two threads plus main; loom's state space is
//! exponential in both thread count and atomic-op count, and two
//! threads already cover the pairwise races these primitives exist to
//! resolve.

#![cfg(feature = "loom")]

use std::sync::Arc;

use cscam::util::sync::{
    AdmissionGauge, AtomicUsize, BatchChannel, JobGuard, Ordering, PublishSlot,
};
use loom::thread;

/// A snapshot never observes a half-published value, and snapshots are
/// monotonic: once a reader has seen generation g, it never sees an
/// older one.  The payload pairs are self-describing (`.1 == .0 * 10`),
/// so any torn or stale-mix read fails the arithmetic check.
#[test]
fn publish_slot_snapshots_are_atomic_and_monotonic() {
    loom::model(|| {
        let slot = Arc::new(PublishSlot::new(Arc::new((0usize, 0usize))));
        let writer = {
            let slot = Arc::clone(&slot);
            thread::spawn(move || {
                slot.publish(Arc::new((1, 10)));
                slot.publish(Arc::new((2, 20)));
            })
        };
        let first = slot.snapshot();
        let second = slot.snapshot();
        assert_eq!(first.1, first.0 * 10, "torn snapshot: {:?}", *first);
        assert_eq!(second.1, second.0 * 10, "torn snapshot: {:?}", *second);
        assert!(
            second.0 >= first.0,
            "snapshot went backwards: {} after {}",
            second.0,
            first.0
        );
        writer.join().expect("writer panicked");
    });
}

/// `AdmissionGauge` retires with Release and loads with Acquire, so a
/// reader that observes depth zero also observes every write the
/// retiring worker made before `retire()`.  With Relaxed orderings this
/// model fails: loom finds the interleaving where depth reads zero but
/// the payload store has not yet become visible.
#[test]
fn admission_gauge_zero_publishes_the_workers_writes() {
    loom::model(|| {
        let gauge = Arc::new(AdmissionGauge::new());
        let payload = Arc::new(AtomicUsize::new(0));
        gauge.admit(1);
        let worker = {
            let gauge = Arc::clone(&gauge);
            let payload = Arc::clone(&payload);
            thread::spawn(move || {
                // lint:allow(relaxed: the gauge's Release/Acquire edge is
                // the ordering under test; the payload itself rides it)
                payload.store(42, Ordering::Relaxed);
                gauge.retire(1);
            })
        };
        if gauge.load() == 0 {
            assert_eq!(
                payload.load(Ordering::Relaxed),
                42,
                "gauge hit zero before the worker's write became visible"
            );
        }
        worker.join().expect("worker panicked");
    });
}

/// Two workers racing on the ring with batched pops serve each job
/// exactly once, and the sender-count shutdown protocol wakes both of
/// them: neither worker deadlocks in `pop_batch()` after the last sender
/// detaches, whether the detach lands before, between, or after the
/// pops.  This is the reactor's worker-pool loop in miniature.
#[test]
fn batch_channel_serves_every_job_exactly_once() {
    loom::model(|| {
        let chan = Arc::new(BatchChannel::with_capacity(4));
        let served = Arc::new(AtomicUsize::new(0));
        chan.push(1u32);
        chan.push(2u32);
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let chan = Arc::clone(&chan);
                let served = Arc::clone(&served);
                thread::spawn(move || {
                    let mut batch = Vec::new();
                    loop {
                        batch.clear();
                        if chan.pop_batch(2, &mut batch) == 0 {
                            return;
                        }
                        for _job in batch.drain(..) {
                            let _done = JobGuard::new(&chan);
                            served.fetch_add(1, Ordering::AcqRel);
                        }
                    }
                })
            })
            .collect();
        chan.remove_sender();
        for worker in workers {
            worker.join().expect("worker panicked");
        }
        assert_eq!(served.load(Ordering::Acquire), 2, "lost or duplicated a job");
    });
}

/// Values pushed by one producer are consumed in that producer's push
/// order even while a second producer interleaves with it — the property
/// that keeps one connection's requests ordered into the worker pool
/// while many connections share the ring.  Also proves shutdown-drain:
/// the consumer sees every value before end-of-stream.
#[test]
fn batch_channel_is_fifo_per_producer_under_contention() {
    loom::model(|| {
        let chan = Arc::new(BatchChannel::with_capacity(4));
        let producers: Vec<_> = (0..2u32)
            .map(|p| {
                chan.add_sender();
                let chan = Arc::clone(&chan);
                thread::spawn(move || {
                    let base = (p + 1) * 10;
                    chan.push(base + 1);
                    chan.push(base + 2);
                    chan.remove_sender();
                })
            })
            .collect();
        chan.remove_sender(); // the creator's handle; producers hold the rest
        let mut last = [0u32; 2];
        let mut total = 0;
        while let Some(v) = chan.pop() {
            chan.job_done();
            let p = (v / 10) as usize - 1;
            assert!(
                v % 10 > last[p] % 10,
                "producer {p} reordered: saw {v} after {}",
                last[p]
            );
            last[p] = v;
            total += 1;
        }
        assert_eq!(total, 4, "shutdown dropped part of the backlog");
        for producer in producers {
            producer.join().expect("producer panicked");
        }
    });
}

/// A single consumer drains jobs in push order, and jobs already queued
/// survive the last sender detaching — shutdown means "no more work",
/// never "drop the backlog".
#[test]
fn batch_channel_is_fifo_and_keeps_the_backlog_through_shutdown() {
    loom::model(|| {
        let chan = Arc::new(BatchChannel::with_capacity(4));
        chan.push(1u32);
        chan.push(2u32);
        let consumer = {
            let chan = Arc::clone(&chan);
            thread::spawn(move || {
                let first = chan.pop();
                chan.job_done();
                let second = chan.pop();
                chan.job_done();
                let third = chan.pop();
                (first, second, third)
            })
        };
        chan.remove_sender();
        let order = consumer.join().expect("consumer panicked");
        assert_eq!(
            order,
            (Some(1), Some(2), None),
            "channel reordered or dropped the backlog"
        );
    });
}

/// `barrier()` returns only after every job enqueued before the call
/// has been marked done — and the completion protocol's SeqCst fences
/// make the worker's side effects visible to the thread that was
/// waiting, in every interleaving.
#[test]
fn barrier_waits_for_prior_jobs_and_sees_their_effects() {
    loom::model(|| {
        let chan = Arc::new(BatchChannel::with_capacity(4));
        let effect = Arc::new(AtomicUsize::new(0));
        chan.push(7u32);
        let worker = {
            let chan = Arc::clone(&chan);
            let effect = Arc::clone(&effect);
            thread::spawn(move || {
                if let Some(_job) = chan.pop() {
                    let _done = JobGuard::new(&chan);
                    // lint:allow(relaxed: ordered by the channel's own
                    // completion hand-off, which is what the model checks)
                    effect.store(1, Ordering::Relaxed);
                }
            })
        };
        chan.barrier();
        assert_eq!(
            effect.load(Ordering::Relaxed),
            1,
            "barrier returned before the in-flight job finished"
        );
        chan.remove_sender();
        worker.join().expect("worker panicked");
    });
}
