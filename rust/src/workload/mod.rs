//! Workload generators: tag populations and query streams.
//!
//! The paper's intro motivates CAMs with TLBs [1] and network routers [2];
//! its analysis assumes uniformly random reduced tags and warns that
//! non-uniform inputs cost power but not correctness (§I/§II-B).  These
//! generators provide all of those regimes:
//!
//! * [`TagDistribution::Uniform`] — i.i.d. uniform tags (the paper's model);
//! * [`TagDistribution::Correlated`] — low-entropy tags: a fixed prefix and
//!   duplicated bit fields, the adversarial case for naive bit selection;
//! * [`TlbTrace`] — synthetic virtual-page-number stream with a working set
//!   and sequential strides (TLB regime);
//! * [`AclTrace`] — synthetic router/classifier tags built from a small
//!   pool of prefixes with random host bits (IPv6 regime of [2]);
//! * [`QueryMix`] — hit/miss-controlled query stream over a stored set,
//!   optionally Zipf-skewed toward hot entries.

use crate::util::Rng;

use crate::bits::BitVec;

/// How full tags are distributed.
#[derive(Debug, Clone, PartialEq)]
pub enum TagDistribution {
    /// Every bit i.i.d. Bernoulli(1/2).
    Uniform,
    /// Structured low-entropy tags: the top `fixed_bits` are a constant
    /// pattern (e.g. a process/VM id), and each bit in `mirror_span` repeats
    /// the bit below it (strong pairwise correlation).
    Correlated { fixed_bits: usize, mirror_span: usize },
}

impl TagDistribution {
    /// Draw one n-bit tag.
    pub fn sample(&self, n: usize, rng: &mut Rng) -> BitVec {
        match self {
            TagDistribution::Uniform => random_tag(n, rng),
            TagDistribution::Correlated { fixed_bits, mirror_span } => {
                let mut t = random_tag(n, rng);
                // constant high field
                for b in n.saturating_sub(*fixed_bits)..n {
                    t.set(b, (b % 2) == 0);
                }
                // mirrored low field: bit b copies bit b−1 for odd b
                let span = (*mirror_span).min(n.saturating_sub(*fixed_bits));
                for b in (1..span).step_by(2) {
                    let below = t.get(b - 1);
                    t.set(b, below);
                }
                t
            }
        }
    }

    /// Draw `count` *distinct* tags (the CAM stores unique entries).
    pub fn sample_distinct(&self, n: usize, count: usize, rng: &mut Rng) -> Vec<BitVec> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::with_capacity(count);
        let mut guard = 0usize;
        while out.len() < count {
            let t = self.sample(n, rng);
            guard += 1;
            assert!(
                guard < count * 1000 + 10_000,
                "tag space too small for {count} distinct tags"
            );
            if seen.insert(t.clone()) {
                out.push(t);
            }
        }
        out
    }
}

/// One uniform n-bit tag.
pub fn random_tag(n: usize, rng: &mut Rng) -> BitVec {
    let mut t = BitVec::zeros(n);
    for w in t.words_mut() {
        *w = rng.gen_u64();
    }
    // mask tail
    let rem = n % 64;
    if rem != 0 {
        if let Some(last) = t.words_mut().last_mut() {
            *last &= (1u64 << rem) - 1;
        }
    }
    t
}

/// A query stream over a stored tag set with a controlled hit ratio and
/// optional Zipf skew toward low-index (hot) entries.
#[derive(Debug, Clone)]
pub struct QueryMix {
    /// Probability a query hits a stored tag.
    pub hit_ratio: f64,
    /// Zipf exponent over the stored set (0.0 = uniform over entries).
    pub zipf_s: f64,
}

impl Default for QueryMix {
    fn default() -> Self {
        QueryMix { hit_ratio: 1.0, zipf_s: 0.0 }
    }
}

impl QueryMix {
    /// Draw one query: a stored tag (hit) or a fresh random tag (miss).
    pub fn sample<'a>(
        &self,
        stored: &'a [BitVec],
        n: usize,
        rng: &mut Rng,
    ) -> (BitVec, Option<usize>) {
        if !stored.is_empty() && rng.gen_bool(self.hit_ratio.clamp(0.0, 1.0)) {
            let i = if self.zipf_s > 0.0 {
                zipf_index(stored.len(), self.zipf_s, rng)
            } else {
                rng.gen_range(stored.len())
            };
            (stored[i].clone(), Some(i))
        } else {
            (random_tag(n, rng), None)
        }
    }
}

/// Draw an index in [0, n) with P(i) ∝ 1/(i+1)^s (simple inverse-CDF walk —
/// fine for the n ≤ a few thousand this simulator uses).
fn zipf_index(n: usize, s: f64, rng: &mut Rng) -> usize {
    let h: f64 = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(s)).sum();
    let mut u = rng.gen_f64() * h;
    for i in 0..n {
        u -= 1.0 / ((i + 1) as f64).powf(s);
        if u <= 0.0 {
            return i;
        }
    }
    n - 1
}

/// Hot-shard query stream: hammers one bank of a sharded fleet — the
/// rebalance-relevant scenario where one bank saturates while the rest of
/// the fleet idles.  Hits draw from per-bank stored-tag pools (see
/// [`crate::shard::ShardRouter::partition`]): with probability
/// `hot_fraction` from the hot bank's pool, otherwise uniformly from the
/// remaining banks' pools; misses are fresh random tags (which route
/// roughly uniformly under hash placement).
#[derive(Debug, Clone)]
pub struct HotShardMix {
    /// Index of the bank to hammer.
    pub hot_bank: usize,
    /// Probability a hit targets the hot bank's stored tags.
    pub hot_fraction: f64,
    /// Probability a query hits a stored tag at all.
    pub hit_ratio: f64,
}

impl HotShardMix {
    /// Draw one query.  `by_bank[i]` holds the tags stored in bank `i`;
    /// returns the query and the bank it targets (`None` for a miss).
    pub fn sample(
        &self,
        by_bank: &[Vec<BitVec>],
        n: usize,
        rng: &mut Rng,
    ) -> (BitVec, Option<usize>) {
        assert!(self.hot_bank < by_bank.len(), "hot bank out of range");
        if !rng.gen_bool(self.hit_ratio.clamp(0.0, 1.0)) {
            return (random_tag(n, rng), None);
        }
        let hot = &by_bank[self.hot_bank];
        let cold_total: usize = by_bank
            .iter()
            .enumerate()
            .filter(|(b, _)| *b != self.hot_bank)
            .map(|(_, pool)| pool.len())
            .sum();
        let use_hot = !hot.is_empty()
            && (cold_total == 0 || rng.gen_bool(self.hot_fraction.clamp(0.0, 1.0)));
        if use_hot {
            (hot[rng.gen_range(hot.len())].clone(), Some(self.hot_bank))
        } else if cold_total > 0 {
            let mut i = rng.gen_range(cold_total);
            for (b, pool) in by_bank.iter().enumerate() {
                if b == self.hot_bank {
                    continue;
                }
                if i < pool.len() {
                    return (pool[i].clone(), Some(b));
                }
                i -= pool.len();
            }
            unreachable!("cold index in range");
        } else {
            // nothing stored anywhere: degrade to a miss
            (random_tag(n, rng), None)
        }
    }
}

/// Synthetic TLB trace: virtual page numbers with a hot working set,
/// sequential strides (page walks), and occasional random jumps.
#[derive(Debug, Clone)]
pub struct TlbTrace {
    /// Tag width (VPN bits, zero-extended to the CAM's N).
    pub n: usize,
    /// Working-set size in pages.
    pub working_set: usize,
    /// Probability of a sequential next-page access.
    pub p_sequential: f64,
    /// Probability of jumping to a brand-new page (TLB miss pressure).
    pub p_new: f64,
}

impl TlbTrace {
    /// Generate `len` VPN accesses; returns the trace and the set of unique
    /// pages touched (in first-touch order) for CAM population.
    pub fn generate(&self, len: usize, rng: &mut Rng) -> (Vec<BitVec>, Vec<BitVec>) {
        assert!(self.working_set > 0 && self.n <= 63);
        let mask = (1u64 << self.n) - 1;
        let mut pages: Vec<u64> = (0..self.working_set).map(|_| rng.gen_u64() & mask).collect();
        let mut trace = Vec::with_capacity(len);
        let mut seen = std::collections::HashSet::new();
        let mut uniq = Vec::new();
        let mut cur = pages[0];
        for _ in 0..len {
            let r = rng.gen_f64();
            if r < self.p_sequential {
                cur = cur.wrapping_add(1) & mask;
            } else if r < self.p_sequential + self.p_new {
                cur = rng.gen_u64() & mask;
                pages.push(cur);
            } else {
                cur = pages[rng.gen_range(pages.len())];
            }
            let tag = BitVec::from_u128(cur as u128, self.n);
            if seen.insert(cur) {
                uniq.push(tag.clone());
            }
            trace.push(tag);
        }
        (trace, uniq)
    }
}

/// Synthetic router/ACL tags: a handful of route prefixes (high bits) with
/// uniform host bits — strongly non-uniform in the high field, exactly the
/// case §II-B's bit selection addresses.
#[derive(Debug, Clone)]
pub struct AclTrace {
    pub n: usize,
    /// Number of distinct prefixes.
    pub prefixes: usize,
    /// Prefix length in bits.
    pub prefix_len: usize,
}

impl AclTrace {
    /// Generate `count` distinct classifier tags.
    pub fn generate(&self, count: usize, rng: &mut Rng) -> Vec<BitVec> {
        assert!(self.prefix_len < self.n);
        let prefixes: Vec<u64> = (0..self.prefixes).map(|_| rng.gen_u64()).collect();
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::with_capacity(count);
        while out.len() < count {
            let p = prefixes[rng.gen_range(prefixes.len())];
            let mut t = random_tag(self.n, rng);
            for b in 0..self.prefix_len {
                t.set(self.n - 1 - b, (p >> (b % 64)) & 1 == 1);
            }
            if seen.insert(t.clone()) {
                out.push(t);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn uniform_tags_have_full_entropy() {
        let mut rng = Rng::seed_from_u64(1);
        let tags = TagDistribution::Uniform.sample_distinct(128, 500, &mut rng);
        assert_eq!(tags.len(), 500);
        // every bit position should be ~half set
        for b in [0usize, 31, 64, 127] {
            let ones = tags.iter().filter(|t| t.get(b)).count();
            assert!((150..350).contains(&ones), "bit {b}: {ones}");
        }
    }

    #[test]
    fn correlated_tags_have_constant_high_field() {
        let mut rng = Rng::seed_from_u64(2);
        let d = TagDistribution::Correlated { fixed_bits: 32, mirror_span: 16 };
        let tags: Vec<_> = (0..100).map(|_| d.sample(128, &mut rng)).collect();
        for b in 96..128 {
            let ones = tags.iter().filter(|t| t.get(b)).count();
            assert!(ones == 0 || ones == 100, "bit {b} should be constant");
        }
        // mirrored: odd low bits equal the bit below
        for t in &tags {
            for b in (1..16).step_by(2) {
                assert_eq!(t.get(b), t.get(b - 1));
            }
        }
    }

    #[test]
    fn query_mix_hits_controlled() {
        let mut rng = Rng::seed_from_u64(3);
        let stored = TagDistribution::Uniform.sample_distinct(64, 50, &mut rng);
        let mix = QueryMix { hit_ratio: 0.8, zipf_s: 0.0 };
        let mut hits = 0;
        for _ in 0..1000 {
            let (_, hit) = mix.sample(&stored, 64, &mut rng);
            hits += hit.is_some() as usize;
        }
        assert!((730..870).contains(&hits), "hits = {hits}");
    }

    #[test]
    fn zipf_skews_to_head() {
        let mut rng = Rng::seed_from_u64(4);
        let stored = TagDistribution::Uniform.sample_distinct(64, 100, &mut rng);
        let mix = QueryMix { hit_ratio: 1.0, zipf_s: 1.2 };
        let mut head = 0;
        for _ in 0..2000 {
            let (_, hit) = mix.sample(&stored, 64, &mut rng);
            if hit.unwrap() < 10 {
                head += 1;
            }
        }
        // top-10 of 100 entries should draw well over 10 % of queries
        assert!(head > 600, "head = {head}");
    }

    #[test]
    fn zipf_hot_entry_hit_rates_match_the_closed_form() {
        // The Zipf path is what the hot-shard workload stands on: check the
        // per-entry skew actually materializes, not just "head > tail".
        // With s = 1 over 100 entries, P(i) = 1/((i+1)·H_100), H_100 ≈ 5.187:
        // P(0) ≈ 0.1928, top-10 mass = H_10/H_100 ≈ 0.565, tail 50+ ≈ 0.133.
        let mut rng = Rng::seed_from_u64(40);
        let stored = TagDistribution::Uniform.sample_distinct(64, 100, &mut rng);
        let mix = QueryMix { hit_ratio: 1.0, zipf_s: 1.0 };
        let trials = 20_000usize;
        let mut counts = vec![0usize; 100];
        for _ in 0..trials {
            let (_, hit) = mix.sample(&stored, 64, &mut rng);
            counts[hit.expect("hit_ratio = 1")] += 1;
        }
        let frac = |c: usize| c as f64 / trials as f64;
        assert!(
            (0.17..0.22).contains(&frac(counts[0])),
            "entry 0 drew {}",
            frac(counts[0])
        );
        let head10: usize = counts[..10].iter().sum();
        assert!((0.52..0.61).contains(&frac(head10)), "top-10 mass {}", frac(head10));
        let tail: usize = counts[50..].iter().sum();
        assert!(frac(tail) < 0.18, "tail mass {}", frac(tail));
        // monotone-in-expectation head: entry 0 clearly above entries 4 and 20
        assert!(counts[0] > counts[4] && counts[4] > counts[20]);
        // and the skew is the Zipf path's doing: s = 0 is flat
        let flat = QueryMix { hit_ratio: 1.0, zipf_s: 0.0 };
        let mut flat0 = 0usize;
        for _ in 0..trials {
            let (_, hit) = flat.sample(&stored, 64, &mut rng);
            flat0 += (hit.unwrap() == 0) as usize;
        }
        assert!((0.005..0.02).contains(&frac(flat0)), "uniform entry 0 drew {}", frac(flat0));
    }

    #[test]
    fn hot_shard_mix_hammers_one_bank() {
        let mut rng = Rng::seed_from_u64(41);
        let tags = TagDistribution::Uniform.sample_distinct(32, 200, &mut rng);
        let router = crate::shard::ShardRouter::tag_hash(4);
        let by_bank = router.partition(&tags);
        let hot = 2usize;
        let mix = HotShardMix { hot_bank: hot, hot_fraction: 0.9, hit_ratio: 1.0 };
        let mut per_bank = [0usize; 4];
        for _ in 0..2_000 {
            let (q, bank) = mix.sample(&by_bank, 32, &mut rng);
            let b = bank.expect("hit_ratio = 1");
            assert_eq!(router.place(&q), Some(b), "pool must agree with placement");
            per_bank[b] += 1;
        }
        assert!(per_bank[hot] > 1_700, "hot bank drew {}", per_bank[hot]);
        for (b, &c) in per_bank.iter().enumerate() {
            if b != hot {
                assert!(c < 150, "cold bank {b} drew {c}");
            }
        }
    }

    #[test]
    fn hot_shard_mix_degrades_gracefully_when_pools_are_empty() {
        let mut rng = Rng::seed_from_u64(42);
        let empty: Vec<Vec<BitVec>> = vec![Vec::new(); 4];
        let mix = HotShardMix { hot_bank: 0, hot_fraction: 0.9, hit_ratio: 1.0 };
        let (q, bank) = mix.sample(&empty, 32, &mut rng);
        assert_eq!(bank, None, "no stored tags ⇒ forced miss");
        assert_eq!(q.len(), 32);
        // only the hot pool populated: everything lands there
        let mut by_bank = empty;
        by_bank[0] = TagDistribution::Uniform.sample_distinct(32, 5, &mut rng);
        let (_, bank) = mix.sample(&by_bank, 32, &mut rng);
        assert_eq!(bank, Some(0));
    }

    #[test]
    fn tlb_trace_has_locality() {
        let mut rng = Rng::seed_from_u64(5);
        let t = TlbTrace { n: 52, working_set: 32, p_sequential: 0.5, p_new: 0.02 };
        let (trace, uniq) = t.generate(2000, &mut rng);
        assert_eq!(trace.len(), 2000);
        assert!(!uniq.is_empty());
        // locality ⇒ far fewer unique pages than accesses
        assert!(uniq.len() < 800, "unique = {}", uniq.len());
    }

    #[test]
    fn acl_trace_prefixes_are_reused() {
        let mut rng = Rng::seed_from_u64(6);
        let a = AclTrace { n: 128, prefixes: 4, prefix_len: 48 };
        let tags = a.generate(200, &mut rng);
        assert_eq!(tags.len(), 200);
        // high prefix bits take at most `prefixes` distinct patterns
        let mut pats = std::collections::HashSet::new();
        for t in &tags {
            let pat: Vec<bool> = (0..48).map(|b| t.get(127 - b)).collect();
            pats.insert(pat);
        }
        assert!(pats.len() <= 4, "{} prefixes", pats.len());
    }

    #[test]
    fn distinct_sampler_rejects_impossible_requests() {
        let mut rng = Rng::seed_from_u64(7);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            TagDistribution::Uniform.sample_distinct(2, 100, &mut rng)
        }));
        assert!(r.is_err(), "2-bit space cannot hold 100 distinct tags");
    }
}
