//! L8 — replication: log-shipping primary→replica streaming, read
//! replicas, and failover promotion with epoch fencing.
//!
//! The design rides entirely on two invariants the lower layers already
//! provide:
//!
//! 1. **The per-bank WAL is the replication log.**  Every acknowledged
//!    mutation is a checksummed frame in `bank-<i>/wal.log` *before* the
//!    client sees the ack ([`crate::store`]), and replay order equals
//!    acknowledgement order.  [`ReplicaFeed`] therefore tails the
//!    primary's own files ([`crate::store::wal::tail_wal`]) — no second
//!    log, no divergent encoding — and ships the verbatim frame bytes.
//! 2. **Apply and replay are one code path.**  A replica pushes shipped
//!    records through the same [`crate::store::apply_record`] the
//!    recovery replay uses, inside the bank writer's barrier
//!    ([`crate::coordinator::server::ServerHandle::apply_replicated`]),
//!    which logs to the replica's own WAL and RCU-publishes a fresh
//!    `SearchState` — so replica reads go through the exact reader-pool
//!    machinery of a primary, bit-identical field for field.
//!
//! ```text
//!   primary                                 replica
//!   ┌───────────────────────┐   SubscribeLog  ┌──────────────────────┐
//!   │ banks ── WAL files ◀──┼──(poll, v5)─────┼── chaser thread      │
//!   │           │           │                 │   │ decode_frames    │
//!   │      ReplicaFeed ─────┼──LogBatch ─────▶│   ▼ apply_replicated │
//!   │  (tail_wal, snapshots)│  SnapshotTransfer   banks ── WAL ── RCU│
//!   │ ReplicationController │                 │   reads: reader pools│
//!   └───────────────────────┘                 │   writes: forwarded ─┼──▶ primary
//!                                             └──────────────────────┘
//! ```
//!
//! **Ordering and the ack point.**  A `SubscribeLog` requesting offset
//! `o` *is* the acknowledgement of every byte before `o` — the feed keeps
//! no send queue and nothing is dropped by a slow replica; it just reads
//! an earlier suffix of the file.  Because frames enter the WAL before
//! the client ack, "every acked write" is exactly "every frame below the
//! tail", and a replica whose cursor reaches the tail has every
//! acknowledged write.
//!
//! **Failover and fencing.**  The `fleet.kv` manifest carries an
//! **epoch** ([`crate::store::FleetManifest::epoch`]).  [`promote`] bumps
//! it on the chosen replica's directory (pick the replica with the
//! highest acked offsets — the [`ReplicationController`] exposes them);
//! the promoted fleet then serves writes.  Every `SubscribeLog` carries
//! the subscriber's epoch, and a feed refuses a mismatch with
//! `ERR_FENCED`, so an old primary that comes back and tries to chase
//! (or a replica still keyed to the dead lineage) is fenced off instead
//! of silently forking history.

pub mod feed;
pub mod replica;

pub use feed::{ReplicaFeed, ReplicationController};
pub use replica::{ReplicaOptions, ReplicaServer, WriteForwarder};

use std::path::Path;

use crate::coordinator::server::PersistError;
use crate::net::proto::WireError;
use crate::store::{FleetManifest, StoreError};

/// The replication role a TCP front-end serves with
/// ([`crate::net::CamTcpServer::with_repl`]).
pub enum ReplRole {
    /// This node owns the data: answer `SubscribeLog` from its data
    /// directory and track subscriber progress.
    Primary(ReplicaFeed),
    /// This node chases a primary: serve reads locally, forward `Insert`
    /// and `Delete` upstream (the mutation comes back through the log).
    Replica(WriteForwarder),
}

/// Errors of the replication layer.
#[derive(Debug)]
pub enum ReplError {
    /// The upstream connection or protocol failed.
    Wire(WireError),
    /// The local durability layer failed.
    Store(StoreError),
    /// A bank writer barrier failed.
    Persist(PersistError),
    /// The feed refused this subscriber's epoch — the fleet was promoted
    /// past it and this lineage must not be replayed.
    Fenced { local: u64, server: u64 },
    /// The feed answered something the protocol does not allow here.
    Protocol(String),
}

impl std::fmt::Display for ReplError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReplError::Wire(e) => write!(f, "replication transport: {e}"),
            ReplError::Store(e) => write!(f, "replication store: {e}"),
            ReplError::Persist(e) => write!(f, "replication apply: {e}"),
            ReplError::Fenced { local, server } => write!(
                f,
                "fenced: this node is at epoch {local}, the feed serves epoch {server}"
            ),
            ReplError::Protocol(msg) => write!(f, "replication protocol: {msg}"),
        }
    }
}

impl std::error::Error for ReplError {}

impl From<WireError> for ReplError {
    fn from(e: WireError) -> Self {
        ReplError::Wire(e)
    }
}

impl From<StoreError> for ReplError {
    fn from(e: StoreError) -> Self {
        ReplError::Store(e)
    }
}

impl From<PersistError> for ReplError {
    fn from(e: PersistError) -> Self {
        ReplError::Persist(e)
    }
}

/// Promote the fleet at `dir`: bump the manifest epoch by one and store
/// it durably.  Run *offline* (the serving process stopped) on the
/// replica chosen to take over — typically the one whose acked offsets
/// were highest.  Returns the new epoch.  After promotion the directory
/// serves as a writable primary, and any subscriber still at the old
/// epoch (including the crashed ex-primary, should it rejoin as a
/// replica) is refused with `ERR_FENCED`.
pub fn promote(dir: &Path) -> Result<u64, StoreError> {
    let mut manifest = FleetManifest::load(dir)?;
    manifest.epoch += 1;
    manifest.store(dir)?;
    Ok(manifest.epoch)
}
