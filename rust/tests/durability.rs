//! Crash-recovery integration battery: a reopened store must rebuild
//! engine state *bit-identical* to the pre-crash fleet — the same matched
//! addresses, λ, energy breakdown and delay for every tag — across
//! hash/broadcast/learned placements, with and without snapshots in the
//! mix, and a torn final WAL frame must be truncated, never fatal.
//!
//! The crash is simulated the only way a same-process test honestly can:
//! the durable handles are dropped mid-stream without drain or flush.
//! The WAL's write-through contract (every acknowledged record reaches the
//! OS before the ack) is exactly what makes this equivalent to a SIGKILL
//! for acknowledged state; the CI `durability-smoke` job performs the real
//! kill -9 against a serving process.

use cscam::bits::BitVec;
use cscam::config::DesignConfig;
use cscam::coordinator::{BatchPolicy, LookupEngine};
use cscam::net::{CamClient, CamTcpServer, NetConfig};
use cscam::shard::{PlacementMode, ShardedCamServer, ShardedOutcome};
use cscam::store::{
    apply_record, wal, BankImage, DurableBank, FsyncPolicy, StoreError, StoreOptions, WalRecord,
    SNAPSHOT_FILE, WAL_FILE,
};
use cscam::util::Rng;
use cscam::workload::TagDistribution;
use std::path::PathBuf;
use std::time::Duration;

fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("cscam-durability-{}", std::process::id()))
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn fleet_cfg() -> DesignConfig {
    // 4 banks × 64 entries = one 256-entry fleet
    DesignConfig { m: 256, n: 32, zeta: 4, c: 3, l: 4, shards: 4, ..DesignConfig::reference() }
}

fn policy() -> BatchPolicy {
    BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(100) }
}

/// Drive the same seeded insert/delete history through a durable bank and
/// a never-crashed reference engine, asserting the addresses agree along
/// the way.  Returns the tags ever inserted (the lookup probe set).
fn seeded_history(
    bank: &mut DurableBank,
    reference: &mut LookupEngine,
    cfg: &DesignConfig,
    seed: u64,
    ops: usize,
) -> Vec<BitVec> {
    let mut rng = Rng::seed_from_u64(seed);
    let pool = TagDistribution::Uniform.sample_distinct(cfg.n, ops, &mut rng);
    let mut next = 0usize;
    let mut live: Vec<usize> = Vec::new();
    let mut touched = Vec::new();
    for _ in 0..ops {
        let do_insert = live.is_empty() || rng.gen_bool(0.7);
        if do_insert && next < pool.len() {
            let t = &pool[next];
            next += 1;
            match (bank.insert(t), reference.insert(t)) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a, b, "durable and reference engines diverged on placement");
                    live.push(a);
                    touched.push(t.clone());
                }
                (Err(e1), Err(e2)) => assert_eq!(e1, e2, "divergent insert errors"),
                (a, b) => panic!("insert divergence: durable {a:?}, reference {b:?}"),
            }
        } else if !live.is_empty() {
            let victim = live.swap_remove(rng.gen_range(live.len()));
            bank.delete(victim).unwrap();
            reference.delete(victim).unwrap();
        }
    }
    touched
}

/// Field-for-field equality of every outcome: stored tags and misses.
fn assert_bank_bit_identical(
    bank: &mut DurableBank,
    reference: &mut LookupEngine,
    probes: &[BitVec],
    n: usize,
    seed: u64,
) {
    for t in probes {
        assert_eq!(bank.lookup(t).unwrap(), reference.lookup(t).unwrap());
    }
    let mut rng = Rng::seed_from_u64(seed);
    for _ in 0..40 {
        let t = cscam::workload::random_tag(n, &mut rng);
        assert_eq!(bank.lookup(&t).unwrap(), reference.lookup(&t).unwrap());
    }
}

#[test]
fn bank_recovery_is_bit_identical_for_seeded_histories() {
    for seed in [11u64, 12, 13] {
        let dir = test_dir(&format!("bank-history-{seed}"));
        let cfg = DesignConfig::small_test();
        let mut reference = LookupEngine::new(cfg.clone());
        let probes = {
            let (mut bank, _) =
                DurableBank::open(&dir, cfg.clone(), StoreOptions::default()).unwrap();
            seeded_history(&mut bank, &mut reference, &cfg, seed, 90)
            // bank dropped here mid-stream: no drain, no flush, no compact
        };
        let (mut bank, report) =
            DurableBank::open(&dir, cfg.clone(), StoreOptions::default()).unwrap();
        assert!(report.wal_records > 0);
        assert_eq!(report.occupancy, reference.occupancy());
        assert_bank_bit_identical(&mut bank, &mut reference, &probes, cfg.n, seed + 100);
    }
}

#[test]
fn bank_recovery_with_compaction_in_the_history_is_bit_identical() {
    // a tiny compaction threshold forces several snapshot+truncate cycles
    // mid-history, so recovery exercises snapshot-base + WAL-tail replay
    for seed in [21u64, 22] {
        let dir = test_dir(&format!("bank-compact-{seed}"));
        let cfg = DesignConfig::small_test();
        let opts = StoreOptions { fsync: FsyncPolicy::EveryN(16), compact_bytes: 512 };
        let mut reference = LookupEngine::new(cfg.clone());
        let probes = {
            let (mut bank, _) = DurableBank::open(&dir, cfg.clone(), opts).unwrap();
            seeded_history(&mut bank, &mut reference, &cfg, seed, 120)
        };
        assert!(dir.join(SNAPSHOT_FILE).exists(), "threshold must have compacted");
        let (mut bank, report) = DurableBank::open(&dir, cfg.clone(), opts).unwrap();
        assert!(report.snapshot_loaded);
        assert_eq!(report.occupancy, reference.occupancy());
        assert_bank_bit_identical(&mut bank, &mut reference, &probes, cfg.n, seed + 100);
    }
}

#[test]
fn crash_between_snapshot_and_wal_reset_recovers_bit_identically() {
    // The compaction crash window: the snapshot (generation g+1) has been
    // renamed into place but the WAL (still generation g) was never reset.
    // Replaying that log against the snapshot would double-apply every
    // insert — inflating the stale-delete counter and potentially firing
    // a spurious retrain — so recovery must DISCARD it instead, and the
    // result must still be bit-identical to the never-crashed engine.
    let dir = test_dir("compact-window");
    let cfg = DesignConfig::small_test();
    let mut reference = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(71);
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 30, &mut rng);
    let wal_path = dir.join(WAL_FILE);
    {
        let (mut bank, _) = DurableBank::open(&dir, cfg.clone(), StoreOptions::default()).unwrap();
        for t in &tags {
            assert_eq!(bank.insert(t).unwrap(), reference.insert(t).unwrap());
        }
        bank.delete(4).unwrap();
        reference.delete(4).unwrap();
        let stale_log = std::fs::read(&wal_path).unwrap();
        bank.compact().unwrap();
        drop(bank);
        // resurrect the pre-compaction log: new snapshot + old WAL is
        // exactly what a crash between the two steps leaves behind
        std::fs::write(&wal_path, &stale_log).unwrap();
    }
    let (mut bank, report) = DurableBank::open(&dir, cfg.clone(), StoreOptions::default()).unwrap();
    assert!(report.snapshot_loaded);
    assert_eq!(report.discarded_records, 31, "stale log is discarded, not replayed");
    assert_eq!(report.wal_records, 0);
    assert_eq!(bank.engine().stale_delete_count(), reference.stale_delete_count());
    assert_bank_bit_identical(&mut bank, &mut reference, &tags, cfg.n, 72);
    // the finished compaction leaves a usable log: new writes persist
    let extra = cscam::workload::random_tag(cfg.n, &mut rng);
    bank.insert(&extra).unwrap();
    drop(bank);
    let (bank, report) = DurableBank::open(&dir, cfg, StoreOptions::default()).unwrap();
    assert_eq!(report.wal_records, 1);
    assert_eq!(report.discarded_records, 0);
    assert_eq!(bank.occupancy(), 30);
}

#[test]
fn wal_tailing_survives_compaction_by_resubscribing_from_the_new_generation() {
    // A log subscriber (the replication feed tails exactly like this)
    // holding a generation-0 cursor must see `Restarted` once compaction
    // resets the log — WAL replay is not idempotent, so shipping any
    // stale generation-0 prefix would double-apply records.  The correct
    // resubscription is snapshot base + the new generation's tail, and
    // that must rebuild the state bit-identically.
    let dir = test_dir("tail-compaction");
    let cfg = DesignConfig::small_test();
    let mut reference = LookupEngine::new(cfg.clone());
    let (mut bank, _) = DurableBank::open(&dir, cfg.clone(), StoreOptions::default()).unwrap();
    let mut rng = Rng::seed_from_u64(81);
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 24, &mut rng);
    let wal_path = dir.join(WAL_FILE);

    // first half of the history, tailed mid-stream like a subscriber
    for t in tags.iter().take(12) {
        assert_eq!(bank.insert(t).unwrap(), reference.insert(t).unwrap());
    }
    let cursor = match wal::tail_wal(&wal_path, 0, wal::WAL_HEADER_LEN, 1 << 20).unwrap() {
        wal::TailStep::Batch { generation, next_offset, frames, remaining, .. } => {
            assert_eq!(generation, 0);
            assert_eq!(remaining, 0);
            assert_eq!(wal::decode_frames(&frames).unwrap().len(), 12);
            next_offset
        }
        other => panic!("mid-history tail answered {other:?}"),
    };

    // compaction moves the history into a snapshot and resets the log;
    // the second half lands in the new generation
    bank.compact().unwrap();
    for t in tags.iter().skip(12) {
        assert_eq!(bank.insert(t).unwrap(), reference.insert(t).unwrap());
    }

    // the stale generation-0 cursor is told the log restarted — it gets
    // neither an error nor a prefix of the new log under its old offsets
    match wal::tail_wal(&wal_path, 0, cursor, 1 << 20).unwrap() {
        wal::TailStep::Restarted { generation } => assert_eq!(generation, 1),
        other => panic!("stale cursor answered {other:?}"),
    }

    // resubscribe from the new generation: snapshot base + log tail
    let image = BankImage::decode(&std::fs::read(dir.join(SNAPSHOT_FILE)).unwrap()).unwrap();
    assert_eq!(image.wal_generation, 1);
    let mut resub = image.into_engine().unwrap();
    match wal::tail_wal(&wal_path, 1, wal::WAL_HEADER_LEN, 1 << 20).unwrap() {
        wal::TailStep::Batch { generation, frames, remaining, .. } => {
            assert_eq!(generation, 1);
            assert_eq!(remaining, 0);
            for r in &wal::decode_frames(&frames).unwrap() {
                apply_record(&mut resub, r).unwrap();
            }
        }
        other => panic!("resubscribed tail answered {other:?}"),
    }
    for t in &tags {
        assert_eq!(resub.lookup(t).unwrap(), reference.lookup(t).unwrap());
    }
    for _ in 0..40 {
        let t = cscam::workload::random_tag(cfg.n, &mut rng);
        assert_eq!(resub.lookup(&t).unwrap(), reference.lookup(&t).unwrap());
    }
}

#[test]
fn torn_final_wal_frame_is_truncated_not_fatal() {
    let dir = test_dir("torn-tail");
    let cfg = DesignConfig::small_test();
    let mut reference = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(31);
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 20, &mut rng);
    {
        let (mut bank, _) = DurableBank::open(&dir, cfg.clone(), StoreOptions::default()).unwrap();
        for t in &tags {
            assert_eq!(bank.insert(t).unwrap(), reference.insert(t).unwrap());
        }
    }
    // simulate a crash mid-append: half of one more frame at the tail
    let torn = wal::encode_frame(&WalRecord::Insert {
        addr: 20,
        tag: cscam::workload::random_tag(cfg.n, &mut rng),
    });
    let wal_path = dir.join(WAL_FILE);
    let mut raw = std::fs::read(&wal_path).unwrap();
    raw.extend_from_slice(&torn[..torn.len() / 2]);
    std::fs::write(&wal_path, &raw).unwrap();

    let (mut bank, report) = DurableBank::open(&dir, cfg.clone(), StoreOptions::default()).unwrap();
    assert_eq!(report.truncated_bytes as usize, torn.len() / 2);
    assert_eq!(report.wal_records, 20, "every complete record survives");
    assert_bank_bit_identical(&mut bank, &mut reference, &tags, cfg.n, 32);
}

fn placement_for(kind: &str, shards: usize, sample: &[BitVec], n: usize) -> PlacementMode {
    match kind {
        "hash" => PlacementMode::TagHash,
        "broadcast" => PlacementMode::Broadcast,
        "prefix" => PlacementMode::learned(shards, sample, n),
        other => panic!("unknown placement {other}"),
    }
}

#[test]
fn fleet_recovery_is_bit_identical_across_placements() {
    for kind in ["hash", "broadcast", "prefix"] {
        let dir = test_dir(&format!("fleet-{kind}"));
        let cfg = fleet_cfg();
        let mut rng = Rng::seed_from_u64(41);
        let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 120, &mut rng);
        let mode = placement_for(kind, cfg.shards, &tags, cfg.n);

        // never-crashed reference fleet and the durable fleet run the same
        // sequential history; addresses must agree insert by insert
        let reference = ShardedCamServer::new(&cfg, mode.clone(), policy()).spawn();
        let (durable, _) =
            ShardedCamServer::open_durable(&cfg, mode, policy(), &dir, StoreOptions::default())
                .unwrap();
        let handle = durable.spawn();
        let mut stored = Vec::new();
        for t in &tags {
            match (handle.insert(t.clone()), reference.insert(t.clone())) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a, b, "{kind}: placement diverged");
                    stored.push((t.clone(), a));
                }
                (Err(e1), Err(e2)) => assert_eq!(e1, e2, "{kind}: divergent errors"),
                (a, b) => panic!("{kind}: insert divergence {a:?} vs {b:?}"),
            }
        }
        for (_, g) in stored.iter().take(15) {
            handle.delete(*g).unwrap();
            reference.delete(*g).unwrap();
        }
        // crash: drop the durable fleet's handles without drain or flush
        drop(handle);

        // reopen with a freshly made mode of the same kind — for the
        // learned prefix this sample differs, proving the manifest's
        // recorded positions win over the new selection
        let mut rng2 = Rng::seed_from_u64(42);
        let other_sample = TagDistribution::Uniform.sample_distinct(cfg.n, 60, &mut rng2);
        let fresh_mode = placement_for(kind, cfg.shards, &other_sample, cfg.n);
        let (reopened, recovery) = ShardedCamServer::open_durable(
            &cfg,
            fresh_mode,
            policy(),
            &dir,
            StoreOptions::default(),
        )
        .unwrap();
        assert!(recovery.manifest_loaded, "{kind}: restart validates the manifest");
        assert_eq!(recovery.total_occupancy(), stored.len() - 15, "{kind}");
        let recovered = reopened.spawn();

        for (i, (t, g)) in stored.iter().enumerate() {
            let want: Option<usize> = (i >= 15).then_some(*g);
            let a: ShardedOutcome = recovered.lookup(t.clone()).unwrap();
            let b = reference.lookup(t.clone()).unwrap();
            assert_eq!(a, b, "{kind}: outcome diverged for tag {i}");
            assert_eq!(a.addr, want, "{kind}: wrong address for tag {i}");
        }
        let mut rng3 = Rng::seed_from_u64(43);
        for _ in 0..40 {
            let t = cscam::workload::random_tag(cfg.n, &mut rng3);
            assert_eq!(
                recovered.lookup(t.clone()).unwrap(),
                reference.lookup(t.clone()).unwrap(),
                "{kind}: miss probe diverged"
            );
        }
    }
}

#[test]
fn wire_snapshot_flush_and_restart_are_bit_identical_over_tcp() {
    let dir = test_dir("wire-restart");
    let cfg = fleet_cfg();
    let (fleet, _) = ShardedCamServer::open_durable(
        &cfg,
        PlacementMode::TagHash,
        policy(),
        &dir,
        StoreOptions::default(),
    )
    .unwrap();
    let handle = fleet.spawn();
    let server =
        CamTcpServer::bind(handle.clone(), "127.0.0.1:0", NetConfig::default()).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let net = server.spawn().unwrap();

    let mut rng = Rng::seed_from_u64(51);
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 40, &mut rng);
    let mut client = CamClient::connect(addr).unwrap();
    for t in tags.iter().take(30) {
        client.insert(t).unwrap();
    }
    client.flush().unwrap();
    // wire-forced compaction: the first 30 move into the snapshot
    client.snapshot().unwrap();
    for t in tags.iter().skip(30) {
        client.insert(t).unwrap();
    }
    let before: Vec<ShardedOutcome> =
        tags.iter().map(|t| client.lookup(t).unwrap()).collect();
    client.shutdown().unwrap();
    net.join();

    // restart from disk, re-serve, and require wire answers to be
    // bit-identical to the pre-restart fleet's
    let (fleet2, recovery) = ShardedCamServer::open_durable(
        &cfg,
        PlacementMode::TagHash,
        policy(),
        &dir,
        StoreOptions::default(),
    )
    .unwrap();
    assert!(recovery.banks.iter().any(|b| b.snapshot_loaded), "wire Snapshot compacted");
    assert_eq!(recovery.total_records(), 10, "only post-snapshot inserts replay");
    assert_eq!(recovery.total_occupancy(), 40);
    let handle2 = fleet2.spawn();
    let server2 =
        CamTcpServer::bind(handle2.clone(), "127.0.0.1:0", NetConfig::default()).unwrap();
    let addr2 = server2.local_addr().unwrap().to_string();
    let net2 = server2.spawn().unwrap();
    let mut client2 = CamClient::connect(addr2).unwrap();
    for (t, want) in tags.iter().zip(&before) {
        assert_eq!(&client2.lookup(t).unwrap(), want, "wire outcome changed across restart");
    }
    client2.shutdown().unwrap();
    net2.join();
}

#[test]
fn recovery_refuses_a_corrupt_snapshot_loudly() {
    let dir = test_dir("corrupt-snapshot");
    let cfg = DesignConfig::small_test();
    {
        let (mut bank, _) = DurableBank::open(&dir, cfg.clone(), StoreOptions::default()).unwrap();
        let mut rng = Rng::seed_from_u64(61);
        for t in &TagDistribution::Uniform.sample_distinct(cfg.n, 10, &mut rng) {
            bank.insert(t).unwrap();
        }
        bank.compact().unwrap();
    }
    let snap = dir.join(SNAPSHOT_FILE);
    let mut raw = std::fs::read(&snap).unwrap();
    let mid = raw.len() / 2;
    raw[mid] ^= 0x55;
    std::fs::write(&snap, &raw).unwrap();
    match DurableBank::open(&dir, cfg, StoreOptions::default()) {
        Err(StoreError::Corrupt(_)) => {}
        Err(other) => panic!("wrong error class for a corrupt snapshot: {other:?}"),
        Ok(_) => panic!("corrupt snapshot must refuse recovery"),
    }
}
