//! Property-based tests over randomized inputs (in-tree driver: hundreds of
//! seeded random cases per property — the offline stand-in for proptest).
//!
//! Invariants under test are the paper's correctness arguments:
//!  P1  the CNN never misses: every stored tag's sub-block is enabled;
//!  P2  enables are the exact ζ-group OR of the activation map;
//!  P3  λ equals the number of entries sharing the query's reduced tag
//!      (single-trained-address networks);
//!  P4  the proposed search returns exactly the same matches as the
//!      conventional full search (classification saves power, not answers);
//!  P5  energy accounting is additive and monotone in enabled rows;
//!  P6  insert → delete → retrain returns the engine to a clean state.

use cscam::bits::BitVec;
use cscam::cam::CamArray;
use cscam::cnn::{ClusteredNetwork, Selection};
use cscam::config::DesignConfig;
use cscam::coordinator::LookupEngine;
use cscam::energy::{energy_from_activity, CalibrationConstants, SearchActivity};
use cscam::util::Rng;
use cscam::workload::TagDistribution;

/// Run `body` for `cases` random geometries.
fn for_random_geometries(
    cases: usize,
    seed: u64,
    mut body: impl FnMut(&mut Rng, usize, usize, usize, usize),
) {
    let mut rng = Rng::seed_from_u64(seed);
    for _ in 0..cases {
        let c = 1 + rng.gen_range(4); // 1..=4
        let l = 1usize << (1 + rng.gen_range(4)); // 2..=16
        let zeta = 1usize << rng.gen_range(4); // 1..=8
        let m = zeta * (4 + rng.gen_range(32)); // multiple of zeta
        let mut r2 = rng.fork();
        body(&mut r2, c, l, m, zeta);
    }
}

#[test]
fn p1_no_false_negatives_across_geometries() {
    for_random_geometries(150, 101, |rng, c, l, m, zeta| {
        let mut net = ClusteredNetwork::new(c, l, m, zeta);
        let entries = 1 + rng.gen_range(m);
        let mut tags = Vec::new();
        for addr in 0..entries {
            let idx: Vec<u16> = (0..c).map(|_| rng.gen_range(l) as u16).collect();
            net.train(&idx, addr);
            tags.push(idx);
        }
        for (addr, idx) in tags.iter().enumerate() {
            let a = net.decode(idx);
            assert!(a.act.get(addr), "c={c} l={l} m={m} ζ={zeta} addr={addr}");
            assert!(a.enables.get(addr / zeta));
        }
    });
}

#[test]
fn p2_enables_are_exact_group_or() {
    for_random_geometries(150, 202, |rng, c, l, m, zeta| {
        let mut net = ClusteredNetwork::new(c, l, m, zeta);
        for addr in 0..m / 2 {
            let idx: Vec<u16> = (0..c).map(|_| rng.gen_range(l) as u16).collect();
            net.train(&idx, addr);
        }
        let q: Vec<u16> = (0..c).map(|_| rng.gen_range(l) as u16).collect();
        let a = net.decode(&q);
        for b in 0..m / zeta {
            let group_any = (b * zeta..(b + 1) * zeta).any(|i| a.act.get(i));
            assert_eq!(a.enables.get(b), group_any, "block {b}");
        }
        assert_eq!(a.lambda, a.act.count_ones());
    });
}

#[test]
fn p3_lambda_counts_reduced_tag_collisions() {
    for_random_geometries(100, 303, |rng, c, l, m, zeta| {
        let mut net = ClusteredNetwork::new(c, l, m, zeta);
        let mut stored: Vec<Vec<u16>> = Vec::new();
        for addr in 0..m {
            let idx: Vec<u16> = (0..c).map(|_| rng.gen_range(l) as u16).collect();
            net.train(&idx, addr);
            stored.push(idx);
        }
        let probe = &stored[rng.gen_range(stored.len())];
        let expected = stored.iter().filter(|s| s == &probe).count();
        assert_eq!(net.decode(probe).lambda, expected);
    });
}

#[test]
fn p4_proposed_and_conventional_return_identical_matches() {
    let mut rng = Rng::seed_from_u64(404);
    for _ in 0..60 {
        let cfg = DesignConfig::small_test();
        let mut engine = LookupEngine::new(cfg.clone());
        let mut cam = CamArray::new(cfg.m, cfg.n, cfg.zeta);
        let count = 1 + rng.gen_range(cfg.m);
        let tags = TagDistribution::Uniform.sample_distinct(cfg.n, count, &mut rng);
        for (a, t) in tags.iter().enumerate() {
            engine.insert(t).unwrap();
            cam.write(a, t.clone());
        }
        // stored hits and random probes
        for probe in tags.iter().take(8).cloned().chain((0..8).map(|_| {
            cscam::workload::random_tag(cfg.n, &mut rng)
        })) {
            let prop = engine.lookup(&probe).unwrap();
            let conv = cam.search_all(&probe);
            assert_eq!(prop.all_matches, conv.matches, "classified search changed the answer");
        }
    }
}

#[test]
fn p5_energy_monotone_and_additive() {
    let cfg = DesignConfig::reference();
    let calib = CalibrationConstants::reference_130nm();
    let mut rng = Rng::seed_from_u64(505);
    for _ in 0..200 {
        let rows_a = rng.gen_range(cfg.m);
        let rows_b = rng.gen_range(cfg.m - rows_a.min(cfg.m - 1));
        let act = |rows: usize| SearchActivity {
            enabled_rows: rows,
            enabled_blocks: rows / cfg.zeta,
            tag_bits: cfg.n,
            total_blocks: cfg.beta(),
            ..Default::default()
        };
        let e_a = energy_from_activity(&cfg, &calib, &act(rows_a), 1).total_fj();
        let e_b = energy_from_activity(&cfg, &calib, &act(rows_b), 1).total_fj();
        let e_ab = energy_from_activity(&cfg, &calib, &act(rows_a + rows_b), 2).total_fj();
        assert!((e_a + e_b - e_ab).abs() < 1e-6, "additivity");
        if rows_a > rows_b {
            assert!(e_a > e_b, "monotonicity");
        }
    }
}

#[test]
fn p6_insert_delete_retrain_reaches_clean_state() {
    let mut rng = Rng::seed_from_u64(606);
    for _ in 0..40 {
        let cfg = DesignConfig::small_test();
        let mut engine = LookupEngine::new(cfg.clone());
        engine.retrain_threshold = 0.0;
        let count = 1 + rng.gen_range(cfg.m / 2);
        let tags = TagDistribution::Uniform.sample_distinct(cfg.n, count, &mut rng);
        let mut addrs = Vec::new();
        for t in &tags {
            addrs.push(engine.insert(t).unwrap());
        }
        for &a in &addrs {
            engine.delete(a).unwrap();
        }
        engine.retrain();
        assert_eq!(engine.occupancy(), 0);
        for t in &tags {
            let out = engine.lookup(t).unwrap();
            assert_eq!(out.addr, None);
            assert_eq!(out.lambda, 0, "stale weights must be gone");
            assert_eq!(out.comparisons, 0, "clean engine burns nothing");
        }
    }
}

#[test]
fn p7_bit_selection_policies_never_affect_correctness() {
    // §II-B: bit selection changes power, never the final answer.
    let mut rng = Rng::seed_from_u64(707);
    let cfg = DesignConfig::small_test();
    for sel in [
        Selection::contiguous(cfg.c, cfg.k()),
        Selection::strided(cfg.n, cfg.c, cfg.k()),
    ] {
        let mut engine = LookupEngine::with_selection(cfg.clone(), sel);
        let tags = TagDistribution::Correlated { fixed_bits: 16, mirror_span: 8 }
            .sample_distinct(cfg.n, 48, &mut rng);
        for t in &tags {
            engine.insert(t).unwrap();
        }
        for (i, t) in tags.iter().enumerate() {
            assert_eq!(engine.lookup(t).unwrap().addr, Some(i));
        }
    }
}

#[test]
fn p8_bitvec_word_ops_match_naive_bit_loop() {
    let mut rng = Rng::seed_from_u64(808);
    for _ in 0..200 {
        let n = 1 + rng.gen_range(300);
        let a_bits: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.5)).collect();
        let b_bits: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.5)).collect();
        let a = BitVec::from_bools(&a_bits);
        let b = BitVec::from_bools(&b_bits);
        let mut and = a.clone();
        and.and_assign(&b);
        let mut or = a.clone();
        or.or_assign(&b);
        for i in 0..n {
            assert_eq!(and.get(i), a_bits[i] && b_bits[i]);
            assert_eq!(or.get(i), a_bits[i] || b_bits[i]);
        }
        let ham = a_bits.iter().zip(&b_bits).filter(|(x, y)| x != y).count();
        assert_eq!(a.hamming(&b), ham);
        assert_eq!(a.count_ones(), a_bits.iter().filter(|&&x| x).count());
    }
}
