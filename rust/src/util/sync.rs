//! The concurrency kernel shared by the serving layers, extracted behind
//! one auditable facade: the MPMC work queue the reader pool drains, the
//! RCU publish slot lookups snapshot from, and the admission gauge that
//! sheds load — plus the poison-recovery lock helpers every serving path
//! uses instead of `.unwrap()` on a lock result.
//!
//! Two properties of this module are enforced elsewhere in the repo:
//!
//! * **loom-swappable primitives** — everything here builds against either
//!   `std::sync` (default) or `loom::sync` (cargo feature `loom`), so the
//!   model-checking battery in `rust/tests/loom_models.rs` can exhaustively
//!   interleave the queue/publish/drain protocols with the *same* code the
//!   production threads run, not a re-implementation that could drift.
//! * **no panic on poison** — a reader thread that panics while holding a
//!   stripe or queue lock must not wedge the whole bank: every lock/wait in
//!   this module recovers the guard with [`lock_recover`]/[`PoisonError::
//!   into_inner`].  The invariants the guards protect are documented at
//!   each recovery site; `cargo xtask lint` bans bare `.unwrap()`/`.expect`
//!   on lock results in the serving modules that build on this facade.

#[cfg(feature = "loom")]
pub use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
#[cfg(feature = "loom")]
pub use loom::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
#[cfg(not(feature = "loom"))]
pub use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
#[cfg(not(feature = "loom"))]
pub use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

use std::collections::VecDeque;
use std::sync::Arc;
use std::sync::PoisonError;

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// Sound only when every critical section leaves the protected value in a
/// consistent state at every panic point — which is the standing rule for
/// this facade: critical sections are a few field updates (queue push/pop,
/// counter bumps, metric folds) with no mid-section invariant windows, so
/// the data a poisoned guard hands back is never torn.  Recovering keeps
/// one panicked reader from turning every later lock on the bank into a
/// panic cascade.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`lock_recover`] for the read half of an [`RwLock`].
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// [`lock_recover`] for the write half of an [`RwLock`].
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

// --------------------------------------------------------- publish slot

/// RCU-style publish slot: a single writer replaces the published
/// `Arc<T>`; any number of readers snapshot it and then work lock-free on
/// their clone.  The lock is held only for the pointer clone/store — never
/// across a search — so readers cannot block each other and the writer
/// blocks readers only for the O(1) swap.
///
/// This is the slot behind [`crate::coordinator::engine::SharedSearch`];
/// the loom battery checks that a snapshot never observes a torn or
/// rolled-back publication.
pub struct PublishSlot<T> {
    slot: RwLock<Arc<T>>,
}

impl<T> std::fmt::Debug for PublishSlot<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PublishSlot").finish_non_exhaustive()
    }
}

impl<T> PublishSlot<T> {
    pub fn new(initial: Arc<T>) -> Self {
        PublishSlot { slot: RwLock::new(initial) }
    }

    /// The currently published value (O(1): one read-lock + Arc clone).
    pub fn snapshot(&self) -> Arc<T> {
        read_recover(&self.slot).clone()
    }

    /// Publish `next`, making it the value every subsequent
    /// [`Self::snapshot`] returns.  In-flight snapshots keep their old
    /// `Arc` alive until dropped (that is the RCU grace period).
    pub fn publish(&self, next: Arc<T>) {
        *write_recover(&self.slot) = next;
    }
}

// ------------------------------------------------------ admission gauge

/// Count of lookup tags admitted (enqueued) but not yet picked up by a
/// serving thread — the load-shedding input for `try_lookup`'s `Busy`
/// path and the post-drain "queue is empty again" probe the tests read.
///
/// Orderings: [`Self::retire`] releases and [`Self::load`] acquires, so a
/// thread that observes the gauge at zero also observes the effects of
/// serving every retired job.  The drain barrier itself synchronizes
/// through the work queue's mutex, so the gauge does not carry the
/// barrier — the Acquire/Release pair is what makes the gauge's *value*
/// trustworthy on its own, without reasoning about which lock happened to
/// be held nearby (this replaced a set of `Ordering::Relaxed` uses whose
/// soundness rested on exactly that coupling).
pub struct AdmissionGauge {
    depth: AtomicUsize,
}

impl AdmissionGauge {
    pub fn new() -> Self {
        AdmissionGauge { depth: AtomicUsize::new(0) }
    }

    /// Count `n` tags into the queue (enqueue side).
    pub fn admit(&self, n: usize) {
        self.depth.fetch_add(n, Ordering::Release);
    }

    /// Count `n` tags out of the queue (serving side, or enqueue
    /// rollback when the send fails).  Admissions and retirements must
    /// balance; the debug assertion catches a weight mismatch (e.g. a
    /// bulk retired per-message instead of per-tag) in tests.
    pub fn retire(&self, n: usize) {
        let prev = self.depth.fetch_sub(n, Ordering::Release);
        debug_assert!(prev >= n, "admission gauge underflow: retired {n} from {prev}");
    }

    /// Current depth.
    pub fn load(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }
}

impl Default for AdmissionGauge {
    fn default() -> Self {
        Self::new()
    }
}

// ----------------------------------------------------------- work queue

struct WorkQueueInner<T> {
    jobs: VecDeque<T>,
    /// Live sender handles; workers exit once this hits zero and the
    /// queue is empty.
    senders: usize,
    /// Jobs ever pushed (monotonic; drain-barrier bookkeeping).
    enqueued: u64,
    /// Jobs fully served (monotonic; a drain barrier waits for
    /// `completed` to reach the `enqueued` it observed).
    completed: u64,
}

/// A plain Mutex+Condvar MPMC queue with a completion barrier (std mpsc
/// receivers cannot be shared across worker threads).  This is the reader
/// pool's queue, extracted so the loom battery can interleave
/// push/pop/complete/barrier exhaustively.
///
/// Lifecycle: the queue starts with ONE sender registered (the creator);
/// [`Self::add_sender`]/[`Self::remove_sender`] track clones.  [`Self::pop`]
/// blocks while senders remain, and returns `None` only once every sender
/// is gone *and* the queue ran dry — queued jobs are always finished first.
pub struct WorkQueue<T> {
    inner: Mutex<WorkQueueInner<T>>,
    takeable: Condvar,
    drained: Condvar,
}

impl<T> WorkQueue<T> {
    pub fn new() -> Self {
        WorkQueue {
            inner: Mutex::new(WorkQueueInner {
                jobs: VecDeque::new(),
                senders: 1,
                enqueued: 0,
                completed: 0,
            }),
            takeable: Condvar::new(),
            drained: Condvar::new(),
        }
    }

    pub fn push(&self, job: T) {
        let mut q = lock_recover(&self.inner);
        q.jobs.push_back(job);
        q.enqueued += 1;
        self.takeable.notify_one();
    }

    /// Next job, blocking; `None` once every sender is gone and the queue
    /// ran dry (worker shutdown).
    pub fn pop(&self) -> Option<T> {
        let mut q = lock_recover(&self.inner);
        loop {
            if let Some(j) = q.jobs.pop_front() {
                return Some(j);
            }
            if q.senders == 0 {
                return None;
            }
            q = self.takeable.wait(q).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Mark one popped job fully served (wakes barrier waiters).  Prefer
    /// [`JobGuard`], which calls this even if serving the job panics.
    pub fn job_done(&self) {
        let mut q = lock_recover(&self.inner);
        q.completed += 1;
        self.drained.notify_all();
    }

    /// Drain *barrier*: block until every job enqueued before this call
    /// has been served.  Deliberately NOT "wait until idle" — under a
    /// sustained stream from other senders the queue may never be empty,
    /// and a barrier must still complete in bounded time.
    pub fn barrier(&self) {
        let mut q = lock_recover(&self.inner);
        let target = q.enqueued;
        while q.completed < target {
            q = self.drained.wait(q).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Register one more sender (a handle clone).
    pub fn add_sender(&self) {
        lock_recover(&self.inner).senders += 1;
    }

    /// Unregister a sender; at zero, every parked worker is woken so it
    /// can drain the queue and exit.
    pub fn remove_sender(&self) {
        let mut q = lock_recover(&self.inner);
        q.senders -= 1;
        if q.senders == 0 {
            self.takeable.notify_all();
        }
    }
}

impl<T> Default for WorkQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Marks a dequeued job finished even if serving it panics — a job that
/// never counts as completed would wedge every later
/// [`WorkQueue::barrier`].
pub struct JobGuard<'a, T>(&'a WorkQueue<T>);

impl<'a, T> JobGuard<'a, T> {
    pub fn new(queue: &'a WorkQueue<T>) -> Self {
        JobGuard(queue)
    }
}

impl<T> Drop for JobGuard<'_, T> {
    fn drop(&mut self) {
        self.0.job_done();
    }
}

// Unit tests run against the std primitives (the loom battery is the
// schedule-exhaustive counterpart in rust/tests/loom_models.rs).
#[cfg(all(test, not(feature = "loom")))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recover_hands_back_a_poisoned_guard() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "the lock must actually be poisoned");
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn rw_recover_hands_back_poisoned_guards() {
        let l = Arc::new(RwLock::new(1u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the rwlock");
        })
        .join();
        assert_eq!(*read_recover(&l), 1);
        *write_recover(&l) = 2;
        assert_eq!(*read_recover(&l), 2);
    }

    #[test]
    fn publish_slot_snapshots_the_latest_publication() {
        let slot = PublishSlot::new(Arc::new(1u32));
        let before = slot.snapshot();
        slot.publish(Arc::new(2));
        assert_eq!(*before, 1, "in-flight snapshots keep the old state alive");
        assert_eq!(*slot.snapshot(), 2);
    }

    #[test]
    fn admission_gauge_balances() {
        let g = AdmissionGauge::new();
        assert_eq!(g.load(), 0);
        g.admit(3);
        g.admit(1);
        assert_eq!(g.load(), 4);
        g.retire(3);
        g.retire(1);
        assert_eq!(g.load(), 0);
    }

    #[test]
    fn work_queue_serves_fifo_and_shuts_down() {
        let q = Arc::new(WorkQueue::new());
        q.push(1u32);
        q.push(2);
        assert_eq!(q.pop(), Some(1));
        q.job_done();
        assert_eq!(q.pop(), Some(2));
        q.job_done();
        q.remove_sender();
        assert_eq!(q.pop(), None, "no senders + empty queue = shutdown");
    }

    #[test]
    fn queued_jobs_are_served_before_shutdown() {
        let q = Arc::new(WorkQueue::new());
        q.push(1u32);
        q.remove_sender();
        assert_eq!(q.pop(), Some(1), "queued jobs outlive the last sender");
        q.job_done();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn barrier_waits_for_prior_jobs_only() {
        let q = Arc::new(WorkQueue::new());
        q.push(10u32);
        q.push(11);
        let worker = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                while let Some(_job) = q.pop() {
                    let _guard = JobGuard::new(&q);
                }
            })
        };
        q.barrier(); // must return once both queued jobs completed
        q.remove_sender();
        worker.join().unwrap();
        q.add_sender(); // barrier on an idle queue returns immediately
        q.barrier();
        q.remove_sender();
    }

    #[test]
    fn job_guard_completes_on_panic() {
        let q = Arc::new(WorkQueue::new());
        q.push(1u32);
        let q2 = Arc::clone(&q);
        let _ = std::thread::spawn(move || {
            let _job = q2.pop();
            let _guard = JobGuard::new(&q2);
            panic!("die mid-job");
        })
        .join();
        q.barrier(); // would hang forever if the panicked job never completed
    }
}
