"""L1 — Pallas kernels for clustered-sparse-network (CNN) global decoding & training.

The paper's compute hot-spot is eq. (1):

    v_{n_i'} = AND_{i=1..c} OR_{j=1..l} ( w_{(i,j)(i')} AND v_{(i,j)} )

i.e. a P_II neuron fires iff *every* cluster of P_I has at least one active
connection to it.  Because local decoding (LD) activates exactly one neuron per
cluster, the OR over j degenerates to "read the one weight row the LD selected"
— the paper implements this in hardware by fusing the one-hot decoder with the
SRAM word-lines (Fig. 4).

TPU rethink (see DESIGN.md §Hardware-Adaptation): a gather of one row per
cluster followed by a popcount across clusters is exactly a *matmul against a
one-hot matrix*:

    counts = U @ W          U ∈ {0,1}^{B×(c·l)}  (LD one-hots, concatenated)
                            W ∈ {0,1}^{(c·l)×M}  (binary connection weights)
    act    = counts >= c    (AND across clusters == all c clusters hit)

which maps onto the MXU systolic array in a single pass.  The ζ-group OR that
drives the CAM compare-enable lines (Fig. 4, right) is a max-pool over the
minor axis, fused into the same kernel before writeback so only B×(M/ζ) enable
bits leave VMEM alongside the activation map.

`W` is tiled along M via BlockSpec so each (B-tile, M-tile) stays VMEM-resident
— the analogue of the paper's per-cluster SRAM banking.

All kernels run with interpret=True: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute (see /opt/xla-example/README).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gd_decode", "gd_decode_gather", "train_weights", "DEFAULT_BLOCK_M"]

# Default M-tile. 256 f32 columns × (c·l) rows plus a B×256 accumulator is a
# few tens of KiB — comfortably inside one TPU core's ~16 MiB VMEM even for
# B=64, leaving room for double-buffering the W stream from HBM.
DEFAULT_BLOCK_M = 256


def _gd_tile_kernel(u_ref, w_ref, act_ref, en_ref, *, c: int, zeta: int):
    """One (B, block_m) tile of global decode + fused ζ-group OR."""
    u = u_ref[...]  # (B, c·l) f32 one-hots
    w = w_ref[...]  # (c·l, block_m) f32 binary weights
    # MXU pass: per-neuron count of clusters with an active connection.
    counts = jnp.dot(u, w, preferred_element_type=jnp.float32)
    # AND across clusters: every one of the c clusters contributed a hit.
    act = (counts >= c).astype(jnp.float32)
    act_ref[...] = act
    b, mt = act.shape
    # ζ-group OR → compare-enable bits, fused before writeback.
    en_ref[...] = jnp.max(act.reshape(b, mt // zeta, zeta), axis=-1)


def gd_decode(
    u: jax.Array,
    w: jax.Array,
    *,
    c: int,
    zeta: int,
    block_m: int | None = None,
    interpret: bool = True,
):
    """Batched global decode.

    Args:
      u: (B, c·l) f32 — concatenated one-hot LD outputs, one 1 per cluster.
      w: (c·l, M) f32 — binary connection weights (0.0 / 1.0).
      c: number of clusters in P_I.
      zeta: CAM rows per compare-enabled sub-block (ζ).
      block_m: M-tile width; must divide M and be a multiple of ζ.
      interpret: run the Pallas kernel in interpret mode (required on CPU).

    Returns:
      act:     (B, M)   f32 — P_II neural activations (0/1).
      enables: (B, M/ζ) f32 — per-sub-block compare-enable bits (0/1).
    """
    b, cl = u.shape
    cl_w, m = w.shape
    if cl != cl_w:
        raise ValueError(f"u/w cluster-dim mismatch: {cl} vs {cl_w}")
    if m % zeta != 0:
        raise ValueError(f"M={m} not divisible by zeta={zeta}")
    if block_m is None:
        block_m = min(m, DEFAULT_BLOCK_M)
    if m % block_m != 0 or block_m % zeta != 0:
        raise ValueError(f"block_m={block_m} must divide M={m} and be a multiple of zeta={zeta}")

    grid = (m // block_m,)
    return pl.pallas_call(
        functools.partial(_gd_tile_kernel, c=c, zeta=zeta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, cl), lambda i: (0, 0)),  # U broadcast to every tile
            pl.BlockSpec((cl_w, block_m), lambda i: (0, i)),  # W streamed along M
        ],
        out_specs=[
            pl.BlockSpec((b, block_m), lambda i: (0, i)),
            pl.BlockSpec((b, block_m // zeta), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), jnp.float32),
            jax.ShapeDtypeStruct((b, m // zeta), jnp.float32),
        ],
        interpret=interpret,
    )(u, w)


def _gd_gather_tile_kernel(idx_ref, w_ref, act_ref, en_ref, *, c: int, l: int, zeta: int):
    """Gather-formulation tile: read ONE weight row per cluster and AND.

    This is the literal transcription of the paper's Fig. 4 trick — the
    one-hot decoder fused with the SRAM word-lines so only the c selected
    rows are ever read ("inherently eliminates unnecessary w ∧ v
    operations").  On TPU the matmul formulation usually wins (the MXU is
    free; VMEM bandwidth is not), but this variant exists to (a) mirror the
    hardware exactly and (b) A/B the two lowerings; both are tested against
    the same oracle and each other.
    """
    idx = idx_ref[...]  # (B, c) int32 cluster indices
    w = w_ref[...]  # (c·l, block_m)
    b = idx.shape[0]
    mt = w.shape[1]
    acc = jnp.ones((b, mt), dtype=jnp.float32)
    for cluster in range(c):
        # row gather: (B, block_m) — one SRAM row per cluster per query
        rows = jnp.take(w, cluster * l + idx[:, cluster], axis=0)
        acc = acc * rows  # AND over clusters (0/1 values)
    act_ref[...] = acc
    en_ref[...] = jnp.max(acc.reshape(b, mt // zeta, zeta), axis=-1)


def gd_decode_gather(
    idx: jax.Array,
    w: jax.Array,
    *,
    c: int,
    l: int,
    zeta: int,
    block_m: int | None = None,
    interpret: bool = True,
):
    """Batched global decode, row-gather formulation (Fig. 4 literal).

    Args:
      idx: (B, c) int32 — LD cluster indices (not one-hots).
      w:   (c·l, M) f32 — binary connection weights.

    Returns the same (act, enables) pair as :func:`gd_decode`.
    """
    b, c_in = idx.shape
    cl_w, m = w.shape
    if c_in != c or cl_w != c * l:
        raise ValueError(f"idx/w geometry mismatch: idx c={c_in}, w rows={cl_w}, c·l={c * l}")
    if m % zeta != 0:
        raise ValueError(f"M={m} not divisible by zeta={zeta}")
    if block_m is None:
        block_m = min(m, DEFAULT_BLOCK_M)
    if m % block_m != 0 or block_m % zeta != 0:
        raise ValueError(f"block_m={block_m} must divide M={m} and be a multiple of zeta={zeta}")

    grid = (m // block_m,)
    return pl.pallas_call(
        functools.partial(_gd_gather_tile_kernel, c=c, l=l, zeta=zeta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, c), lambda i: (0, 0)),
            pl.BlockSpec((cl_w, block_m), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((b, block_m), lambda i: (0, i)),
            pl.BlockSpec((b, block_m // zeta), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), jnp.float32),
            jax.ShapeDtypeStruct((b, m // zeta), jnp.float32),
        ],
        interpret=interpret,
    )(idx, w)


def _train_tile_kernel(u_ref, a_ref, w_ref):
    """One (c·l, block_m) tile of the weight matrix from a full training set."""
    u = u_ref[...]  # (E, c·l) — LD one-hots of the stored reduced tags
    a = a_ref[...]  # (E, block_m) — one-hot CAM addresses (tile)
    # Binary weights: a connection exists if *any* stored entry created it.
    # min(1, Uᵀ·A) == OR over entries — matmul + clamp, one MXU pass.
    w_ref[...] = jnp.minimum(jnp.dot(u.T, a, preferred_element_type=jnp.float32), 1.0)


def train_weights(
    u: jax.Array,
    a: jax.Array,
    *,
    block_m: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Batch-train the binary weight matrix from all stored entries at once.

    Args:
      u: (E, c·l) f32 — LD one-hots of the E stored entries' reduced tags.
      a: (E, M)   f32 — one-hot CAM addresses of the same entries.

    Returns:
      w: (c·l, M) f32 binary weights.
    """
    e, cl = u.shape
    e_a, m = a.shape
    if e != e_a:
        raise ValueError(f"entry-count mismatch: {e} vs {e_a}")
    if block_m is None:
        block_m = min(m, DEFAULT_BLOCK_M)
    if m % block_m != 0:
        raise ValueError(f"block_m={block_m} must divide M={m}")

    grid = (m // block_m,)
    return pl.pallas_call(
        _train_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((e, cl), lambda i: (0, 0)),
            pl.BlockSpec((e, block_m), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((cl, block_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((cl, m), jnp.float32),
        interpret=interpret,
    )(u, a)
