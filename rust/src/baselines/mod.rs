//! Baseline architectures for Table II and the PB-CAM comparison of §I.

pub mod literature;
pub mod pbcam;

pub use literature::{anchor_rows, AnchorRow};
pub use pbcam::PbCam;
