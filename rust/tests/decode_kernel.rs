//! Bit-identity battery for the slab decode kernels and the bloom
//! pre-filter.
//!
//! The word-parallel hot path (contiguous `BitSlab` weight/tag storage,
//! `bits::kernel` AND / XOR-popcount sweeps, and the per-bank counting-bloom
//! pre-filter) must change *nothing observable*: every lookup reports the
//! same matches, the same λ, the same activity counters and the same
//! modelled energy/delay as a naive per-bit evaluation of the paper's
//! equations over the materialized rows.  The battery checks that
//! equivalence
//!
//! * against a from-scratch per-bit reference (no slabs, no kernels, no
//!   filter) on stored tags, random probes and single-bit near-misses;
//! * through seeded insert / overwrite / delete / retrain histories, where
//!   the writer-maintained filter must stay equal to the deterministic
//!   rebuild from the CAM's valid tags;
//! * per bank of a sharded fleet under all three placement modes
//!   (tag-hash, learned-prefix, broadcast);
//! * across a snapshot → restart cycle, both when the image carries the
//!   filter section and when it is stripped (the v1 rebuild fallback).
//!
//! Pre-filter semantics pinned here: a reject is bit-identical to an
//! unfiltered lookup whose decode activated nothing — λ = 0, zero enabled
//! blocks, zero compared rows, the energy of that all-quiet search — and
//! the filter never rejects a tag the CAM actually holds.

use cscam::bits::BitVec;
use cscam::config::DesignConfig;
use cscam::coordinator::{LookupEngine, LookupOutcome, SearchState};
use cscam::energy::{EnergyModel, SearchActivity};
use cscam::shard::{PlacementMode, ShardedCam};
use cscam::store::BankImage;
use cscam::util::Rng;
use cscam::workload::{random_tag, TagDistribution};

/// Everything the per-bit reference derives for one probe.
struct Reference {
    all_matches: Vec<usize>,
    lambda: usize,
    activity: SearchActivity,
}

/// The proposed lookup computed bit-by-bit from materialized rows: per-bit
/// AND of the selected weight rows, per-group OR for the enables, per-bit
/// XOR over enabled blocks — the scalar path the slab kernels replaced.
fn reference_lookup(e: &LookupEngine, tag: &BitVec) -> Reference {
    let cfg = e.config().clone();
    let idx = e.cluster_indices(tag);
    let weights = e.network().weight_rows();
    let mut act = vec![false; cfg.m];
    let mut lambda = 0usize;
    for entry in 0..cfg.m {
        let on = idx
            .iter()
            .enumerate()
            .all(|(cluster, &j)| weights[cluster * cfg.l + j as usize].get(entry));
        act[entry] = on;
        lambda += on as usize;
    }
    let mut enables = vec![false; cfg.beta()];
    for (entry, &on) in act.iter().enumerate() {
        if on {
            enables[entry / cfg.zeta] = true;
        }
    }

    let tags = e.cam().tag_rows();
    let valid = e.cam().valid_bits();
    let mut activity =
        SearchActivity { total_blocks: cfg.beta(), tag_bits: cfg.n, ..Default::default() };
    let mut all_matches = Vec::new();
    for (block, &en) in enables.iter().enumerate() {
        if !en {
            continue;
        }
        activity.enabled_blocks += 1;
        for row in block * cfg.zeta..(block + 1) * cfg.zeta {
            activity.enabled_rows += 1;
            if !valid.get(row) {
                activity.mismatched_rows += 1;
                activity.mismatch_bits += cfg.n / 2;
                continue;
            }
            activity.compared_rows += 1;
            activity.compared_bits += cfg.n;
            let dist = (0..cfg.n).filter(|&b| tags[row].get(b) != tag.get(b)).count();
            if dist == 0 {
                activity.matched_rows += 1;
                all_matches.push(row);
            } else {
                activity.mismatched_rows += 1;
                activity.mismatch_bits += dist;
            }
        }
    }
    Reference { all_matches, lambda, activity }
}

/// Assert an engine outcome equals the per-bit reference, field for field
/// (matches, λ, activity-derived counters, modelled energy).
fn assert_matches_reference(e: &LookupEngine, out: &LookupOutcome, tag: &BitVec, ctx: &str) {
    let r = reference_lookup(e, tag);
    assert_eq!(out.addr, r.all_matches.first().copied(), "{ctx}: addr");
    assert_eq!(out.all_matches, r.all_matches, "{ctx}: matches");
    assert_eq!(out.lambda, r.lambda, "{ctx}: lambda");
    assert_eq!(out.enabled_blocks, r.activity.enabled_blocks, "{ctx}: enabled blocks");
    assert_eq!(out.comparisons, r.activity.enabled_rows, "{ctx}: comparisons");
    let energy = EnergyModel::new(e.config().clone()).proposed_measured(&r.activity, 1);
    assert_eq!(out.energy, energy, "{ctx}: energy");
}

/// Check the filtered path on one probe: transparent wherever the filter
/// passes, the canonical λ = 0 reject (and a genuine miss) where it rejects.
fn assert_filter_consistent(e: &mut LookupEngine, tag: &BitVec, ctx: &str) {
    let passes = e.search_state().filter().may_contain(tag);
    let filtered = e.lookup(tag).unwrap();
    let unfiltered = e.lookup_unfiltered(tag).unwrap();
    if passes {
        assert_eq!(filtered, unfiltered, "{ctx}: filter must be transparent when it passes");
    } else {
        // no false negatives: a reject means the CAM provably misses
        let r = reference_lookup(e, tag);
        assert!(r.all_matches.is_empty(), "{ctx}: filter rejected a stored tag");
        assert_eq!(filtered.addr, None, "{ctx}: reject addr");
        assert!(filtered.all_matches.is_empty(), "{ctx}: reject matches");
        assert_eq!(filtered.lambda, 0, "{ctx}: reject lambda");
        assert_eq!(filtered.enabled_blocks, 0, "{ctx}: reject blocks");
        assert_eq!(filtered.comparisons, 0, "{ctx}: reject comparisons");
        let cfg = e.config();
        let quiet =
            SearchActivity { total_blocks: cfg.beta(), tag_bits: cfg.n, ..Default::default() };
        let energy = EnergyModel::new(cfg.clone()).proposed_measured(&quiet, 1);
        assert_eq!(filtered.energy, energy, "{ctx}: reject energy");
        assert_eq!(filtered.delay, unfiltered.delay, "{ctx}: reject delay");
    }
}

/// Stored tags plus derived probes: bit-flip near-misses and random tags.
fn probe_set(stored: &[BitVec], n: usize, rng: &mut Rng) -> Vec<BitVec> {
    let mut probes = stored.to_vec();
    for (i, t) in stored.iter().enumerate().take(16) {
        let mut near = t.clone();
        let bit = (i * 7) % n;
        near.set(bit, !near.get(bit));
        probes.push(near);
    }
    probes.extend((0..32).map(|_| random_tag(n, rng)));
    probes
}

#[test]
fn slab_path_matches_the_per_bit_reference() {
    let cfg = DesignConfig::small_test();
    let mut e = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(11);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m / 2, &mut rng);
    for t in &stored {
        e.insert(t).unwrap();
    }
    for (i, tag) in probe_set(&stored, cfg.n, &mut rng).iter().enumerate() {
        let out = e.lookup_unfiltered(tag).unwrap();
        assert_matches_reference(&e, &out, tag, &format!("probe {i}"));
        assert_filter_consistent(&mut e, tag, &format!("probe {i}"));
    }
}

#[test]
fn stored_tags_are_never_rejected() {
    let cfg = DesignConfig::small_test();
    let mut e = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(23);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &stored {
        e.insert(t).unwrap();
    }
    let filter = e.search_state();
    for (i, t) in stored.iter().enumerate() {
        assert!(filter.filter().may_contain(t), "stored tag {i} rejected");
        let out = e.lookup(t).unwrap();
        assert_eq!(out.addr, Some(i), "stored tag {i} must still hit through the filter");
        assert_matches_reference(&e, &out, t, &format!("stored {i}"));
    }
}

#[test]
fn seeded_histories_preserve_identity_and_filter_equality() {
    for seed in [1u64, 7, 42] {
        let cfg = DesignConfig::small_test();
        let mut e = LookupEngine::new(cfg.clone());
        // retrains fire mid-history at the default threshold — that's part
        // of what the battery must survive
        let mut rng = Rng::seed_from_u64(seed);
        let pool = TagDistribution::Uniform.sample_distinct(cfg.n, 2 * cfg.m, &mut rng);
        let mut live: Vec<usize> = Vec::new();
        for step in 0..300 {
            match rng.gen_range(4) {
                0 | 1 => {
                    let t = &pool[rng.gen_range(pool.len())];
                    if let Ok(addr) = e.insert(t) {
                        live.push(addr);
                    }
                }
                2 if !live.is_empty() => {
                    let addr = live.swap_remove(rng.gen_range(live.len()));
                    e.delete(addr).unwrap();
                }
                _ => {
                    // overwrite a random slot (TLB-style replacement)
                    let addr = rng.gen_range(cfg.m);
                    let t = &pool[rng.gen_range(pool.len())];
                    e.insert_at(addr, t).unwrap();
                    if !live.contains(&addr) {
                        live.push(addr);
                    }
                }
            }
            // the writer-maintained filter must equal the deterministic
            // rebuild at every step of the history
            if step % 25 == 0 {
                let st = e.search_state();
                assert_eq!(
                    *st.filter(),
                    SearchState::rebuild_filter(st.cam()),
                    "seed {seed} step {step}: filter drifted from the rebuild"
                );
            }
        }
        let st = e.search_state();
        assert_eq!(*st.filter(), SearchState::rebuild_filter(st.cam()), "seed {seed}: final");
        let probes: Vec<BitVec> = (0..48)
            .map(|i| {
                if i % 2 == 0 {
                    pool[rng.gen_range(pool.len())].clone()
                } else {
                    random_tag(cfg.n, &mut rng)
                }
            })
            .collect();
        for (i, tag) in probes.iter().enumerate() {
            let out = e.lookup_unfiltered(tag).unwrap();
            assert_matches_reference(&e, &out, tag, &format!("seed {seed} probe {i}"));
            assert_filter_consistent(&mut e, tag, &format!("seed {seed} probe {i}"));
        }
    }
}

#[test]
fn sharded_placements_stay_bit_identical_per_bank() {
    let cfg = DesignConfig { m: 256, shards: 4, ..DesignConfig::small_test() };
    let mut rng = Rng::seed_from_u64(5);
    let sample = TagDistribution::Uniform.sample_distinct(cfg.n, 128, &mut rng);
    let modes = [
        ("hash", PlacementMode::TagHash),
        ("broadcast", PlacementMode::Broadcast),
        ("learned", PlacementMode::learned(cfg.shards, &sample, cfg.n)),
    ];
    for (name, mode) in modes {
        let mut fleet = ShardedCam::new(&cfg, mode);
        let mut rng = Rng::seed_from_u64(9);
        let stored = TagDistribution::Uniform.sample_distinct(cfg.n, 150, &mut rng);
        let mut addrs = Vec::new();
        for t in &stored {
            addrs.push(fleet.insert(t).unwrap());
        }
        for &a in addrs.iter().step_by(3) {
            fleet.delete(a).unwrap();
        }
        let probes = probe_set(&stored, cfg.n, &mut rng);
        for b in 0..fleet.shard_count() {
            let bank = fleet.bank_mut(b);
            let st = bank.search_state();
            assert_eq!(
                *st.filter(),
                SearchState::rebuild_filter(st.cam()),
                "{name} bank {b}: filter drifted"
            );
            for (i, tag) in probes.iter().enumerate() {
                let out = bank.lookup_unfiltered(tag).unwrap();
                assert_matches_reference(bank, &out, tag, &format!("{name} bank {b} probe {i}"));
                assert_filter_consistent(bank, tag, &format!("{name} bank {b} probe {i}"));
            }
        }
        // surviving tags still route to a hit through the filtered path
        for (i, (t, &a)) in stored.iter().zip(&addrs).enumerate() {
            if i % 3 == 0 {
                continue; // deleted above
            }
            assert_eq!(fleet.lookup(t).unwrap().addr, Some(a), "{name} tag {i}");
        }
    }
}

#[test]
fn snapshot_restart_cycle_rebuilds_an_identical_filter() {
    let cfg = DesignConfig::small_test();
    let mut e = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(77);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m - 8, &mut rng);
    for t in &stored {
        e.insert(t).unwrap();
    }
    for a in (0..stored.len()).step_by(5) {
        e.delete(a).unwrap();
    }
    e.retrain();

    // carried filter: decode → restore must hand back the very same filter
    let bytes = BankImage::from_engine(&e).encode();
    let image = BankImage::decode(&bytes).expect("snapshot decodes");
    assert_eq!(
        image.filter.as_ref(),
        Some(e.search_state().filter()),
        "snapshot must carry the writer's filter verbatim"
    );
    let mut restored = image.into_engine().expect("snapshot restores");

    // stripped filter (a v1 producer): restore must rebuild the same one
    let mut v1 = BankImage::from_engine(&e);
    v1.filter = None;
    let mut rebuilt = v1.into_engine().expect("filterless image restores");

    let probes = probe_set(&stored, cfg.n, &mut rng);
    for (i, tag) in probes.iter().enumerate() {
        let want_f = e.lookup(tag).unwrap();
        let want_u = e.lookup_unfiltered(tag).unwrap();
        for (which, eng) in [("restored", &mut restored), ("rebuilt", &mut rebuilt)] {
            assert_eq!(eng.lookup(tag).unwrap(), want_f, "{which} probe {i}: filtered");
            assert_eq!(
                eng.lookup_unfiltered(tag).unwrap(),
                want_u,
                "{which} probe {i}: unfiltered"
            );
        }
    }
    assert_eq!(restored.search_state().filter(), e.search_state().filter());
    assert_eq!(rebuilt.search_state().filter(), e.search_state().filter());
}
