//! Tiny CLI argument helper (offline build — no clap): `--flag`,
//! `--key value`, and positional arguments, with typed accessors and an
//! unknown-flag check.

use anyhow::{anyhow, bail, Result};

/// Parsed argument bag.
#[derive(Debug, Clone, Default)]
pub struct Args {
    positional: Vec<String>,
    flags: Vec<String>,
    options: Vec<(String, String)>,
}

impl Args {
    /// Parse from an iterator of raw arguments (not including argv[0]).
    /// `bool_flags` lists the flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, bool_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.push((k.to_string(), v.to_string()));
                } else if bool_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("flag --{name} expects a value"))?;
                    out.options.push((name.to_string(), v));
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        // last occurrence wins (shell-override convention)
        self.options.iter().rev().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} {v}: {e}")),
        }
    }

    /// Multi-value option: `--sizes 256,512,1024`.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str, default: Vec<T>) -> Result<Vec<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().map_err(|e| anyhow!("--{name} '{s}': {e}")))
                .collect(),
        }
    }

    /// Error on flags/options outside the allowed set (catches typos).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                bail!("unknown flag --{f}");
            }
        }
        for (k, _) in &self.options {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let a = Args::parse(
            sv(&["serve", "--lookups", "100", "--pjrt", "--hit-ratio=0.9"]),
            &["pjrt"],
        )
        .unwrap();
        assert_eq!(a.positional(), &["serve".to_string()]);
        assert!(a.flag("pjrt"));
        assert_eq!(a.get("lookups"), Some("100"));
        assert_eq!(a.get_parse("hit-ratio", 0.5f64).unwrap(), 0.9);
        assert_eq!(a.get_parse("missing", 7usize).unwrap(), 7);
    }

    #[test]
    fn list_option() {
        let a = Args::parse(sv(&["--sizes", "256,512, 1024"]), &[]).unwrap();
        assert_eq!(a.get_list("sizes", vec![1usize]).unwrap(), vec![256, 512, 1024]);
        assert_eq!(a.get_list("other", vec![9usize]).unwrap(), vec![9]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(sv(&["--lookups"]), &[]).is_err());
    }

    #[test]
    fn unknown_flag_check() {
        let a = Args::parse(sv(&["--weird", "1"]), &[]).unwrap();
        assert!(a.check_known(&["lookups"]).is_err());
        assert!(a.check_known(&["weird"]).is_ok());
    }

    #[test]
    fn last_occurrence_wins() {
        let a = Args::parse(sv(&["--m", "1", "--m", "2"]), &[]).unwrap();
        assert_eq!(a.get("m"), Some("2"));
    }
}
