//! The primary side of log shipping: [`ReplicaFeed`] answers
//! `SubscribeLog` polls from the primary's own data directory, and
//! [`ReplicationController`] tracks every subscriber's progress.
//!
//! The feed holds **no queue and no per-subscriber send state** — each
//! poll is answered by reading the bank's WAL file past the requested
//! offset ([`crate::store::wal::tail_wal`]).  That is safe against the
//! live writer thread because appends are write-through and every frame
//! carries its own length prefix and checksum (a concurrently appended
//! partial frame just ends the batch), and a concurrent compaction is
//! seen as a generation change, answered with a fresh
//! `SnapshotTransfer` instead of a stale log prefix (WAL replay is not
//! idempotent, so a stale prefix must never be shipped).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::net::proto::{
    Response, ERR_FENCED, ERR_PERSIST, ERR_PROTOCOL, REPL_MANIFEST_BANK, SUBSCRIBE_BOOTSTRAP,
};
use crate::obs::{ReplLag, ReplStatus};
use crate::store::wal::{self, TailStep, WAL_HEADER_LEN};
use crate::store::{BankImage, FleetManifest, StoreError, SNAPSHOT_FILE, WAL_FILE};

/// Default per-poll cap on shipped frame bytes (1 MiB — far below the
/// wire's `MAX_FRAME_LEN`, large enough that a chasing replica converges
/// in a few round trips).
pub const DEFAULT_BATCH_BYTES: usize = 1 << 20;

/// Per-subscriber, per-bank progress as seen by the feed.
#[derive(Debug, Clone, Copy, Default)]
struct BankProgress {
    acked_offset: u64,
    lag_records: u64,
}

/// Tracks every subscriber's acknowledged offsets and lag.  An offset is
/// "acked" when the subscriber *requests* it — the poll for offset `o`
/// proves everything before `o` was applied — so the controller needs no
/// second acknowledgement channel.  Feeds the `cscam_repl_*` gauges and
/// the operator's failover choice (promote the replica with the highest
/// acked offsets).
pub struct ReplicationController {
    epoch: u64,
    progress: Mutex<BTreeMap<(u64, u32), BankProgress>>,
}

impl ReplicationController {
    pub fn new(epoch: u64) -> ReplicationController {
        ReplicationController { epoch, progress: Mutex::new(BTreeMap::new()) }
    }

    /// The fleet epoch this controller's feed serves at.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    fn observe(&self, replica: u64, bank: u32, acked_offset: u64, lag_records: u64) {
        let mut map = self.progress.lock().unwrap_or_else(|p| p.into_inner());
        let entry = map.entry((replica, bank)).or_default();
        entry.acked_offset = acked_offset;
        entry.lag_records = lag_records;
    }

    /// Snapshot of every subscriber's progress for the exposition.
    pub fn status(&self) -> ReplStatus {
        let map = self.progress.lock().unwrap_or_else(|p| p.into_inner());
        ReplStatus {
            epoch: self.epoch,
            lags: map
                .iter()
                .map(|(&(replica, bank), p)| ReplLag {
                    replica,
                    bank,
                    acked_offset: p.acked_offset,
                    lag_records: p.lag_records,
                })
                .collect(),
        }
    }
}

/// Answers `SubscribeLog` polls from a fleet data directory.
///
/// One poll → one response:
///
/// * pseudo-bank [`REPL_MANIFEST_BANK`] → `SnapshotTransfer` carrying the
///   `fleet.kv` manifest text with `generation` = the fleet epoch (this
///   is how a joining replica learns the epoch, so it is exempt from the
///   fence check);
/// * stale subscriber epoch → `ERR_FENCED` with the feed's epoch in
///   `aux`;
/// * offset [`SUBSCRIBE_BOOTSTRAP`] → `SnapshotTransfer` of the bank's
///   snapshot file, or (never-compacted bank) the generation-0 log from
///   its first frame;
/// * a live cursor → `LogBatch` of whole frames past it, capped at
///   [`DEFAULT_BATCH_BYTES`] per poll; a cursor whose generation the log
///   has moved past is answered like a bootstrap.
pub struct ReplicaFeed {
    dir: PathBuf,
    epoch: u64,
    manifest_text: String,
    banks: u32,
    batch_bytes: usize,
    controller: ReplicationController,
}

impl ReplicaFeed {
    /// Open a feed over the fleet directory at `dir` (the same directory
    /// the serving fleet holds open; the feed only reads).
    pub fn open(dir: &Path) -> Result<ReplicaFeed, StoreError> {
        let manifest = FleetManifest::load(dir)?;
        Ok(ReplicaFeed {
            dir: dir.to_path_buf(),
            epoch: manifest.epoch,
            manifest_text: manifest.to_kv(),
            banks: manifest.cfg.shards as u32,
            batch_bytes: DEFAULT_BATCH_BYTES,
            controller: ReplicationController::new(manifest.epoch),
        })
    }

    /// Override the per-poll frame-byte cap (tests drive multi-batch
    /// chases with tiny caps).
    pub fn with_batch_bytes(mut self, batch_bytes: usize) -> ReplicaFeed {
        self.batch_bytes = batch_bytes.max(1);
        self
    }

    /// The fleet epoch this feed serves at.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Subscriber progress for the exposition.
    pub fn status(&self) -> ReplStatus {
        self.controller.status()
    }

    /// Answer one `SubscribeLog` poll.
    pub fn serve(
        &self,
        replica: u64,
        epoch: u64,
        bank: u32,
        generation: u64,
        offset: u64,
    ) -> Response {
        if bank == REPL_MANIFEST_BANK {
            return Response::SnapshotTransfer {
                bank: REPL_MANIFEST_BANK,
                generation: self.epoch,
                image: self.manifest_text.clone().into_bytes(),
            };
        }
        if epoch != self.epoch {
            return Response::Error { code: ERR_FENCED, aux: self.epoch };
        }
        if bank >= self.banks {
            return Response::Error { code: ERR_PROTOCOL, aux: u64::from(bank) };
        }
        if offset == SUBSCRIBE_BOOTSTRAP {
            return self.bootstrap(bank);
        }
        let path = self.bank_dir(bank).join(WAL_FILE);
        match wal::tail_wal(&path, generation, offset, self.batch_bytes) {
            Ok(TailStep::Batch { generation, next_offset, frames, records, remaining }) => {
                // requesting `offset` acknowledges everything before it;
                // the subscriber's lag is everything at or past it
                self.controller.observe(replica, bank, offset, records + remaining);
                Response::LogBatch { bank, generation, next_offset, remaining, frames }
            }
            // the cursor's log is gone (a compaction reset it): restart
            // the stream from the current snapshot, never a stale prefix
            Ok(TailStep::Restarted { .. }) => self.bootstrap(bank),
            Err(e) => {
                eprintln!("cscam-repl: feed tail of bank {bank} failed: {e}");
                Response::Error { code: ERR_PERSIST, aux: 0 }
            }
        }
    }

    fn bank_dir(&self, bank: u32) -> PathBuf {
        self.dir.join(format!("bank-{bank}"))
    }

    fn bootstrap(&self, bank: u32) -> Response {
        let dir = self.bank_dir(bank);
        // Compaction renames the snapshot into place *before* resetting
        // the WAL, so a log at generation > 0 implies a snapshot exists;
        // one retry covers the rename racing the exists() check.
        for _ in 0..2 {
            let snap = dir.join(SNAPSHOT_FILE);
            if snap.exists() {
                return match std::fs::read(&snap)
                    .map_err(StoreError::Io)
                    .and_then(|bytes| BankImage::decode(&bytes).map(|img| (img, bytes)))
                {
                    Ok((img, bytes)) => Response::SnapshotTransfer {
                        bank,
                        generation: img.wal_generation,
                        image: bytes,
                    },
                    Err(e) => {
                        eprintln!("cscam-repl: feed snapshot of bank {bank} unreadable: {e}");
                        Response::Error { code: ERR_PERSIST, aux: 0 }
                    }
                };
            }
            // never-compacted bank: its whole history is the generation-0
            // log, shipped from the first frame
            match wal::tail_wal(&dir.join(WAL_FILE), 0, WAL_HEADER_LEN, self.batch_bytes) {
                Ok(TailStep::Batch { generation, next_offset, frames, records: _, remaining }) => {
                    return Response::LogBatch { bank, generation, next_offset, remaining, frames }
                }
                // the log moved past generation 0 — a snapshot just
                // landed; re-check for it
                Ok(TailStep::Restarted { .. }) => continue,
                Err(e) => {
                    eprintln!("cscam-repl: feed bootstrap tail of bank {bank} failed: {e}");
                    return Response::Error { code: ERR_PERSIST, aux: 0 };
                }
            }
        }
        eprintln!("cscam-repl: bank {bank} kept restarting mid-bootstrap");
        Response::Error { code: ERR_PERSIST, aux: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DesignConfig;
    use crate::coordinator::BatchPolicy;
    use crate::shard::{PlacementMode, ShardedCamServer, ShardedServerHandle};
    use crate::store::StoreOptions;
    use crate::util::Rng;
    use crate::workload::TagDistribution;
    use std::path::PathBuf;
    use std::time::Duration;

    fn test_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("cscam-repl-feed-{}", std::process::id()))
            .join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn cfg() -> DesignConfig {
        DesignConfig { shards: 2, ..DesignConfig::small_test() }
    }

    fn policy() -> BatchPolicy {
        BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(100) }
    }

    fn open_primary(dir: &Path) -> ShardedServerHandle {
        let (fleet, _) = ShardedCamServer::open_durable(
            &cfg(),
            PlacementMode::TagHash,
            policy(),
            dir,
            StoreOptions::default(),
        )
        .unwrap();
        fleet.spawn()
    }

    #[test]
    fn feed_serves_manifest_fences_and_ships_the_log() {
        let dir = test_dir("serve");
        let handle = open_primary(&dir);
        let mut rng = Rng::seed_from_u64(7);
        let tags = TagDistribution::Uniform.sample_distinct(cfg().n, 12, &mut rng);
        for t in &tags {
            handle.insert(t.clone()).unwrap();
        }

        let feed = ReplicaFeed::open(&dir).unwrap();
        assert_eq!(feed.epoch(), 0);

        // the manifest pseudo-bank answers regardless of epoch and
        // carries the fleet epoch as its generation
        match feed.serve(1, 999, REPL_MANIFEST_BANK, 0, SUBSCRIBE_BOOTSTRAP) {
            Response::SnapshotTransfer { bank, generation, image } => {
                assert_eq!(bank, REPL_MANIFEST_BANK);
                assert_eq!(generation, 0);
                let m = FleetManifest::from_kv(&String::from_utf8(image).unwrap()).unwrap();
                assert_eq!(m.cfg, cfg());
                assert_eq!(m.epoch, 0);
            }
            other => panic!("manifest poll answered {other:?}"),
        }

        // a subscriber from another epoch is fenced, with the feed's
        // epoch in aux
        match feed.serve(1, 3, 0, 0, WAL_HEADER_LEN) {
            Response::Error { code: ERR_FENCED, aux } => assert_eq!(aux, 0),
            other => panic!("stale epoch answered {other:?}"),
        }

        // a bank index past the fleet is a protocol error, not a panic
        assert!(matches!(
            feed.serve(1, 0, 99, 0, WAL_HEADER_LEN),
            Response::Error { code: ERR_PROTOCOL, .. }
        ));

        // bootstrap of a never-compacted bank ships the generation-0 log;
        // chasing to next_offset drains it and registers the ack
        let mut total = 0usize;
        for bank in 0..2u32 {
            let (generation, next_offset, frames) =
                match feed.serve(1, 0, bank, 0, SUBSCRIBE_BOOTSTRAP) {
                    Response::LogBatch { generation, next_offset, remaining, frames, .. } => {
                        assert_eq!(remaining, 0);
                        (generation, next_offset, frames)
                    }
                    other => panic!("bootstrap answered {other:?}"),
                };
            assert_eq!(generation, 0);
            let records = wal::decode_frames(&frames).unwrap();
            total += records.len();
            match feed.serve(1, 0, bank, generation, next_offset) {
                Response::LogBatch { next_offset: n2, remaining, frames, .. } => {
                    assert_eq!(n2, next_offset, "caught-up poll must not advance");
                    assert_eq!(remaining, 0);
                    assert!(frames.is_empty());
                }
                other => panic!("caught-up poll answered {other:?}"),
            }
            let status = feed.status();
            let row = status
                .lags
                .iter()
                .find(|l| l.replica == 1 && l.bank == bank)
                .expect("poll must register progress");
            assert_eq!(row.acked_offset, next_offset);
            assert_eq!(row.lag_records, 0);
        }
        assert_eq!(total, tags.len(), "every insert ships exactly once across the banks");
        handle.shutdown().unwrap();
    }

    #[test]
    fn tiny_batch_cap_pages_through_the_log_with_honest_lag() {
        let dir = test_dir("paging");
        let handle = open_primary(&dir);
        let mut rng = Rng::seed_from_u64(8);
        let tags = TagDistribution::Uniform.sample_distinct(cfg().n, 10, &mut rng);
        for t in &tags {
            handle.insert(t.clone()).unwrap();
        }
        // cap of one byte: every poll ships exactly one frame (the cap
        // always admits at least one), the rest counted as lag
        let feed = ReplicaFeed::open(&dir).unwrap().with_batch_bytes(1);
        let mut shipped = 0usize;
        for bank in 0..2u32 {
            let mut offset = WAL_HEADER_LEN;
            let mut last_remaining = u64::MAX;
            loop {
                match feed.serve(2, 0, bank, 0, offset) {
                    Response::LogBatch { next_offset, remaining, frames, .. } => {
                        if frames.is_empty() {
                            assert_eq!(remaining, 0);
                            break;
                        }
                        let records = wal::decode_frames(&frames).unwrap();
                        assert_eq!(records.len(), 1, "one frame per capped poll");
                        assert!(remaining < last_remaining, "lag must shrink every poll");
                        last_remaining = remaining;
                        shipped += 1;
                        offset = next_offset;
                    }
                    other => panic!("capped poll answered {other:?}"),
                }
            }
        }
        assert_eq!(shipped, tags.len(), "paged polls ship the whole history exactly once");
        handle.shutdown().unwrap();
    }

    #[test]
    fn a_stale_cursor_is_answered_with_the_fresh_snapshot_not_a_stale_prefix() {
        let dir = test_dir("restart");
        let handle = open_primary(&dir);
        let mut rng = Rng::seed_from_u64(9);
        for t in &TagDistribution::Uniform.sample_distinct(cfg().n, 12, &mut rng) {
            handle.insert(t.clone()).unwrap();
        }
        handle.snapshot_stores().unwrap(); // compaction: snapshot + WAL reset, generation 1

        let feed = ReplicaFeed::open(&dir).unwrap();
        // the old generation-0 cursor no longer exists; the feed must
        // restart the stream from the generation-1 snapshot
        match feed.serve(1, 0, 0, 0, WAL_HEADER_LEN) {
            Response::SnapshotTransfer { bank, generation, image } => {
                assert_eq!(bank, 0);
                assert_eq!(generation, 1);
                let img = BankImage::decode(&image).unwrap();
                assert_eq!(img.wal_generation, 1);
            }
            other => panic!("stale cursor answered {other:?}"),
        }
        // bootstrap now also comes from the snapshot
        assert!(matches!(
            feed.serve(1, 0, 0, 0, SUBSCRIBE_BOOTSTRAP),
            Response::SnapshotTransfer { generation: 1, .. }
        ));
        handle.shutdown().unwrap();
    }
}
