"""L1 correctness: Pallas GD / training kernels vs the pure-jnp oracle.

Hypothesis sweeps geometry (B, c, l, M, ζ, block_m) and weight densities;
every case asserts exact agreement (the values are binary, so allclose with
tight tolerance == exact).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.gd_decode import gd_decode, gd_decode_gather, train_weights
from compile.kernels.ref import gd_decode_ref, lambda_ref, train_weights_ref


def _make_onehots(rng, batch, c, l):
    """Concatenated one-hot LD outputs: exactly one active neuron per cluster."""
    u = np.zeros((batch, c * l), dtype=np.float32)
    idx = rng.integers(0, l, size=(batch, c))
    for b in range(batch):
        for i in range(c):
            u[b, i * l + idx[b, i]] = 1.0
    return u, idx


geometry = st.tuples(
    st.integers(1, 8),                     # batch
    st.integers(1, 4),                     # c
    st.sampled_from([2, 4, 8, 16]),        # l
    st.sampled_from([8, 16, 32, 64, 128]), # M
    st.sampled_from([1, 2, 4, 8]),         # zeta
    st.integers(0, 2**31 - 1),             # seed
)


@settings(max_examples=60, deadline=None)
@given(geometry, st.floats(0.0, 1.0))
def test_gd_decode_matches_ref(geom, density):
    batch, c, l, m, zeta, seed = geom
    if m % zeta != 0:
        m = zeta * max(1, m // zeta)
    rng = np.random.default_rng(seed)
    u, _ = _make_onehots(rng, batch, c, l)
    w = (rng.random((c * l, m)) < density).astype(np.float32)

    act, en = gd_decode(jnp.asarray(u), jnp.asarray(w), c=c, zeta=zeta)
    act_r, en_r = gd_decode_ref(jnp.asarray(u), jnp.asarray(w), c=c, zeta=zeta)

    np.testing.assert_allclose(np.asarray(act), np.asarray(act_r), atol=0)
    np.testing.assert_allclose(np.asarray(en), np.asarray(en_r), atol=0)


@settings(max_examples=40, deadline=None)
@given(geometry, st.floats(0.0, 1.0))
def test_gather_formulation_matches_matmul_formulation(geom, density):
    """The Fig. 4 row-gather kernel and the MXU matmul kernel are two
    lowerings of the same eq. (1) — they must agree bit-for-bit."""
    batch, c, l, m, zeta, seed = geom
    if m % zeta != 0:
        m = zeta * max(1, m // zeta)
    rng = np.random.default_rng(seed)
    u, idx = _make_onehots(rng, batch, c, l)
    w = (rng.random((c * l, m)) < density).astype(np.float32)

    act_mm, en_mm = gd_decode(jnp.asarray(u), jnp.asarray(w), c=c, zeta=zeta)
    act_g, en_g = gd_decode_gather(
        jnp.asarray(idx.astype(np.int32)), jnp.asarray(w), c=c, l=l, zeta=zeta
    )
    np.testing.assert_array_equal(np.asarray(act_mm), np.asarray(act_g))
    np.testing.assert_array_equal(np.asarray(en_mm), np.asarray(en_g))


def test_gather_shape_validation():
    idx = jnp.zeros((2, 3), jnp.int32)
    w = jnp.zeros((24, 16), jnp.float32)
    with pytest.raises(ValueError):
        gd_decode_gather(idx, w, c=2, l=8, zeta=4)  # c mismatch
    with pytest.raises(ValueError):
        gd_decode_gather(idx, w, c=3, l=4, zeta=4)  # c·l mismatch
    with pytest.raises(ValueError):
        gd_decode_gather(idx, w, c=3, l=8, zeta=5)  # zeta ∤ M


@settings(max_examples=40, deadline=None)
@given(geometry)
def test_train_matches_ref(geom):
    entries, c, l, m, _, seed = geom
    rng = np.random.default_rng(seed)
    u, _ = _make_onehots(rng, entries, c, l)
    addr = rng.integers(0, m, size=entries)
    a = np.eye(m, dtype=np.float32)[addr]

    w = train_weights(jnp.asarray(u), jnp.asarray(a))
    w_r = train_weights_ref(jnp.asarray(u), jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_r), atol=0)


@settings(max_examples=25, deadline=None)
@given(geometry)
def test_train_then_decode_no_false_negative(geom):
    """The paper's correctness invariant: the CNN may over-activate
    (ambiguities cost power) but must NEVER miss the trained entry —
    'accuracy of the final output is not affected' (§I)."""
    entries, c, l, m, zeta, seed = geom
    if m % zeta != 0:
        m = zeta * max(1, m // zeta)
    entries = min(entries, m)
    rng = np.random.default_rng(seed)
    u, _ = _make_onehots(rng, entries, c, l)
    addr = rng.choice(m, size=entries, replace=False)
    a = np.eye(m, dtype=np.float32)[addr]

    w = train_weights(jnp.asarray(u), jnp.asarray(a))
    act, en = gd_decode(jnp.asarray(u), w, c=c, zeta=zeta)
    act = np.asarray(act)
    en = np.asarray(en)
    for e in range(entries):
        assert act[e, addr[e]] == 1.0, "trained P_II neuron must activate"
        assert en[e, addr[e] // zeta] == 1.0, "its sub-block must be enabled"


@pytest.mark.parametrize("block_m", [8, 16, 32, 64, 128])
def test_block_m_invariance(block_m):
    """Tiling must not change results — the VMEM schedule is semantics-free."""
    rng = np.random.default_rng(7)
    c, l, m, zeta, batch = 3, 8, 128, 4, 5
    u, _ = _make_onehots(rng, batch, c, l)
    w = (rng.random((c * l, m)) < 0.1).astype(np.float32)
    base_act, base_en = gd_decode(jnp.asarray(u), jnp.asarray(w), c=c, zeta=zeta, block_m=m)
    act, en = gd_decode(jnp.asarray(u), jnp.asarray(w), c=c, zeta=zeta, block_m=block_m)
    np.testing.assert_array_equal(np.asarray(act), np.asarray(base_act))
    np.testing.assert_array_equal(np.asarray(en), np.asarray(base_en))


def test_empty_weights_activate_nothing():
    u = np.zeros((2, 6), dtype=np.float32)
    u[:, 0] = 1.0
    u[:, 3] = 1.0  # c=2, l=3... use l=4 power of two geometry instead
    c, l, m, zeta = 2, 4, 16, 4
    u = np.zeros((2, c * l), dtype=np.float32)
    u[:, 1] = 1.0
    u[:, l + 2] = 1.0
    w = np.zeros((c * l, m), dtype=np.float32)
    act, en = gd_decode(jnp.asarray(u), jnp.asarray(w), c=c, zeta=zeta)
    assert np.asarray(act).sum() == 0
    assert np.asarray(en).sum() == 0


def test_full_weights_activate_everything():
    c, l, m, zeta = 3, 4, 32, 8
    rng = np.random.default_rng(3)
    u, _ = _make_onehots(rng, 4, c, l)
    w = np.ones((c * l, m), dtype=np.float32)
    act, en = gd_decode(jnp.asarray(u), jnp.asarray(w), c=c, zeta=zeta)
    assert np.asarray(act).min() == 1.0
    assert np.asarray(en).min() == 1.0


def test_lambda_counts_activations():
    c, l, m, zeta = 2, 4, 16, 2
    rng = np.random.default_rng(11)
    u, _ = _make_onehots(rng, 6, c, l)
    w = (rng.random((c * l, m)) < 0.5).astype(np.float32)
    act, _ = gd_decode(jnp.asarray(u), jnp.asarray(w), c=c, zeta=zeta)
    lam = lambda_ref(act)
    np.testing.assert_array_equal(np.asarray(lam), np.asarray(act).sum(-1).astype(np.int32))


def test_shape_validation():
    u = jnp.zeros((2, 8), jnp.float32)
    w = jnp.zeros((6, 16), jnp.float32)
    with pytest.raises(ValueError):
        gd_decode(u, w, c=2, zeta=4)  # cl mismatch
    w = jnp.zeros((8, 15), jnp.float32)
    with pytest.raises(ValueError):
        gd_decode(u, w, c=2, zeta=4)  # M not divisible by zeta
    w = jnp.zeros((8, 16), jnp.float32)
    with pytest.raises(ValueError):
        gd_decode(u, w, c=2, zeta=4, block_m=6)  # bad tile
