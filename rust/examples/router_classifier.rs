//! Network-router packet classifier — the paper's second motivating
//! application (§I, ref [2]: IPv6 packet classification with CAMs).
//!
//! Stores IPv6-flavoured classifier tags (a handful of route prefixes with
//! random host bits — strongly *non-uniform* in the high bits) and shows
//! §II-B in action: naive truncation of the correlated prefix region
//! inflates the number of enabled sub-blocks, while the entropy-driven
//! bit selection restores the ~2-comparison behaviour.  Accuracy is
//! unaffected either way.  Scale-out across four shards handles a table
//! larger than one macro.
//!
//! Run: `cargo run --release --example router_classifier`

use cscam::cnn::Selection;
use cscam::config::DesignConfig;
use cscam::coordinator::LookupEngine;
use cscam::shard::{PlacementMode, ShardedCam};
use cscam::stats::OnlineStats;
use cscam::util::Rng;
use cscam::workload::AclTrace;

fn main() -> anyhow::Result<()> {
    let cfg = DesignConfig::reference();
    let mut rng = Rng::seed_from_u64(6);
    let acl = AclTrace { n: cfg.n, prefixes: 6, prefix_len: 48 };
    let rules = acl.generate(cfg.m, &mut rng);

    println!("# router classifier — {} rules, {} route prefixes, {}-bit tags\n", cfg.m, 6, cfg.n);

    // Three bit-selection policies over the same rule set.
    let policies: Vec<(&str, Selection)> = vec![
        (
            "high-bits (worst: constant prefix)",
            Selection::explicit((cfg.n - cfg.q()..cfg.n).collect(), cfg.k()),
        ),
        ("strided (paper's 'pattern')", Selection::strided(cfg.n, cfg.c, cfg.k())),
        ("entropy-greedy (data-driven)", Selection::entropy_greedy(&rules, cfg.n, cfg.c, cfg.k())),
    ];

    println!(
        "{:<36} {:>10} {:>12} {:>14} {:>10}",
        "bit selection", "mean λ", "mean blocks", "mean E [fJ]", "correct"
    );
    for (name, sel) in policies {
        let mut engine = LookupEngine::with_selection(cfg.clone(), sel);
        for r in &rules {
            engine.insert(r)?;
        }
        let mut lambda = OnlineStats::new();
        let mut blocks = OnlineStats::new();
        let mut energy = OnlineStats::new();
        let mut correct = true;
        for (i, r) in rules.iter().enumerate() {
            let out = engine.lookup(r)?;
            correct &= out.addr == Some(i);
            lambda.push(out.lambda as f64);
            blocks.push(out.enabled_blocks as f64);
            energy.push(out.energy.total_fj());
        }
        println!(
            "{:<36} {:>10.2} {:>12.2} {:>14.1} {:>10}",
            name,
            lambda.mean(),
            blocks.mean(),
            energy.mean(),
            if correct { "yes" } else { "NO" }
        );
    }

    // Scale-out: a 2048-rule table across four sharded macros.  ACL tags
    // have a nearly-constant prefix region, so use the learned-prefix
    // placement (entropy-driven bit selection) instead of hashing blind.
    println!("\n# shard scale-out: 2048 rules over 4 × {}-entry macros", cfg.m);
    let big_rules = AclTrace { n: cfg.n, prefixes: 16, prefix_len: 44 }.generate(1800, &mut rng);
    let fleet_cfg = DesignConfig { m: 4 * cfg.m, shards: 4, ..cfg.clone() };
    let mut cam = ShardedCam::new(&fleet_cfg, PlacementMode::learned(4, &big_rules, cfg.n));
    let mut stored = 0usize;
    for r in &big_rules {
        if cam.insert(r).is_ok() {
            stored += 1;
        }
    }
    let mut found = 0usize;
    let mut energy = OnlineStats::new();
    let mut banks_touched = OnlineStats::new();
    for r in &big_rules {
        let out = cam.lookup(r)?;
        found += out.addr.is_some() as usize;
        energy.push(out.energy.total_fj());
        banks_touched.push(out.banks_searched as f64);
    }
    println!(
        "stored {}/{}, found {}, mean lookup energy {:.1} fJ, banks touched/lookup {:.1}",
        stored,
        big_rules.len(),
        found,
        energy.mean(),
        banks_touched.mean()
    );
    println!("(one shard active per lookup: scale-out adds capacity at constant search energy)");
    Ok(())
}
