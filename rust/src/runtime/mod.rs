//! PJRT runtime — loads the AOT-lowered HLO text artifacts and executes
//! them on the request path (the L3 ↔ L2 bridge).
//!
//! `python/compile/aot.py` lowers the JAX decode/train graphs once at build
//! time (`make artifacts`) into `artifacts/*.hlo.txt` plus `manifest.json`;
//! this module compiles them on a [`xla::PjRtClient`] at startup and keeps
//! the weight matrix resident as a device buffer, so a lookup only ships
//! `B × c` i32 cluster indices in and `B × β` enable bits (+ λ) out.
//! Python never runs after build.
//!
//! HLO *text* is the interchange format — the crate's xla_extension 0.5.1
//! rejects jax ≥ 0.5 serialized protos (64-bit ids); the text parser
//! reassigns ids.
//!
//! The whole PJRT stack sits behind the `pjrt` cargo feature (see
//! `rust/README.md`): the default build is pure Rust and only carries the
//! manifest parser, the [`DecodeOutput`] type the coordinator consumes, and
//! the artifact-directory helpers.  [`ArtifactStore`] and everything that
//! touches the `xla` crate compiles only with `--features pjrt`.

pub mod manifest;

pub use manifest::{ArtifactInfo, Manifest};

use std::path::PathBuf;

#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;
#[cfg(feature = "pjrt")]
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::anyhow;

use crate::bits::BitVec;
#[cfg(feature = "pjrt")]
use crate::Result;

/// Outputs of one batched decode through the PJRT artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeOutput {
    /// Per-query compare-enable masks (β bits each).
    pub enables: Vec<BitVec>,
    /// Per-query λ (number of activated P_II neurons).
    pub lambda: Vec<u32>,
}

/// Compiled artifact store: one executable per decode batch size, plus the
/// train / add-entry graphs, plus the resident weight buffer.
#[cfg(feature = "pjrt")]
pub struct ArtifactStore {
    client: xla::PjRtClient,
    manifest: Manifest,
    decode: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    train: Option<xla::PjRtLoadedExecutable>,
    /// (c·l) × M weight matrix as a resident device buffer.
    weights: Option<xla::PjRtBuffer>,
}

#[cfg(feature = "pjrt")]
impl std::fmt::Debug for ArtifactStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactStore")
            .field("platform", &self.client.platform_name())
            .field("batches", &self.decode.keys().collect::<Vec<_>>())
            .field("has_train", &self.train.is_some())
            .field("has_weights", &self.weights.is_some())
            .finish()
    }
}

#[cfg(feature = "pjrt")]
impl ArtifactStore {
    /// Load and compile every artifact listed in `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;

        let mut decode = BTreeMap::new();
        let mut train = None;
        for (name, info) in &manifest.artifacts {
            let path = dir.join(format!("{name}.hlo.txt"));
            match info.kind.as_str() {
                "decode" => {
                    let batch =
                        info.batch.ok_or_else(|| anyhow!("decode artifact without batch"))?;
                    decode.insert(batch, compile_hlo(&client, &path)?);
                }
                "train" => train = Some(compile_hlo(&client, &path)?),
                // add_entry loads lazily if ever needed; the native path
                // handles inserts (see coordinator::engine).
                _ => {}
            }
        }
        anyhow::ensure!(!decode.is_empty(), "no decode artifacts in manifest");
        Ok(ArtifactStore { client, manifest, decode, train, weights: None })
    }

    /// Geometry the artifacts were lowered for.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Batch sizes with a compiled decode executable, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.decode.keys().copied().collect()
    }

    /// Smallest compiled batch ≥ `n` (or the largest available).
    pub fn pick_batch(&self, n: usize) -> usize {
        self.decode
            .keys()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| *self.decode.keys().last().expect("non-empty"))
    }

    /// Upload the CNN weight rows (the Fig. 4 SRAM contents) as the resident
    /// device buffer used by subsequent [`Self::decode`] calls.
    pub fn set_weights(&mut self, rows: &[BitVec]) -> Result<()> {
        let cfg = &self.manifest.config;
        anyhow::ensure!(rows.len() == cfg.c * cfg.l, "expected c·l weight rows");
        let mut host = vec![0f32; cfg.c * cfg.l * cfg.m];
        for (r, row) in rows.iter().enumerate() {
            anyhow::ensure!(row.len() == cfg.m, "weight row width mismatch");
            for i in row.iter_ones() {
                host[r * cfg.m + i] = 1.0;
            }
        }
        let buf = self
            .client
            .buffer_from_host_buffer(&host, &[cfg.c * cfg.l, cfg.m], None)
            .map_err(|e| anyhow!("upload weights: {e}"))?;
        self.weights = Some(buf);
        Ok(())
    }

    /// Batched decode: `idx` holds `c` cluster indices per query.  The
    /// queries are padded up to a compiled batch size with index 0 and the
    /// padding rows are dropped from the output.
    pub fn decode(&self, idx: &[Vec<u16>]) -> Result<DecodeOutput> {
        let cfg = &self.manifest.config;
        let weights =
            self.weights.as_ref().ok_or_else(|| anyhow!("weights not uploaded; call set_weights"))?;
        anyhow::ensure!(!idx.is_empty(), "empty decode batch");
        let batch = self.pick_batch(idx.len());
        anyhow::ensure!(idx.len() <= batch, "batch {} exceeds compiled sizes", idx.len());
        let exe = &self.decode[&batch];

        let mut host = vec![0i32; batch * cfg.c];
        for (i, q) in idx.iter().enumerate() {
            anyhow::ensure!(q.len() == cfg.c, "query must carry c cluster indices");
            for (j, &v) in q.iter().enumerate() {
                host[i * cfg.c + j] = v as i32;
            }
        }
        let idx_buf = self
            .client
            .buffer_from_host_buffer(&host, &[batch, cfg.c], None)
            .map_err(|e| anyhow!("upload idx: {e}"))?;

        let outs = exe.execute_b(&[&idx_buf, weights]).map_err(|e| anyhow!("execute: {e}"))?;
        let lit = outs[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e}"))?;
        let (en_lit, lam_lit) = lit.to_tuple2().map_err(|e| anyhow!("untuple: {e}"))?;
        let en: Vec<f32> = en_lit.to_vec().map_err(|e| anyhow!("enables: {e}"))?;
        let lam: Vec<i32> = lam_lit.to_vec().map_err(|e| anyhow!("lambda: {e}"))?;

        let beta = cfg.beta;
        let mut enables = Vec::with_capacity(idx.len());
        let mut lambda = Vec::with_capacity(idx.len());
        for i in 0..idx.len() {
            let mut bv = BitVec::zeros(beta);
            for b in 0..beta {
                if en[i * beta + b] != 0.0 {
                    bv.set(b, true);
                }
            }
            enables.push(bv);
            lambda.push(lam[i] as u32);
        }
        Ok(DecodeOutput { enables, lambda })
    }

    /// Full retrain through the PJRT train artifact: takes the M stored
    /// entries' cluster indices and addresses, produces the weight matrix
    /// and installs it as the resident buffer.  Returns the weight rows.
    pub fn train(&mut self, idx: &[Vec<u16>], addr: &[u32]) -> Result<Vec<BitVec>> {
        let cfg = self.manifest.config.clone();
        let exe = self.train.as_ref().ok_or_else(|| anyhow!("no train artifact"))?;
        anyhow::ensure!(
            idx.len() == cfg.m && addr.len() == cfg.m,
            "train expects exactly M entries"
        );

        let mut idx_host = vec![0i32; cfg.m * cfg.c];
        for (i, q) in idx.iter().enumerate() {
            for (j, &v) in q.iter().enumerate() {
                idx_host[i * cfg.c + j] = v as i32;
            }
        }
        let addr_host: Vec<i32> = addr.iter().map(|&a| a as i32).collect();
        let idx_buf = self
            .client
            .buffer_from_host_buffer(&idx_host, &[cfg.m, cfg.c], None)
            .map_err(|e| anyhow!("upload idx: {e}"))?;
        let addr_buf = self
            .client
            .buffer_from_host_buffer(&addr_host, &[cfg.m], None)
            .map_err(|e| anyhow!("upload addr: {e}"))?;

        let outs = exe.execute_b(&[&idx_buf, &addr_buf]).map_err(|e| anyhow!("execute: {e}"))?;
        let lit = outs[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e}"))?;
        let w_lit = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        let w: Vec<f32> = w_lit.to_vec().map_err(|e| anyhow!("weights: {e}"))?;

        let mut rows = Vec::with_capacity(cfg.c * cfg.l);
        for r in 0..cfg.c * cfg.l {
            let mut bv = BitVec::zeros(cfg.m);
            for m in 0..cfg.m {
                if w[r * cfg.m + m] != 0.0 {
                    bv.set(m, true);
                }
            }
            rows.push(bv);
        }
        self.set_weights(&rows)?;
        Ok(rows)
    }
}

#[cfg(feature = "pjrt")]
fn compile_hlo(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compile {}: {e}", path.display()))
}

/// Locate the artifacts directory: `$CSCAM_ARTIFACTS`, else `./artifacts`,
/// else `<crate root>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("CSCAM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.join("manifest.json").exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if AOT artifacts are present (tests skip PJRT paths otherwise).
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-dependent tests live in rust/tests/pjrt_roundtrip.rs (they need
    // `make artifacts` to have run).  Here: manifest-independent logic.

    #[test]
    fn pick_batch_prefers_smallest_fit() {
        // Synthesize a store-shaped map (no PJRT needed for this logic).
        let sizes = [1usize, 16, 64];
        let pick = |n: usize| sizes.iter().copied().find(|&b| b >= n).unwrap_or(64);
        assert_eq!(pick(1), 1);
        assert_eq!(pick(2), 16);
        assert_eq!(pick(16), 16);
        assert_eq!(pick(17), 64);
        assert_eq!(pick(64), 64);
        assert_eq!(pick(65), 64);
    }

    #[test]
    fn default_dir_env_override() {
        std::env::set_var("CSCAM_ARTIFACTS", "/tmp/xyz-artifacts");
        assert_eq!(default_artifact_dir(), PathBuf::from("/tmp/xyz-artifacts"));
        std::env::remove_var("CSCAM_ARTIFACTS");
    }
}
