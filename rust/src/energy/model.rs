//! The energy model proper: conventional, proposed (analytic and
//! activity-measured), and the CNN classifier's own consumption.

use crate::cam::MatchlineKind;
use crate::config::DesignConfig;
use crate::tech::{self, TechNode};

use super::breakdown::{EnergyBreakdown, SearchActivity};
use super::calib::CalibrationConstants;

/// Convenience wrapper binding a calibration to a design point.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    pub calib: CalibrationConstants,
    pub cfg: DesignConfig,
}

impl EnergyModel {
    pub fn new(cfg: DesignConfig) -> Self {
        EnergyModel { calib: CalibrationConstants::reference_130nm(), cfg }
    }

    /// Conventional (monolithic) search energy at the config's node.
    pub fn conventional(&self, ml: MatchlineKind) -> EnergyBreakdown {
        let b = conventional_search_energy(self.cfg.m, self.cfg.n, ml, &self.calib);
        rescale(b, self.cfg.tech())
    }

    /// Proposed-design energy using the closed-form expected activity
    /// (uniform reduced tags), at the config's node.
    pub fn proposed_expected(&self) -> EnergyBreakdown {
        let b = proposed_search_energy(&self.cfg, &self.calib);
        rescale(b, self.cfg.tech())
    }

    /// Per-search proposed-design energy from *measured* switching activity,
    /// at the config's node.  `activity` may be the accumulation of
    /// `searches` individual searches; the result is the per-search average.
    pub fn proposed_measured(&self, activity: &SearchActivity, searches: usize) -> EnergyBreakdown {
        let searches = searches.max(1) as f64;
        let mut total = energy_from_activity(&self.cfg, &self.calib, activity, searches as usize);
        let mut cnn = cnn_decode_energy(&self.cfg, &self.calib).scaled(searches);
        cnn.enable_driver_fj = self.calib.e_enable_driver_block * activity.enabled_blocks as f64;
        total.add(&cnn);
        rescale(total.scaled(1.0 / searches), self.cfg.tech())
    }
}

fn rescale(b: EnergyBreakdown, node: TechNode) -> EnergyBreakdown {
    let k = tech::scale_energy(1.0, tech::NODE_130NM, node);
    b.scaled(k)
}

/// Search energy of a conventional M×N CAM (all rows compare every cycle):
/// every cell burns SL + ML + its share of global wire.
pub fn conventional_search_energy(
    m: usize,
    n: usize,
    ml: MatchlineKind,
    calib: &CalibrationConstants,
) -> EnergyBreakdown {
    let cells = (m * n) as f64;
    let ml_e = match ml {
        MatchlineKind::Nor => calib.e_ml_nor,
        MatchlineKind::Nand => calib.e_ml_nand,
    };
    EnergyBreakdown {
        searchline_fj: cells * calib.e_sl_cell,
        matchline_fj: cells * ml_e,
        global_wire_fj: cells * calib.e_global_wire,
        ..Default::default()
    }
}

/// The CNN classifier's per-decode energy (Fig. 4): c one-hot decoders, one
/// M-bit SRAM row read per cluster, and the P_II AND/OR logic.  The
/// compare-enable drivers are activity-dependent and added by the caller.
pub fn cnn_decode_energy(cfg: &DesignConfig, calib: &CalibrationConstants) -> EnergyBreakdown {
    EnergyBreakdown {
        decoder_fj: (cfg.cl()) as f64 * calib.e_decoder_line,
        sram_read_fj: (cfg.c * cfg.m) as f64 * calib.e_sram_read_bit,
        pii_logic_fj: cfg.m as f64 * calib.e_pii_logic_neuron,
        ..Default::default()
    }
}

/// Closed-form expected per-search energy of the proposed design under
/// uniformly distributed reduced tags (the paper's design-point analysis):
/// only `E[active blocks]·ζ` rows burn SL+ML energy; the global broadcast
/// wire and the CNN always switch.
pub fn proposed_search_energy(cfg: &DesignConfig, calib: &CalibrationConstants) -> EnergyBreakdown {
    let blocks = cfg.expected_active_blocks();
    let rows = blocks * cfg.zeta as f64;
    let cells = rows * cfg.n as f64;
    let ml_e = match cfg.ml_kind {
        MatchlineKind::Nor => calib.e_ml_nor,
        MatchlineKind::Nand => calib.e_ml_nand,
    };
    let mut b = cnn_decode_energy(cfg, calib);
    b.searchline_fj = cells * calib.e_sl_cell;
    b.matchline_fj = cells * ml_e;
    b.global_wire_fj = (cfg.m * cfg.n) as f64 * calib.e_global_wire;
    b.enable_driver_fj = blocks * calib.e_enable_driver_block;
    b.enable_gate_fj = rows * calib.e_enable_gate_row;
    b
}

/// CAM-side energy of `searches` searches whose accumulated switching
/// activity is `activity` (no CNN components — see
/// [`EnergyModel::proposed_measured`] which adds them per decode).  Enabled
/// rows burn SL+ML; the global broadcast wire burns once per search.
pub fn energy_from_activity(
    cfg: &DesignConfig,
    calib: &CalibrationConstants,
    activity: &SearchActivity,
    searches: usize,
) -> EnergyBreakdown {
    let cells = (activity.enabled_rows * cfg.n) as f64;
    let ml_e = match cfg.ml_kind {
        MatchlineKind::Nor => calib.e_ml_nor,
        MatchlineKind::Nand => calib.e_ml_nand,
    };
    EnergyBreakdown {
        searchline_fj: cells * calib.e_sl_cell,
        matchline_fj: cells * ml_e,
        global_wire_fj: searches as f64 * (cfg.m * cfg.n) as f64 * calib.e_global_wire,
        enable_gate_fj: activity.enabled_rows as f64 * calib.e_enable_gate_row,
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reference() -> DesignConfig {
        DesignConfig::reference()
    }

    #[test]
    fn conventional_nand_reproduces_paper_anchor() {
        let cfg = reference();
        let calib = CalibrationConstants::reference_130nm();
        let b = conventional_search_energy(cfg.m, cfg.n, MatchlineKind::Nand, &calib);
        let per_bit = b.per_bit(cfg.m, cfg.n);
        assert!((per_bit - 1.30).abs() < 1e-9, "got {per_bit}");
    }

    #[test]
    fn conventional_nor_reproduces_paper_anchor() {
        let cfg = reference();
        let calib = CalibrationConstants::reference_130nm();
        let b = conventional_search_energy(cfg.m, cfg.n, MatchlineKind::Nor, &calib);
        let per_bit = b.per_bit(cfg.m, cfg.n);
        assert!((per_bit - 2.39).abs() < 1e-9, "got {per_bit}");
    }

    #[test]
    fn proposed_prediction_lands_near_paper() {
        // Paper: 0.124 fJ/bit/search (9.5 % of Ref. NAND). Our structural
        // prediction must land in the same band without being fitted to it.
        let cfg = reference();
        let calib = CalibrationConstants::reference_130nm();
        let per_bit = proposed_search_energy(&cfg, &calib).per_bit(cfg.m, cfg.n);
        assert!(
            (0.105..0.145).contains(&per_bit),
            "proposed prediction {per_bit} fJ/bit/search out of band"
        );
        let ratio = per_bit / 1.30;
        assert!((0.08..0.11).contains(&ratio), "energy ratio {ratio} out of band");
    }

    #[test]
    fn cnn_share_is_dominated_by_sram_reads() {
        let cfg = reference();
        let calib = CalibrationConstants::reference_130nm();
        let b = cnn_decode_energy(&cfg, &calib);
        assert!(b.sram_read_fj > 0.8 * b.total_fj());
    }

    #[test]
    fn proposed_beats_both_conventionals_at_reference_point() {
        let m = EnergyModel::new(reference());
        let p = m.proposed_expected().total_fj();
        assert!(p < m.conventional(MatchlineKind::Nand).total_fj());
        assert!(p < m.conventional(MatchlineKind::Nor).total_fj());
    }

    #[test]
    fn proposed_degrades_gracefully_with_more_ambiguity() {
        // Fewer reduced-tag bits (smaller q) ⇒ more active blocks ⇒ more energy.
        let calib = CalibrationConstants::reference_130nm();
        let mut prev = 0.0;
        for c in (1..=3).rev() {
            let cfg = DesignConfig { c, ..reference() };
            let e = proposed_search_energy(&cfg, &calib).total_fj();
            assert!(e > prev, "energy must rise as q shrinks: {e} vs {prev}");
            prev = e;
        }
    }

    #[test]
    fn measured_matches_expected_on_exact_activity() {
        // Feed the measured path the exact expected activity — it must agree
        // with the closed form to first order.
        let cfg = reference();
        let model = EnergyModel::new(cfg.clone());
        let blocks = cfg.expected_active_blocks();
        let act = SearchActivity {
            total_blocks: cfg.beta(),
            enabled_blocks: blocks.round() as usize,
            enabled_rows: (blocks * cfg.zeta as f64).round() as usize,
            tag_bits: cfg.n,
            ..Default::default()
        };
        let measured = model.proposed_measured(&act, 1).total_fj();
        let expected = model.proposed_expected().total_fj();
        let rel = (measured - expected).abs() / expected;
        assert!(rel < 0.02, "measured {measured} vs expected {expected}");
    }

    #[test]
    fn energy_scales_to_90nm_like_the_paper() {
        let mut cfg = reference();
        cfg.node = "90nm".into();
        let (m, n) = (cfg.m, cfg.n);
        let model = EnergyModel::new(cfg);
        let per_bit = model.proposed_expected().per_bit(m, n);
        // Paper §IV: 0.060 fJ/bit/search at 90 nm / 1.0 V.
        assert!((0.050..0.070).contains(&per_bit), "got {per_bit}");
    }
}
