//! Network serving throughput: wire-protocol lookups/s through the TCP
//! front-end on loopback — the headline row for the L5 claim that the
//! network layer rides on the sharded fleet's scale-out instead of
//! bottlenecking it (compare against the in-process rows of
//! `coordinator_throughput`).
//!
//! Two scenarios per shard count:
//! * the 8-thread pipelined row (one connection per thread) that predates
//!   the reactor — the apples-to-apples row against the old
//!   thread-per-connection front-end at its connection cap;
//! * a 256-connection multiplexed ramp at the same offered load, which a
//!   thread-per-connection design could not hold at all — the row that
//!   makes the reactor's event-driven claim measurable.
//!
//! Run: `cargo bench --bench net_throughput`
//!
//! Flags (after `--`):
//! * `--quick`        fewer lookups (CI smoke);
//! * `--shards 1,4`   shard counts for the headline rows (default 1,4);
//! * `--conns N`      connection count for the ramp rows (default 256);
//! * `--json PATH`    append the rows (tagged `net`) to a `BENCH_*.json`
//!   trajectory snapshot — the same file the coordinator bench writes to.

use cscam::config::DesignConfig;
use cscam::coordinator::BatchPolicy;
use cscam::net::{CamTcpServer, LoadGen, NetConfig};
use cscam::shard::{PlacementMode, ShardedCamServer};
use cscam::util::bench::{write_bench_json, BenchRecord};
use cscam::util::cli::Args;

fn run_net(shards: usize, lookups: usize, conns: usize) -> anyhow::Result<BenchRecord> {
    let cfg = DesignConfig { shards, ..DesignConfig::reference() };
    cfg.validate()?;
    let fleet = ShardedCamServer::new(&cfg, PlacementMode::TagHash, BatchPolicy::default()).spawn();
    let net = NetConfig { max_connections: conns.max(64), ..NetConfig::default() };
    let server = CamTcpServer::bind(fleet, "127.0.0.1:0", net)?;
    let addr = server.local_addr()?.to_string();
    let handle = server.spawn()?;

    let driver = LoadGen {
        addr,
        threads: 8,
        lookups,
        chunk: 256,
        hit_ratio: 0.9,
        population: cfg.m * 7 / 10,
        rate: 0.0,
        conns,
        seed: 1,
    };
    let report = driver.run().map_err(|e| anyhow::anyhow!("loadgen: {e}"))?;
    let scenario = if conns > 8 { format!("/conns{conns}") } else { String::new() };
    println!(
        "{:<44} {:>10.0} lookups/s  (frame p50 {:>8} ns, p99 {:>9} ns, hit {:.1} %)",
        format!("net/shards={shards}/8t/bulk256{scenario}"),
        report.throughput_lps,
        report.p50_ns,
        report.p99_ns,
        100.0 * report.hit_ratio()
    );

    handle.shutdown();
    handle.join();
    Ok(report.to_record())
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["quick"])?;
    args.check_known(&["quick", "shards", "conns", "json"])?;
    let quick = args.flag("quick");
    let shard_counts: Vec<usize> = args.get_list("shards", vec![1, 4])?;
    let ramp_conns: usize = args.get_parse("conns", 256)?;
    let lookups = if quick { 40_000 } else { 300_000 };

    println!(
        "# net throughput over loopback TCP (reference design, 90 % hit mix{})",
        if quick { ", --quick" } else { "" }
    );
    let mut records = Vec::new();
    for &s in &shard_counts {
        records.push(run_net(s, lookups, 0)?);
        records.push(run_net(s, lookups, ramp_conns)?);
    }

    if let Some(path) = args.get("json") {
        write_bench_json(std::path::Path::new(path), "net", &records)?;
        println!("\nappended {} 'net' rows to {path}", records.len());
    }
    Ok(())
}
