//! Coordinator throughput: lookups/s through the threaded serve loop under
//! varying client concurrency, batch policies and shard counts — the L3/L4
//! claim is that the serving layers never bottleneck the modelled device
//! (see rust/README.md).
//!
//! Run: `cargo bench --bench coordinator_throughput`
//!
//! Flags (after `--`):
//! * `--quick`          headline rows only, fewer lookups (CI smoke);
//! * `--shards 1,4`     shard counts for the headline rows (default 1,4);
//! * `--json PATH`      append the headline rows (tagged `coordinator`) to
//!   a `BENCH_*.json` trajectory snapshot (throughput, p50/p99 latency,
//!   mean λ) so future PRs can diff serving performance against this
//!   baseline; the `net_throughput` bench shares the same file.

use std::time::{Duration, Instant};

use cscam::config::DesignConfig;
use cscam::coordinator::{BatchPolicy, CamServer, DecodeBackend, LookupEngine};
use cscam::shard::{ShardRouter, ShardedCamServer};
use cscam::util::bench::{write_bench_json, BenchRecord};
use cscam::util::cli::Args;
use cscam::util::Rng;
use cscam::workload::{QueryMix, TagDistribution};

fn run_serve(
    name: &str,
    backend: DecodeBackend,
    threads: usize,
    lookups: usize,
    policy: BatchPolicy,
) {
    let cfg = DesignConfig::reference();
    let mut engine = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(1);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &stored {
        engine.insert(t).unwrap();
    }
    let h = CamServer::with_engine(engine, backend, policy).spawn();

    let mix = QueryMix { hit_ratio: 0.9, zipf_s: 0.0 };
    let mut per_thread: Vec<Vec<cscam::bits::BitVec>> = vec![Vec::new(); threads];
    for i in 0..lookups {
        let (tag, _) = mix.sample(&stored, cfg.n, &mut rng);
        per_thread[i % threads].push(tag);
    }

    let t0 = Instant::now();
    let joins: Vec<_> = per_thread
        .into_iter()
        .map(|qs| {
            let h = h.clone();
            std::thread::spawn(move || {
                for t in qs {
                    let _ = h.lookup(t).unwrap();
                }
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed();
    let m = h.metrics().unwrap();
    println!(
        "{:<44} {:>10.0} lookups/s  (batch̄ {:>5.1}, p50 {:>7} ns, p99 {:>8} ns)",
        name,
        lookups as f64 / wall.as_secs_f64(),
        m.batch_size.mean(),
        m.host_latency_ns.quantile(0.5),
        m.host_latency_ns.quantile(0.99),
    );
}

fn run_bulk(name: &str, backend: DecodeBackend, lookups: usize, chunk: usize) {
    let cfg = DesignConfig::reference();
    let mut engine = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(1);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &stored {
        engine.insert(t).unwrap();
    }
    let h = CamServer::with_engine(
        engine,
        backend,
        BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(100) },
    )
    .spawn();
    let mix = QueryMix { hit_ratio: 0.9, zipf_s: 0.0 };
    let batches: Vec<Vec<cscam::bits::BitVec>> = (0..lookups / chunk)
        .map(|_| (0..chunk).map(|_| mix.sample(&stored, cfg.n, &mut rng).0).collect())
        .collect();
    let t0 = Instant::now();
    for b in batches {
        for r in h.lookup_many(b) {
            let _ = r.unwrap();
        }
    }
    let wall = t0.elapsed();
    println!(
        "{:<44} {:>10.0} lookups/s  (bulk chunks of {chunk})",
        name,
        lookups as f64 / wall.as_secs_f64()
    );
}

/// The headline trajectory row: a tag-hash fleet of `shards` banks at the
/// SAME total capacity (reference M = 512 split across the banks), uniform
/// 90 % hit mix, 8 client threads shipping bulk chunks.  1 bank vs 4 banks
/// is the scale-out claim: same stored content, `S×` engine threads.
fn run_sharded(shards: usize, lookups: usize) -> BenchRecord {
    let threads = 8usize;
    let chunk = 256usize;
    let cfg = DesignConfig { shards, ..DesignConfig::reference() };
    let router = ShardRouter::tag_hash(shards);
    let bank_cfg = cfg.per_bank();

    // ~70 % fill with headroom: hash placement is binomial across banks
    let mut rng = Rng::seed_from_u64(1);
    let candidates =
        TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m * 7 / 10, &mut rng);
    let mut banks: Vec<LookupEngine> =
        (0..shards).map(|_| LookupEngine::new(bank_cfg.clone())).collect();
    let mut stored = Vec::new();
    for t in &candidates {
        let b = router.place(t).expect("hash mode");
        if banks[b].insert(t).is_ok() {
            stored.push(t.clone());
        }
    }
    let policy = BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(100) };
    let h = ShardedCamServer::with_banks(banks, router, policy).spawn();

    let mix = QueryMix { hit_ratio: 0.9, zipf_s: 0.0 };
    let mut per_thread: Vec<Vec<Vec<cscam::bits::BitVec>>> = vec![Vec::new(); threads];
    let mut current: Vec<Vec<cscam::bits::BitVec>> = vec![Vec::new(); threads];
    for i in 0..lookups {
        let t = i % threads;
        current[t].push(mix.sample(&stored, cfg.n, &mut rng).0);
        if current[t].len() == chunk {
            per_thread[t].push(std::mem::take(&mut current[t]));
        }
    }
    for (t, rest) in current.into_iter().enumerate() {
        if !rest.is_empty() {
            per_thread[t].push(rest);
        }
    }

    let t0 = Instant::now();
    let joins: Vec<_> = per_thread
        .into_iter()
        .map(|chunks| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut hits = 0usize;
                for c in chunks {
                    for r in h.lookup_many(c) {
                        hits += r.unwrap().addr.is_some() as usize;
                    }
                }
                hits
            })
        })
        .collect();
    let mut hits = 0usize;
    for j in joins {
        hits += j.join().unwrap();
    }
    let wall = t0.elapsed();

    let fm = h.fleet_metrics().unwrap();
    let throughput = lookups as f64 / wall.as_secs_f64();
    println!(
        "{:<44} {:>10.0} lookups/s  (λ̄ {:.3}, p50 {:>7} ns, p99 {:>8} ns, hits {})",
        format!("sharded/banks={shards}/uniform/bulk{chunk}x{threads}t"),
        throughput,
        fm.aggregate.lambda.mean(),
        fm.aggregate.host_latency_ns.quantile(0.5),
        fm.aggregate.host_latency_ns.quantile(0.99),
        hits,
    );

    let mut rec = BenchRecord::new(format!("sharded/banks={shards}/uniform/bulk{chunk}x{threads}t"));
    rec.push("shards", shards as f64);
    rec.push("lookups", lookups as f64);
    rec.push("throughput_lps", throughput);
    rec.push("p50_ns", fm.aggregate.host_latency_ns.quantile(0.5) as f64);
    rec.push("p99_ns", fm.aggregate.host_latency_ns.quantile(0.99) as f64);
    rec.push("mean_lambda", fm.aggregate.lambda.mean());
    rec.push("mean_batch", fm.aggregate.batch_size.mean());
    rec.push("hit_ratio", fm.aggregate.hit_ratio());
    rec
}

fn main() -> anyhow::Result<()> {
    // `cargo bench ... -- FLAGS` forwards FLAGS here (harness = false)
    let args = Args::parse(std::env::args().skip(1), &["quick", "bench"])?;
    args.check_known(&["quick", "bench", "shards", "json"])?;
    let quick = args.flag("quick");
    let shard_counts: Vec<usize> = args.get_list("shards", vec![1, 4])?;
    let lookups = if quick { 60_000 } else { 400_000 };

    println!(
        "# coordinator throughput (reference design, 90 % hit mix{})",
        if quick { ", --quick" } else { "" }
    );
    let mut records = Vec::new();
    for &s in &shard_counts {
        // clean CLI error instead of a deep CamArray assert on bad geometry
        DesignConfig { shards: s, ..DesignConfig::reference() }.validate()?;
        records.push(run_sharded(s, lookups));
    }

    if !quick {
        println!();
        let fast = BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(100) };
        for threads in [1usize, 2, 4, 8, 16] {
            run_serve(
                &format!("native/threads={threads}/max_batch=64"),
                DecodeBackend::Native,
                threads,
                200_000,
                fast,
            );
        }
        println!();
        for max_batch in [1usize, 8, 64, 256] {
            run_serve(
                &format!("native/threads=8/max_batch={max_batch}"),
                DecodeBackend::Native,
                8,
                200_000,
                BatchPolicy { max_batch, max_wait: Duration::from_micros(100) },
            );
        }

        println!();
        run_bulk("native/bulk=256", DecodeBackend::Native, 500_000, 256);
        run_bulk("native/bulk=4096", DecodeBackend::Native, 500_000, 4096);

        pjrt_rows(fast);
    }

    if let Some(path) = args.get("json") {
        write_bench_json(std::path::Path::new(path), "coordinator", &records)?;
        println!("\nappended {} 'coordinator' trajectory rows to {path}", records.len());
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_rows(fast: BatchPolicy) {
    use cscam::runtime::{artifacts_available, default_artifact_dir, ArtifactStore};

    if !artifacts_available() {
        println!("(skipping pjrt rows: run `make artifacts`)");
        return;
    }
    println!();
    for threads in [4usize, 16] {
        let store = ArtifactStore::load(&default_artifact_dir()).expect("artifacts");
        run_serve(
            &format!("pjrt/threads={threads}/max_batch=64"),
            DecodeBackend::pjrt(store),
            threads,
            20_000,
            fast,
        );
    }
    let store = ArtifactStore::load(&default_artifact_dir()).expect("artifacts");
    run_bulk("pjrt/bulk=64", DecodeBackend::pjrt(store), 50_000, 64);
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_rows(_fast: BatchPolicy) {
    println!("(skipping pjrt rows: built without the `pjrt` feature)");
}
