//! Calibration contract (see `cscam::energy::calib`): the two *conventional* designs are
//! the fitted anchors; everything else — the proposed design, the headline
//! ratios, the 90 nm projection, the Table I selection — must come out of
//! the model as *predictions* within the reproduction bands.

use cscam::cam::MatchlineKind;
use cscam::config::DesignConfig;
use cscam::energy::{conventional_search_energy, proposed_search_energy, CalibrationConstants};
use cscam::sweep::{select_design, SweepConstraints};
use cscam::tech::{self, NODE_130NM, NODE_90NM};
use cscam::timing::{conventional_delay, proposed_delay, scaled_delay, DelayConstants};
use cscam::transistor::{overhead_vs_nand, TransistorAssumptions};

fn cfg() -> DesignConfig {
    DesignConfig::reference()
}

#[test]
fn anchor_energy_ref_nand() {
    // Table II anchor: 1.30 fJ/bit/search.
    let e = conventional_search_energy(
        512,
        128,
        MatchlineKind::Nand,
        &CalibrationConstants::reference_130nm(),
    );
    assert!((e.per_bit(512, 128) - 1.30).abs() < 1e-9);
}

#[test]
fn anchor_energy_ref_nor() {
    // Table II anchor: 2.39 fJ/bit/search.
    let e = conventional_search_energy(
        512,
        128,
        MatchlineKind::Nor,
        &CalibrationConstants::reference_130nm(),
    );
    assert!((e.per_bit(512, 128) - 2.39).abs() < 1e-9);
}

#[test]
fn anchor_delay_ref_nand_and_nor() {
    // Table II anchors: 2.30 ns (NAND), 0.55 ns (NOR).
    let k = DelayConstants::reference();
    let nand = conventional_delay(512, 128, MatchlineKind::Nand, &k, NODE_130NM);
    let nor = conventional_delay(512, 128, MatchlineKind::Nor, &k, NODE_130NM);
    assert!((nand.cycle_ns - 2.30).abs() < 0.12, "NAND {}", nand.cycle_ns);
    assert!((nor.cycle_ns - 0.55).abs() < 0.05, "NOR {}", nor.cycle_ns);
}

#[test]
fn prediction_proposed_energy_and_headline_ratio() {
    // Paper: 0.124 fJ/bit/search = 9.5 % of Ref. NAND. Prediction band ±15 %.
    let e = proposed_search_energy(&cfg(), &CalibrationConstants::reference_130nm());
    let per_bit = e.per_bit(512, 128);
    assert!((per_bit - 0.124).abs() / 0.124 < 0.15, "per_bit {per_bit}");
    let ratio = per_bit / 1.30;
    assert!((ratio - 0.095).abs() < 0.02, "ratio {ratio}");
}

#[test]
fn prediction_proposed_delay_and_headline_ratio() {
    // Paper: 0.70 ns = 30.4 % of Ref. NAND.
    let k = DelayConstants::reference();
    let d = proposed_delay(&cfg(), &k);
    assert!((d.cycle_ns - 0.70).abs() / 0.70 < 0.10, "cycle {}", d.cycle_ns);
    let nand = conventional_delay(512, 128, MatchlineKind::Nand, &k, NODE_130NM);
    let ratio = d.cycle_ns / nand.cycle_ns;
    assert!((ratio - 0.304).abs() < 0.05, "ratio {ratio}");
}

#[test]
fn prediction_transistor_overhead() {
    // Paper: +3.4 %.  Structural model lands in the small-single-digit band
    // (the peripheral-sizing caveat is documented in `transistor`).
    let ovh = overhead_vs_nand(&cfg(), &TransistorAssumptions::default());
    assert!((0.01..0.06).contains(&ovh), "overhead {ovh}");
}

#[test]
fn prediction_90nm_projection() {
    // Paper §IV: 0.060 fJ/bit/search and 0.582 ns at 90 nm / 1.0 V.
    let calib = CalibrationConstants::reference_130nm();
    let k = DelayConstants::reference();
    let e130 = proposed_search_energy(&cfg(), &calib).per_bit(512, 128);
    let e90 = tech::scale_energy(e130, NODE_130NM, NODE_90NM);
    assert!((e90 - 0.060).abs() / 0.060 < 0.15, "e90 {e90}");
    let d90 = scaled_delay(proposed_delay(&cfg(), &k), NODE_130NM, NODE_90NM);
    assert!((d90.cycle_ns - 0.582).abs() / 0.582 < 0.10, "d90 {}", d90.cycle_ns);
}

#[test]
fn prediction_table1_design_point_selected() {
    // Table I reproduces from the constrained design-space sweep.
    let best = select_design(512, 128, &SweepConstraints::default()).unwrap();
    assert_eq!((best.cfg.c, best.cfg.l, best.cfg.zeta), (3, 8, 8));
}

#[test]
fn who_wins_ordering_holds_at_common_node() {
    // Table II's qualitative conclusion at 0.13 µm: proposed < NAND < NOR
    // on energy; NOR < proposed < NAND on delay.
    let calib = CalibrationConstants::reference_130nm();
    let k = DelayConstants::reference();
    let e_prop = proposed_search_energy(&cfg(), &calib).per_bit(512, 128);
    assert!(e_prop < 1.30 && 1.30 < 2.39);
    let d_prop = proposed_delay(&cfg(), &k).cycle_ns;
    let d_nand = conventional_delay(512, 128, MatchlineKind::Nand, &k, NODE_130NM).cycle_ns;
    let d_nor = conventional_delay(512, 128, MatchlineKind::Nor, &k, NODE_130NM).cycle_ns;
    assert!(d_nor < d_prop && d_prop < d_nand);
}

#[test]
fn energy_scaling_is_monotone_down_the_node_ladder() {
    let calib = CalibrationConstants::reference_130nm();
    let base = proposed_search_energy(&cfg(), &calib).per_bit(512, 128);
    let mut prev = f64::INFINITY;
    for node in [tech::NODE_180NM, NODE_130NM, NODE_90NM, tech::NODE_65NM, tech::NODE_32NM] {
        let e = tech::scale_energy(base, NODE_130NM, node);
        assert!(e < prev, "{}: {e}", node.name);
        prev = e;
    }
}
