//! The concurrent read path's correctness battery.
//!
//! Two properties anchor the refactor:
//!
//! 1. **Equivalence** — lookups served concurrently (reader pools, direct
//!    reads, the net reactor's worker pool) are *bit-identical* — matched
//!    address, all matches, λ, enabled blocks, comparisons, the full
//!    energy breakdown and the delay report — to the single-threaded
//!    reference engine, across hash/broadcast/learned placements.
//! 2. **Linearizability** — with N reader threads hammering lookups while
//!    a single writer inserts and deletes, every observed outcome equals
//!    the outcome of the same probe on *some prefix* of the mutation
//!    history replayed on a reference engine (the seeded-history pattern
//!    of `tests/durability.rs`, pointed at concurrency instead of crash
//!    recovery).  Readers may be stale by in-flight mutations, but can
//!    never observe a torn or un-acknowledged state.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use cscam::bits::BitVec;
use cscam::config::DesignConfig;
use cscam::coordinator::{
    BatchPolicy, CamServer, DecodeBackend, DecodeScratch, LookupEngine, LookupOutcome,
};
use cscam::net::{CamClient, CamTcpServer, NetConfig};
use cscam::shard::{PlacementMode, ShardedCam, ShardedCamServer};
use cscam::util::Rng;
use cscam::workload::TagDistribution;

fn fleet_cfg() -> DesignConfig {
    // 4 banks × 64 entries = one 256-entry fleet
    DesignConfig { m: 256, n: 32, zeta: 4, c: 3, l: 4, shards: 4, ..DesignConfig::reference() }
}

fn policy() -> BatchPolicy {
    BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(100) }
}

fn placement_for(kind: &str, shards: usize, sample: &[BitVec], n: usize) -> PlacementMode {
    match kind {
        "hash" => PlacementMode::TagHash,
        "broadcast" => PlacementMode::Broadcast,
        "prefix" => PlacementMode::learned(shards, sample, n),
        other => panic!("unknown placement {other}"),
    }
}

/// Equivalence across every read path and every placement mode: the
/// threaded fleet (reader pools), direct reads, and the wire must answer
/// exactly what the synchronous single-threaded `ShardedCam` answers.
#[test]
fn concurrent_reads_are_bit_identical_across_placements_and_the_wire() {
    for kind in ["hash", "broadcast", "prefix"] {
        let cfg = fleet_cfg();
        let mut rng = Rng::seed_from_u64(301);
        let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 120, &mut rng);
        let mode = placement_for(kind, cfg.shards, &tags, cfg.n);

        // reference: the synchronous fleet, no threads anywhere
        let mut reference = ShardedCam::new(&cfg, mode.clone());
        // the system under test: reader pools per bank + a TCP front-end
        let fleet =
            ShardedCamServer::new(&cfg, mode, policy()).with_readers(2).spawn();
        let server =
            CamTcpServer::bind(fleet.clone(), "127.0.0.1:0", NetConfig::default()).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let net = server.spawn().unwrap();
        let mut client = CamClient::connect(addr).unwrap();

        let mut stored = Vec::new();
        for t in &tags {
            match (fleet.insert(t.clone()), reference.insert(t)) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a, b, "{kind}: placement diverged");
                    stored.push((t.clone(), a));
                }
                (Err(e1), Err(e2)) => assert_eq!(e1, e2, "{kind}: divergent insert errors"),
                (a, b) => panic!("{kind}: insert divergence {a:?} vs {b:?}"),
            }
        }
        for (_, g) in stored.iter().take(10) {
            fleet.delete(*g).unwrap();
            reference.delete(*g).unwrap();
        }

        let mut probes: Vec<BitVec> = stored.iter().map(|(t, _)| t.clone()).collect();
        probes.extend(TagDistribution::Uniform.sample_distinct(cfg.n, 40, &mut rng));
        let expected: Vec<_> = probes.iter().map(|t| reference.lookup(t).unwrap()).collect();

        // (a) reader-pool path, hammered from several client threads
        let mut joins = Vec::new();
        for _ in 0..4 {
            let fleet = fleet.clone();
            let probes = probes.clone();
            let expected = expected.clone();
            joins.push(std::thread::spawn(move || {
                for (t, want) in probes.iter().zip(&expected) {
                    assert_eq!(&fleet.lookup(t.clone()).unwrap(), want, "pool path diverged");
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }

        // (b) bulk via the pool fan-out, order preserved
        let bulk = fleet.lookup_many(probes.clone());
        for (r, want) in bulk.iter().zip(&expected) {
            assert_eq!(r.as_ref().unwrap(), want, "{kind}: bulk pool path diverged");
        }

        // (c) direct reads (the conn-thread path), own scratch
        let mut scratch = DecodeScratch::new();
        for (t, want) in probes.iter().zip(&expected) {
            assert_eq!(
                &fleet.lookup_direct(t, &mut scratch).unwrap(),
                want,
                "{kind}: direct path diverged"
            );
        }

        // (d) over TCP, single and pipelined bulk
        for (t, want) in probes.iter().zip(&expected) {
            assert_eq!(&client.lookup(t).unwrap(), want, "{kind}: wire path diverged");
        }
        let wire_bulk = client.lookup_bulk(&probes, 32).unwrap();
        for (r, want) in wire_bulk.iter().zip(&expected) {
            assert_eq!(r.as_ref().unwrap(), want, "{kind}: wire bulk diverged");
        }

        client.shutdown().unwrap();
        net.join();
    }
}

/// One step of a seeded mutation history (the durability harness's
/// insert/delete pattern, recorded as explicit ops so the same history can
/// be replayed on a reference engine prefix by prefix).
#[derive(Debug, Clone)]
enum Op {
    Insert(BitVec),
    Delete(usize),
}

/// Generate a seeded insert/delete history for one bank, mirroring
/// `tests/durability.rs::seeded_history`: ~70 % inserts from a distinct
/// pool, deletes pick a random live address.
fn seeded_ops(cfg: &DesignConfig, seed: u64, count: usize) -> Vec<Op> {
    let mut rng = Rng::seed_from_u64(seed);
    let pool = TagDistribution::Uniform.sample_distinct(cfg.n, count, &mut rng);
    let mut shadow = LookupEngine::new(cfg.clone());
    let mut live: Vec<usize> = Vec::new();
    let mut next = 0usize;
    let mut ops = Vec::new();
    for _ in 0..count {
        let do_insert = live.is_empty() || rng.gen_bool(0.7);
        if do_insert && next < pool.len() {
            let t = pool[next].clone();
            next += 1;
            if let Ok(a) = shadow.insert(&t) {
                live.push(a);
                ops.push(Op::Insert(t));
            }
        } else if !live.is_empty() {
            let victim = live.swap_remove(rng.gen_range(live.len()));
            shadow.delete(victim).unwrap();
            ops.push(Op::Delete(victim));
        }
    }
    ops
}

/// Linearizability under a concurrent writer: every outcome a reader
/// observes — through the pool or through direct reads — must equal the
/// probe's outcome at SOME prefix of the mutation history (replayed on a
/// reference engine), field for field.  A torn state, a lost publish or a
/// read of un-acked state would produce an outcome outside every prefix.
#[test]
fn concurrent_readers_observe_only_prefixes_of_the_mutation_history() {
    let cfg = DesignConfig::small_test();
    let ops = seeded_ops(&cfg, 71, 80);

    // probe set: tags that get inserted (and some deleted) mid-history,
    // plus two never-inserted tags (must always miss, at every prefix)
    let mut probes: Vec<BitVec> = ops
        .iter()
        .filter_map(|op| match op {
            Op::Insert(t) => Some(t.clone()),
            Op::Delete(_) => None,
        })
        .take(8)
        .collect();
    let mut rng = Rng::seed_from_u64(72);
    probes.push(cscam::workload::random_tag(cfg.n, &mut rng));
    probes.push(cscam::workload::random_tag(cfg.n, &mut rng));

    // allowed[p] = the probe's outcomes after 0, 1, …, H mutations
    // (deduplicated consecutively), plus the expected insert addresses —
    // one prefix-by-prefix replay on a reference engine
    let mut allowed: Vec<Vec<LookupOutcome>> = vec![Vec::new(); probes.len()];
    let record = |engine: &mut LookupEngine, allowed: &mut Vec<Vec<LookupOutcome>>| {
        for (p, t) in probes.iter().enumerate() {
            let out = engine.lookup(t).unwrap();
            if allowed[p].last() != Some(&out) {
                allowed[p].push(out);
            }
        }
    };
    let mut prefix_engine = LookupEngine::new(cfg.clone());
    record(&mut prefix_engine, &mut allowed);
    let mut expected_addrs = Vec::new();
    for op in &ops {
        match op {
            Op::Insert(t) => expected_addrs.push(Some(prefix_engine.insert(t).unwrap())),
            Op::Delete(a) => {
                prefix_engine.delete(*a).unwrap();
                expected_addrs.push(None);
            }
        }
        record(&mut prefix_engine, &mut allowed);
    }
    let allowed = Arc::new(allowed);
    let probes = Arc::new(probes);

    // the live system: one writer (this thread, through the handle),
    // 3 pool readers + 4 hammering client threads (pool and direct mixed)
    let h = CamServer::new(cfg, DecodeBackend::Native, policy()).with_readers(3).spawn();
    let done = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for reader in 0..4 {
        let h = h.clone();
        let done = Arc::clone(&done);
        let allowed = Arc::clone(&allowed);
        let probes = Arc::clone(&probes);
        joins.push(std::thread::spawn(move || {
            let mut scratch = DecodeScratch::new();
            let mut observed = 0usize;
            loop {
                for (p, t) in probes.iter().enumerate() {
                    let out = if reader % 2 == 0 {
                        h.lookup(t.clone()).unwrap()
                    } else {
                        h.lookup_direct(t, &mut scratch).unwrap()
                    };
                    assert!(
                        allowed[p].contains(&out),
                        "reader {reader} observed an outcome outside every \
                         history prefix for probe {p}: {out:?}"
                    );
                    observed += 1;
                }
                // check after the sweep: every reader completes at least
                // one full pass, and the post-`done` pass still only sees
                // the final prefix
                if done.load(Ordering::Acquire) {
                    return observed;
                }
            }
        }));
    }

    for (op, want) in ops.iter().zip(&expected_addrs) {
        match op {
            Op::Insert(t) => {
                let got = h.insert(t.clone()).unwrap();
                assert_eq!(Some(got), *want, "writer placement diverged from the reference");
            }
            Op::Delete(a) => h.delete(*a).unwrap(),
        }
    }
    done.store(true, Ordering::Release);
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert!(total >= probes.len(), "readers must have observed at least one sweep");

    // quiescent: every reader now sees exactly the final prefix
    let final_outcomes = allowed.iter().map(|a| a.last().unwrap().clone());
    let mut scratch = DecodeScratch::new();
    for (t, want) in probes.iter().zip(final_outcomes) {
        assert_eq!(h.lookup_direct(t, &mut scratch).unwrap(), want);
        assert_eq!(h.lookup(t.clone()).unwrap(), want);
    }
}

/// Read-your-writes over the wire while other connections hammer reads:
/// after an acknowledged insert (or delete), every connection observes it.
#[test]
fn acked_mutations_are_visible_to_every_connection() {
    let cfg = fleet_cfg();
    let fleet = ShardedCamServer::new(&cfg, PlacementMode::TagHash, policy())
        .with_readers(2)
        .spawn();
    let server =
        CamTcpServer::bind(fleet.clone(), "127.0.0.1:0", NetConfig::default()).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let net = server.spawn().unwrap();

    let mut writer = CamClient::connect(addr.clone()).unwrap();
    let mut observer = CamClient::connect(addr).unwrap();
    let mut rng = Rng::seed_from_u64(303);
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 30, &mut rng);
    for t in &tags {
        let g = writer.insert(t).unwrap();
        // a *different* connection — a different thread, a different
        // scratch — sees the acked insert immediately
        assert_eq!(observer.lookup(t).unwrap().addr, Some(g as usize));
        // and so does the in-process pool path
        assert_eq!(fleet.lookup(t.clone()).unwrap().addr, Some(g as usize));
    }
    let victim = writer.lookup(&tags[0]).unwrap().addr.unwrap();
    writer.delete(victim as u64).unwrap();
    assert_eq!(observer.lookup(&tags[0]).unwrap().addr, None, "acked delete visible");

    writer.shutdown().unwrap();
    net.join();
}
