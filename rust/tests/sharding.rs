//! Sharded-fleet properties: the scatter-gather path must be functionally
//! indistinguishable from one monolithic CAM of the same total M, and the
//! serving layer must surface hot-shard skew in its fleet metrics.

use std::collections::HashMap;

use cscam::bits::BitVec;
use cscam::cam::CamArray;
use cscam::config::DesignConfig;
use cscam::coordinator::BatchPolicy;
use cscam::shard::{PlacementMode, ShardedCam, ShardedCamServer};
use cscam::util::Rng;
use cscam::workload::{HotShardMix, QueryMix, TagDistribution};

fn fleet_cfg() -> DesignConfig {
    // 4 banks × 64 entries = one 256-entry monolith
    DesignConfig { m: 256, n: 32, zeta: 4, c: 3, l: 4, shards: 4, ..DesignConfig::reference() }
}

/// The property: insert a population through the sharded router, mirror
/// each entry into a single `CamArray` of the same total M at the sharded
/// flat address, then fire 10 000 mixed (hit/miss) lookups and require
/// bit-for-bit agreement — identical match sets AND identical summed
/// `SearchActivity` on the raw path, identical answers on the classified
/// path.
fn sharded_matches_monolith(
    dist: TagDistribution,
    seed: u64,
    mode_for: impl Fn(&[BitVec]) -> PlacementMode,
) {
    let cfg = fleet_cfg();
    let mut rng = Rng::seed_from_u64(seed);
    let tags = dist.sample_distinct(cfg.n, 160, &mut rng);

    let mut sharded = ShardedCam::new(&cfg, mode_for(&tags));
    let mut mono = CamArray::new(cfg.m, cfg.n, cfg.zeta);
    let mut addr_of: HashMap<BitVec, usize> = HashMap::new();
    let mut stored = Vec::new();
    for t in &tags {
        let g = sharded.insert(t).expect("bank overflow: pick a friendlier seed");
        mono.write(g, t.clone());
        addr_of.insert(t.clone(), g);
        stored.push(t.clone());
    }
    assert_eq!(sharded.occupancy(), mono.occupancy());

    let mix = QueryMix { hit_ratio: 0.7, zipf_s: 0.0 };
    let mut hits = 0usize;
    for _ in 0..10_000 {
        let (q, _) = mix.sample(&stored, cfg.n, &mut rng);
        // raw scatter-gather ≡ monolithic full search, bit for bit
        let sh = sharded.search_unclassified(&q);
        let mo = mono.search_all(&q);
        assert_eq!(sh.matches, mo.matches, "match sets diverged");
        assert_eq!(sh.activity, mo.activity, "summed activity diverged");
        // classified (CNN-gated) lookup agrees on the answer
        let out = sharded.lookup(&q).unwrap();
        assert_eq!(out.addr, mo.matches.first().copied());
        assert_eq!(out.all_matches, mo.matches);
        if let Some(g) = out.addr {
            assert_eq!(addr_of.get(&q), Some(&g), "hit resolved to the wrong entry");
            hits += 1;
        }
    }
    assert!((6_500..7_500).contains(&hits), "hit mix off: {hits}");
}

#[test]
fn sharded_equals_monolith_uniform_tag_hash() {
    sharded_matches_monolith(TagDistribution::Uniform, 101, |_| PlacementMode::TagHash);
}

#[test]
fn sharded_equals_monolith_uniform_broadcast() {
    sharded_matches_monolith(TagDistribution::Uniform, 102, |_| PlacementMode::Broadcast);
}

#[test]
fn sharded_equals_monolith_correlated_tag_hash() {
    sharded_matches_monolith(
        TagDistribution::Correlated { fixed_bits: 8, mirror_span: 8 },
        103,
        |_| PlacementMode::TagHash,
    );
}

#[test]
fn sharded_equals_monolith_correlated_learned_prefix() {
    sharded_matches_monolith(
        TagDistribution::Correlated { fixed_bits: 8, mirror_span: 8 },
        104,
        |sample| PlacementMode::learned(4, sample, 32),
    );
}

#[test]
fn deletes_preserve_the_equivalence() {
    let cfg = fleet_cfg();
    let mut rng = Rng::seed_from_u64(105);
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 120, &mut rng);
    let mut sharded = ShardedCam::new(&cfg, PlacementMode::TagHash);
    let mut mono = CamArray::new(cfg.m, cfg.n, cfg.zeta);
    let mut addrs = Vec::new();
    for t in &tags {
        let g = sharded.insert(t).unwrap();
        mono.write(g, t.clone());
        addrs.push(g);
    }
    for i in (0..tags.len()).step_by(3) {
        sharded.delete(addrs[i]).unwrap();
        mono.erase(addrs[i]);
    }
    for t in &tags {
        let sh = sharded.search_unclassified(t);
        let mo = mono.search_all(t);
        assert_eq!(sh.matches, mo.matches);
        assert_eq!(sh.activity, mo.activity);
        assert_eq!(sharded.lookup(t).unwrap().addr, mo.matches.first().copied());
    }
}

#[test]
fn hot_shard_workload_shows_up_in_fleet_metrics() {
    // The rebalance-relevant scenario: a Zipf-backed hot-shard stream
    // saturates one bank while the fleet view stays balanced-looking only
    // in aggregate.
    let cfg = fleet_cfg();
    let h = ShardedCamServer::new(&cfg, PlacementMode::TagHash, BatchPolicy::default()).spawn();
    let mut rng = Rng::seed_from_u64(106);
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 160, &mut rng);
    let mut stored = Vec::new();
    for t in &tags {
        if h.insert(t.clone()).is_ok() {
            stored.push(t.clone());
        }
    }
    let by_bank = h.router().partition(&stored);
    let hot = (0..4).max_by_key(|&b| by_bank[b].len()).unwrap();
    let mix = HotShardMix { hot_bank: hot, hot_fraction: 0.9, hit_ratio: 1.0 };
    for _ in 0..2_000 {
        let (q, _) = mix.sample(&by_bank, cfg.n, &mut rng);
        assert!(h.lookup(q).unwrap().addr.is_some());
    }
    let fm = h.fleet_metrics().unwrap();
    assert_eq!(fm.aggregate.lookups, 2_000);
    assert_eq!(fm.hottest_bank(), hot);
    assert!(fm.hot_fraction() > 0.8, "hot bank fraction {}", fm.hot_fraction());
}
