//! Design-space exploration — how Table I was chosen (§III: "a set of
//! design points were selected among 15 different parameter sets with the
//! common goal of discovering the minimum energy consumption per search,
//! while keeping the silicon area overhead and the delay reasonable").
//!
//! Evaluates the full candidate space with the energy / delay / transistor
//! models, shows the constrained winner (the Table I point), and then
//! relaxes each constraint in turn to show *why* the constraints matter.
//!
//! Run: `cargo run --release --example design_space_sweep`

use cscam::sweep::{run_sweep, select_design, SweepConstraints};

fn print_table(m: usize, n: usize, constraints: &SweepConstraints) {
    println!(
        "{:<3} {:<3} {:<4} {:<3} {:<4} {:>15} {:>10} {:>9} {:>8} {:>9}",
        "c", "l", "ζ", "q", "β", "E [fJ/bit/srch]", "cycle[ns]", "overhead", "E[cmp]", "feasible"
    );
    for p in run_sweep(m, n, constraints) {
        println!(
            "{:<3} {:<3} {:<4} {:<3} {:<4} {:>15.4} {:>10.3} {:>8.1}% {:>8.2} {:>9}",
            p.cfg.c,
            p.cfg.l,
            p.cfg.zeta,
            p.cfg.q(),
            p.cfg.beta(),
            p.energy_fj_bit,
            p.cycle_ns,
            100.0 * p.overhead,
            p.comparisons,
            if p.feasible { "yes" } else { "no" }
        );
    }
}

fn main() {
    let (m, n) = (512, 128);
    let base = SweepConstraints::default();

    println!("# design-space exploration, M={m} N={n}");
    println!(
        "# constraints: cycle ≤ {} ns, overhead ≤ {:.0} %, β ≤ {}\n",
        base.max_cycle_ns,
        100.0 * base.max_overhead,
        base.max_blocks
    );
    print_table(m, n, &base);
    let best = select_design(m, n, &base).expect("feasible design");
    println!(
        "\nwinner: c={} l={} ζ={} (q={}, β={}) — Table I's point",
        best.cfg.c,
        best.cfg.l,
        best.cfg.zeta,
        best.cfg.q(),
        best.cfg.beta()
    );

    // Ablate each constraint to show what it guards against.
    println!("\n# constraint ablations");
    let no_wiring = SweepConstraints { max_blocks: usize::MAX, ..base };
    let w = select_design(m, n, &no_wiring).unwrap();
    println!(
        "without the β ≤ {} wiring budget  → c={} l={} ζ={} ({:.4} fJ/bit/search): finer blocks win on paper but cost enable-line routing",
        base.max_blocks, w.cfg.c, w.cfg.l, w.cfg.zeta, w.energy_fj_bit
    );
    let no_area = SweepConstraints { max_overhead: f64::INFINITY, max_blocks: 64, ..base };
    let a = select_design(m, n, &no_area).unwrap();
    println!(
        "without the area budget           → c={} l={} ζ={} ({:.4} fJ/bit/search, +{:.1} % transistors): a fatter CNN SRAM buys fewer ambiguities",
        a.cfg.c, a.cfg.l, a.cfg.zeta, a.energy_fj_bit, 100.0 * a.overhead
    );

    // The ζ ablation at fixed (c, l): comparisons vs interconnect trade-off.
    println!("\n# ζ ablation at c=3, l=8 (q=9)");
    println!("{:>5} {:>6} {:>10} {:>15}", "ζ", "β", "E[cmp]", "E [fJ/bit/srch]");
    for zeta in [1usize, 2, 4, 8, 16, 32, 64] {
        let cfg = cscam::config::DesignConfig { zeta, ..cscam::config::DesignConfig::reference() };
        let p = cscam::sweep::evaluate(&cfg, &base);
        println!(
            "{:>5} {:>6} {:>10.2} {:>15.4}",
            zeta,
            cfg.beta(),
            p.comparisons,
            p.energy_fj_bit
        );
    }
    println!("\nζ=8 is where the comparison count stops paying for the extra enable wiring —");
    println!("§III-B criteria 1 and 2 in one column.");
}
