//! Dynamic-energy model — the SPECTRE substitute.
//!
//! All energies are *switched-capacitance* dynamic energies, `E = α·C·V²`,
//! expressed directly in femtojoules at the reference node (0.13 µm, 1.2 V)
//! and rescaled to other nodes with [`crate::tech::scale_energy`].
//!
//! Calibration contract: the four CAM-cell primitives are fitted **once**
//! so that the two *conventional* reference designs reproduce the paper's
//! SPECTRE measurements (Table II: Ref. NAND = 1.30 fJ/bit/search, Ref. NOR
//! = 2.39 fJ/bit/search at 512×128).  Every other number this module
//! produces — the proposed design, all sweeps, all ablations, all other
//! nodes — is a *prediction* of the same structural model.  The headline
//! 9.5 % energy ratio is an output, not an input.

pub mod breakdown;
pub mod calib;
pub mod model;

pub use breakdown::{EnergyBreakdown, SearchActivity};
pub use calib::CalibrationConstants;
pub use model::{
    cnn_decode_energy, conventional_search_energy, energy_from_activity, proposed_search_energy,
    EnergyModel,
};
