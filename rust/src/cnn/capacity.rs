//! Weight-density and churn analysis — the Gripon–Berrou capacity theory
//! ([8], [9]) applied to this CAM's operating regime.
//!
//! In the classifier, P_II neuron `j` holds exactly `c` weights while entry
//! `j` is live.  Two effects make extra weights accumulate:
//!
//! 1. **address reuse** — rewriting a CAM slot trains new weights on the
//!    same neuron without clearing the old ones (superposition);
//! 2. **deletes without retrain** — the coordinator invalidates the CAM row
//!    but leaves the weights (correct, per §I, but they keep firing).
//!
//! Weight density `d` (fraction of the l·M possible connections per cluster
//! that are set) drives the false-activation probability of a *dead*
//! neuron: `P(fire) = d^c` for a uniform random query, so the expected
//! extra enabled blocks grow as `M_stale · d^c / ζ`-ish.  This module gives
//! the closed forms and a Monte-Carlo churn simulator used to pick the
//! coordinator's retrain threshold (`LookupEngine::retrain_threshold`).

use crate::config::DesignConfig;
use crate::coordinator::LookupEngine;
use crate::util::Rng;
use crate::workload::TagDistribution;

/// Per-cluster weight density after `t` trainings of one neuron with
/// uniform cluster indices: `1 − (1 − 1/l)^t`.
pub fn weight_density(l: usize, trainings: usize) -> f64 {
    1.0 - (1.0 - 1.0 / l as f64).powi(trainings as i32)
}

/// Probability a neuron trained `t` times fires on a uniform random query:
/// each cluster independently hits one of its set weights.
pub fn fire_probability(c: usize, l: usize, trainings: usize) -> f64 {
    weight_density(l, trainings).powi(c as i32)
}

/// Expected λ for a random (non-stored) query against a network whose every
/// neuron was trained `t` times (churned network).
pub fn expected_lambda_churned(cfg: &DesignConfig, trainings: usize) -> f64 {
    cfg.m as f64 * fire_probability(cfg.c, cfg.l, trainings)
}

/// Measured churn outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnReport {
    /// Replacements applied per slot on average.
    pub rewrites_per_slot: f64,
    /// Mean λ on stored-tag queries after churn.
    pub mean_lambda: f64,
    /// Mean enabled blocks after churn.
    pub mean_blocks: f64,
    /// Same engine immediately after a retrain.
    pub mean_blocks_after_retrain: f64,
}

/// Monte-Carlo churn: fill the engine, then apply `rewrites` random
/// replacements with retraining disabled, and measure the enable bloat a
/// retrain removes.
pub fn simulate_churn(cfg: &DesignConfig, rewrites: usize, seed: u64) -> ChurnReport {
    let mut rng = Rng::seed_from_u64(seed);
    let mut engine = LookupEngine::new(cfg.clone());
    engine.retrain_threshold = 0.0; // manual control
    let mut tags = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &tags {
        engine.insert(t).unwrap();
    }
    for _ in 0..rewrites {
        let slot = rng.gen_range(cfg.m);
        let fresh = crate::workload::random_tag(cfg.n, &mut rng);
        engine.insert_at(slot, &fresh).unwrap();
        tags[slot] = fresh;
    }
    let probe = |engine: &mut LookupEngine, rng: &mut Rng| {
        let (mut lam, mut blk) = (0.0, 0.0);
        let samples = 512.min(cfg.m);
        for _ in 0..samples {
            let out = engine.lookup(&tags[rng.gen_range(cfg.m)]).unwrap();
            lam += out.lambda as f64;
            blk += out.enabled_blocks as f64;
        }
        (lam / samples as f64, blk / samples as f64)
    };
    let (mean_lambda, mean_blocks) = probe(&mut engine, &mut rng);
    engine.retrain();
    let (_, mean_blocks_after_retrain) = probe(&mut engine, &mut rng);
    ChurnReport {
        rewrites_per_slot: rewrites as f64 / cfg.m as f64,
        mean_lambda,
        mean_blocks,
        mean_blocks_after_retrain,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_closed_form() {
        assert_eq!(weight_density(8, 0), 0.0);
        assert!((weight_density(8, 1) - 0.125).abs() < 1e-12);
        assert!(weight_density(8, 100) > 0.999_99);
        // monotone in trainings
        assert!(weight_density(8, 5) < weight_density(8, 10));
    }

    #[test]
    fn fire_probability_drops_with_more_clusters() {
        // more clusters = more independent AND terms (the sparse-network
        // robustness of [8])
        assert!(fire_probability(4, 8, 3) < fire_probability(2, 8, 3));
        assert!(fire_probability(3, 8, 1) < 0.01);
    }

    #[test]
    fn churn_bloats_enables_and_retrain_recovers() {
        let cfg = DesignConfig::small_test();
        let r = simulate_churn(&cfg, 2 * cfg.m, 3);
        assert!(
            r.mean_blocks > r.mean_blocks_after_retrain,
            "churned {} vs retrained {}",
            r.mean_blocks,
            r.mean_blocks_after_retrain
        );
        assert!(r.mean_lambda >= 1.0, "stored tags must still activate");
    }

    #[test]
    fn churned_lambda_tracks_theory_order_of_magnitude() {
        // After ~2 rewrites/slot every neuron has been trained ~3 times on
        // average; predicted extra activations for the small config:
        let cfg = DesignConfig::small_test();
        let r = simulate_churn(&cfg, 2 * cfg.m, 9);
        let predicted_extra = expected_lambda_churned(&cfg, 3);
        // loose band: same order of magnitude
        assert!(
            r.mean_lambda - 1.0 < 10.0 * (predicted_extra + 1.0),
            "measured extra {} vs predicted {}",
            r.mean_lambda - 1.0,
            predicted_extra
        );
    }

    #[test]
    fn no_churn_means_no_bloat() {
        let cfg = DesignConfig::small_test();
        let r = simulate_churn(&cfg, 0, 5);
        assert!((r.mean_blocks - r.mean_blocks_after_retrain).abs() < 0.2);
    }
}
