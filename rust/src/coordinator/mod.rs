//! L3 — the serving coordinator.
//!
//! The paper's device is a lookup engine; the coordinator wraps it the way
//! a TLB/router integration would: a threaded request loop with a dynamic
//! batcher in front of the decode stage, shard routing across multiple CAM
//! macros, an insert/delete path that keeps the CNN consistent with the
//! array, and per-request energy/latency accounting.
//!
//! * [`engine`] — one CAM macro + its CNN classifier (the Fig. 1 system).
//! * [`batcher`] — size/deadline dynamic batching for the decode stage
//!   (feeds the PJRT artifact whose batch sizes are fixed at AOT time).
//! * [`server`] — threaded serve loop: mpsc in, per-request response
//!   channels out, graceful drain.
//! * [`router`] — hash-sharding across engines (multi-macro scale-out).
//! * [`metrics`] — counters + latency/energy aggregation.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{EngineError, LookupEngine, LookupOutcome};
pub use metrics::Metrics;
pub use router::ShardRouter;
pub use server::{CamServer, DecodeBackend, ServerHandle};
