"""Pure-jnp oracle for the CNN global-decode / training kernels.

Deliberately written as a *semantic* transcription of the paper's eq. (1) —
per-cluster OR, then AND across clusters — rather than the matmul formulation
the Pallas kernel uses, so the two implementations are genuinely independent.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gd_decode_ref", "train_weights_ref", "lambda_ref"]


def gd_decode_ref(u, w, *, c: int, zeta: int):
    """Reference global decode.

    Computes eq. (1) literally:  v_{n_i'} = AND_i OR_j (w_{(i,j)(i')} ∧ v_{(i,j)}),
    then the ζ-group OR producing compare-enable bits (Fig. 4).

    Args / returns match kernels.gd_decode.
    """
    b, cl = u.shape
    _, m = w.shape
    l = cl // c
    u3 = u.reshape(b, c, l)  # per-cluster neural values
    w3 = w.reshape(c, l, m)  # per-cluster connection weights
    # OR_j (w ∧ v): with 0/1 values, "any product nonzero" == sum > 0.
    cluster_hit = jnp.einsum("bcl,clm->bcm", u3, w3) > 0.0
    act = jnp.all(cluster_hit, axis=1).astype(jnp.float32)  # AND_i
    enables = act.reshape(b, m // zeta, zeta).max(axis=-1)  # ζ-group OR
    return act, enables


def train_weights_ref(u, a):
    """Reference training: w_{(i,j)(i')} = 1 iff some stored entry links them."""
    e, cl = u.shape
    _, m = a.shape
    w = jnp.zeros((cl, m), dtype=jnp.float32)
    # OR over entries of the one-hot outer products — loop form on purpose.
    for ei in range(e):
        w = jnp.maximum(w, jnp.outer(u[ei], a[ei]))
    return w


def lambda_ref(act):
    """Number of activated P_II neurons per query (the paper's λ)."""
    return jnp.sum(act, axis=-1).astype(jnp.int32)
