//! The versioned, length-prefixed binary wire format.
//!
//! Every message is one *frame*:
//!
//! ```text
//! [len: u32][checksum: u64][request id: u64][op: u8][payload: len-17 bytes]
//! ```
//!
//! all little-endian; `len` counts everything after itself and `checksum`
//! is FNV-1a ([`crate::util::hash`] — the same definition that routes tags
//! to banks) over the id, op and payload bytes.  Request ids are chosen by
//! the client and echoed verbatim in the response, which is what makes
//! multiplexing work: a client may have several frames in flight and must
//! match the answers back up by id — since v6 the server advertises
//! [`ServerHello::multiplex`] and responses to pipelined frames may
//! complete in *any* order (a fast lookup overtakes a slow drain on the
//! same connection).  Writers should bound how far they run ahead —
//! socket buffers are finite in both directions; see the window in
//! [`crate::net::CamClient::lookup_bulk`].
//!
//! A connection starts with a handshake: the client sends magic + version
//! ([`write_client_hello`]), the server answers with magic + version +
//! flags + fleet geometry ([`ServerHello`]), and both sides hang up on a
//! mismatch rather than guess at an incompatible stream.
//!
//! Responses carry the full [`ShardedOutcome`] — matched global address,
//! λ, the [`crate::energy::EnergyBreakdown`] and the delay report — with
//! every `f64` shipped as its IEEE-754 bit pattern, so a wire client sees
//! the paper's metrics *bit-identical* to an in-process caller (the
//! `net_roundtrip` integration tests assert exactly that).  Engine
//! failures map to typed error codes ([`engine_error_code`]):
//! [`EngineError::Busy`] (v3) is queue-shed admission,
//! [`EngineError::Full`] strictly means "no free CAM slot".

use crate::bits::BitVec;
use crate::coordinator::engine::EngineError;
use crate::energy::EnergyBreakdown;
use crate::shard::ShardedOutcome;
use crate::timing::DelayReport;
use crate::util::codec::{put_bitvec, put_f64, put_u16, put_u32, put_u64, CodecError, Cursor};
use crate::util::hash::Fnv1a;

use std::io::{self, Read, Write};

/// Protocol magic (first bytes of both hellos).
pub const MAGIC: [u8; 4] = *b"CSCM";

/// Protocol version this build speaks.
///
/// History: v1 — initial op set (Insert…Shutdown); v2 — added the
/// durability ops `Snapshot`/`Flush` and the `ERR_PERSIST` error code;
/// v3 — added `ERR_BUSY` (6), splitting queue-shed admission
/// ([`EngineError::Busy`]) from `ERR_FULL`, which now strictly means "no
/// free CAM slot"; v4 — added `OP_METRICS` (10), returning the
/// Prometheus-text exposition of the fleet's serving metrics in-band
/// (see [`crate::obs`]); v5 — added the replication ops
/// `OP_SUBSCRIBE_LOG` (11) / `OP_LOG_BATCH` (12) /
/// `OP_SNAPSHOT_TRANSFER` (13) and `ERR_FENCED` (7), the log-shipping
/// transport of [`crate::repl`]; v6 — multiplexing: the server hello's
/// flags word gained the `multiplex` bit (bit 1), announcing that
/// responses to pipelined frames may arrive in *any* order and clients
/// must re-match them by request id (the byte layout of every frame is
/// unchanged — v6 relaxes an ordering promise, it adds no ops).  Both
/// sides hang up on a version mismatch (strict equality), so a mixed
/// deployment must upgrade in lock-step.
pub const VERSION: u16 = 6;

/// Upper bound on one frame (64 MiB) — rejects garbage lengths before any
/// allocation.
pub const MAX_FRAME_LEN: u32 = 1 << 26;

/// Tags wider than this are rejected at decode time (a million bits is far
/// past any design point; real N is 32–128).
pub const MAX_TAG_BITS: u32 = 1 << 20;

/// Most tags one `LookupBulk` frame may carry.  Responses are much larger
/// than requests (an outcome is ~15× a tag), so without this cap a
/// request that fits [`MAX_FRAME_LEN`] comfortably could demand a response
/// frame the peer is obliged to reject — the work would be done, then
/// thrown away as a protocol violation.  4096 outcomes stay well under a
/// megabyte.  [`crate::net::CamClient::lookup_bulk`] clamps its chunk size
/// to this.
pub const MAX_BULK_TAGS: usize = 4096;

// Request opcodes (responses echo the same op; errors use OP_ERROR).
pub const OP_INSERT: u8 = 1;
pub const OP_DELETE: u8 = 2;
pub const OP_LOOKUP: u8 = 3;
pub const OP_LOOKUP_BULK: u8 = 4;
pub const OP_STATS: u8 = 5;
pub const OP_DRAIN: u8 = 6;
pub const OP_SHUTDOWN: u8 = 7;
/// Force a compaction: every bank snapshots its state and truncates its
/// WAL (v2; no-op ack on a fleet serving without `--data-dir`).
pub const OP_SNAPSHOT: u8 = 8;
/// Fsync every bank's WAL (v2; no-op ack without `--data-dir`).
pub const OP_FLUSH: u8 = 9;
/// Fetch the Prometheus-text metrics exposition (v4; see [`crate::obs`]).
pub const OP_METRICS: u8 = 10;
/// Poll the primary's per-bank WAL past a replica's cursor (v5).  One
/// request yields exactly one response: a [`Response::LogBatch`] of raw
/// WAL frames, a [`Response::SnapshotTransfer`] when the cursor is
/// unusable (bootstrap, or compaction advanced the generation), or an
/// `ERR_FENCED` error when the subscriber's epoch is stale.
pub const OP_SUBSCRIBE_LOG: u8 = 11;
/// Response op: a batch of verbatim WAL frames plus the advanced cursor
/// (v5; only ever sent in answer to [`OP_SUBSCRIBE_LOG`]).
pub const OP_LOG_BATCH: u8 = 12;
/// Response op: a full bank snapshot image — or, for the manifest
/// pseudo-bank [`REPL_MANIFEST_BANK`], the `fleet.kv` manifest text —
/// for a subscriber that must re-bootstrap (v5).
pub const OP_SNAPSHOT_TRANSFER: u8 = 13;
pub const OP_ERROR: u8 = 0xEE;

/// Pseudo bank index in a [`Request::SubscribeLog`] that asks for the
/// fleet manifest (`fleet.kv` text in a [`Response::SnapshotTransfer`],
/// its `generation` field carrying the fleet epoch) instead of a real
/// bank's log — how a replica learns geometry, placement and epoch
/// before it subscribes to any bank.
pub const REPL_MANIFEST_BANK: u32 = u32::MAX;

/// Cursor sentinel in a [`Request::SubscribeLog`] that means "I have
/// nothing — bootstrap me": the primary answers with a snapshot
/// transfer (or an empty-prefix log batch when the bank has never been
/// snapshotted).
pub const SUBSCRIBE_BOOTSTRAP: u64 = u64::MAX;

// Typed error codes.
pub const ERR_FULL: u16 = 1;
pub const ERR_BAD_ADDRESS: u16 = 2;
pub const ERR_TAG_WIDTH: u16 = 3;
pub const ERR_SHUTDOWN: u16 = 4;
/// Admission queue at capacity — transient overload, retry later (v3).
/// Distinct from [`ERR_FULL`], which means the CAM has no free slot.
pub const ERR_BUSY: u16 = 6;
/// The subscriber's replication epoch is older than the fleet's (v5):
/// a promotion happened behind its back, so its log position may name a
/// divergent history.  `aux` carries the server's current epoch.  This
/// is a wire-level verdict with no [`EngineError`] equivalent — a fenced
/// peer must re-bootstrap or stand down, not retry.
pub const ERR_FENCED: u16 = 7;
/// The durability layer failed to log or snapshot (disk full, I/O error).
/// The detailed [`crate::store::StoreError`] stays in the server log; the
/// wire carries only the code.
pub const ERR_PERSIST: u16 = 5;
/// Malformed frame / payload (no [`EngineError`] equivalent).
pub const ERR_PROTOCOL: u16 = 100;
/// Opcode the server does not know.
pub const ERR_UNKNOWN_OP: u16 = 101;

/// Everything that can go wrong on the wire.
#[derive(Debug)]
pub enum WireError {
    /// Transport failure (includes peer disconnect).
    Io(io::Error),
    /// Bytes that violate the protocol contract (bad magic, bad checksum,
    /// truncated payload, unknown opcode…).
    Protocol(String),
    /// The server answered with a typed engine error.
    Engine(EngineError),
    /// The server is at its connection cap (hello `busy` flag).
    Busy,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
            WireError::Protocol(m) => write!(f, "wire protocol violation: {m}"),
            WireError::Engine(e) => write!(f, "engine error over the wire: {e}"),
            WireError::Busy => write!(f, "server at connection capacity"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        WireError::Io(e)
    }
}

impl From<CodecError> for WireError {
    fn from(e: CodecError) -> Self {
        WireError::Protocol(e.0)
    }
}

/// Map an engine error onto its wire code plus auxiliary word
/// (`BadAddress` carries the address; `TagWidth` packs got/want).
pub fn engine_error_code(e: &EngineError) -> (u16, u64) {
    match e {
        EngineError::Full => (ERR_FULL, 0),
        EngineError::Busy => (ERR_BUSY, 0),
        EngineError::BadAddress(a) => (ERR_BAD_ADDRESS, *a as u64),
        EngineError::TagWidth { got, want } => {
            (ERR_TAG_WIDTH, ((*got as u64) << 32) | (*want as u64 & 0xFFFF_FFFF))
        }
        EngineError::Shutdown => (ERR_SHUTDOWN, 0),
        EngineError::Persist(_) => (ERR_PERSIST, 0),
    }
}

/// Inverse of [`engine_error_code`]; `None` for protocol-level codes.
/// `ERR_PERSIST` decodes to a [`EngineError::Persist`] with a generic
/// message — the detailed store error never crosses the wire.
pub fn engine_error_from_code(code: u16, aux: u64) -> Option<EngineError> {
    match code {
        ERR_FULL => Some(EngineError::Full),
        ERR_BUSY => Some(EngineError::Busy),
        ERR_BAD_ADDRESS => Some(EngineError::BadAddress(aux as usize)),
        ERR_TAG_WIDTH => Some(EngineError::TagWidth {
            got: (aux >> 32) as usize,
            want: (aux & 0xFFFF_FFFF) as usize,
        }),
        ERR_SHUTDOWN => Some(EngineError::Shutdown),
        ERR_PERSIST => Some(EngineError::Persist("remote persistence failure".into())),
        _ => None,
    }
}

/// A client-side request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Insert { tag: BitVec },
    Delete { addr: u64 },
    Lookup { tag: BitVec },
    LookupBulk { tags: Vec<BitVec> },
    Stats,
    Drain,
    Shutdown,
    /// Force every bank to snapshot + truncate its WAL (v2).
    Snapshot,
    /// Fsync every bank's WAL (v2).
    Flush,
    /// Fetch the Prometheus-text metrics exposition (v4).
    Metrics,
    /// Poll one bank's WAL past this subscriber's cursor (v5).  `replica`
    /// names the subscriber (for lag accounting), `epoch` is the fleet
    /// epoch it believes in (fenced when stale), and
    /// `generation`/`offset` are its WAL cursor — requesting `offset`
    /// acknowledges everything before it.  `offset` =
    /// [`SUBSCRIBE_BOOTSTRAP`] asks for a snapshot; `bank` =
    /// [`REPL_MANIFEST_BANK`] asks for the fleet manifest.
    SubscribeLog { replica: u64, epoch: u64, bank: u32, generation: u64, offset: u64 },
}

/// Fleet statistics snapshot shipped for [`Request::Stats`].
#[derive(Debug, Clone, PartialEq)]
pub struct StatsReport {
    pub shards: u32,
    pub bank_m: u32,
    pub tag_bits: u32,
    pub lookups: u64,
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub deletes: u64,
    pub mean_lambda: f64,
    pub mean_energy_fj: f64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub hottest_bank: u32,
    pub hot_fraction: f64,
    pub per_bank_lookups: Vec<u64>,
}

/// A server-side response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Inserted { addr: u64 },
    Deleted,
    Lookup(Box<ShardedOutcome>),
    /// One result per input tag, in order; per-item errors stay typed.
    LookupBulk(Vec<Result<ShardedOutcome, EngineError>>),
    Stats(Box<StatsReport>),
    Drained,
    ShutdownAck,
    /// Every bank snapshotted and truncated its WAL (v2).  Also the ack on
    /// a fleet serving without persistence (nothing to compact).
    Snapshotted,
    /// Every bank's WAL is synced to disk (v2; no-op ack without
    /// persistence).
    Flushed,
    /// The Prometheus-text exposition page (v4) — the same text `GET
    /// /metrics` serves on the HTTP sidecar, shipped in-band as UTF-8.
    Metrics { text: String },
    /// A batch of verbatim WAL frames starting at the subscriber's
    /// requested offset (v5).  `next_offset` is the cursor for the next
    /// poll; `remaining` counts complete frames already on disk past it
    /// (the subscriber's lag in records); an empty `frames` with
    /// `remaining` = 0 means the subscriber is caught up.
    LogBatch { bank: u32, generation: u64, next_offset: u64, remaining: u64, frames: Vec<u8> },
    /// A full bank snapshot image stamped with its WAL generation (v5);
    /// the subscriber installs it and re-subscribes from the fresh
    /// generation's log start.  For [`REPL_MANIFEST_BANK`] the bytes are
    /// the `fleet.kv` manifest text and `generation` is the fleet epoch.
    SnapshotTransfer { bank: u32, generation: u64, image: Vec<u8> },
    /// Whole-request failure (see the `ERR_*` codes).
    Error { code: u16, aux: u64 },
}

// ---------------------------------------------------------------- hellos

/// Client hello: magic, version, two reserved zero bytes.
pub fn write_client_hello(w: &mut impl Write) -> io::Result<()> {
    w.write_all(&MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&[0u8; 2])
}

/// Parse a client hello from its 8 raw bytes; returns the peer's version.
pub fn parse_client_hello(buf: &[u8; 8]) -> Result<u16, WireError> {
    if buf[..4] != MAGIC {
        return Err(WireError::Protocol("bad magic in client hello".into()));
    }
    Ok(u16::from_le_bytes([buf[4], buf[5]]))
}

/// What the server announces after a valid client hello.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerHello {
    pub version: u16,
    /// Set when the server is at its connection cap and will close the
    /// connection right after this hello.
    pub busy: bool,
    /// Set when the server multiplexes requests (v6): responses to
    /// pipelined frames may arrive in any order and must be re-matched
    /// by request id.  A client that needs strict ordering must simply
    /// not pipeline.
    pub multiplex: bool,
    pub shards: u32,
    /// Entries per bank (total capacity = `shards * bank_m`).
    pub bank_m: u32,
    /// Tag width N the fleet expects.
    pub tag_bits: u32,
}

/// Bit 0 of the server hello's flags word: at the connection cap.
const HELLO_FLAG_BUSY: u16 = 1 << 0;
/// Bit 1 of the server hello's flags word: out-of-order multiplexing (v6).
const HELLO_FLAG_MULTIPLEX: u16 = 1 << 1;

pub fn write_server_hello(w: &mut impl Write, h: &ServerHello) -> io::Result<()> {
    let mut flags = 0u16;
    if h.busy {
        flags |= HELLO_FLAG_BUSY;
    }
    if h.multiplex {
        flags |= HELLO_FLAG_MULTIPLEX;
    }
    w.write_all(&MAGIC)?;
    w.write_all(&h.version.to_le_bytes())?;
    w.write_all(&flags.to_le_bytes())?;
    w.write_all(&h.shards.to_le_bytes())?;
    w.write_all(&h.bank_m.to_le_bytes())?;
    w.write_all(&h.tag_bits.to_le_bytes())
}

/// Read and validate a server hello (20 bytes).
pub fn read_server_hello(r: &mut impl Read) -> Result<ServerHello, WireError> {
    let mut buf = [0u8; 20];
    r.read_exact(&mut buf)?;
    if buf[..4] != MAGIC {
        return Err(WireError::Protocol("bad magic in server hello".into()));
    }
    let u16_at = |i: usize| u16::from_le_bytes([buf[i], buf[i + 1]]);
    let u32_at = |i: usize| u32::from_le_bytes([buf[i], buf[i + 1], buf[i + 2], buf[i + 3]]);
    Ok(ServerHello {
        version: u16_at(4),
        busy: u16_at(6) & HELLO_FLAG_BUSY != 0,
        multiplex: u16_at(6) & HELLO_FLAG_MULTIPLEX != 0,
        shards: u32_at(8),
        bank_m: u32_at(12),
        tag_bits: u32_at(16),
    })
}

// ------------------------------------------------------ payload encoding
//
// The primitive writers/readers (`put_*`, `Cursor`) are the shared codec
// of `util::codec` — the same helpers serialize the on-disk snapshot and
// WAL formats (`crate::store`), so the byte conventions cannot drift
// between the wire and the disk.  Only the domain encodings (tags with
// the defensive tail mask, outcomes, stats) live here.

fn put_tag(buf: &mut Vec<u8>, tag: &BitVec) {
    // byte-identical to the store codec's bit-vector encoding — one
    // definition of the layout; only the decoders differ on purpose
    // (take_tag masks tail slack, take_bitvec rejects it)
    put_bitvec(buf, tag);
}

fn put_outcome(buf: &mut Vec<u8>, o: &ShardedOutcome) {
    match o.addr {
        Some(a) => {
            buf.push(1);
            put_u64(buf, a as u64);
        }
        None => {
            buf.push(0);
            put_u64(buf, 0);
        }
    }
    put_u32(buf, o.all_matches.len() as u32);
    for &a in &o.all_matches {
        put_u64(buf, a as u64);
    }
    put_u32(buf, o.banks_searched as u32);
    put_u64(buf, o.lambda as u64);
    put_u64(buf, o.enabled_blocks as u64);
    put_u64(buf, o.comparisons as u64);
    let e = &o.energy;
    for v in [
        e.searchline_fj,
        e.matchline_fj,
        e.global_wire_fj,
        e.sram_read_fj,
        e.decoder_fj,
        e.pii_logic_fj,
        e.enable_driver_fj,
        e.enable_gate_fj,
    ] {
        put_f64(buf, v);
    }
    put_f64(buf, o.delay.cycle_ns);
    put_f64(buf, o.delay.latency_ns);
}

/// Read one tag: `u32` width + the packed words.  Unlike the store codec's
/// strict [`crate::util::codec::Cursor::take_bitvec`], tail slack a hostile
/// peer may have set is *masked* rather than rejected — a live connection
/// should survive a sloppy-but-unambiguous peer, whereas a stored image
/// with slack garbage is evidence of corruption.
fn take_tag(c: &mut Cursor<'_>) -> Result<BitVec, WireError> {
    let nbits = c.take_u32()?;
    if nbits == 0 || nbits > MAX_TAG_BITS {
        return Err(WireError::Protocol(format!("tag width {nbits} out of range")));
    }
    let n = nbits as usize;
    let mut tag = BitVec::zeros(n);
    for w in tag.words_mut() {
        *w = c.take_u64()?;
    }
    // Defensive: clear tail slack a hostile peer may have set (it would
    // corrupt count_ones/iter_ones invariants downstream).
    let rem = n % 64;
    if rem != 0 {
        if let Some(last) = tag.words_mut().last_mut() {
            *last &= (1u64 << rem) - 1;
        }
    }
    Ok(tag)
}

fn take_outcome(c: &mut Cursor<'_>) -> Result<ShardedOutcome, WireError> {
    let has_addr = c.take_u8()? == 1;
    let addr_raw = c.take_u64()?;
    let n_matches = c.take_u32()? as usize;
    if n_matches > c.remaining() / 8 {
        return Err(WireError::Protocol(format!(
            "{n_matches} matches cannot fit the {} remaining payload bytes",
            c.remaining()
        )));
    }
    let mut all_matches = Vec::with_capacity(n_matches);
    for _ in 0..n_matches {
        all_matches.push(c.take_u64()? as usize);
    }
    let banks_searched = c.take_u32()? as usize;
    let lambda = c.take_u64()? as usize;
    let enabled_blocks = c.take_u64()? as usize;
    let comparisons = c.take_u64()? as usize;
    let energy = EnergyBreakdown {
        searchline_fj: c.take_f64()?,
        matchline_fj: c.take_f64()?,
        global_wire_fj: c.take_f64()?,
        sram_read_fj: c.take_f64()?,
        decoder_fj: c.take_f64()?,
        pii_logic_fj: c.take_f64()?,
        enable_driver_fj: c.take_f64()?,
        enable_gate_fj: c.take_f64()?,
    };
    let delay = DelayReport { cycle_ns: c.take_f64()?, latency_ns: c.take_f64()? };
    Ok(ShardedOutcome {
        addr: has_addr.then_some(addr_raw as usize),
        all_matches,
        banks_searched,
        lambda,
        enabled_blocks,
        comparisons,
        energy,
        delay,
    })
}

impl Request {
    pub fn op(&self) -> u8 {
        match self {
            Request::Insert { .. } => OP_INSERT,
            Request::Delete { .. } => OP_DELETE,
            Request::Lookup { .. } => OP_LOOKUP,
            Request::LookupBulk { .. } => OP_LOOKUP_BULK,
            Request::Stats => OP_STATS,
            Request::Drain => OP_DRAIN,
            Request::Shutdown => OP_SHUTDOWN,
            Request::Snapshot => OP_SNAPSHOT,
            Request::Flush => OP_FLUSH,
            Request::Metrics => OP_METRICS,
            Request::SubscribeLog { .. } => OP_SUBSCRIBE_LOG,
        }
    }

    pub fn encode_payload(&self, buf: &mut Vec<u8>) {
        match self {
            Request::Insert { tag } | Request::Lookup { tag } => put_tag(buf, tag),
            Request::Delete { addr } => put_u64(buf, *addr),
            Request::LookupBulk { tags } => {
                put_u32(buf, tags.len() as u32);
                for t in tags {
                    put_tag(buf, t);
                }
            }
            Request::Stats
            | Request::Drain
            | Request::Shutdown
            | Request::Snapshot
            | Request::Flush
            | Request::Metrics => {}
            Request::SubscribeLog { replica, epoch, bank, generation, offset } => {
                put_u64(buf, *replica);
                put_u64(buf, *epoch);
                put_u32(buf, *bank);
                put_u64(buf, *generation);
                put_u64(buf, *offset);
            }
        }
    }

    pub fn decode(op: u8, payload: &[u8]) -> Result<Request, WireError> {
        let mut c = Cursor::new(payload);
        let req = match op {
            OP_INSERT => Request::Insert { tag: take_tag(&mut c)? },
            OP_DELETE => Request::Delete { addr: c.take_u64()? },
            OP_LOOKUP => Request::Lookup { tag: take_tag(&mut c)? },
            OP_LOOKUP_BULK => {
                let n = c.take_u32()? as usize;
                if n > MAX_BULK_TAGS {
                    return Err(WireError::Protocol(format!(
                        "bulk count {n} exceeds the per-frame cap of {MAX_BULK_TAGS}"
                    )));
                }
                // the smallest tag encoding is 12 bytes (u32 width + 1 word)
                if n > c.remaining() / 12 {
                    return Err(WireError::Protocol(format!(
                        "bulk count {n} cannot fit the {} remaining payload bytes",
                        c.remaining()
                    )));
                }
                let mut tags = Vec::with_capacity(n);
                for _ in 0..n {
                    tags.push(take_tag(&mut c)?);
                }
                Request::LookupBulk { tags }
            }
            OP_STATS => Request::Stats,
            OP_DRAIN => Request::Drain,
            OP_SHUTDOWN => Request::Shutdown,
            OP_SNAPSHOT => Request::Snapshot,
            OP_FLUSH => Request::Flush,
            OP_METRICS => Request::Metrics,
            OP_SUBSCRIBE_LOG => Request::SubscribeLog {
                replica: c.take_u64()?,
                epoch: c.take_u64()?,
                bank: c.take_u32()?,
                generation: c.take_u64()?,
                offset: c.take_u64()?,
            },
            other => return Err(WireError::Protocol(format!("unknown request op {other}"))),
        };
        c.finish()?;
        Ok(req)
    }
}

impl Response {
    pub fn op(&self) -> u8 {
        match self {
            Response::Inserted { .. } => OP_INSERT,
            Response::Deleted => OP_DELETE,
            Response::Lookup(_) => OP_LOOKUP,
            Response::LookupBulk(_) => OP_LOOKUP_BULK,
            Response::Stats(_) => OP_STATS,
            Response::Drained => OP_DRAIN,
            Response::ShutdownAck => OP_SHUTDOWN,
            Response::Snapshotted => OP_SNAPSHOT,
            Response::Flushed => OP_FLUSH,
            Response::Metrics { .. } => OP_METRICS,
            Response::LogBatch { .. } => OP_LOG_BATCH,
            Response::SnapshotTransfer { .. } => OP_SNAPSHOT_TRANSFER,
            Response::Error { .. } => OP_ERROR,
        }
    }

    pub fn encode_payload(&self, buf: &mut Vec<u8>) {
        match self {
            Response::Inserted { addr } => put_u64(buf, *addr),
            Response::Deleted
            | Response::Drained
            | Response::ShutdownAck
            | Response::Snapshotted
            | Response::Flushed => {}
            Response::Lookup(o) => put_outcome(buf, o),
            Response::LookupBulk(items) => {
                put_u32(buf, items.len() as u32);
                for item in items {
                    match item {
                        Ok(o) => {
                            buf.push(1);
                            put_outcome(buf, o);
                        }
                        Err(e) => {
                            buf.push(0);
                            let (code, aux) = engine_error_code(e);
                            put_u16(buf, code);
                            put_u64(buf, aux);
                        }
                    }
                }
            }
            Response::Stats(s) => {
                put_u32(buf, s.shards);
                put_u32(buf, s.bank_m);
                put_u32(buf, s.tag_bits);
                for v in [s.lookups, s.hits, s.misses, s.inserts, s.deletes] {
                    put_u64(buf, v);
                }
                put_f64(buf, s.mean_lambda);
                put_f64(buf, s.mean_energy_fj);
                put_u64(buf, s.p50_ns);
                put_u64(buf, s.p99_ns);
                put_u32(buf, s.hottest_bank);
                put_f64(buf, s.hot_fraction);
                put_u32(buf, s.per_bank_lookups.len() as u32);
                for &v in &s.per_bank_lookups {
                    put_u64(buf, v);
                }
            }
            Response::Metrics { text } => {
                put_u32(buf, text.len() as u32);
                buf.extend_from_slice(text.as_bytes());
            }
            Response::LogBatch { bank, generation, next_offset, remaining, frames } => {
                put_u32(buf, *bank);
                put_u64(buf, *generation);
                put_u64(buf, *next_offset);
                put_u64(buf, *remaining);
                put_u32(buf, frames.len() as u32);
                buf.extend_from_slice(frames);
            }
            Response::SnapshotTransfer { bank, generation, image } => {
                put_u32(buf, *bank);
                put_u64(buf, *generation);
                put_u32(buf, image.len() as u32);
                buf.extend_from_slice(image);
            }
            Response::Error { code, aux } => {
                put_u16(buf, *code);
                put_u64(buf, *aux);
            }
        }
    }

    pub fn decode(op: u8, payload: &[u8]) -> Result<Response, WireError> {
        let mut c = Cursor::new(payload);
        let resp = match op {
            OP_INSERT => Response::Inserted { addr: c.take_u64()? },
            OP_DELETE => Response::Deleted,
            OP_LOOKUP => Response::Lookup(Box::new(take_outcome(&mut c)?)),
            OP_LOOKUP_BULK => {
                let n = c.take_u32()? as usize;
                // the smallest item encoding is 11 bytes (error: flag+code+aux)
                if n > c.remaining() / 11 {
                    return Err(WireError::Protocol(format!(
                        "bulk result count {n} cannot fit the {} remaining payload bytes",
                        c.remaining()
                    )));
                }
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    if c.take_u8()? == 1 {
                        items.push(Ok(take_outcome(&mut c)?));
                    } else {
                        let code = c.take_u16()?;
                        let aux = c.take_u64()?;
                        let e = engine_error_from_code(code, aux).ok_or_else(|| {
                            WireError::Protocol(format!(
                                "non-engine error code {code} in bulk item"
                            ))
                        })?;
                        items.push(Err(e));
                    }
                }
                Response::LookupBulk(items)
            }
            OP_STATS => {
                let shards = c.take_u32()?;
                let bank_m = c.take_u32()?;
                let tag_bits = c.take_u32()?;
                let lookups = c.take_u64()?;
                let hits = c.take_u64()?;
                let misses = c.take_u64()?;
                let inserts = c.take_u64()?;
                let deletes = c.take_u64()?;
                let mean_lambda = c.take_f64()?;
                let mean_energy_fj = c.take_f64()?;
                let p50_ns = c.take_u64()?;
                let p99_ns = c.take_u64()?;
                let hottest_bank = c.take_u32()?;
                let hot_fraction = c.take_f64()?;
                let nb = c.take_u32()? as usize;
                if nb > c.remaining() / 8 {
                    return Err(WireError::Protocol(format!(
                        "{nb} banks cannot fit the {} remaining payload bytes",
                        c.remaining()
                    )));
                }
                let mut per_bank_lookups = Vec::with_capacity(nb);
                for _ in 0..nb {
                    per_bank_lookups.push(c.take_u64()?);
                }
                Response::Stats(Box::new(StatsReport {
                    shards,
                    bank_m,
                    tag_bits,
                    lookups,
                    hits,
                    misses,
                    inserts,
                    deletes,
                    mean_lambda,
                    mean_energy_fj,
                    p50_ns,
                    p99_ns,
                    hottest_bank,
                    hot_fraction,
                    per_bank_lookups,
                }))
            }
            OP_DRAIN => Response::Drained,
            OP_SHUTDOWN => Response::ShutdownAck,
            OP_SNAPSHOT => Response::Snapshotted,
            OP_FLUSH => Response::Flushed,
            OP_METRICS => {
                let n = c.take_u32()? as usize;
                // take() itself bounds n by the remaining payload (no
                // allocation happens before the bytes are proven present)
                let bytes = c.take(n)?;
                let text = String::from_utf8(bytes.to_vec()).map_err(|_| {
                    WireError::Protocol("metrics exposition is not valid UTF-8".into())
                })?;
                Response::Metrics { text }
            }
            OP_LOG_BATCH => {
                let bank = c.take_u32()?;
                let generation = c.take_u64()?;
                let next_offset = c.take_u64()?;
                let remaining = c.take_u64()?;
                let n = c.take_u32()? as usize;
                // take() bounds n by the remaining payload before any
                // allocation, as in the Metrics arm
                let frames = c.take(n)?.to_vec();
                Response::LogBatch { bank, generation, next_offset, remaining, frames }
            }
            OP_SNAPSHOT_TRANSFER => {
                let bank = c.take_u32()?;
                let generation = c.take_u64()?;
                let n = c.take_u32()? as usize;
                let image = c.take(n)?.to_vec();
                Response::SnapshotTransfer { bank, generation, image }
            }
            OP_ERROR => Response::Error { code: c.take_u16()?, aux: c.take_u64()? },
            other => return Err(WireError::Protocol(format!("unknown response op {other}"))),
        };
        c.finish()?;
        Ok(resp)
    }
}

/// Build an error response from an engine error.
pub fn error_response(e: &EngineError) -> Response {
    let (code, aux) = engine_error_code(e);
    Response::Error { code, aux }
}

// --------------------------------------------------------------- framing

/// Write one frame (no flush — callers batch frames, then flush once,
/// which is what makes pipelined bulk lookups one syscall burst).  A
/// payload past [`MAX_FRAME_LEN`] errors here, on the sender — the peer
/// would reject it unread anyway.
pub fn write_frame(w: &mut impl Write, id: u64, op: u8, payload: &[u8]) -> io::Result<()> {
    if payload.len() as u64 + 17 > MAX_FRAME_LEN as u64 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("frame payload of {} bytes exceeds MAX_FRAME_LEN", payload.len()),
        ));
    }
    let len = (8 + 8 + 1 + payload.len()) as u32;
    let mut h = Fnv1a::new();
    h.update(&id.to_le_bytes());
    h.update(&[op]);
    h.update(payload);
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&h.finish().to_le_bytes())?;
    w.write_all(&id.to_le_bytes())?;
    w.write_all(&[op])?;
    w.write_all(payload)
}

/// Validate a frame length prefix.
pub fn check_frame_len(len: u32) -> Result<usize, WireError> {
    if len < 17 || len > MAX_FRAME_LEN {
        return Err(WireError::Protocol(format!("frame length {len} out of range")));
    }
    Ok(len as usize)
}

/// Decode the body of a frame (everything after the length prefix):
/// verifies the checksum and returns `(id, op, payload)`.
pub fn decode_frame_body(body: &[u8]) -> Result<(u64, u8, &[u8]), WireError> {
    if body.len() < 17 {
        return Err(WireError::Protocol("frame body shorter than its header".into()));
    }
    // lint:allow(infallible: the slice is exactly 8 bytes by construction,
    // guarded by the length check above)
    let want = u64::from_le_bytes(<[u8; 8]>::try_from(&body[0..8]).unwrap());
    let got = crate::util::hash::fnv1a_bytes(&body[8..]);
    if want != got {
        return Err(WireError::Protocol(format!(
            "frame checksum mismatch: header {want:#018x}, computed {got:#018x}"
        )));
    }
    // lint:allow(infallible: 8-byte slice by construction, see length check)
    let id = u64::from_le_bytes(<[u8; 8]>::try_from(&body[8..16]).unwrap());
    Ok((id, body[16], &body[17..]))
}

/// Blocking read of one whole frame → `(id, op, payload)`.
pub fn read_frame(r: &mut impl Read) -> Result<(u64, u8, Vec<u8>), WireError> {
    let mut lenb = [0u8; 4];
    r.read_exact(&mut lenb)?;
    let len = check_frame_len(u32::from_le_bytes(lenb))?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    let (id, op, payload) = decode_frame_body(&body)?;
    Ok((id, op, payload.to_vec()))
}

/// Write a request frame.
pub fn write_request(w: &mut impl Write, id: u64, req: &Request) -> io::Result<()> {
    let mut payload = Vec::new();
    req.encode_payload(&mut payload);
    write_frame(w, id, req.op(), &payload)
}

/// Write a single-tag request (`OP_INSERT` or `OP_LOOKUP`) straight from a
/// borrowed tag — the hot-path sibling of [`write_request`] that skips
/// cloning the tag into a [`Request`].
pub fn write_tag_request(w: &mut impl Write, id: u64, op: u8, tag: &BitVec) -> io::Result<()> {
    debug_assert!(op == OP_INSERT || op == OP_LOOKUP, "op {op} does not carry one tag");
    let mut payload = Vec::new();
    put_tag(&mut payload, tag);
    write_frame(w, id, op, &payload)
}

/// Write a `LookupBulk` request straight from a borrowed slice — the
/// pipelined client sends thousands of these per run, so the tags must
/// not be cloned just to be serialized and dropped.
pub fn write_lookup_bulk_request(w: &mut impl Write, id: u64, tags: &[BitVec]) -> io::Result<()> {
    let mut payload = Vec::new();
    put_u32(&mut payload, tags.len() as u32);
    for t in tags {
        put_tag(&mut payload, t);
    }
    write_frame(w, id, OP_LOOKUP_BULK, &payload)
}

/// Blocking read of one request frame.
pub fn read_request(r: &mut impl Read) -> Result<(u64, Request), WireError> {
    let (id, op, payload) = read_frame(r)?;
    Ok((id, Request::decode(op, &payload)?))
}

/// Write a response frame.
pub fn write_response(w: &mut impl Write, id: u64, resp: &Response) -> io::Result<()> {
    let mut payload = Vec::new();
    resp.encode_payload(&mut payload);
    write_frame(w, id, resp.op(), &payload)
}

/// Blocking read of one response frame.
pub fn read_response(r: &mut impl Read) -> Result<(u64, Response), WireError> {
    let (id, op, payload) = read_frame(r)?;
    Ok((id, Response::decode(op, &payload)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TagDistribution;
    use crate::util::Rng;

    fn sample_outcome(hit: bool) -> ShardedOutcome {
        ShardedOutcome {
            addr: hit.then_some(133),
            all_matches: if hit { vec![133, 450] } else { vec![] },
            banks_searched: 4,
            lambda: 3,
            enabled_blocks: 2,
            comparisons: 16,
            energy: EnergyBreakdown {
                searchline_fj: 1.25,
                matchline_fj: 2.5,
                global_wire_fj: 0.1,
                sram_read_fj: 0.2,
                decoder_fj: 0.3,
                pii_logic_fj: 0.4,
                enable_driver_fj: 0.5,
                enable_gate_fj: 0.6,
            },
            delay: DelayReport { cycle_ns: 0.733, latency_ns: 1.466 },
        }
    }

    fn roundtrip_request(req: Request) {
        let mut wire = Vec::new();
        write_request(&mut wire, 42, &req).unwrap();
        let (id, back) = read_request(&mut wire.as_slice()).unwrap();
        assert_eq!(id, 42);
        assert_eq!(back, req);
    }

    fn roundtrip_response(resp: Response) {
        let mut wire = Vec::new();
        write_response(&mut wire, 7, &resp).unwrap();
        let (id, back) = read_response(&mut wire.as_slice()).unwrap();
        assert_eq!(id, 7);
        assert_eq!(back, resp);
    }

    #[test]
    fn requests_roundtrip() {
        let mut rng = Rng::seed_from_u64(1);
        let tags = TagDistribution::Uniform.sample_distinct(100, 3, &mut rng);
        roundtrip_request(Request::Insert { tag: tags[0].clone() });
        roundtrip_request(Request::Delete { addr: 987 });
        roundtrip_request(Request::Lookup { tag: tags[1].clone() });
        roundtrip_request(Request::LookupBulk { tags: tags.clone() });
        roundtrip_request(Request::LookupBulk { tags: Vec::new() });
        roundtrip_request(Request::Stats);
        roundtrip_request(Request::Drain);
        roundtrip_request(Request::Shutdown);
        roundtrip_request(Request::Snapshot);
        roundtrip_request(Request::Flush);
        roundtrip_request(Request::Metrics);
        roundtrip_request(Request::SubscribeLog {
            replica: 0xDEAD_BEEF,
            epoch: 3,
            bank: 2,
            generation: 9,
            offset: 4096,
        });
        roundtrip_request(Request::SubscribeLog {
            replica: 1,
            epoch: 0,
            bank: REPL_MANIFEST_BANK,
            generation: 0,
            offset: SUBSCRIBE_BOOTSTRAP,
        });
    }

    #[test]
    fn responses_roundtrip_bit_identical() {
        roundtrip_response(Response::Inserted { addr: 511 });
        roundtrip_response(Response::Deleted);
        roundtrip_response(Response::Lookup(Box::new(sample_outcome(true))));
        roundtrip_response(Response::Lookup(Box::new(sample_outcome(false))));
        roundtrip_response(Response::LookupBulk(vec![
            Ok(sample_outcome(true)),
            Err(EngineError::Full),
            Ok(sample_outcome(false)),
            Err(EngineError::TagWidth { got: 16, want: 32 }),
        ]));
        roundtrip_response(Response::Stats(Box::new(StatsReport {
            shards: 4,
            bank_m: 128,
            tag_bits: 32,
            lookups: 1000,
            hits: 900,
            misses: 100,
            inserts: 64,
            deletes: 3,
            mean_lambda: 1.998,
            mean_energy_fj: 7887.5,
            p50_ns: 1200,
            p99_ns: 56000,
            hottest_bank: 2,
            hot_fraction: 0.31,
            per_bank_lookups: vec![250, 240, 310, 200],
        })));
        roundtrip_response(Response::Drained);
        roundtrip_response(Response::ShutdownAck);
        roundtrip_response(Response::Snapshotted);
        roundtrip_response(Response::Flushed);
        roundtrip_response(Response::Metrics {
            text: "# TYPE cscam_lookups_total counter\ncscam_lookups_total 7\n".into(),
        });
        roundtrip_response(Response::Metrics { text: String::new() });
        roundtrip_response(Response::LogBatch {
            bank: 3,
            generation: 2,
            next_offset: 1234,
            remaining: 17,
            frames: vec![0xAB; 64],
        });
        roundtrip_response(Response::LogBatch {
            bank: 0,
            generation: 0,
            next_offset: 16,
            remaining: 0,
            frames: Vec::new(),
        });
        roundtrip_response(Response::SnapshotTransfer {
            bank: 1,
            generation: 5,
            image: (0u16..512).map(|b| b as u8).collect(),
        });
        roundtrip_response(Response::Error { code: ERR_FULL, aux: 0 });
        roundtrip_response(Response::Error { code: ERR_FENCED, aux: 4 });
    }

    #[test]
    fn repl_byte_payloads_are_bounded_by_the_frame() {
        // a LogBatch whose length prefix overruns the payload is a
        // protocol error before any allocation, like the Metrics arm
        let mut payload = Vec::new();
        put_u32(&mut payload, 0);
        put_u64(&mut payload, 1);
        put_u64(&mut payload, 16);
        put_u64(&mut payload, 0);
        put_u32(&mut payload, 1_000_000);
        payload.extend_from_slice(b"tiny");
        let mut wire = Vec::new();
        write_frame(&mut wire, 11, OP_LOG_BATCH, &payload).unwrap();
        assert!(matches!(read_response(&mut wire.as_slice()), Err(WireError::Protocol(_))));
        let mut payload = Vec::new();
        put_u32(&mut payload, 0);
        put_u64(&mut payload, 1);
        put_u32(&mut payload, 1_000_000);
        let mut wire = Vec::new();
        write_frame(&mut wire, 12, OP_SNAPSHOT_TRANSFER, &payload).unwrap();
        assert!(matches!(read_response(&mut wire.as_slice()), Err(WireError::Protocol(_))));
    }

    #[test]
    fn metrics_text_must_be_utf8_and_fit_the_payload() {
        // a length prefix past the payload is a protocol error, not a panic
        let mut payload = Vec::new();
        put_u32(&mut payload, 1_000);
        payload.extend_from_slice(b"short");
        let mut wire = Vec::new();
        write_frame(&mut wire, 5, OP_METRICS, &payload).unwrap();
        assert!(matches!(read_response(&mut wire.as_slice()), Err(WireError::Protocol(_))));
        // invalid UTF-8 is refused with a typed error
        let mut payload = Vec::new();
        put_u32(&mut payload, 2);
        payload.extend_from_slice(&[0xFF, 0xFE]);
        let mut wire = Vec::new();
        write_frame(&mut wire, 6, OP_METRICS, &payload).unwrap();
        match read_response(&mut wire.as_slice()) {
            Err(WireError::Protocol(m)) => assert!(m.contains("UTF-8"), "{m}"),
            other => panic!("expected UTF-8 rejection, got {other:?}"),
        }
    }

    #[test]
    fn borrowed_writers_match_the_owned_encoding() {
        let mut rng = Rng::seed_from_u64(2);
        let tags = TagDistribution::Uniform.sample_distinct(32, 3, &mut rng);
        let mut owned = Vec::new();
        write_request(&mut owned, 9, &Request::Lookup { tag: tags[0].clone() }).unwrap();
        let mut borrowed = Vec::new();
        write_tag_request(&mut borrowed, 9, OP_LOOKUP, &tags[0]).unwrap();
        assert_eq!(owned, borrowed);
        let mut owned = Vec::new();
        write_request(&mut owned, 10, &Request::LookupBulk { tags: tags.clone() }).unwrap();
        let mut borrowed = Vec::new();
        write_lookup_bulk_request(&mut borrowed, 10, &tags).unwrap();
        assert_eq!(owned, borrowed);
    }

    #[test]
    fn engine_error_codes_roundtrip() {
        for e in [
            EngineError::Full,
            EngineError::Busy,
            EngineError::BadAddress(12345),
            EngineError::TagWidth { got: 64, want: 128 },
            EngineError::Shutdown,
        ] {
            let (code, aux) = engine_error_code(&e);
            assert_eq!(engine_error_from_code(code, aux), Some(e));
        }
        assert_eq!(engine_error_from_code(ERR_PROTOCOL, 0), None);
        // the two overload-adjacent conditions stay distinct on the wire
        assert_ne!(
            engine_error_code(&EngineError::Busy).0,
            engine_error_code(&EngineError::Full).0
        );
        // Persist carries a local-only message: the code roundtrips to the
        // variant, the text stays on the server
        let (code, aux) = engine_error_code(&EngineError::Persist("disk full".into()));
        assert_eq!(code, ERR_PERSIST);
        assert!(matches!(engine_error_from_code(code, aux), Some(EngineError::Persist(_))));
    }

    #[test]
    fn corrupt_checksum_is_a_protocol_error() {
        let mut wire = Vec::new();
        write_request(&mut wire, 1, &Request::Stats).unwrap();
        *wire.last_mut().unwrap() ^= 0xFF; // flip a payload... op byte here
        match read_request(&mut wire.as_slice()) {
            Err(WireError::Protocol(m)) => assert!(m.contains("checksum"), "{m}"),
            other => panic!("expected checksum failure, got {other:?}"),
        }
    }

    #[test]
    fn oversized_and_runt_frames_are_rejected() {
        assert!(check_frame_len(16).is_err());
        assert!(check_frame_len(MAX_FRAME_LEN + 1).is_err());
        assert!(check_frame_len(17).is_ok());
        // a length prefix of garbage magnitude never allocates
        let wire = (u32::MAX).to_le_bytes().to_vec();
        assert!(matches!(read_frame(&mut wire.as_slice()), Err(WireError::Protocol(_))));
    }

    #[test]
    fn count_prefixes_are_bounded_by_payload_size() {
        // a 4-byte payload claiming 13M bulk tags must be rejected before
        // Vec::with_capacity can reserve for it
        let mut payload = Vec::new();
        put_u32(&mut payload, 13_000_000);
        let mut wire = Vec::new();
        write_frame(&mut wire, 3, OP_LOOKUP_BULK, &payload).unwrap();
        assert!(matches!(read_request(&mut wire.as_slice()), Err(WireError::Protocol(_))));
        let mut wire = Vec::new();
        write_frame(&mut wire, 3, OP_LOOKUP_BULK, &payload).unwrap();
        assert!(matches!(read_response(&mut wire.as_slice()), Err(WireError::Protocol(_))));
    }

    #[test]
    fn trailing_payload_bytes_are_rejected() {
        let mut payload = Vec::new();
        Request::Stats.encode_payload(&mut payload);
        payload.push(0xAB);
        let mut wire = Vec::new();
        write_frame(&mut wire, 9, OP_STATS, &payload).unwrap();
        assert!(matches!(read_request(&mut wire.as_slice()), Err(WireError::Protocol(_))));
    }

    #[test]
    fn hostile_tag_tail_bits_are_masked() {
        // 70-bit tag: bits 70..127 of the word image are slack; a peer that
        // sets them must not corrupt BitVec invariants.
        let mut payload = Vec::new();
        put_u32(&mut payload, 70);
        put_u64(&mut payload, u64::MAX);
        put_u64(&mut payload, u64::MAX);
        let tag = take_tag(&mut Cursor::new(&payload)).unwrap();
        assert_eq!(tag.len(), 70);
        assert_eq!(tag.count_ones(), 70, "tail slack must be cleared");
    }

    #[test]
    fn hellos_roundtrip_and_reject_bad_magic() {
        let mut wire = Vec::new();
        write_client_hello(&mut wire).unwrap();
        assert_eq!(wire.len(), 8);
        let version = parse_client_hello(&<[u8; 8]>::try_from(&wire[..]).unwrap()).unwrap();
        assert_eq!(version, VERSION);
        let mut bad = <[u8; 8]>::try_from(&wire[..]).unwrap();
        bad[0] = b'X';
        assert!(matches!(parse_client_hello(&bad), Err(WireError::Protocol(_))));

        let hello = ServerHello {
            version: VERSION,
            busy: false,
            multiplex: true,
            shards: 4,
            bank_m: 64,
            tag_bits: 32,
        };
        let mut wire = Vec::new();
        write_server_hello(&mut wire, &hello).unwrap();
        assert_eq!(read_server_hello(&mut wire.as_slice()).unwrap(), hello);
        assert_eq!(wire[6], 0b10, "multiplex is bit 1 of the flags word");
        let busy = ServerHello { busy: true, multiplex: false, ..hello };
        let mut wire2 = Vec::new();
        write_server_hello(&mut wire2, &busy).unwrap();
        assert_eq!(read_server_hello(&mut wire2.as_slice()).unwrap(), busy);
        assert_eq!(wire2[6], 0b01, "busy is bit 0 of the flags word");
        wire[2] = b'Z';
        assert!(matches!(read_server_hello(&mut wire.as_slice()), Err(WireError::Protocol(_))));
    }
}
