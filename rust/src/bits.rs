//! Minimal bit-vector utilities shared by the CNN (weight rows, activation
//! maps) and the CAM (tags, compare-enable masks).
//!
//! Bits are packed little-endian into `u64` words: bit `i` lives in word
//! `i / 64` at position `i % 64`.  The hot loops of the native decode path
//! ([`crate::cnn`]) operate directly on the word slices, so the layout here
//! *is* the performance contract.


/// A fixed-length packed bit vector.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

impl BitVec {
    /// All-zeros vector of `len` bits.
    pub fn zeros(len: usize) -> Self {
        BitVec { words: vec![0; len.div_ceil(64)], len }
    }

    /// All-ones vector of `len` bits (trailing bits in the last word clear).
    pub fn ones(len: usize) -> Self {
        let mut v = BitVec { words: vec![!0u64; len.div_ceil(64)], len };
        v.mask_tail();
        v
    }

    /// Build from explicit bools.
    pub fn from_bools(bits: &[bool]) -> Self {
        let mut v = BitVec::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                v.set(i, true);
            }
        }
        v
    }

    /// Build from the low `len` bits of a u128 (little-endian).
    pub fn from_u128(value: u128, len: usize) -> Self {
        assert!(len <= 128);
        let mut v = BitVec::zeros(len);
        if len > 0 {
            v.words[0] = value as u64;
            if len > 64 {
                v.words[1] = (value >> 64) as u64;
            }
            v.mask_tail();
        }
        v
    }

    fn mask_tail(&mut self) {
        let rem = self.len % 64;
        if rem != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << rem) - 1;
            }
        }
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if `len() == 0`.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read bit `i`.
    ///
    /// Panics if `i >= len()`, in release builds too: indices in
    /// `len..words*64` land inside the word slice, so a `debug_assert!`
    /// alone would let them silently slip through in release.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.len, "bit index {i} out of bounds for BitVec of len {}", self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Write bit `i`.
    ///
    /// Panics if `i >= len()` (see [`Self::get`]): a stray write into the
    /// tail slack of the last word would corrupt `count_ones`/`iter_ones`
    /// without any index ever failing the word-slice bounds check.
    #[inline]
    pub fn set(&mut self, i: usize, value: bool) {
        assert!(i < self.len, "bit index {i} out of bounds for BitVec of len {}", self.len);
        let (w, b) = (i / 64, i % 64);
        if value {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    /// Number of set bits.
    #[inline]
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True if no bit is set.
    pub fn is_zero(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// In-place AND with another vector of the same length.
    #[inline]
    pub fn and_assign(&mut self, other: &BitVec) {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= *b;
        }
    }

    /// In-place OR with another vector of the same length.
    #[inline]
    pub fn or_assign(&mut self, other: &BitVec) {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= *b;
        }
    }

    /// Hamming distance to another vector of the same length.
    pub fn hamming(&self, other: &BitVec) -> usize {
        debug_assert_eq!(self.len, other.len);
        self.words.iter().zip(&other.words).map(|(a, b)| (a ^ b).count_ones() as usize).sum()
    }

    /// Indices of all set bits, ascending.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }

    /// Serialize to bytes: the packed words in ascending order, each as 8
    /// little-endian bytes — `ceil(len/64) * 8` bytes total, independent of
    /// host endianness.  The inverse is [`Self::from_bytes`]; the snapshot
    /// and WAL encodings ([`crate::store`]) depend on this layout being
    /// exact and stable.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.words.len() * 8);
        for &w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// Deserialize from the [`Self::to_bytes`] layout, validating strictly:
    /// the byte count must be exactly `ceil(len/64) * 8`, and any set bit in
    /// the tail slack past `len` is rejected rather than masked — slack
    /// garbage in a stored image means the producer (or the medium) is
    /// corrupt, and masking it would let a damaged file decode "cleanly".
    pub fn from_bytes(bytes: &[u8], len: usize) -> Result<Self, FromBytesError> {
        let expected = len.div_ceil(64) * 8;
        if bytes.len() != expected {
            return Err(FromBytesError::LengthMismatch { expected, got: bytes.len() });
        }
        let mut v = BitVec::zeros(len);
        for (w, chunk) in v.words.iter_mut().zip(bytes.chunks_exact(8)) {
            *w = u64::from_le_bytes(chunk.try_into().expect("chunks_exact yields 8 bytes"));
        }
        let rem = len % 64;
        if rem != 0 {
            if let Some(&last) = v.words.last() {
                if last & !((1u64 << rem) - 1) != 0 {
                    return Err(FromBytesError::TailBitsSet { len });
                }
            }
        }
        Ok(v)
    }

    /// Raw word access (hot-path decode loops).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Mutable raw word access.
    #[inline]
    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }
}

/// Why [`BitVec::from_bytes`] refused the input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FromBytesError {
    /// The byte slice is not exactly `ceil(len/64) * 8` bytes.
    LengthMismatch { expected: usize, got: usize },
    /// A bit past `len` is set in the last word (tail-slack garbage).
    TailBitsSet { len: usize },
}

impl std::fmt::Display for FromBytesError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FromBytesError::LengthMismatch { expected, got } => {
                write!(f, "expected {expected} bytes, got {got}")
            }
            FromBytesError::TailBitsSet { len } => {
                write!(f, "set bits past the {len}-bit length")
            }
        }
    }
}

impl std::error::Error for FromBytesError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut v = BitVec::zeros(130);
        for i in [0, 1, 63, 64, 65, 127, 128, 129] {
            assert!(!v.get(i));
            v.set(i, true);
            assert!(v.get(i));
        }
        assert_eq!(v.count_ones(), 8);
        v.set(64, false);
        assert!(!v.get(64));
        assert_eq!(v.count_ones(), 7);
    }

    #[test]
    fn ones_masks_tail() {
        let v = BitVec::ones(70);
        assert_eq!(v.count_ones(), 70);
        assert_eq!(v.words()[1], (1u64 << 6) - 1);
    }

    #[test]
    fn and_or_semantics() {
        let a = BitVec::from_bools(&[true, true, false, false]);
        let b = BitVec::from_bools(&[true, false, true, false]);
        let mut and = a.clone();
        and.and_assign(&b);
        assert_eq!(and, BitVec::from_bools(&[true, false, false, false]));
        let mut or = a.clone();
        or.or_assign(&b);
        assert_eq!(or, BitVec::from_bools(&[true, true, true, false]));
    }

    #[test]
    fn hamming_distance() {
        let a = BitVec::from_u128(0b1011, 100);
        let b = BitVec::from_u128(0b0110, 100);
        assert_eq!(a.hamming(&b), 3);
        assert_eq!(a.hamming(&a), 0);
    }

    #[test]
    fn iter_ones_crosses_word_boundaries() {
        let mut v = BitVec::zeros(200);
        let idx = [3, 63, 64, 100, 199];
        for &i in &idx {
            v.set(i, true);
        }
        assert_eq!(v.iter_ones().collect::<Vec<_>>(), idx);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn set_in_tail_slack_panics_in_release_too() {
        // len=70 → the word slice holds 128 bits; indices 70..127 must still
        // panic or they would corrupt count_ones/iter_ones undetected.
        let mut v = BitVec::zeros(70);
        v.set(100, true);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn get_in_tail_slack_panics_in_release_too() {
        let v = BitVec::zeros(70);
        v.get(100);
    }

    #[test]
    fn tail_invariant_preserved_under_legal_ops() {
        // count_ones over the tail slack stays exact after heavy set/unset.
        let mut v = BitVec::zeros(70);
        for i in 0..70 {
            v.set(i, true);
        }
        assert_eq!(v.count_ones(), 70);
        assert_eq!(v.iter_ones().count(), 70);
        for i in (0..70).step_by(2) {
            v.set(i, false);
        }
        assert_eq!(v.count_ones(), 35);
    }

    #[test]
    fn byte_roundtrip_at_word_boundaries() {
        // the lengths the snapshot codec cares about: empty, single-bit,
        // one-under/at/over a word boundary, and two full words
        for len in [0usize, 1, 63, 64, 65, 127, 128] {
            let mut v = BitVec::zeros(len);
            for i in (0..len).step_by(7) {
                v.set(i, true);
            }
            if len > 0 {
                v.set(len - 1, true); // exercise the highest legal bit
            }
            let bytes = v.to_bytes();
            assert_eq!(bytes.len(), len.div_ceil(64) * 8, "len={len}");
            assert_eq!(BitVec::from_bytes(&bytes, len).unwrap(), v, "len={len}");
        }
    }

    #[test]
    fn from_bytes_rejects_wrong_byte_count() {
        for len in [0usize, 1, 63, 64, 65, 127, 128] {
            let good = BitVec::zeros(len).to_bytes();
            let mut long = good.clone();
            long.push(0);
            if len > 0 {
                let mut short = good.clone();
                short.pop();
                assert!(
                    matches!(
                        BitVec::from_bytes(&short, len),
                        Err(FromBytesError::LengthMismatch { .. })
                    ),
                    "len={len} short"
                );
            }
            assert!(
                matches!(
                    BitVec::from_bytes(&long, len),
                    Err(FromBytesError::LengthMismatch { .. })
                ),
                "len={len} long"
            );
        }
    }

    #[test]
    fn from_bytes_rejects_tail_slack_garbage() {
        // for every non-word-multiple length, a set bit just past `len`
        // must be rejected, not silently masked
        for len in [1usize, 63, 65, 127] {
            let mut bytes = BitVec::zeros(len).to_bytes();
            let slack_bit = len % 64; // first illegal bit within the last word
            let last_word_byte = (len / 64) * 8 + slack_bit / 8;
            bytes[last_word_byte] |= 1 << (slack_bit % 8);
            assert!(
                matches!(BitVec::from_bytes(&bytes, len), Err(FromBytesError::TailBitsSet { .. })),
                "len={len}"
            );
        }
        // word-multiple lengths have no slack: every bit pattern is legal
        for len in [64usize, 128] {
            let bytes = vec![0xFFu8; len / 8];
            assert_eq!(BitVec::from_bytes(&bytes, len).unwrap().count_ones(), len);
        }
    }

    #[test]
    fn from_u128_layout() {
        let v = BitVec::from_u128(u128::MAX, 128);
        assert_eq!(v.count_ones(), 128);
        let v = BitVec::from_u128(1u128 << 64, 65);
        assert_eq!(v.iter_ones().collect::<Vec<_>>(), vec![64]);
    }
}
