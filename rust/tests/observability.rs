//! End-to-end observability battery: the `/metrics` page a scraper sees
//! must be well-formed Prometheus text exposition (format 0.0.4), its
//! family set is pinned by a golden file, and the recovery gauges must
//! survive a durable restart — the scrape replaces log-grepping for
//! recovery facts.
//!
//! The grammar check is deliberately written against the *text*, not the
//! renderer's internals: every non-comment line must parse as
//! `name[{label="v",…}] value` with a finite value, every series must be
//! preceded by exactly one `# TYPE` header for its family, and no series
//! (name + label set) may repeat.  That is what real scrapers enforce.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use cscam::config::DesignConfig;
use cscam::coordinator::BatchPolicy;
use cscam::obs::{render_prometheus, MetricsHttpServer, RenderFn, PROMETHEUS_CONTENT_TYPE};
use cscam::shard::{PlacementMode, ShardedCamServer, ShardedServerHandle};
use cscam::store::StoreOptions;
use cscam::util::Rng;
use cscam::workload::TagDistribution;

fn fleet_cfg() -> DesignConfig {
    DesignConfig { m: 256, n: 32, zeta: 4, c: 3, l: 4, shards: 4, ..DesignConfig::reference() }
}

/// Spawn an in-memory fleet and run some traffic through it so every
/// counter family has non-trivial values.
fn busy_fleet() -> ShardedServerHandle {
    let fleet =
        ShardedCamServer::new(&fleet_cfg(), PlacementMode::TagHash, BatchPolicy::default())
            .spawn();
    let mut rng = Rng::seed_from_u64(501);
    let tags = TagDistribution::Uniform.sample_distinct(32, 40, &mut rng);
    for t in &tags {
        let _ = fleet.insert(t.clone());
    }
    for t in &tags {
        let _ = fleet.lookup(t.clone());
    }
    let _ = fleet.lookup(TagDistribution::Uniform.sample(32, &mut rng)); // a miss
    fleet
}

/// One parsed sample line: series id (name + label block) and value.
struct Sample {
    family: String,
    series: String,
    value: f64,
}

/// Validate the exposition grammar; returns `(families in # TYPE order,
/// samples)`.  Panics with a line-accurate message on any violation.
fn validate_exposition(text: &str) -> (Vec<(String, String)>, Vec<Sample>) {
    let mut families: Vec<(String, String)> = Vec::new();
    let mut helped: Vec<String> = Vec::new();
    let mut samples: Vec<Sample> = Vec::new();
    for (no, line) in text.lines().enumerate() {
        let no = no + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (Some(name), Some(kind), None) = (it.next(), it.next(), it.next()) else {
                panic!("line {no}: malformed TYPE header: {line}");
            };
            assert!(
                matches!(kind, "counter" | "gauge" | "summary" | "histogram" | "untyped"),
                "line {no}: unknown metric kind {kind}"
            );
            assert!(
                !families.iter().any(|(n, _)| n == name),
                "line {no}: duplicate # TYPE for {name}"
            );
            assert!(
                helped.last().map(String::as_str) == Some(name),
                "line {no}: # TYPE {name} not directly after its # HELP"
            );
            families.push((name.to_string(), kind.to_string()));
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or("");
            assert!(!name.is_empty(), "line {no}: HELP without a metric name");
            helped.push(name.to_string());
            continue;
        }
        assert!(!line.starts_with('#'), "line {no}: unknown comment form: {line}");
        // sample line: name[{labels}] value
        let (series, value_str) = match line.find('}') {
            Some(i) => {
                let (s, v) = line.split_at(i + 1);
                (s, v.trim())
            }
            None => {
                let mut it = line.splitn(2, ' ');
                let s = it.next().unwrap_or("");
                (s, it.next().unwrap_or("").trim())
            }
        };
        let base = series.split('{').next().unwrap_or("");
        // `_count` samples belong to their summary family
        let family = base.strip_suffix("_count").unwrap_or(base);
        assert!(
            families.iter().any(|(n, _)| n == family),
            "line {no}: series {series} has no preceding # TYPE {family}"
        );
        let value: f64 =
            value_str.parse().unwrap_or_else(|e| panic!("line {no}: bad value {value_str}: {e}"));
        assert!(value.is_finite(), "line {no}: non-finite value in {line}");
        if let Some(open) = series.find('{') {
            let labels = &series[open + 1..series.len() - 1];
            for pair in labels.split(',') {
                let (k, v) = pair
                    .split_once('=')
                    .unwrap_or_else(|| panic!("line {no}: malformed label {pair}"));
                assert!(!k.is_empty() && v.starts_with('"') && v.ends_with('"'),
                    "line {no}: malformed label value {pair}");
            }
        }
        assert!(
            !samples.iter().any(|s| s.series == series),
            "line {no}: duplicate series {series}"
        );
        samples.push(Sample {
            family: family.to_string(),
            series: series.to_string(),
            value,
        });
    }
    (families, samples)
}

/// One HTTP/1.1 request against the sidecar; returns (status line, body).
fn scrape(addr: SocketAddr, request: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("connect sidecar");
    s.write_all(request.as_bytes()).expect("send request");
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("read response");
    let head_end = raw.find("\r\n\r\n").expect("response head");
    let status = raw.lines().next().unwrap_or("").to_string();
    (status, raw[head_end + 4..].to_string())
}

#[test]
fn scraped_page_is_valid_exposition_and_matches_the_golden_family_set() {
    let fleet = busy_fleet();
    let scrape_fleet = fleet.clone();
    // synthetic recovery + replication context so the golden file pins
    // the *full* family set, optional blocks included
    let recovery = cscam::shard::FleetRecovery { manifest_loaded: true, banks: vec![] };
    let repl = cscam::obs::ReplStatus {
        epoch: 1,
        lags: vec![cscam::obs::ReplLag { replica: 9, bank: 0, acked_offset: 16, lag_records: 2 }],
    };
    let render: RenderFn = Arc::new(move || match scrape_fleet.fleet_metrics() {
        Some(fm) => render_prometheus(&fm, 64, 32, Some(&recovery), Some(&repl)),
        None => String::new(),
    });
    let sidecar = MetricsHttpServer::spawn("127.0.0.1:0", render).expect("bind sidecar");
    let (status, body) = scrape(sidecar.local_addr(), "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(status.contains("200"), "scrape failed: {status}");

    let (families, samples) = validate_exposition(&body);

    // golden family set: names and kinds, in exposition order
    let golden_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/metrics_series.txt");
    let golden = std::fs::read_to_string(&golden_path)
        .unwrap_or_else(|e| panic!("missing golden file {}: {e}", golden_path.display()));
    let rendered: String =
        families.iter().map(|(n, k)| format!("{n} {k}\n")).collect();
    assert_eq!(
        rendered, golden,
        "family set drifted from tests/golden/metrics_series.txt — if the change \
         is intentional, update the golden file and the README metric table"
    );

    // the traffic pushed through busy_fleet is visible
    let get = |series: &str| samples.iter().find(|s| s.series == series).map(|s| s.value);
    assert!(get("cscam_lookups_total").expect("lookups series") >= 41.0);
    assert!(get("cscam_inserts_total").expect("inserts series") >= 1.0);
    let hit_ratio = get("cscam_hit_ratio").expect("hit ratio");
    assert!((0.0..=1.0).contains(&hit_ratio));
    // per-bank families carry one labelled series per bank
    let banks = samples.iter().filter(|s| s.family == "cscam_bank_hot_fraction").count();
    assert_eq!(banks, 4, "one hot-fraction series per bank");
    let hot_sum: f64 = samples
        .iter()
        .filter(|s| s.family == "cscam_bank_hot_fraction")
        .map(|s| s.value)
        .sum();
    assert!((hot_sum - 1.0).abs() < 1e-9, "bank fractions sum to 1, got {hot_sum}");
    // the replication block renders per-replica, per-bank labelled series
    assert_eq!(get("cscam_repl_epoch"), Some(1.0));
    assert_eq!(get(r#"cscam_repl_acked_offset{replica="9",bank="0"}"#), Some(16.0));
    assert_eq!(get(r#"cscam_repl_lag_records{replica="9",bank="0"}"#), Some(2.0));

    sidecar.shutdown();
    fleet.shutdown().expect("fleet shutdown");
}

#[test]
fn recovery_gauges_survive_a_durable_restart_scrape() {
    let dir = std::env::temp_dir().join(format!("cscam-obs-restart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = fleet_cfg();

    // first life: write some entries straight to the WAL, stop
    let (fleet, _) = ShardedCamServer::open_durable(
        &cfg,
        PlacementMode::TagHash,
        BatchPolicy::default(),
        &dir,
        StoreOptions::default(),
    )
    .unwrap();
    let handle = fleet.spawn();
    let mut rng = Rng::seed_from_u64(502);
    let tags = TagDistribution::Uniform.sample_distinct(32, 30, &mut rng);
    let mut stored = 0usize;
    for t in &tags {
        if handle.insert(t.clone()).is_ok() {
            stored += 1;
        }
    }
    handle.flush_stores().expect("flush WALs");
    drop(handle);

    // second life: recovery facts must be scrapeable, not just logged
    let (fleet2, recovery) = ShardedCamServer::open_durable(
        &cfg,
        PlacementMode::TagHash,
        BatchPolicy::default(),
        &dir,
        StoreOptions::default(),
    )
    .unwrap();
    assert!(recovery.manifest_loaded);
    let handle2 = fleet2.spawn();
    let scrape_fleet = handle2.clone();
    let render: RenderFn = Arc::new(move || match scrape_fleet.fleet_metrics() {
        Some(fm) => render_prometheus(&fm, 64, 32, Some(&recovery), None),
        None => String::new(),
    });
    let sidecar = MetricsHttpServer::spawn("127.0.0.1:0", render).expect("bind sidecar");
    let (status, body) = scrape(sidecar.local_addr(), "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(status.contains("200"), "{status}");
    assert!(status.is_ascii());
    let (_, samples) = validate_exposition(&body);
    let get = |series: &str| samples.iter().find(|s| s.series == series).map(|s| s.value);
    assert_eq!(
        get("cscam_recovery_replayed_records"),
        Some(stored as f64),
        "every acknowledged insert replays on restart"
    );
    assert_eq!(get("cscam_recovery_recovered_entries"), Some(stored as f64));
    assert_eq!(get("cscam_recovery_manifest_loaded"), Some(1.0));
    assert_eq!(get("cscam_recovery_truncated_banks"), Some(0.0));
    // WAL activity of the *current* life shows up once mutations land
    let t = TagDistribution::Uniform.sample(32, &mut rng);
    let _ = handle2.insert(t);
    let (_, body2) = scrape(sidecar.local_addr(), "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
    let (_, samples2) = validate_exposition(&body2);
    let appends = samples2
        .iter()
        .find(|s| s.series == "cscam_wal_appends_total")
        .map(|s| s.value)
        .unwrap_or(0.0);
    assert!(appends >= 1.0, "fresh WAL appends must be visible in the scrape");

    sidecar.shutdown();
    drop(handle2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn content_type_and_error_paths_behave_like_an_http_server() {
    let render: RenderFn = Arc::new(|| "cscam_up 1\n".to_string());
    let sidecar = MetricsHttpServer::spawn("127.0.0.1:0", render).expect("bind");
    let addr = sidecar.local_addr();

    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    assert!(raw.contains(PROMETHEUS_CONTENT_TYPE), "content type pinned: {raw}");
    assert!(raw.contains("Connection: close"));

    let (status, _) = scrape(addr, "GET /not-metrics HTTP/1.1\r\n\r\n");
    assert!(status.contains("404"), "{status}");
    let (status, _) = scrape(addr, "DELETE /metrics HTTP/1.1\r\n\r\n");
    assert!(status.contains("405"), "{status}");
    sidecar.shutdown();
}
