//! Serving metrics: request counters, hit ratio, energy & ambiguity
//! aggregation, host-side latency histogram.


use crate::stats::{Histogram, OnlineStats};

/// Aggregated serving metrics for one engine/server.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub lookups: u64,
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub deletes: u64,
    pub retrains: u64,
    pub batches: u64,
    /// Modelled per-search energy (fJ) — the paper's metric.
    pub energy_fj: OnlineStats,
    /// λ per lookup.
    pub lambda: OnlineStats,
    /// Enabled sub-blocks per lookup.
    pub enabled_blocks: OnlineStats,
    /// Host-side service latency (nanoseconds).
    pub host_latency_ns: Histogram,
    /// Decode batch sizes seen.
    pub batch_size: OnlineStats,
    /// Lookups answered by the bloom pre-filter before decode (definite
    /// misses — zero enabled blocks, zero compared rows).  Drained from
    /// [`crate::coordinator::DecodeScratch::take_prefilter_rejects`] by the
    /// serving layers.
    pub prefilter_rejects: u64,
    /// Lookups shed at the admission queue (`EngineError::Busy`) —
    /// transient overload, the client should retry.
    pub shed_busy: u64,
    /// Inserts refused for want of a free CAM slot (`EngineError::Full`).
    pub shed_full: u64,
    /// WAL appends recorded by this bank's store (0 when volatile).
    pub wal_appends: u64,
    /// Total WAL bytes appended.
    pub wal_appended_bytes: u64,
    /// WAL fsyncs issued (policy-driven `sync_data` calls).
    pub wal_fsyncs: u64,
    /// WAL append (`write(2)`) latency in nanoseconds.
    pub wal_append_ns: Histogram,
    /// WAL fsync latency in nanoseconds.
    pub wal_fsync_ns: Histogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            lookups: 0,
            hits: 0,
            misses: 0,
            inserts: 0,
            deletes: 0,
            retrains: 0,
            batches: 0,
            energy_fj: OnlineStats::new(),
            lambda: OnlineStats::new(),
            enabled_blocks: OnlineStats::new(),
            host_latency_ns: Histogram::log_linear(1 << 30),
            batch_size: OnlineStats::new(),
            prefilter_rejects: 0,
            shed_busy: 0,
            shed_full: 0,
            wal_appends: 0,
            wal_appended_bytes: 0,
            wal_fsyncs: 0,
            wal_append_ns: Histogram::log_linear(1 << 30),
            wal_fsync_ns: Histogram::log_linear(1 << 30),
        }
    }

    /// Record one lookup outcome.
    pub fn record_lookup(&mut self, outcome: &crate::coordinator::engine::LookupOutcome) {
        self.lookups += 1;
        if outcome.addr.is_some() {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        self.energy_fj.push(outcome.energy.total_fj());
        self.lambda.push(outcome.lambda as f64);
        self.enabled_blocks.push(outcome.enabled_blocks as f64);
    }

    /// Record one decode batch dispatch.
    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batch_size.push(size as f64);
    }

    /// Record host-side latency of a served request.
    pub fn record_latency(&mut self, nanos: u64) {
        self.host_latency_ns.record(nanos);
    }

    pub fn hit_ratio(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    /// fJ/bit/search given the array geometry — Table II's metric.
    /// 0.0 (not NaN) on an empty metrics object, so summaries and bench
    /// rows serialized before any lookup stay finite.
    pub fn energy_per_bit(&self, m: usize, n: usize) -> f64 {
        self.energy_fj.mean_or(0.0) / (m as f64 * n as f64)
    }

    /// Snapshot a store's cumulative WAL statistics into this metrics
    /// object (overwrite, not add: the [`crate::store::WalStats`] totals
    /// are already cumulative for the bank; cross-bank aggregation
    /// happens in [`Self::merge`]).
    pub fn absorb_wal(&mut self, w: &crate::store::WalStats) {
        self.wal_appends = w.appends;
        self.wal_appended_bytes = w.appended_bytes;
        self.wal_fsyncs = w.fsyncs;
        self.wal_append_ns = w.append_ns.clone();
        self.wal_fsync_ns = w.fsync_ns.clone();
    }

    /// Merge a peer's metrics (shard aggregation).
    pub fn merge(&mut self, other: &Metrics) {
        self.lookups += other.lookups;
        self.hits += other.hits;
        self.misses += other.misses;
        self.inserts += other.inserts;
        self.deletes += other.deletes;
        self.retrains += other.retrains;
        self.batches += other.batches;
        self.energy_fj.merge(&other.energy_fj);
        self.lambda.merge(&other.lambda);
        self.enabled_blocks.merge(&other.enabled_blocks);
        self.batch_size.merge(&other.batch_size);
        self.host_latency_ns.merge(&other.host_latency_ns);
        self.prefilter_rejects += other.prefilter_rejects;
        self.shed_busy += other.shed_busy;
        self.shed_full += other.shed_full;
        self.wal_appends += other.wal_appends;
        self.wal_appended_bytes += other.wal_appended_bytes;
        self.wal_fsyncs += other.wal_fsyncs;
        self.wal_append_ns.merge(&other.wal_append_ns);
        self.wal_fsync_ns.merge(&other.wal_fsync_ns);
    }

    /// One-line human summary.
    pub fn summary(&self, m: usize, n: usize) -> String {
        format!(
            "lookups={} hits={} ({:.1}%) E={:.4} fJ/bit/search λ̄={:.3} blocks̄={:.3} p50={}ns p99={}ns",
            self.lookups,
            self.hits,
            100.0 * self.hit_ratio(),
            self.energy_per_bit(m, n),
            self.lambda.mean_or(0.0),
            self.enabled_blocks.mean_or(0.0),
            self.host_latency_ns.quantile(0.5),
            self.host_latency_ns.quantile(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EnergyBreakdown;
    use crate::timing::DelayReport;

    fn outcome(hit: bool, energy: f64, lambda: usize) -> crate::coordinator::LookupOutcome {
        crate::coordinator::LookupOutcome {
            addr: hit.then_some(3),
            all_matches: if hit { vec![3] } else { vec![] },
            lambda,
            enabled_blocks: lambda.max(1),
            comparisons: 8,
            energy: EnergyBreakdown { matchline_fj: energy, ..Default::default() },
            delay: DelayReport { cycle_ns: 0.7, latency_ns: 1.3 },
        }
    }

    #[test]
    fn hit_ratio_and_energy() {
        let mut m = Metrics::new();
        m.record_lookup(&outcome(true, 100.0, 2));
        m.record_lookup(&outcome(false, 50.0, 1));
        m.record_lookup(&outcome(true, 150.0, 3));
        assert_eq!(m.lookups, 3);
        assert!((m.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.energy_fj.mean() - 100.0).abs() < 1e-12);
        assert!((m.lambda.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = Metrics::new();
        a.record_lookup(&outcome(true, 10.0, 1));
        let mut b = Metrics::new();
        b.record_lookup(&outcome(false, 30.0, 2));
        b.record_batch(16);
        a.merge(&b);
        assert_eq!(a.lookups, 2);
        assert_eq!(a.batches, 1);
        assert!((a.energy_fj.mean() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_stay_finite() {
        // regression: OnlineStats::mean() is NaN at n=0, which used to
        // leak through energy_per_bit and the summary line
        let m = Metrics::new();
        assert_eq!(m.energy_per_bit(512, 128), 0.0);
        assert_eq!(m.hit_ratio(), 0.0);
        let s = m.summary(512, 128);
        assert!(!s.contains("NaN"), "empty-metrics summary carries NaN: {s}");
    }

    #[test]
    fn merge_adds_shed_and_wal_counters() {
        let mut a = Metrics::new();
        a.shed_busy = 2;
        a.wal_appends = 5;
        a.wal_append_ns.record(700);
        let mut b = Metrics::new();
        b.shed_busy = 1;
        b.shed_full = 4;
        b.wal_appends = 3;
        b.wal_appended_bytes = 96;
        b.wal_fsyncs = 1;
        b.wal_fsync_ns.record(90_000);
        b.prefilter_rejects = 7;
        a.prefilter_rejects = 2;
        a.merge(&b);
        assert_eq!(a.shed_busy, 3);
        assert_eq!(a.shed_full, 4);
        assert_eq!(a.prefilter_rejects, 9);
        assert_eq!(a.wal_appends, 8);
        assert_eq!(a.wal_appended_bytes, 96);
        assert_eq!(a.wal_fsyncs, 1);
        assert_eq!(a.wal_append_ns.total(), 1);
        assert_eq!(a.wal_fsync_ns.total(), 1);
    }

    #[test]
    fn summary_formats() {
        let mut m = Metrics::new();
        m.record_lookup(&outcome(true, 7887.0, 2));
        m.record_latency(1234);
        let s = m.summary(512, 128);
        assert!(s.contains("lookups=1"));
        assert!(s.contains("fJ/bit/search"));
    }
}
