//! L4 — horizontal scale-out: many independent CNN+CAM banks behind one
//! scatter-gather routing front-end.
//!
//! The paper's device already decomposes one array into `β = M/ζ`
//! compare-enabled sub-blocks; this layer applies the same move one level
//! up.  A fleet of `S` banks — each a complete Fig. 1 system with its own
//! clustered network, CAM array, dynamic batcher, writer thread and
//! lookup reader pool — serves a tag space partitioned by a
//! [`ShardRouter`]:
//!
//! * **owner placement** ([`PlacementMode::TagHash`] /
//!   [`PlacementMode::LearnedPrefix`]): a lookup touches exactly one bank,
//!   so search energy stays that of a single `M/S`-entry device while
//!   capacity and throughput scale with `S`;
//! * **broadcast** ([`PlacementMode::Broadcast`]): lookups scatter to
//!   every bank and the answers are gathered — matches are globalized,
//!   [`crate::energy::SearchActivity`] counters and energy sum, timing
//!   takes the slowest bank.
//!
//! * [`placement`] — placement modes and the stable tag-hash.
//! * [`sharded`] — [`ShardedCam`], the synchronous multi-bank core, with
//!   the merge rules and the monolith-equivalence search.
//! * [`server`] — [`ShardedCamServer`] / [`ShardedServerHandle`], the
//!   threaded fleet with per-bank writer threads + reader pools, direct
//!   reads, load shedding and [`FleetMetrics`] aggregation.

pub mod placement;
pub mod server;
pub mod sharded;

pub use placement::{PlacementMode, ShardRouter};
pub use server::{FleetMetrics, FleetRecovery, ShardedCamServer, ShardedServerHandle};
pub use sharded::{ShardedCam, ShardedOutcome};
