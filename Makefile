# Convenience targets for the cscam workspace.

.PHONY: build test artifacts

# Tier-1 gate.
build:
	cargo build --release

test:
	cargo test -q

# Lower the JAX decode/train graphs to HLO text artifacts for the PJRT
# backend (build-time Python; the Rust request path never runs Python).
# Consumed by `cargo run --features pjrt -- serve --pjrt` and the
# pjrt_roundtrip tests.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
