//! L7 — observability: Prometheus-text exposition over the serving
//! metrics, plus a tiny `std::net` HTTP sidecar so curl/Prometheus can
//! scrape a running fleet without speaking CSCM.
//!
//! Two transports share one renderer ([`render_prometheus`]):
//!
//! * the wire op `OP_METRICS` (`crate::net::Request::Metrics`, wire v4)
//!   returns the exposition text in-band on the CSCM port;
//! * `serve --metrics-addr HOST:PORT` spawns [`MetricsHttpServer`], a
//!   plain-HTTP listener answering `GET /metrics` with
//!   `text/plain; version=0.0.4` — the Prometheus text exposition
//!   content type.
//!
//! The exposition is assembled through [`Exposition`], which enforces the
//! format invariants the golden test checks: every series is preceded by
//! exactly one `# TYPE` header, series names are unique per metric, and
//! every value renders finite (the NaN-clamping in
//! [`crate::coordinator::Metrics`] feeds this).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::shard::{FleetMetrics, FleetRecovery};
use crate::stats::Histogram;

/// The Prometheus text exposition content type (format version 0.0.4).
pub const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Quantiles exported for every latency summary series.
const SUMMARY_QUANTILES: [f64; 4] = [0.5, 0.9, 0.99, 0.999];

/// Builder for one exposition page.  Keeps the format honest: a metric
/// must be opened with a `# TYPE` header (exactly once) before its series
/// are emitted, and f64 values are clamped finite.
struct Exposition {
    out: String,
    seen: Vec<String>,
}

impl Exposition {
    fn new() -> Self {
        Exposition { out: String::new(), seen: Vec::new() }
    }

    /// Open a metric family: `# HELP` + `# TYPE` headers.  Debug-asserts
    /// that each family is opened once — duplicate `# TYPE` lines are a
    /// format violation scrapers reject.
    fn family(&mut self, name: &str, kind: &str, help: &str) {
        debug_assert!(
            !self.seen.iter().any(|s| s == name),
            "metric family {name} opened twice"
        );
        self.seen.push(name.to_string());
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// One unlabelled series.
    fn series(&mut self, name: &str, value: f64) {
        self.labelled(name, &[], value);
    }

    /// One series with `label="value"` pairs.
    fn labelled(&mut self, name: &str, labels: &[(&str, String)], value: f64) {
        let v = if value.is_finite() { value } else { 0.0 };
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, val)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(&format!("{k}=\"{val}\""));
            }
            self.out.push('}');
        }
        self.out.push_str(&format!(" {v}\n"));
    }

    /// A latency histogram exported as a Prometheus summary: one series
    /// per quantile plus the `_count` sample.
    fn summary_ns(&mut self, name: &str, help: &str, h: &Histogram) {
        self.family(name, "summary", help);
        for q in SUMMARY_QUANTILES {
            self.labelled(name, &[("quantile", format!("{q}"))], h.quantile(q) as f64);
        }
        self.labelled(&format!("{name}_count"), &[], h.total() as f64);
    }
}

/// Replication progress snapshot for the exposition — produced by
/// [`crate::repl`] (the feed's controller on a primary, the chaser's own
/// cursors on a replica); obs only renders it, so the dependency points
/// repl → obs and the renderer stays usable without a replication role.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ReplStatus {
    /// Fleet epoch this node serves at (promotion increments it; a
    /// subscriber from an older epoch is fenced).
    pub epoch: u64,
    /// Per-subscriber, per-bank progress.  A replica reports one row per
    /// bank with its own id.
    pub lags: Vec<ReplLag>,
}

/// One subscriber's progress on one bank's log.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplLag {
    /// Subscriber id (the `replica` field of its `SubscribeLog` polls).
    pub replica: u64,
    /// Bank index.
    pub bank: u32,
    /// WAL byte offset the subscriber has acknowledged — everything
    /// before it is applied on the replica.
    pub acked_offset: u64,
    /// Complete records appended past the acked offset: the lag.
    pub lag_records: u64,
}

/// Render the fleet's serving metrics as one Prometheus exposition page.
///
/// `bank_m`/`bank_n` are the per-bank geometry (for the modelled
/// fJ/bit/search); `recovery` adds the `cscam_recovery_*` gauges when the
/// fleet was opened durably (the HTTP sidecar has it, the wire op does
/// not — a purely in-memory fleet simply omits the family); `repl` adds
/// the `cscam_repl_*` gauges on a node with a replication role.
pub fn render_prometheus(
    fleet: &FleetMetrics,
    bank_m: usize,
    bank_n: usize,
    recovery: Option<&FleetRecovery>,
    repl: Option<&ReplStatus>,
) -> String {
    let mut e = Exposition::new();
    let a = &fleet.aggregate;

    e.family("cscam_lookups_total", "counter", "Lookups served across the fleet.");
    e.series("cscam_lookups_total", a.lookups as f64);
    e.family("cscam_hits_total", "counter", "Lookups that matched a stored tag.");
    e.series("cscam_hits_total", a.hits as f64);
    e.family("cscam_misses_total", "counter", "Lookups that matched nothing.");
    e.series("cscam_misses_total", a.misses as f64);
    e.family("cscam_inserts_total", "counter", "Acknowledged inserts.");
    e.series("cscam_inserts_total", a.inserts as f64);
    e.family("cscam_deletes_total", "counter", "Acknowledged deletes.");
    e.series("cscam_deletes_total", a.deletes as f64);
    e.family("cscam_batches_total", "counter", "Decode batches dispatched.");
    e.series("cscam_batches_total", a.batches as f64);
    e.family(
        "cscam_prefilter_rejects_total",
        "counter",
        "Lookups answered by the per-bank bloom pre-filter before decode \
         (definite misses: zero enabled blocks, zero compared rows).",
    );
    e.series("cscam_prefilter_rejects_total", a.prefilter_rejects as f64);

    e.family("cscam_hit_ratio", "gauge", "hits / lookups (0 when idle).");
    e.series("cscam_hit_ratio", a.hit_ratio());
    e.family(
        "cscam_energy_fj_per_bit_per_search",
        "gauge",
        "Modelled search energy, femtojoules per bit per search (Table II metric).",
    );
    e.series("cscam_energy_fj_per_bit_per_search", a.energy_per_bit(bank_m, bank_n));
    e.family("cscam_lambda_mean", "gauge", "Mean ambiguity (candidate clusters) per lookup.");
    e.series("cscam_lambda_mean", a.lambda.mean_or(0.0));
    e.family(
        "cscam_enabled_blocks_mean",
        "gauge",
        "Mean compare-enabled CAM sub-blocks per lookup.",
    );
    e.series("cscam_enabled_blocks_mean", a.enabled_blocks.mean_or(0.0));

    e.family(
        "cscam_shed_total",
        "counter",
        "Requests refused by admission control, by reason (busy = queue at \
         capacity, full = no free CAM slot).",
    );
    e.labelled("cscam_shed_total", &[("reason", "busy".into())], a.shed_busy as f64);
    e.labelled("cscam_shed_total", &[("reason", "full".into())], a.shed_full as f64);

    e.family("cscam_bank_lookups_total", "counter", "Lookups served, per bank.");
    for (i, m) in fleet.per_bank.iter().enumerate() {
        e.labelled("cscam_bank_lookups_total", &[("bank", format!("{i}"))], m.lookups as f64);
    }
    e.family(
        "cscam_bank_hot_fraction",
        "gauge",
        "Fraction of all fleet lookups served by each bank (1/S when balanced).",
    );
    for (i, m) in fleet.per_bank.iter().enumerate() {
        let f = if a.lookups == 0 { 0.0 } else { m.lookups as f64 / a.lookups as f64 };
        e.labelled("cscam_bank_hot_fraction", &[("bank", format!("{i}"))], f);
    }
    e.family(
        "cscam_hot_fraction",
        "gauge",
        "Fraction of fleet lookups served by the hottest bank.",
    );
    e.series("cscam_hot_fraction", fleet.hot_fraction());

    e.summary_ns(
        "cscam_host_latency_ns",
        "Host-side service latency per request, nanoseconds.",
        &a.host_latency_ns,
    );

    e.family("cscam_wal_appends_total", "counter", "WAL frames appended across the fleet.");
    e.series("cscam_wal_appends_total", a.wal_appends as f64);
    e.family("cscam_wal_appended_bytes_total", "counter", "WAL bytes appended.");
    e.series("cscam_wal_appended_bytes_total", a.wal_appended_bytes as f64);
    e.family("cscam_wal_fsyncs_total", "counter", "WAL fsync (sync_data) calls issued.");
    e.series("cscam_wal_fsyncs_total", a.wal_fsyncs as f64);
    e.summary_ns(
        "cscam_wal_append_ns",
        "WAL append write(2) latency, nanoseconds.",
        &a.wal_append_ns,
    );
    e.summary_ns(
        "cscam_wal_fsync_ns",
        "WAL fsync latency, nanoseconds.",
        &a.wal_fsync_ns,
    );

    if let Some(rec) = recovery {
        e.family(
            "cscam_recovery_replayed_records",
            "gauge",
            "WAL records replayed at the last open, across all banks.",
        );
        e.series("cscam_recovery_replayed_records", rec.total_records() as f64);
        e.family(
            "cscam_recovery_recovered_entries",
            "gauge",
            "Live entries recovered at the last open.",
        );
        e.series("cscam_recovery_recovered_entries", rec.total_occupancy() as f64);
        e.family(
            "cscam_recovery_truncated_banks",
            "gauge",
            "Banks whose WAL had a torn tail truncated at the last open.",
        );
        e.series("cscam_recovery_truncated_banks", rec.truncated_banks() as f64);
        e.family(
            "cscam_recovery_snapshots_loaded",
            "gauge",
            "Banks restored from a snapshot at the last open.",
        );
        e.series(
            "cscam_recovery_snapshots_loaded",
            rec.banks.iter().filter(|b| b.snapshot_loaded).count() as f64,
        );
        e.family(
            "cscam_recovery_manifest_loaded",
            "gauge",
            "1 when the fleet manifest already existed (restart), 0 on first boot.",
        );
        e.series("cscam_recovery_manifest_loaded", if rec.manifest_loaded { 1.0 } else { 0.0 });
    }

    if let Some(rs) = repl {
        e.family(
            "cscam_repl_epoch",
            "gauge",
            "Fleet epoch this node serves at (promotion increments it; \
             subscribers from older epochs are fenced).",
        );
        e.series("cscam_repl_epoch", rs.epoch as f64);
        e.family(
            "cscam_repl_acked_offset",
            "gauge",
            "WAL byte offset each subscriber has acknowledged, per replica and bank.",
        );
        for l in &rs.lags {
            e.labelled(
                "cscam_repl_acked_offset",
                &[("replica", format!("{}", l.replica)), ("bank", format!("{}", l.bank))],
                l.acked_offset as f64,
            );
        }
        e.family(
            "cscam_repl_lag_records",
            "gauge",
            "Records appended past the acked offset — each subscriber's lag, \
             per replica and bank.",
        );
        for l in &rs.lags {
            e.labelled(
                "cscam_repl_lag_records",
                &[("replica", format!("{}", l.replica)), ("bank", format!("{}", l.bank))],
                l.lag_records as f64,
            );
        }
    }

    e.out
}

// ------------------------------------------------------------- sidecar

/// The renderer a [`MetricsHttpServer`] calls per scrape.  A closure so
/// the listener needs no knowledge of fleets or recovery reports — the
/// caller captures whatever feeds its page.
pub type RenderFn = Arc<dyn Fn() -> String + Send + Sync>;

/// Largest request head we will buffer before answering 400 — scrape
/// requests are one short line plus a few headers.
const MAX_REQUEST_BYTES: usize = 8192;

/// Accept-loop poll interval while idle (the listener is non-blocking so
/// shutdown never hangs on `accept`).
const POLL_INTERVAL: Duration = Duration::from_millis(25);

/// A minimal plain-HTTP metrics listener: `GET /metrics` answers the
/// rendered exposition, anything else 404.  One request per connection
/// (`Connection: close`), served inline on the accept thread — scrapes
/// are rare and tiny, so no pool is warranted.
pub struct MetricsHttpServer;

/// Handle to a running sidecar; dropping it stops the listener.
pub struct MetricsHttpHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl MetricsHttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and serve scrapes of `render` on
    /// a background thread until the handle is shut down or dropped.
    pub fn spawn<A: ToSocketAddrs>(addr: A, render: RenderFn) -> std::io::Result<MetricsHttpHandle> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("cscam-metrics-http".into())
            .spawn(move || accept_loop(&listener, &stop2, &render))?;
        Ok(MetricsHttpHandle { addr, stop, join: Some(join) })
    }
}

impl MetricsHttpHandle {
    /// The bound address (port resolved when the caller asked for `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for MetricsHttpHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(listener: &TcpListener, stop: &AtomicBool, render: &RenderFn) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => serve_one(stream, render),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL_INTERVAL);
            }
            Err(_) => std::thread::sleep(POLL_INTERVAL),
        }
    }
}

/// Read the request head (bounded), answer, close.  Errors are dropped:
/// a broken scrape connection must never disturb the serving process.
fn serve_one(mut stream: TcpStream, render: &RenderFn) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    let complete = loop {
        match stream.read(&mut buf) {
            Ok(0) => break false,
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                if head.windows(4).any(|w| w == b"\r\n\r\n") {
                    break true;
                }
                if head.len() > MAX_REQUEST_BYTES {
                    break false;
                }
            }
            Err(_) => break false,
        }
    };
    if !complete {
        respond(&mut stream, 400, "Bad Request", "text/plain", "bad request\n");
        return;
    }
    let first_line = head
        .split(|&b| b == b'\n')
        .next()
        .map(|l| String::from_utf8_lossy(l).trim().to_string())
        .unwrap_or_default();
    let mut parts = first_line.split_whitespace();
    match (parts.next(), parts.next()) {
        (Some("GET"), Some("/metrics")) => {
            let body = render();
            respond(&mut stream, 200, "OK", PROMETHEUS_CONTENT_TYPE, &body);
        }
        (Some("GET"), Some(_)) => {
            respond(&mut stream, 404, "Not Found", "text/plain", "only /metrics here\n");
        }
        _ => {
            respond(&mut stream, 405, "Method Not Allowed", "text/plain", "GET only\n");
        }
    }
}

fn respond(stream: &mut TcpStream, code: u16, reason: &str, ctype: &str, body: &str) {
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;

    fn sample_fleet() -> FleetMetrics {
        let mut b0 = Metrics::new();
        b0.lookups = 30;
        b0.hits = 24;
        b0.misses = 6;
        b0.inserts = 10;
        b0.shed_busy = 2;
        b0.host_latency_ns.record(1800);
        b0.wal_appends = 10;
        b0.wal_appended_bytes = 420;
        b0.wal_fsyncs = 5;
        b0.wal_fsync_ns.record(120_000);
        let mut b1 = Metrics::new();
        b1.lookups = 10;
        b1.hits = 10;
        b1.shed_full = 1;
        let mut aggregate = Metrics::new();
        aggregate.merge(&b0);
        aggregate.merge(&b1);
        FleetMetrics { per_bank: vec![b0, b1], aggregate }
    }

    #[test]
    fn exposition_carries_the_headline_series() {
        let text = render_prometheus(&sample_fleet(), 64, 32, None, None);
        for needle in [
            "# TYPE cscam_lookups_total counter",
            "cscam_lookups_total 40",
            "cscam_hit_ratio 0.85",
            "cscam_shed_total{reason=\"busy\"} 2",
            "cscam_shed_total{reason=\"full\"} 1",
            "cscam_bank_hot_fraction{bank=\"0\"} 0.75",
            "cscam_bank_lookups_total{bank=\"1\"} 10",
            "cscam_hot_fraction 0.75",
            "# TYPE cscam_wal_fsync_ns summary",
            "cscam_wal_fsync_ns_count 5",
            "cscam_wal_appended_bytes_total 420",
            "cscam_host_latency_ns{quantile=\"0.5\"}",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        assert!(!text.contains("cscam_recovery_"), "no recovery block without a report");
        assert!(!text.contains("cscam_repl_"), "no replication block without a status");
        assert!(!text.contains("NaN"));
    }

    #[test]
    fn repl_block_renders_per_replica_per_bank_series() {
        let rs = ReplStatus {
            epoch: 3,
            lags: vec![
                ReplLag { replica: 7, bank: 0, acked_offset: 16, lag_records: 0 },
                ReplLag { replica: 7, bank: 1, acked_offset: 96, lag_records: 4 },
            ],
        };
        let text = render_prometheus(&sample_fleet(), 64, 32, None, Some(&rs));
        for needle in [
            "# TYPE cscam_repl_epoch gauge",
            "cscam_repl_epoch 3",
            "cscam_repl_acked_offset{replica=\"7\",bank=\"0\"} 16",
            "cscam_repl_acked_offset{replica=\"7\",bank=\"1\"} 96",
            "cscam_repl_lag_records{replica=\"7\",bank=\"0\"} 0",
            "cscam_repl_lag_records{replica=\"7\",bank=\"1\"} 4",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn recovery_block_renders_when_a_report_is_supplied() {
        use crate::store::RecoveryReport;
        let rec = FleetRecovery {
            manifest_loaded: true,
            banks: vec![
                RecoveryReport {
                    snapshot_loaded: true,
                    wal_records: 7,
                    discarded_records: 0,
                    truncated_bytes: 12,
                    occupancy: 5,
                },
                RecoveryReport {
                    snapshot_loaded: false,
                    wal_records: 3,
                    discarded_records: 0,
                    truncated_bytes: 0,
                    occupancy: 3,
                },
            ],
        };
        let text = render_prometheus(&sample_fleet(), 64, 32, Some(&rec), None);
        assert!(text.contains("cscam_recovery_replayed_records 10"));
        assert!(text.contains("cscam_recovery_recovered_entries 8"));
        assert!(text.contains("cscam_recovery_truncated_banks 1"));
        assert!(text.contains("cscam_recovery_snapshots_loaded 1"));
        assert!(text.contains("cscam_recovery_manifest_loaded 1"));
    }

    #[test]
    fn empty_fleet_renders_finite_values() {
        let fleet = FleetMetrics {
            per_bank: vec![Metrics::new()],
            aggregate: Metrics::new(),
        };
        let text = render_prometheus(&fleet, 64, 32, None, None);
        assert!(!text.contains("NaN"), "empty fleet must render finite:\n{text}");
        assert!(text.contains("cscam_energy_fj_per_bit_per_search 0"));
    }

    #[test]
    fn http_sidecar_answers_a_scrape() {
        let render: RenderFn =
            Arc::new(|| render_prometheus(&sample_fleet(), 64, 32, None, None));
        let h = MetricsHttpServer::spawn("127.0.0.1:0", render).unwrap();
        let addr = h.local_addr();

        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "got:\n{resp}");
        assert!(resp.contains(PROMETHEUS_CONTENT_TYPE));
        assert!(resp.contains("cscam_lookups_total 40"));

        // any other path is a 404, not a hang or a panic
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET /other HTTP/1.1\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 404"));

        // POST is refused with 405
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "POST /metrics HTTP/1.1\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 405"));

        h.shutdown();
    }
}
