//! End-to-end three-layer integration: the AOT artifacts produced by
//! `python/compile/aot.py` (L2/L1) must decode *bit-identically* to the
//! native bit-packed CNN (L3's reference path).
//!
//! Requires the `pjrt` cargo feature (this whole file compiles away without
//! it) and `make artifacts` to have run; every test self-skips when the
//! artifacts are missing.

#![cfg(feature = "pjrt")]

use cscam::bits::BitVec;
use cscam::cnn::ClusteredNetwork;
use cscam::config::DesignConfig;
use cscam::coordinator::{BatchPolicy, CamServer, DecodeBackend, LookupEngine};
use cscam::runtime::{artifacts_available, default_artifact_dir, ArtifactStore};
use cscam::util::Rng;
use cscam::workload::TagDistribution;

fn store_or_skip() -> Option<ArtifactStore> {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(ArtifactStore::load(&default_artifact_dir()).expect("artifacts load"))
}

/// Build a trained network matching the artifact geometry plus the entry
/// list used to train it.
fn trained_network(store: &ArtifactStore, seed: u64) -> (ClusteredNetwork, Vec<Vec<u16>>) {
    let cfg = &store.manifest().config;
    let mut rng = Rng::seed_from_u64(seed);
    let mut net = ClusteredNetwork::new(cfg.c, cfg.l, cfg.m, cfg.zeta);
    let mut entries = Vec::with_capacity(cfg.m);
    for addr in 0..cfg.m {
        let idx: Vec<u16> = (0..cfg.c).map(|_| rng.gen_range(cfg.l) as u16).collect();
        net.train(&idx, addr);
        entries.push(idx);
    }
    (net, entries)
}

#[test]
fn artifact_decode_matches_native_bit_for_bit() {
    let Some(mut store) = store_or_skip() else { return };
    let (net, entries) = trained_network(&store, 42);
    store.set_weights(&net.weight_rows()).expect("upload weights");

    let cfg = store.manifest().config.clone();
    let mut rng = Rng::seed_from_u64(7);
    // mix of stored and random reduced tags, across every compiled batch size
    for &batch in &store.batch_sizes() {
        let queries: Vec<Vec<u16>> = (0..batch)
            .map(|i| {
                if i % 2 == 0 {
                    entries[rng.gen_range(entries.len())].clone()
                } else {
                    (0..cfg.c).map(|_| rng.gen_range(cfg.l) as u16).collect()
                }
            })
            .collect();
        let out = store.decode(&queries).expect("pjrt decode");
        assert_eq!(out.enables.len(), batch);
        for (i, q) in queries.iter().enumerate() {
            let native = net.decode(q);
            assert_eq!(out.lambda[i] as usize, native.lambda, "λ mismatch, batch {batch} q {i}");
            assert_eq!(out.enables[i], native.enables, "enable mismatch, batch {batch} q {i}");
        }
    }
}

#[test]
fn artifact_decode_pads_partial_batches() {
    let Some(mut store) = store_or_skip() else { return };
    let (net, entries) = trained_network(&store, 1);
    store.set_weights(&net.weight_rows()).expect("upload weights");
    // 3 queries → padded to the smallest compiled batch ≥ 3
    let queries: Vec<Vec<u16>> = entries[..3].to_vec();
    let out = store.decode(&queries).expect("decode");
    assert_eq!(out.enables.len(), 3);
    assert_eq!(out.lambda.len(), 3);
    for (i, q) in queries.iter().enumerate() {
        assert_eq!(out.lambda[i] as usize, net.decode(q).lambda);
    }
}

#[test]
fn artifact_train_matches_native_training() {
    let Some(mut store) = store_or_skip() else { return };
    let cfg = store.manifest().config.clone();
    let mut rng = Rng::seed_from_u64(9);
    let idx: Vec<Vec<u16>> = (0..cfg.m)
        .map(|_| (0..cfg.c).map(|_| rng.gen_range(cfg.l) as u16).collect())
        .collect();
    let addr: Vec<u32> = (0..cfg.m as u32).collect();

    let rows = store.train(&idx, &addr).expect("pjrt train");

    let mut net = ClusteredNetwork::new(cfg.c, cfg.l, cfg.m, cfg.zeta);
    for (a, i) in idx.iter().enumerate() {
        net.train(i, a);
    }
    let want_rows = net.weight_rows();
    assert_eq!(rows.len(), want_rows.len());
    for (r, (got, want)) in rows.iter().zip(want_rows.iter()).enumerate() {
        assert_eq!(got, want, "weight row {r} mismatch");
    }
}

#[test]
fn served_lookups_agree_between_backends() {
    let Some(store) = store_or_skip() else { return };
    let mcfg = store.manifest().config.clone();
    let cfg = DesignConfig {
        m: mcfg.m,
        n: 128,
        zeta: mcfg.zeta,
        c: mcfg.c,
        l: mcfg.l,
        ..DesignConfig::reference()
    };

    // identical engines + tag sets on both backends
    let mut rng = Rng::seed_from_u64(21);
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 256, &mut rng);

    let mut native_engine = LookupEngine::new(cfg.clone());
    let mut pjrt_engine = LookupEngine::new(cfg.clone());
    for t in &tags {
        native_engine.insert(t).unwrap();
        pjrt_engine.insert(t).unwrap();
    }
    let native =
        CamServer::with_engine(native_engine, DecodeBackend::Native, BatchPolicy::default())
            .spawn();
    let pjrt =
        CamServer::with_engine(pjrt_engine, DecodeBackend::pjrt(store), BatchPolicy::default())
            .spawn();

    let mut miss_rng = Rng::seed_from_u64(5);
    for i in 0..64 {
        let tag: BitVec = if i % 3 == 0 {
            cscam::workload::random_tag(cfg.n, &mut miss_rng)
        } else {
            tags[i * 3 % tags.len()].clone()
        };
        let a = native.lookup(tag.clone()).unwrap();
        let b = pjrt.lookup(tag).unwrap();
        assert_eq!(a.addr, b.addr, "query {i}");
        assert_eq!(a.lambda, b.lambda, "query {i}");
        assert_eq!(a.enabled_blocks, b.enabled_blocks, "query {i}");
    }
    let pm = pjrt.metrics().unwrap();
    assert_eq!(pm.lookups, 64);
}
