//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every simulation in this crate (Fig. 3 Monte Carlo, workload generation,
//! property tests) is seeded explicitly, so results are bit-reproducible
//! across runs and machines — a requirement for recorded experiments.  The
//! generator is Blackman & Vigna's xoshiro256++ (public domain), which
//! passes BigCrush; SplitMix64 expands the u64 seed into the 256-bit state,
//! as the authors recommend.

/// xoshiro256++ generator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (any u64 is a fine seed, including 0).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next u64, uniform.
    #[inline]
    pub fn gen_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next u32.
    #[inline]
    pub fn gen_u32(&mut self) -> u32 {
        (self.gen_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.gen_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n), exact (classic rejection sampling; the
    /// rejection zone is < 1/2^32 for every n this crate uses).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let x = self.gen_u64();
            if x < zone {
                return (x % n) as usize;
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a decorrelated child generator (for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from_u64(self.gen_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from_u64(123);
        let mut b = Rng::seed_from_u64(123);
        for _ in 0..100 {
            assert_eq!(a.gen_u64(), b.gen_u64());
        }
        let mut c = Rng::seed_from_u64(124);
        assert_ne!(a.gen_u64(), c.gen_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn gen_range_is_roughly_uniform_and_in_bounds() {
        let mut r = Rng::seed_from_u64(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let x = r.gen_range(10);
            counts[x] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!((9_000..11_000).contains(&c), "bucket {i}: {c}");
        }
    }

    #[test]
    fn gen_range_one_is_always_zero() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(r.gen_range(1), 0);
        }
    }

    #[test]
    fn bool_probability() {
        let mut r = Rng::seed_from_u64(11);
        let hits = (0..100_000).filter(|_| r.gen_bool(0.3)).count();
        assert!((29_000..31_000).contains(&hits), "{hits}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, sorted, "astronomically unlikely to be identity");
    }

    #[test]
    fn fork_decorrelates() {
        let mut r = Rng::seed_from_u64(3);
        let mut a = r.fork();
        let mut b = r.fork();
        let same = (0..64).filter(|_| a.gen_u64() == b.gen_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn known_answer_vector() {
        // xoshiro256++ with SplitMix64-expanded seed 0 — regression pin so
        // recorded experiment seeds stay valid.
        let mut r = Rng::seed_from_u64(0);
        let first: Vec<u64> = (0..3).map(|_| r.gen_u64()).collect();
        let mut r2 = Rng::seed_from_u64(0);
        let again: Vec<u64> = (0..3).map(|_| r2.gen_u64()).collect();
        assert_eq!(first, again);
    }
}
