//! The replica side: bootstrap from a primary, chase its log, serve
//! reads, forward writes.
//!
//! [`ReplicaServer::start`] connects to the primary, fetches the fleet
//! manifest (adopting its geometry, placement and epoch), opens its own
//! *durable* local fleet, installs a state transfer for every bank, and
//! then spawns a chaser thread that polls `SubscribeLog` per bank and
//! pushes each batch through
//! [`crate::coordinator::server::ServerHandle::apply_replicated`] — the
//! same barrier ordering as a primary mutation (engine apply → local WAL
//! → RCU publish), so replica reads come off published `SearchState`
//! snapshots exactly like primary reads, and a replica restart recovers
//! from its *own* disk before chasing the delta.
//!
//! A batch that fails to apply (or to decode) never advances the cursor
//! — but because a failed apply may have landed a prefix, the bank is
//! re-bootstrapped from a fresh state transfer rather than re-polled
//! (WAL replay is not idempotent; re-shipping an applied prefix would
//! double-apply it).  A feed answer of `ERR_FENCED` ends the chase for
//! good: the fleet was promoted past this lineage.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::bits::BitVec;
use crate::config::DesignConfig;
use crate::coordinator::engine::LookupEngine;
use crate::coordinator::server::PersistError;
use crate::coordinator::BatchPolicy;
use crate::net::client::LogPoll;
use crate::net::proto::{WireError, SUBSCRIBE_BOOTSTRAP};
use crate::net::CamClient;
use crate::obs::{ReplLag, ReplStatus};
use crate::repl::ReplError;
use crate::shard::{FleetRecovery, ShardedCamServer, ShardedServerHandle};
use crate::store::wal::{self, WAL_HEADER_LEN};
use crate::store::{BankImage, FleetManifest, StoreError, StoreOptions};

/// Tunables of a replica.
#[derive(Debug, Clone)]
pub struct ReplicaOptions {
    /// Subscriber id sent with every poll (labels the primary's
    /// `cscam_repl_*` series).
    pub replica_id: u64,
    /// Sleep between caught-up chase passes (and after an unreachable
    /// upstream, before retrying).
    pub poll_interval: Duration,
    /// The replica's own durability options (its WAL/snapshot cadence is
    /// independent of the primary's).
    pub store: StoreOptions,
    /// Batcher policy of the local bank writer threads.
    pub policy: BatchPolicy,
    /// Reader-pool size per bank (0 = engine-thread reads).
    pub readers: usize,
}

impl Default for ReplicaOptions {
    fn default() -> Self {
        ReplicaOptions {
            replica_id: u64::from(std::process::id()),
            poll_interval: Duration::from_millis(20),
            store: StoreOptions::default(),
            policy: BatchPolicy::default(),
            readers: 0,
        }
    }
}

/// Per-bank chase cursor: the primary's `(generation, offset)` this
/// replica has fully applied.  `offset == SUBSCRIBE_BOOTSTRAP` marks a
/// bank awaiting a (re-)bootstrap.
type Cursor = (u64, u64);

struct ChaseState {
    cursors: Vec<Cursor>,
    lags: Vec<u64>,
    fenced: Option<u64>,
    caught_up: bool,
    applied: u64,
}

fn with_state<R>(state: &Mutex<ChaseState>, f: impl FnOnce(&mut ChaseState) -> R) -> R {
    f(&mut state.lock().unwrap_or_else(|p| p.into_inner()))
}

/// A running read replica: a durable local fleet plus the chaser thread
/// keeping it converged with the primary's log.
pub struct ReplicaServer {
    fleet: ShardedServerHandle,
    recovery: FleetRecovery,
    upstream: String,
    epoch: u64,
    replica_id: u64,
    stop: Arc<AtomicBool>,
    state: Arc<Mutex<ChaseState>>,
    chaser: Option<JoinHandle<()>>,
}

impl ReplicaServer {
    /// Bootstrap from the primary at `upstream` into the local directory
    /// `dir` and start chasing.  Returns once every bank holds a state
    /// transfer (reads served after this are a consistent-if-lagging view
    /// of the primary); the chaser converges the remaining delta in the
    /// background.
    pub fn start(
        upstream: &str,
        dir: &Path,
        opts: ReplicaOptions,
    ) -> Result<ReplicaServer, ReplError> {
        let mut client = CamClient::connect(upstream)?;
        let manifest = fetch_manifest(&mut client, opts.replica_id)?;
        let epoch = manifest.epoch;
        std::fs::create_dir_all(dir).map_err(StoreError::Io)?;
        // adopt the primary's manifest locally — geometry, placement and
        // epoch — so a promoted replica carries the lineage marker
        manifest.store(dir)?;
        let mode = manifest.placement.to_mode(manifest.cfg.n)?;
        let (fleet, recovery) = ShardedCamServer::open_durable(
            &manifest.cfg,
            mode,
            opts.policy,
            dir,
            opts.store,
        )?;
        let fleet = if opts.readers > 0 { fleet.with_readers(opts.readers) } else { fleet };
        let handle = fleet.spawn();
        let per_bank = manifest.cfg.per_bank();

        // bootstrap every bank before anything is served: each gets a
        // state transfer (or the full generation-0 log), so stale local
        // state from an earlier run can never leak into the lineage
        let shards = handle.shard_count();
        let mut cursors = Vec::with_capacity(shards);
        for bank in 0..shards {
            cursors.push(bootstrap_bank(
                &mut client,
                &handle,
                &per_bank,
                opts.replica_id,
                epoch,
                bank as u32,
            )?);
        }

        let state = Arc::new(Mutex::new(ChaseState {
            lags: vec![0; shards],
            cursors,
            fenced: None,
            caught_up: false,
            applied: 0,
        }));
        let stop = Arc::new(AtomicBool::new(false));
        let chaser = {
            let handle = handle.clone();
            let state = Arc::clone(&state);
            let stop = Arc::clone(&stop);
            let poll = opts.poll_interval;
            let replica_id = opts.replica_id;
            std::thread::Builder::new()
                .name("cscam-repl-chaser".into())
                .spawn(move || {
                    chase(client, handle, per_bank, state, stop, replica_id, epoch, poll)
                })
                .map_err(StoreError::Io)?
        };

        Ok(ReplicaServer {
            fleet: handle,
            recovery,
            upstream: upstream.to_string(),
            epoch,
            replica_id: opts.replica_id,
            stop,
            state,
            chaser: Some(chaser),
        })
    }

    /// The local fleet handle — bind a [`crate::net::CamTcpServer`] over
    /// a clone of this to serve wire lookups.
    pub fn fleet(&self) -> ShardedServerHandle {
        self.fleet.clone()
    }

    /// What the local durable open recovered (feeds `cscam_recovery_*`).
    pub fn recovery(&self) -> &FleetRecovery {
        &self.recovery
    }

    /// The fleet epoch adopted at bootstrap.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// A forwarder for this replica's upstream, for the TCP front-end's
    /// replica role.
    pub fn forwarder(&self) -> WriteForwarder {
        WriteForwarder::new(self.upstream.clone())
    }

    /// `Some(server_epoch)` once the feed fenced this replica off (the
    /// fleet was promoted past this lineage); the chase has stopped.
    pub fn fenced(&self) -> Option<u64> {
        with_state(&self.state, |s| s.fenced)
    }

    /// Records applied through the chase so far (excludes bootstrap
    /// state transfers).
    pub fn applied_records(&self) -> u64 {
        with_state(&self.state, |s| s.applied)
    }

    /// This replica's own progress view for the exposition: one row per
    /// bank under its own replica id.
    pub fn status(&self) -> ReplStatus {
        status_of(&self.state, self.epoch, self.replica_id)
    }

    /// A `'static` snapshotter of [`ReplicaServer::status`] for a metrics
    /// sidecar's render closure: shares the chase state, so it stays
    /// valid while the server runs and goes quiet after shutdown.
    pub fn status_fn(&self) -> impl Fn() -> ReplStatus + Send + Sync + 'static {
        let state = Arc::clone(&self.state);
        let (epoch, replica) = (self.epoch, self.replica_id);
        move || status_of(&state, epoch, replica)
    }

    /// Block until a full chase pass found every bank caught up (empty
    /// batch, zero remaining), or `timeout` passes.  Returns whether it
    /// converged.  A fence ends the wait immediately with `false`.
    pub fn wait_caught_up(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            let (caught_up, fenced) = with_state(&self.state, |s| (s.caught_up, s.fenced));
            if fenced.is_some() {
                return false;
            }
            if caught_up {
                return true;
            }
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Stop the chase and shut the local fleet down (drain + WAL flush).
    pub fn shutdown(mut self) -> Result<(), PersistError> {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.chaser.take() {
            let _ = t.join();
        }
        self.fleet.shutdown()
    }
}

/// Build the per-bank progress rows out of the chase state.
fn status_of(state: &Mutex<ChaseState>, epoch: u64, replica: u64) -> ReplStatus {
    with_state(state, |s| ReplStatus {
        epoch,
        lags: s
            .cursors
            .iter()
            .zip(&s.lags)
            .enumerate()
            .map(|(bank, (&(_, offset), &lag))| ReplLag {
                replica,
                bank: bank as u32,
                acked_offset: if offset == SUBSCRIBE_BOOTSTRAP { 0 } else { offset },
                lag_records: lag,
            })
            .collect(),
    })
}

/// Fetch and parse the primary's manifest via the pseudo-bank poll.
fn fetch_manifest(client: &mut CamClient, replica_id: u64) -> Result<FleetManifest, ReplError> {
    match client.subscribe_log(
        replica_id,
        0,
        crate::net::proto::REPL_MANIFEST_BANK,
        0,
        SUBSCRIBE_BOOTSTRAP,
    )? {
        LogPoll::Snapshot { image, .. } => {
            let text = String::from_utf8(image)
                .map_err(|_| ReplError::Protocol("manifest transfer is not UTF-8".into()))?;
            Ok(FleetManifest::from_kv(&text)?)
        }
        other => Err(ReplError::Protocol(format!(
            "manifest poll answered {other:?}, expected a snapshot transfer"
        ))),
    }
}

/// The empty per-bank state both sides are born with
/// ([`LookupEngine::new`] is deterministic for a given config), stamped
/// with the primary log's generation — installing it resets any stale
/// local state *and* aligns the local WAL generation before a
/// bootstrap-by-log-replay.
fn fresh_image(per_bank: &DesignConfig, generation: u64) -> BankImage {
    let mut img = BankImage::from_engine(&LookupEngine::new(per_bank.clone()));
    img.wal_generation = generation;
    img
}

/// Bootstrap one bank: install a state transfer (or the fresh state plus
/// the shipped generation-0 log) and return the chase cursor.
fn bootstrap_bank(
    client: &mut CamClient,
    handle: &ShardedServerHandle,
    per_bank: &DesignConfig,
    replica_id: u64,
    epoch: u64,
    bank: u32,
) -> Result<Cursor, ReplError> {
    match client.subscribe_log(replica_id, epoch, bank, 0, SUBSCRIBE_BOOTSTRAP)? {
        LogPoll::Snapshot { generation, image } => {
            let img = BankImage::decode(&image)?;
            handle.bank(bank as usize).install_image(img)?;
            Ok((generation, WAL_HEADER_LEN))
        }
        LogPoll::Batch { generation, next_offset, remaining: _, frames } => {
            handle.bank(bank as usize).install_image(fresh_image(per_bank, generation))?;
            let records = wal::decode_frames(&frames)?;
            handle.bank(bank as usize).apply_replicated(records)?;
            Ok((generation, next_offset))
        }
        LogPoll::Fenced { server_epoch } => {
            Err(ReplError::Fenced { local: epoch, server: server_epoch })
        }
    }
}

/// The chase loop: one poll per bank per pass, sleeping only when a full
/// pass found every bank caught up (or the upstream unreachable).
#[allow(clippy::too_many_arguments)]
fn chase(
    mut client: CamClient,
    handle: ShardedServerHandle,
    per_bank: DesignConfig,
    state: Arc<Mutex<ChaseState>>,
    stop: Arc<AtomicBool>,
    replica_id: u64,
    epoch: u64,
    poll: Duration,
) {
    let shards = handle.shard_count();
    while !stop.load(Ordering::Acquire) {
        let mut caught_up = true;
        for bank in 0..shards {
            if stop.load(Ordering::Acquire) {
                return;
            }
            let (gen, off) = with_state(&state, |s| s.cursors[bank]);
            let bootstrapping = off == SUBSCRIBE_BOOTSTRAP;
            match client.subscribe_log(replica_id, epoch, bank as u32, gen, off) {
                Ok(LogPoll::Batch { generation, next_offset, remaining, frames }) => {
                    if remaining > 0 {
                        caught_up = false;
                    }
                    if frames.is_empty() && !bootstrapping {
                        with_state(&state, |s| s.lags[bank] = remaining);
                        continue;
                    }
                    caught_up = false;
                    if bootstrapping {
                        // bootstrap answered by log replay: reset to the
                        // fresh state first (see `fresh_image`)
                        if let Err(e) =
                            handle.bank(bank).install_image(fresh_image(&per_bank, generation))
                        {
                            eprintln!("cscam-repl: bank {bank} bootstrap reset failed: {e}");
                            continue; // cursor still says bootstrap; retry
                        }
                    }
                    match wal::decode_frames(&frames) {
                        Ok(records) => match handle.bank(bank).apply_replicated(records) {
                            Ok(n) => with_state(&state, |s| {
                                s.applied += n;
                                s.cursors[bank] = (generation, next_offset);
                                s.lags[bank] = remaining;
                            }),
                            Err(e) => {
                                // a failed apply may have landed a prefix;
                                // re-shipping it would double-apply, so the
                                // bank restarts from a state transfer
                                eprintln!(
                                    "cscam-repl: bank {bank} apply failed ({e}); re-bootstrapping"
                                );
                                with_state(&state, |s| {
                                    s.cursors[bank] = (generation, SUBSCRIBE_BOOTSTRAP)
                                });
                            }
                        },
                        Err(e) => {
                            eprintln!(
                                "cscam-repl: bank {bank} shipped frames corrupt ({e}); \
                                 re-bootstrapping"
                            );
                            with_state(&state, |s| {
                                s.cursors[bank] = (generation, SUBSCRIBE_BOOTSTRAP)
                            });
                        }
                    }
                }
                Ok(LogPoll::Snapshot { generation, image }) => {
                    // mid-stream restart: the primary compacted past our
                    // cursor and re-ships its current snapshot
                    caught_up = false;
                    match BankImage::decode(&image) {
                        Ok(img) => match handle.bank(bank).install_image(img) {
                            Ok(()) => with_state(&state, |s| {
                                s.cursors[bank] = (generation, WAL_HEADER_LEN);
                                s.lags[bank] = 0;
                            }),
                            Err(e) => {
                                eprintln!("cscam-repl: bank {bank} snapshot install failed: {e}")
                            }
                        },
                        Err(e) => {
                            eprintln!("cscam-repl: bank {bank} shipped snapshot corrupt: {e}")
                        }
                    }
                }
                Ok(LogPoll::Fenced { server_epoch }) => {
                    eprintln!(
                        "cscam-repl: fenced at epoch {epoch} (feed serves {server_epoch}); \
                         chase stopped — this replica keeps serving its last view"
                    );
                    with_state(&state, |s| s.fenced = Some(server_epoch));
                    return;
                }
                Err(_) => {
                    // upstream unreachable — possibly dead, which is what
                    // failover is for: keep serving reads, retry quietly
                    caught_up = false;
                    std::thread::sleep(poll);
                }
            }
        }
        with_state(&state, |s| s.caught_up = caught_up);
        if caught_up {
            std::thread::sleep(poll);
        }
    }
}

/// Forwards mutations from a replica's TCP front-end to its primary over
/// one lazily (re)connected client.  Mutations are never auto-retried
/// (replaying an insert could double-apply); a transport failure poisons
/// the connection so the next write reconnects.
pub struct WriteForwarder {
    upstream: String,
    client: Mutex<Option<CamClient>>,
}

impl WriteForwarder {
    pub fn new(upstream: impl Into<String>) -> WriteForwarder {
        WriteForwarder { upstream: upstream.into(), client: Mutex::new(None) }
    }

    /// The primary this forwarder writes through.
    pub fn upstream(&self) -> &str {
        &self.upstream
    }

    fn with_client<R>(
        &self,
        f: impl FnOnce(&mut CamClient) -> Result<R, WireError>,
    ) -> Result<R, WireError> {
        let mut guard = self.client.lock().unwrap_or_else(|p| p.into_inner());
        if guard.is_none() {
            *guard = Some(CamClient::connect(self.upstream.clone())?);
        }
        let result = match guard.as_mut() {
            Some(c) => f(c),
            None => return Err(WireError::Protocol("forwarder lost its connection".into())),
        };
        if matches!(
            result,
            Err(WireError::Io(_)) | Err(WireError::Protocol(_)) | Err(WireError::Busy)
        ) {
            *guard = None;
        }
        result
    }

    /// Forward an insert; the returned address is the primary's (the
    /// record reaches this replica through the log).
    pub fn insert(&self, tag: &BitVec) -> Result<u64, WireError> {
        self.with_client(|c| c.insert(tag))
    }

    /// Forward a delete by flat global address.
    pub fn delete(&self, addr: u64) -> Result<(), WireError> {
        self.with_client(|c| c.delete(addr))
    }
}
