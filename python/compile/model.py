"""L2 — JAX compute graphs for the clustered-sparse-network CAM classifier.

Build-time only: these functions are lowered once by `compile/aot.py` into HLO
text artifacts that the Rust coordinator loads via PJRT.  Python never runs on
the request path.

Graphs
------
decode(idx, w)        — LD one-hot → GD Pallas kernel → ζ-group enables + λ.
train(idx, addr)      — full retrain of the binary weight matrix.
add_entry(w, idx, a)  — incremental single-entry train (CAM insert path).

`idx` is the reduced-length tag already split into c cluster indices
(B, c) int32 — tag-bit selection is trivial bit surgery done natively by the
Rust coordinator (`cnn::bitselect`); shipping c small integers keeps the
host↔PJRT marshaling minimal (the paper's analogue: only the q reduced bits
enter the CNN block, Fig. 4 left).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.gd_decode import gd_decode, train_weights

__all__ = ["CnnConfig", "local_decode", "decode", "train", "add_entry"]


class CnnConfig:
    """Static CNN geometry (Table I names).

    Attributes:
      m: number of CAM entries (M).
      c: number of P_I clusters.
      l: neurons per cluster (l = 2^k, k bits of tag per cluster).
      zeta: CAM rows per compare-enabled sub-block (ζ); β = M/ζ sub-blocks.
    """

    def __init__(self, m: int = 512, c: int = 3, l: int = 8, zeta: int = 8):
        if m % zeta != 0:
            raise ValueError(f"M={m} must be divisible by zeta={zeta}")
        if l & (l - 1):
            raise ValueError(f"l={l} must be a power of two")
        self.m = m
        self.c = c
        self.l = l
        self.zeta = zeta

    @property
    def q(self) -> int:
        """Reduced-tag length in bits: q = c·log2(l)."""
        return self.c * (self.l.bit_length() - 1)

    @property
    def beta(self) -> int:
        """Number of CAM sub-blocks: β = M/ζ."""
        return self.m // self.zeta

    @property
    def cl(self) -> int:
        return self.c * self.l

    def __repr__(self):
        return f"CnnConfig(m={self.m}, c={self.c}, l={self.l}, zeta={self.zeta})"


def local_decode(idx: jax.Array, cfg: CnnConfig) -> jax.Array:
    """LD: one neuron per cluster, direct binary-to-integer mapping.

    (B, c) int32 cluster indices → (B, c·l) f32 concatenated one-hots.
    """
    oh = jax.nn.one_hot(idx, cfg.l, dtype=jnp.float32)  # (B, c, l)
    return oh.reshape(idx.shape[0], cfg.cl)


def decode(idx: jax.Array, w: jax.Array, cfg: CnnConfig, *, interpret: bool = True):
    """Full CNN decode: LD → GD (Pallas) → compare-enables + ambiguity count.

    Args:
      idx: (B, c) int32 cluster indices of the reduced tags.
      w:   (c·l, M) f32 binary weights.

    Returns:
      enables: (B, M/ζ) f32 — sub-block compare-enable bits.
      lam:     (B,)     i32 — λ, the number of activated P_II neurons
               (ambiguity statistic of Fig. 3).
    """
    u = local_decode(idx, cfg)
    act, enables = gd_decode(u, w, c=cfg.c, zeta=cfg.zeta, interpret=interpret)
    lam = jnp.sum(act, axis=-1).astype(jnp.int32)
    return enables, lam


def train(idx: jax.Array, addr: jax.Array, cfg: CnnConfig, *, interpret: bool = True) -> jax.Array:
    """Full (re)train from all stored entries.

    Args:
      idx:  (E, c) int32 reduced-tag cluster indices of stored entries.
      addr: (E,)   int32 CAM addresses of the same entries.

    Returns:
      w: (c·l, M) f32 binary weight matrix.
    """
    u = local_decode(idx, cfg)
    a = jax.nn.one_hot(addr, cfg.m, dtype=jnp.float32)
    return train_weights(u, a, interpret=interpret)


def add_entry(w: jax.Array, idx: jax.Array, addr: jax.Array, cfg: CnnConfig) -> jax.Array:
    """Incremental train of one association (the CAM insert path).

    Args:
      w:    (c·l, M) f32 current weights.
      idx:  (c,) int32 reduced-tag cluster indices of the new entry.
      addr: ()   int32 its CAM address.

    Returns:
      updated (c·l, M) weights — OR of the old weights with the new outer product.
    """
    u = local_decode(idx[None, :], cfg)[0]  # (c·l,)
    a = jax.nn.one_hot(addr, cfg.m, dtype=jnp.float32)  # (M,)
    return jnp.maximum(w, jnp.outer(u, a))
