//! Design-point configuration (the knobs of Table I).
//!
//! A [`DesignConfig`] fully determines a hardware instance: CAM geometry
//! (M entries × N tag bits, ζ rows per sub-block), CNN geometry (c clusters
//! of l neurons, q = c·log2(l) reduced-tag bits), cell/match-line choice and
//! technology node.  Configs serialize to/from TOML for the CLI and the
//! design-space sweep.


use crate::cam::MatchlineKind;
use crate::tech::{self, TechNode};

/// Which architecture a model evaluation refers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Architecture {
    /// Conventional monolithic CAM, NAND match-lines (Table II "Ref. NAND").
    ConventionalNand,
    /// Conventional monolithic CAM, NOR match-lines (Table II "Ref. NOR").
    ConventionalNor,
    /// The paper's CNN-classified sub-blocked CAM ("Proposed").
    Proposed,
    /// Precomputation-based CAM baseline (Lin et al. [4]) — ones-count
    /// parameter narrows the search before full comparison.
    PbCam,
}

impl Architecture {
    pub fn name(&self) -> &'static str {
        match self {
            Architecture::ConventionalNand => "Ref. NAND",
            Architecture::ConventionalNor => "Ref. NOR",
            Architecture::Proposed => "Proposed",
            Architecture::PbCam => "PB-CAM",
        }
    }
}

/// A complete design point.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignConfig {
    /// Number of CAM entries (Table I: M).
    pub m: usize,
    /// Tag width in bits (Table I: N).
    pub n: usize,
    /// CAM rows per compare-enabled sub-block (Table I: ζ).
    pub zeta: usize,
    /// Number of P_I clusters (Table I: c).
    pub c: usize,
    /// Neurons per cluster (Table I: l = 2^k).
    pub l: usize,
    /// Match-line architecture of the (sub-blocked) CAM array.
    pub ml_kind: MatchlineKind,
    /// Technology node name (resolved via [`tech::node_by_name`]).
    pub node: String,
    /// Shard geometry: how many independent banks the serving layer
    /// instantiates.  `m` stays the TOTAL capacity across the fleet; each
    /// bank is its own full CNN+CAM instance holding `m / shards` entries
    /// (see [`crate::shard`]).  `1` is the paper's single-macro device.
    pub shards: usize,
}

impl DesignConfig {
    /// Table I reference design: M=512, N=128, ζ=8 (β=64), q=9 (c=3, l=8),
    /// XOR cells with NOR match-lines, 0.13 µm @ 1.2 V.
    pub fn reference() -> Self {
        DesignConfig {
            m: 512,
            n: 128,
            zeta: 8,
            c: 3,
            l: 8,
            ml_kind: MatchlineKind::Nor,
            node: "0.13um".to_string(),
            shards: 1,
        }
    }

    /// A small config for fast tests (keeps all invariants of the reference).
    pub fn small_test() -> Self {
        DesignConfig {
            m: 64,
            n: 32,
            zeta: 4,
            c: 3,
            l: 4,
            ml_kind: MatchlineKind::Nor,
            node: "0.13um".to_string(),
            shards: 1,
        }
    }

    /// The design point of ONE bank of a sharded fleet: identical geometry
    /// with the total capacity divided across the banks.  With `shards == 1`
    /// this is a plain clone.
    pub fn per_bank(&self) -> DesignConfig {
        DesignConfig { m: self.m / self.shards.max(1), shards: 1, ..self.clone() }
    }

    /// Reduced-length tag width: q = c·log2(l) (§II-A).
    pub fn q(&self) -> usize {
        self.c * self.l.trailing_zeros() as usize
    }

    /// Number of CAM sub-blocks: β = M/ζ (§III-B).
    pub fn beta(&self) -> usize {
        self.m / self.zeta
    }

    /// Bits of tag mapped to each cluster: k = log2(l).
    pub fn k(&self) -> usize {
        self.l.trailing_zeros() as usize
    }

    /// Total P_I neurons: c·l.
    pub fn cl(&self) -> usize {
        self.c * self.l
    }

    /// Resolved technology node.
    pub fn tech(&self) -> TechNode {
        tech::node_by_name(&self.node).unwrap_or(tech::NODE_130NM)
    }

    /// Closed-form expected ambiguity count E(λ) for uniformly distributed
    /// reduced tags when the query equals a stored tag (§II-B / Fig. 3):
    /// the true entry plus Binomial(M−1, 2^−q) colliding entries.
    pub fn expected_lambda(&self) -> f64 {
        1.0 + (self.m as f64 - 1.0) / 2f64.powi(self.q() as i32)
    }

    /// Closed-form expected number of *activated sub-blocks*: the true
    /// entry's block plus each colliding entry's block when it differs.
    pub fn expected_active_blocks(&self) -> f64 {
        let extras = self.expected_lambda() - 1.0;
        // A colliding entry lands in the true block w.p. (ζ−1)/(M−1); block
        // double-counting among extras is O(extras²/β), negligible here.
        1.0 + extras * (1.0 - (self.zeta as f64 - 1.0) / (self.m as f64 - 1.0))
    }

    /// Expected number of entry comparisons per search: ζ × active blocks.
    pub fn expected_comparisons(&self) -> f64 {
        self.zeta as f64 * self.expected_active_blocks()
    }

    /// Validate all structural invariants.
    pub fn validate(&self) -> crate::Result<()> {
        use anyhow::ensure;
        ensure!(self.m > 0 && self.n > 0, "M and N must be positive");
        ensure!(self.m % self.zeta == 0, "ζ={} must divide M={}", self.zeta, self.m);
        ensure!(self.l.is_power_of_two(), "l={} must be a power of two", self.l);
        ensure!(self.c > 0, "c must be positive");
        ensure!(
            self.q() <= self.n,
            "reduced tag q={} cannot exceed tag width N={}",
            self.q(),
            self.n
        );
        ensure!(
            tech::node_by_name(&self.node).is_some(),
            "unknown technology node '{}'",
            self.node
        );
        ensure!(self.shards >= 1, "shards must be >= 1");
        ensure!(self.m % self.shards == 0, "shards={} must divide M={}", self.shards, self.m);
        ensure!(
            (self.m / self.shards) % self.zeta == 0,
            "ζ={} must divide the per-bank capacity M/shards={}",
            self.zeta,
            self.m / self.shards
        );
        Ok(())
    }

    /// Load from a `key = value` config file (a TOML subset: one scalar per
    /// line, `#` comments; keys are the field names of this struct).
    pub fn from_kv_file(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let cfg = Self::from_kv(&text)?;
        Ok(cfg)
    }

    /// Parse from `key = value` text; missing keys default to the reference
    /// design point.
    pub fn from_kv(text: &str) -> crate::Result<Self> {
        use anyhow::{bail, Context};
        let mut cfg = DesignConfig::reference();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
            };
            let (k, v) = (k.trim(), v.trim().trim_matches('"'));
            let ctx = || format!("line {}: bad value for {k}", lineno + 1);
            match k {
                "m" => cfg.m = v.parse().with_context(ctx)?,
                "n" => cfg.n = v.parse().with_context(ctx)?,
                "zeta" => cfg.zeta = v.parse().with_context(ctx)?,
                "c" => cfg.c = v.parse().with_context(ctx)?,
                "l" => cfg.l = v.parse().with_context(ctx)?,
                "ml_kind" => {
                    cfg.ml_kind = match v.to_ascii_uppercase().as_str() {
                        "NOR" => MatchlineKind::Nor,
                        "NAND" => MatchlineKind::Nand,
                        _ => bail!("line {}: ml_kind must be NOR or NAND", lineno + 1),
                    }
                }
                "node" => cfg.node = v.to_string(),
                "shards" => cfg.shards = v.parse().with_context(ctx)?,
                _ => bail!("line {}: unknown key '{k}'", lineno + 1),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize to the `key = value` format accepted by [`Self::from_kv`].
    pub fn to_kv(&self) -> String {
        format!(
            "# cscam design point (Table I names)\nm = {}\nn = {}\nzeta = {}\nc = {}\nl = {}\nml_kind = \"{}\"\nnode = \"{}\"\nshards = {}\n",
            self.m,
            self.n,
            self.zeta,
            self.c,
            self.l,
            self.ml_kind.name(),
            self.node,
            self.shards
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_matches_table1() {
        let cfg = DesignConfig::reference();
        cfg.validate().unwrap();
        assert_eq!(cfg.q(), 9);
        assert_eq!(cfg.beta(), 64);
        assert_eq!(cfg.k(), 3);
        assert_eq!(cfg.cl(), 24);
        // Table I: E(λ) = 1 (ambiguities beyond the true entry ≈ 1, i.e.
        // "only two comparisons" ⇒ expected_lambda ≈ 2 activations).
        assert!((cfg.expected_lambda() - 1.998).abs() < 0.01);
    }

    #[test]
    fn expected_comparisons_reference_is_about_two_blocks() {
        let cfg = DesignConfig::reference();
        let blocks = cfg.expected_active_blocks();
        assert!((1.9..2.0).contains(&blocks), "blocks = {blocks}");
        assert!((15.0..16.0).contains(&cfg.expected_comparisons()));
    }

    #[test]
    fn validation_catches_bad_geometry() {
        let mut cfg = DesignConfig::reference();
        cfg.zeta = 7;
        assert!(cfg.validate().is_err());
        let mut cfg = DesignConfig::reference();
        cfg.l = 6;
        assert!(cfg.validate().is_err());
        let mut cfg = DesignConfig::reference();
        cfg.c = 100; // q = 300 > N
        assert!(cfg.validate().is_err());
        let mut cfg = DesignConfig::reference();
        cfg.node = "7nm".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn kv_roundtrip() {
        let cfg = DesignConfig::reference();
        let text = cfg.to_kv();
        let back = DesignConfig::from_kv(&text).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn kv_partial_overrides_reference() {
        let cfg = DesignConfig::from_kv("m = 1024\nzeta = 16 # comment\n\n# c stays 3\n").unwrap();
        assert_eq!(cfg.m, 1024);
        assert_eq!(cfg.zeta, 16);
        assert_eq!(cfg.c, 3);
        assert_eq!(cfg.ml_kind, MatchlineKind::Nor);
    }

    #[test]
    fn kv_rejects_unknown_keys_and_bad_values() {
        assert!(DesignConfig::from_kv("bogus = 1").is_err());
        assert!(DesignConfig::from_kv("m = banana").is_err());
        assert!(DesignConfig::from_kv("ml_kind = \"XNOR\"").is_err());
        assert!(DesignConfig::from_kv("m 512").is_err());
        // structurally invalid after parse
        assert!(DesignConfig::from_kv("zeta = 7").is_err());
    }

    #[test]
    fn shard_geometry_validates_and_splits() {
        let cfg = DesignConfig { shards: 4, ..DesignConfig::reference() };
        cfg.validate().unwrap();
        let bank = cfg.per_bank();
        assert_eq!(bank.m, 128);
        assert_eq!(bank.shards, 1);
        assert_eq!(bank.n, cfg.n);
        bank.validate().unwrap();
        // shards must divide M
        let cfg = DesignConfig { shards: 3, ..DesignConfig::reference() };
        assert!(cfg.validate().is_err());
        // ζ must divide the per-bank capacity, not just M
        let cfg = DesignConfig { m: 16, zeta: 8, shards: 4, ..DesignConfig::small_test() };
        assert!(cfg.validate().is_err());
        let cfg = DesignConfig { shards: 0, ..DesignConfig::reference() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn kv_parses_shards() {
        let cfg = DesignConfig::from_kv("shards = 4").unwrap();
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.per_bank().m, 128);
        assert!(DesignConfig::from_kv("shards = 3").is_err(), "3 does not divide 512");
    }

    #[test]
    fn lambda_decreases_with_q() {
        let mk = |c: usize| DesignConfig { c, ..DesignConfig::reference() };
        assert!(mk(1).expected_lambda() > mk(2).expected_lambda());
        assert!(mk(2).expected_lambda() > mk(3).expected_lambda());
        assert!(mk(3).expected_lambda() > mk(4).expected_lambda());
    }
}
