//! Bench/regeneration harness for **Fig. 3**: expected number of required
//! comparisons vs the number of reduced-length tag bits q, for several CAM
//! sizes, from one million uniformly-random reduced tags (the paper's
//! methodology) — Monte Carlo through the real CNN decode path, printed
//! next to the closed form E[λ] = 1 + (M−1)/2^q.
//!
//! Run: `cargo bench --bench fig3_ambiguity`

use cscam::stats::{expected_lambda, simulate_lambda};
use cscam::util::bench::BenchTimer;
use cscam::util::Rng;

fn main() {
    let sizes = [256usize, 512, 1024];
    let total_trials = 1_000_000usize;
    let qmin = 4usize;
    let qmax = 16usize;
    let per_point = total_trials / (qmax - qmin + 1);

    println!("# Fig. 3 — E[#comparisons] vs q (ζ=1 view), {total_trials} total trials");
    print!("{:>4}", "q");
    for m in sizes {
        print!("{:>12}{:>12}", format!("M={m} sim"), "closed");
    }
    println!();

    let mut rng = Rng::seed_from_u64(3);
    for q in qmin..=qmax {
        print!("{q:>4}");
        for m in sizes {
            let est = simulate_lambda(m, q, 1, per_point, &mut rng);
            print!("{:>12.4}{:>12.4}", est.mean_lambda, expected_lambda(m, q));
        }
        println!();
    }

    // The paper's reading of the figure: the knee where E[comparisons]→2
    // sits at q = log2(M) (+1 for the final approach to 1 ambiguity).
    for m in sizes {
        let knee = (m as f64).log2() as usize;
        let e = expected_lambda(m, knee);
        println!("M={m}: E[λ] at q=log2(M)={knee}: {e:.3} (two comparisons)");
    }

    // Timing: how fast the Monte-Carlo estimator itself runs (the native
    // decode path is the workhorse of every simulation in the repo).
    println!("\n# estimator timing");
    let timer = BenchTimer::coarse();
    let mut trng = Rng::seed_from_u64(99);
    timer.run("simulate_lambda(M=512, q=9, 1k trials)", || {
        simulate_lambda(512, 9, 1, 1_000, &mut trng)
    });
    let mut trng2 = Rng::seed_from_u64(100);
    timer.run("simulate_lambda(M=1024, q=12, 1k trials)", || {
        simulate_lambda(1024, 12, 1, 1_000, &mut trng2)
    });
}
