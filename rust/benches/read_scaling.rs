//! Read-path scaling: bulk-lookup throughput on ONE bank as a function of
//! the reader-pool size — the tentpole claim of the concurrent read path
//! is that lookups no longer serialize behind a single engine thread, so
//! throughput must rise with reader threads on the same stored content.
//!
//! Run: `cargo bench --bench read_scaling`
//!
//! Flags (after `--`):
//! * `--quick`            headline rows only, fewer lookups (CI smoke);
//! * `--readers 1,2,4`    reader-pool sizes for the headline rows
//!   (`0` = the legacy engine-thread path, as a baseline);
//! * `--threads 8`        client threads shipping bulk chunks;
//! * `--json PATH`        append the headline rows (tagged `read_scaling`)
//!   to the `BENCH_*.json` trajectory shared with the `coordinator` and
//!   `net` benches.  Row keys: `readers`, `threads`, `lookups`,
//!   `throughput_lps`, `p50_ns`, `p99_ns`, `mean_lambda`, `hit_ratio`.

use std::time::{Duration, Instant};

use cscam::config::DesignConfig;
use cscam::coordinator::{BatchPolicy, CamServer, DecodeBackend, DecodeScratch, LookupEngine};
use cscam::util::bench::{write_bench_json, BenchRecord};
use cscam::util::cli::Args;
use cscam::util::Rng;
use cscam::workload::{QueryMix, TagDistribution};

const CHUNK: usize = 256;

/// A filled reference-design bank plus the probe stream (90 % hit mix),
/// pre-split per client thread.  Same seed every run: every row measures
/// the same work.
fn setup(threads: usize, lookups: usize) -> (LookupEngine, Vec<Vec<Vec<cscam::bits::BitVec>>>) {
    let cfg = DesignConfig::reference();
    let mut engine = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(1);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &stored {
        engine.insert(t).unwrap();
    }
    let mix = QueryMix { hit_ratio: 0.9, zipf_s: 0.0 };
    let mut per_thread: Vec<Vec<Vec<cscam::bits::BitVec>>> = vec![Vec::new(); threads];
    let mut current: Vec<Vec<cscam::bits::BitVec>> = vec![Vec::new(); threads];
    for i in 0..lookups {
        let t = i % threads;
        current[t].push(mix.sample(&stored, cfg.n, &mut rng).0);
        if current[t].len() == CHUNK {
            per_thread[t].push(std::mem::take(&mut current[t]));
        }
    }
    for (t, rest) in current.into_iter().enumerate() {
        if !rest.is_empty() {
            per_thread[t].push(rest);
        }
    }
    (engine, per_thread)
}

/// The headline row: `readers` pool threads on one bank, `threads` client
/// threads shipping bulk chunks of [`CHUNK`] tags through `lookup_many`
/// (which fans each chunk out across the pool).
fn run_pool(readers: usize, threads: usize, lookups: usize) -> BenchRecord {
    let (engine, per_thread) = setup(threads, lookups);
    let policy = BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(100) };
    let h = CamServer::with_engine(engine, DecodeBackend::Native, policy)
        .with_readers(readers)
        .spawn();

    let t0 = Instant::now();
    let joins: Vec<_> = per_thread
        .into_iter()
        .map(|chunks| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut hits = 0usize;
                for c in chunks {
                    for r in h.lookup_many(c) {
                        hits += r.unwrap().addr.is_some() as usize;
                    }
                }
                hits
            })
        })
        .collect();
    let mut hits = 0usize;
    for j in joins {
        hits += j.join().unwrap();
    }
    let wall = t0.elapsed();
    let m = h.metrics().unwrap();
    let throughput = lookups as f64 / wall.as_secs_f64();
    println!(
        "{:<44} {:>10.0} lookups/s  (λ̄ {:.3}, p50 {:>7} ns, p99 {:>8} ns, hits {})",
        format!("read_scaling/readers={readers}/bulk{CHUNK}x{threads}t"),
        throughput,
        m.lambda.mean(),
        m.host_latency_ns.quantile(0.5),
        m.host_latency_ns.quantile(0.99),
        hits,
    );

    let mut rec =
        BenchRecord::new(format!("read_scaling/readers={readers}/bulk{CHUNK}x{threads}t"));
    rec.push("readers", readers as f64);
    rec.push("threads", threads as f64);
    rec.push("lookups", lookups as f64);
    rec.push("throughput_lps", throughput);
    rec.push("p50_ns", m.host_latency_ns.quantile(0.5) as f64);
    rec.push("p99_ns", m.host_latency_ns.quantile(0.99) as f64);
    rec.push("mean_lambda", m.lambda.mean());
    rec.push("hit_ratio", m.hit_ratio());
    rec
}

/// The zero-queue path the net reactor's worker pool uses: `threads` caller
/// threads, each with its own `DecodeScratch`, searching the published
/// snapshot directly.  Printed for comparison, not recorded (it has no
/// `readers` axis).
fn run_direct(threads: usize, lookups: usize) {
    let (engine, per_thread) = setup(threads, lookups);
    let policy = BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(100) };
    // readers = 0: the direct path needs no pool — measure it without two
    // idle reader threads on the side
    let h = CamServer::with_engine(engine, DecodeBackend::Native, policy)
        .with_readers(0)
        .spawn();

    let t0 = Instant::now();
    let joins: Vec<_> = per_thread
        .into_iter()
        .map(|chunks| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut scratch = DecodeScratch::new();
                let mut hits = 0usize;
                for c in chunks {
                    for t in &c {
                        hits +=
                            h.lookup_direct(t, &mut scratch).unwrap().addr.is_some() as usize;
                    }
                }
                hits
            })
        })
        .collect();
    let mut hits = 0usize;
    for j in joins {
        hits += j.join().unwrap();
    }
    let wall = t0.elapsed();
    println!(
        "{:<44} {:>10.0} lookups/s  (hits {})",
        format!("read_scaling/direct/{threads}t"),
        lookups as f64 / wall.as_secs_f64(),
        hits,
    );
}

fn main() -> anyhow::Result<()> {
    // `cargo bench ... -- FLAGS` forwards FLAGS here (harness = false)
    let args = Args::parse(std::env::args().skip(1), &["quick"])?;
    args.check_known(&["quick", "readers", "threads", "json"])?;
    let quick = args.flag("quick");
    let reader_counts: Vec<usize> = args.get_list("readers", vec![1, 2, 4])?;
    let threads: usize = args.get_parse("threads", 8)?;
    let lookups = if quick { 80_000 } else { 400_000 };

    println!(
        "# read scaling (reference design, one bank, 90 % hit mix, \
         bulk {CHUNK} x {threads} client threads{})",
        if quick { ", --quick" } else { "" }
    );
    let mut records = Vec::new();
    for &r in &reader_counts {
        records.push(run_pool(r, threads, lookups));
    }
    if !quick {
        println!();
        run_direct(threads, lookups);
    }

    if let Some(path) = args.get("json") {
        write_bench_json(std::path::Path::new(path), "read_scaling", &records)?;
        println!("\nappended {} 'read_scaling' trajectory rows to {path}", records.len());
    }
    Ok(())
}
