"""AOT compile path: lower the L2 graphs to HLO *text* artifacts for Rust/PJRT.

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
Emits, for the reference config and each batch size B ∈ {1, 16, 64}:

    gd_decode_b{B}.hlo.txt   (idx i32[B,c], w f32[c·l,M]) → (enables f32[B,β], lam i32[B])
    train.hlo.txt            (idx i32[M,c], addr i32[M]) → w f32[c·l,M]
    add_entry.hlo.txt        (w, idx i32[c], addr i32[]) → w
    manifest.json            shapes/dtypes/config for the Rust ArtifactStore
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import CnnConfig, add_entry, decode, train


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_decode(cfg: CnnConfig, batch: int) -> str:
    idx_spec = jax.ShapeDtypeStruct((batch, cfg.c), jnp.int32)
    w_spec = jax.ShapeDtypeStruct((cfg.cl, cfg.m), jnp.float32)
    fn = lambda idx, w: decode(idx, w, cfg)
    return to_hlo_text(jax.jit(fn).lower(idx_spec, w_spec))


def lower_train(cfg: CnnConfig, entries: int) -> str:
    idx_spec = jax.ShapeDtypeStruct((entries, cfg.c), jnp.int32)
    addr_spec = jax.ShapeDtypeStruct((entries,), jnp.int32)
    fn = lambda idx, addr: train(idx, addr, cfg)
    return to_hlo_text(jax.jit(fn).lower(idx_spec, addr_spec))


def lower_add_entry(cfg: CnnConfig) -> str:
    w_spec = jax.ShapeDtypeStruct((cfg.cl, cfg.m), jnp.float32)
    idx_spec = jax.ShapeDtypeStruct((cfg.c,), jnp.int32)
    addr_spec = jax.ShapeDtypeStruct((), jnp.int32)
    fn = lambda w, idx, addr: add_entry(w, idx, addr, cfg)
    return to_hlo_text(jax.jit(fn).lower(w_spec, idx_spec, addr_spec))


def emit(out_dir: str, cfg: CnnConfig, batches: list[int]) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "config": {
            "m": cfg.m,
            "c": cfg.c,
            "l": cfg.l,
            "zeta": cfg.zeta,
            "q": cfg.q,
            "beta": cfg.beta,
        },
        "artifacts": {},
    }

    for b in batches:
        name = f"gd_decode_b{b}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(lower_decode(cfg, b))
        manifest["artifacts"][name] = {
            "kind": "decode",
            "batch": b,
            "inputs": [
                {"name": "idx", "dtype": "s32", "shape": [b, cfg.c]},
                {"name": "w", "dtype": "f32", "shape": [cfg.cl, cfg.m]},
            ],
            "outputs": [
                {"name": "enables", "dtype": "f32", "shape": [b, cfg.beta]},
                {"name": "lam", "dtype": "s32", "shape": [b]},
            ],
        }
        print(f"wrote {path}")

    path = os.path.join(out_dir, "train.hlo.txt")
    with open(path, "w") as f:
        f.write(lower_train(cfg, cfg.m))
    manifest["artifacts"]["train"] = {
        "kind": "train",
        "entries": cfg.m,
        "inputs": [
            {"name": "idx", "dtype": "s32", "shape": [cfg.m, cfg.c]},
            {"name": "addr", "dtype": "s32", "shape": [cfg.m]},
        ],
        "outputs": [{"name": "w", "dtype": "f32", "shape": [cfg.cl, cfg.m]}],
    }
    print(f"wrote {path}")

    path = os.path.join(out_dir, "add_entry.hlo.txt")
    with open(path, "w") as f:
        f.write(lower_add_entry(cfg))
    manifest["artifacts"]["add_entry"] = {
        "kind": "add_entry",
        "inputs": [
            {"name": "w", "dtype": "f32", "shape": [cfg.cl, cfg.m]},
            {"name": "idx", "dtype": "s32", "shape": [cfg.c]},
            {"name": "addr", "dtype": "s32", "shape": []},
        ],
        "outputs": [{"name": "w", "dtype": "f32", "shape": [cfg.cl, cfg.m]}],
    }
    print(f"wrote {path}")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--m", type=int, default=512, help="CAM entries (M)")
    p.add_argument("--c", type=int, default=3, help="P_I clusters")
    p.add_argument("--l", type=int, default=8, help="neurons per cluster")
    p.add_argument("--zeta", type=int, default=8, help="rows per sub-block (ζ)")
    p.add_argument("--batches", type=int, nargs="+", default=[1, 16, 64])
    args = p.parse_args()
    cfg = CnnConfig(m=args.m, c=args.c, l=args.l, zeta=args.zeta)
    print(f"lowering for {cfg}, batches={args.batches}")
    emit(args.out_dir, cfg, args.batches)


if __name__ == "__main__":
    main()
