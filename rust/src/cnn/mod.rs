//! The clustered-sparse-network classifier — native, bit-packed (Fig. 2/4).
//!
//! This is the Rust-side twin of the Pallas kernel (L1): the coordinator's
//! hot path uses it for single-query lookups and Monte-Carlo sweeps (Fig. 3
//! runs a million decodes), while batched decodes can go through the PJRT
//! artifact ([`crate::runtime`]).  An integration test cross-checks the two
//! implementations bit-for-bit.
//!
//! Representation: the weight matrix is stored row-major as `c·l` rows of
//! `M` bits — exactly the SRAM organization of Fig. 4 (c blocks of l rows ×
//! M columns).  A decode reads one row per cluster (the fused
//! decoder/word-line trick) and ANDs them: `M/64 · c` word operations.

pub mod bitselect;
pub mod capacity;
pub mod network;

pub use bitselect::Selection;
pub use network::{Activation, ClusteredNetwork};
