//! The codec fuzz battery: seeded mutation fuzzing of every binary decode
//! path — wire frames (`net::proto`), snapshot files and WAL frames
//! (`store`).
//!
//! Pattern of `tests/properties.rs`: an in-tree seeded driver (fixed
//! seeds, fixed case budgets — deterministic and CI-fast) stands in for an
//! external fuzzer.  Three mutation classes are applied to known-valid
//! encodings: single-byte flips, truncations, and extensions with garbage.
//! The invariant under test is the durability layer's safety contract:
//!
//! * **no decode path ever panics** on corrupt input (a panic in a frame
//!   decoder is a remote crash; in a snapshot loader it bricks recovery);
//! * **checksummed containers never silently succeed**: any byte flip in
//!   a wire frame or snapshot file must surface as a typed error;
//! * **WAL corruption degrades to truncation**: replay after any mutation
//!   yields a prefix of the original records, and the log stays usable.

use cscam::config::DesignConfig;
use cscam::coordinator::LookupEngine;
use cscam::net::proto::{
    self, read_request, read_response, Request, Response, WireError,
};
use cscam::store::{snapshot::BankImage, wal, FsyncPolicy, StoreError, Wal, WalRecord};
use cscam::util::Rng;
use cscam::workload::TagDistribution;

/// Flip one random byte (possibly several times).
fn flip(bytes: &mut [u8], rng: &mut Rng) {
    let i = rng.gen_range(bytes.len());
    let mut mask = (rng.gen_u64() & 0xFF) as u8;
    if mask == 0 {
        mask = 1;
    }
    bytes[i] ^= mask;
}

fn sample_requests() -> Vec<Request> {
    let mut rng = Rng::seed_from_u64(9001);
    let tags = TagDistribution::Uniform.sample_distinct(70, 6, &mut rng);
    vec![
        Request::Insert { tag: tags[0].clone() },
        Request::Delete { addr: 12345 },
        Request::Lookup { tag: tags[1].clone() },
        Request::LookupBulk { tags: tags.clone() },
        Request::Stats,
        Request::Drain,
        Request::Shutdown,
        Request::Snapshot,
        Request::Flush,
        Request::Metrics,
        Request::SubscribeLog { replica: 7, epoch: 2, bank: 1, generation: 3, offset: 16 },
    ]
}

fn encode_request(req: &Request, id: u64) -> Vec<u8> {
    let mut wire = Vec::new();
    proto::write_request(&mut wire, id, req).unwrap();
    wire
}

#[test]
fn wire_frames_reject_every_single_byte_flip() {
    let mut rng = Rng::seed_from_u64(1101);
    for req in sample_requests() {
        let wire = encode_request(&req, 42);
        // every byte position, not a sample: the frame is small and the
        // checksum must leave no blind spot
        for i in 0..wire.len() {
            let mut bad = wire.clone();
            let mut mask = (rng.gen_u64() & 0xFF) as u8;
            if mask == 0 {
                mask = 1;
            }
            bad[i] ^= mask;
            match read_request(&mut bad.as_slice()) {
                Err(WireError::Protocol(_)) | Err(WireError::Io(_)) => {}
                Ok((id, back)) => {
                    panic!("flip at byte {i} of {req:?} decoded silently as ({id}, {back:?})")
                }
                Err(other) => panic!("flip at byte {i}: unexpected error class {other:?}"),
            }
        }
    }
}

#[test]
fn response_frames_reject_every_single_byte_flip() {
    let mut rng = Rng::seed_from_u64(1102);
    let responses = vec![
        Response::Inserted { addr: 511 },
        Response::Deleted,
        Response::Drained,
        Response::ShutdownAck,
        Response::Snapshotted,
        Response::Flushed,
        Response::Metrics { text: "# TYPE cscam_lookups_total counter\ncscam_lookups_total 7\n".into() },
        Response::Error { code: proto::ERR_PERSIST, aux: 0 },
        Response::Error { code: proto::ERR_FENCED, aux: 3 },
        Response::LogBatch {
            bank: 1,
            generation: 3,
            next_offset: 4096,
            remaining: 12,
            frames: vec![0x5A; 37],
        },
        Response::SnapshotTransfer { bank: 0, generation: 4, image: vec![0xC3; 61] },
    ];
    for resp in responses {
        let mut wire = Vec::new();
        proto::write_response(&mut wire, 5, &resp).unwrap();
        for i in 0..wire.len() {
            let mut bad = wire.clone();
            let mut mask = (rng.gen_u64() & 0xFF) as u8;
            if mask == 0 {
                mask = 1;
            }
            bad[i] ^= mask;
            assert!(
                read_response(&mut bad.as_slice()).is_err(),
                "flip at byte {i} of {resp:?} decoded silently"
            );
        }
    }
}

#[test]
fn wire_frames_reject_every_truncation() {
    for req in sample_requests() {
        let wire = encode_request(&req, 7);
        for cut in 0..wire.len() {
            let mut slice = &wire[..cut];
            assert!(
                read_request(&mut slice).is_err(),
                "{req:?} truncated to {cut} bytes decoded"
            );
        }
    }
}

#[test]
fn wire_frame_extension_is_stream_tail_not_corruption() {
    // trailing bytes after a complete frame belong to the NEXT frame (a
    // TCP stream): the first frame must decode intact and the reader must
    // stop exactly at its boundary
    let req = Request::Delete { addr: 9 };
    let mut wire = encode_request(&req, 3);
    let tail = [0xAAu8; 13];
    wire.extend_from_slice(&tail);
    let mut slice = wire.as_slice();
    let (id, back) = read_request(&mut slice).unwrap();
    assert_eq!(id, 3);
    assert_eq!(back, req);
    assert_eq!(slice, &tail, "reader consumed exactly one frame");
}

#[test]
fn request_and_response_payload_decoders_never_panic_on_garbage() {
    // below the checksum: hammer the op/payload decoders directly with
    // random bytes for every opcode — Ok is allowed (a random payload can
    // be a valid tag), panicking or hanging is not
    let mut rng = Rng::seed_from_u64(2202);
    for op in 0u8..=255 {
        for _ in 0..8 {
            let len = rng.gen_range(64);
            let payload: Vec<u8> = (0..len).map(|_| (rng.gen_u64() & 0xFF) as u8).collect();
            let _ = Request::decode(op, &payload);
            let _ = Response::decode(op, &payload);
        }
    }
    // and with structured prefixes that exercise the count-bounded paths
    for _ in 0..500 {
        let mut payload = Vec::new();
        payload.extend_from_slice(&(rng.gen_u32()).to_le_bytes());
        let len = rng.gen_range(48);
        payload.extend((0..len).map(|_| (rng.gen_u64() & 0xFF) as u8));
        let _ = Request::decode(proto::OP_LOOKUP_BULK, &payload);
        let _ = Response::decode(proto::OP_LOOKUP_BULK, &payload);
        let _ = Response::decode(proto::OP_LOOKUP, &payload);
        let _ = Response::decode(proto::OP_STATS, &payload);
        let _ = Response::decode(proto::OP_METRICS, &payload);
        // the v5 replication frames carry length-prefixed byte bodies —
        // the count-vs-remaining guard is what's under the hammer here
        let _ = Request::decode(proto::OP_SUBSCRIBE_LOG, &payload);
        let _ = Response::decode(proto::OP_LOG_BATCH, &payload);
        let _ = Response::decode(proto::OP_SNAPSHOT_TRANSFER, &payload);
    }
}

fn sample_image() -> BankImage {
    let cfg = DesignConfig { m: 32, n: 32, zeta: 4, c: 2, l: 4, ..DesignConfig::small_test() };
    let mut engine = LookupEngine::new(cfg.clone());
    engine.retrain_threshold = 0.0;
    let mut rng = Rng::seed_from_u64(3303);
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 20, &mut rng);
    for t in &tags {
        engine.insert(t).unwrap();
    }
    engine.delete(5).unwrap();
    BankImage::from_engine(&engine)
}

#[test]
fn snapshot_rejects_every_single_byte_flip() {
    let good = sample_image().encode();
    let mut rng = Rng::seed_from_u64(4404);
    for i in 0..good.len() {
        let mut bad = good.clone();
        let mut mask = (rng.gen_u64() & 0xFF) as u8;
        if mask == 0 {
            mask = 1;
        }
        bad[i] ^= mask;
        match BankImage::decode(&bad) {
            Err(StoreError::Corrupt(_)) | Err(StoreError::Incompatible(_)) => {}
            Ok(_) => panic!("flip at byte {i} of the snapshot decoded silently"),
            Err(other) => panic!("flip at byte {i}: unexpected error class {other:?}"),
        }
    }
}

#[test]
fn snapshot_rejects_truncation_and_extension() {
    let good = sample_image().encode();
    let mut rng = Rng::seed_from_u64(5505);
    for _ in 0..200 {
        let cut = rng.gen_range(good.len());
        assert!(BankImage::decode(&good[..cut]).is_err(), "truncation to {cut} decoded");
    }
    for extra in [1usize, 7, 64] {
        let mut bad = good.clone();
        bad.extend((0..extra).map(|_| (rng.gen_u64() & 0xFF) as u8));
        assert!(BankImage::decode(&bad).is_err(), "extension by {extra} decoded");
    }
    // pure garbage of various sizes
    for len in [0usize, 1, 8, 23, 24, 25, 100] {
        let junk: Vec<u8> = (0..len).map(|_| (rng.gen_u64() & 0xFF) as u8).collect();
        assert!(BankImage::decode(&junk).is_err());
    }
}

fn wal_records() -> Vec<WalRecord> {
    let mut rng = Rng::seed_from_u64(6606);
    let tags = TagDistribution::Uniform.sample_distinct(32, 8, &mut rng);
    let mut recs = Vec::new();
    for (i, t) in tags.iter().enumerate() {
        recs.push(WalRecord::Insert { addr: i as u64, tag: t.clone() });
    }
    recs.push(WalRecord::Delete { addr: 2 });
    recs.push(WalRecord::Insert { addr: 2, tag: tags[0].clone() });
    recs
}

fn write_wal_file(path: &std::path::Path, body_mutator: impl FnOnce(&mut Vec<u8>)) {
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&wal::WAL_MAGIC);
    bytes.extend_from_slice(&wal::WAL_VERSION.to_le_bytes());
    bytes.extend_from_slice(&[0, 0]);
    bytes.extend_from_slice(&0u64.to_le_bytes()); // generation
    for rec in wal_records() {
        bytes.extend_from_slice(&wal::encode_frame(&rec));
    }
    body_mutator(&mut bytes);
    std::fs::write(path, &bytes).unwrap();
}

fn fuzz_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cscam-fuzz-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn wal_mutations_degrade_to_prefix_replay_never_panic() {
    let originals = wal_records();
    let dir = fuzz_dir();
    let mut rng = Rng::seed_from_u64(7707);
    for case in 0..400 {
        let path = dir.join("fuzz.wal");
        let kind = rng.gen_range(3);
        let mut flip_rng = rng.fork();
        write_wal_file(&path, |bytes| match kind {
            0 => flip(bytes, &mut flip_rng),
            1 => {
                let cut = flip_rng.gen_range(bytes.len());
                bytes.truncate(cut.max(1));
            }
            _ => {
                let extra = 1 + flip_rng.gen_range(40);
                bytes.extend((0..extra).map(|_| (flip_rng.gen_u64() & 0xFF) as u8));
            }
        });
        // Open must either repair (truncate the tail) or refuse with a
        // typed error (header damage) — never panic, never invent records.
        match Wal::open(&path, FsyncPolicy::Never) {
            Ok((mut wal, replayed, _recovery)) => {
                assert!(
                    replayed.len() <= originals.len()
                        && replayed == originals[..replayed.len()],
                    "case {case}: replay is not a prefix of the written log"
                );
                // the repaired log must accept appends and replay them
                wal.append(&WalRecord::Delete { addr: 0 }).unwrap();
                drop(wal);
                let (_, again, rec2) = Wal::open(&path, FsyncPolicy::Never).unwrap();
                assert_eq!(again.last(), Some(&WalRecord::Delete { addr: 0 }));
                assert_eq!(rec2.truncated_bytes, 0, "case {case}: repair must be stable");
            }
            Err(StoreError::Corrupt(_)) | Err(StoreError::Incompatible(_)) => {}
            Err(StoreError::Io(e)) => panic!("case {case}: unexpected io error {e}"),
        }
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn clean_wal_replays_exactly_and_extension_is_a_torn_tail() {
    let originals = wal_records();
    let dir = fuzz_dir();
    let path = dir.join("clean.wal");
    write_wal_file(&path, |_| {});
    let (_, replayed, rec) = Wal::open(&path, FsyncPolicy::Never).unwrap();
    assert_eq!(replayed, originals);
    assert_eq!(rec.truncated_bytes, 0);

    // garbage appended after the last complete frame is exactly the
    // torn-tail case: truncated, reported, all real records kept
    write_wal_file(&path, |bytes| bytes.extend_from_slice(&[0xEE; 11]));
    let (_, replayed, rec) = Wal::open(&path, FsyncPolicy::Never).unwrap();
    assert_eq!(replayed, originals);
    assert_eq!(rec.truncated_bytes, 11);
    assert!(rec.torn_reason.is_some());
}
