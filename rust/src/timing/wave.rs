//! Wave-pipelining analysis (§IV): "a wave-pipelining approach under
//! worst-case process conditions (slow-slow) has been followed for clk1 and
//! clk2 signals in Fig. 4 to integrate the CNN and the CAM module."
//!
//! Wave pipelining launches a new input into the CNN stage before the
//! previous wave has left the CAM stage, with no register between them.
//! It works iff the *fast* path of wave k+1 cannot catch the *slow* path of
//! wave k at the CAM sampling point:
//!
//! ```text
//!   T_clk ≥ (D_max − D_min) + t_setup + t_skew      (race constraint)
//!   T_clk ≥ D_max_stage                             (throughput bound)
//!   clk2 offset = D_max_cnn − T_clk·floor(D_max_cnn/T_clk)
//! ```
//!
//! where D_max/D_min are the slowest/fastest combinational paths through
//! the unregistered CNN→CAM cascade.  Process corners derate the nominal
//! delays: the paper quotes the slow-slow corner, modelled here as a
//! multiplicative factor on every path.

use crate::config::DesignConfig;
use crate::timing::{cnn_stage_fo4, subblock_stage_fo4, DelayConstants};

/// Process corner derating factors (× nominal delay).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Corner {
    /// Typical-typical.
    TT,
    /// Slow-slow (worst-case, the paper's sign-off corner).
    SS,
    /// Fast-fast (best-case — sets the *minimum* path for race checks).
    FF,
}

impl Corner {
    pub fn derate(&self) -> f64 {
        match self {
            Corner::TT => 1.0,
            Corner::SS => 1.25,
            Corner::FF => 0.80,
        }
    }
}

/// Wave-pipelining feasibility report for a design point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WaveReport {
    /// Slowest path through CNN + sub-block search at SS, ns.
    pub d_max_ns: f64,
    /// Fastest path at FF, ns (shortest logic depth: decode of an
    /// all-zeros row that settles the enable immediately).
    pub d_min_ns: f64,
    /// Minimum safe clock period, ns.
    pub t_clk_min_ns: f64,
    /// clk2 sampling offset after clk1, ns.
    pub clk2_offset_ns: f64,
    /// Number of waves in flight at T_clk_min.
    pub waves_in_flight: usize,
}

/// Setup + skew guard band, ns (0.13 µm flop + tree ballpark).
pub const GUARD_NS: f64 = 0.08;

/// Analyze wave-pipelined operation of the proposed design.
pub fn analyze(cfg: &DesignConfig, k: &DelayConstants) -> WaveReport {
    let node = cfg.tech();
    let cnn_nom = cnn_stage_fo4(cfg, k) * node.fo4_ps / 1000.0;
    let cam_nom = subblock_stage_fo4(cfg, k) * node.fo4_ps / 1000.0;

    let d_max = (cnn_nom + cam_nom) * Corner::SS.derate();
    // fastest path: one decoder level + SRAM hit + the single AND that
    // kills the enable — about 40 % of the nominal stage depth, at FF.
    let d_min = 0.4 * (cnn_nom + cam_nom) * Corner::FF.derate();

    let race = (d_max - d_min) + GUARD_NS;
    let stage = cnn_nom.max(cam_nom) * Corner::SS.derate();
    let t_clk = race.max(stage);

    let clk2_offset = {
        let dmax_cnn = cnn_nom * Corner::SS.derate();
        dmax_cnn - t_clk * (dmax_cnn / t_clk).floor()
    };
    WaveReport {
        d_max_ns: d_max,
        d_min_ns: d_min,
        t_clk_min_ns: t_clk,
        clk2_offset_ns: clk2_offset,
        waves_in_flight: (d_max / t_clk).ceil() as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DesignConfig;
    use crate::timing::DelayConstants;

    fn report() -> WaveReport {
        analyze(&DesignConfig::reference(), &DelayConstants::reference())
    }

    #[test]
    fn race_constraint_dominates_at_reference() {
        // With an unregistered 2-stage cascade the D_max−D_min spread, not
        // the stage delay, sets T_clk — the cost of skipping the register.
        let r = report();
        assert!(r.t_clk_min_ns > 0.0);
        assert!(r.d_max_ns > r.d_min_ns);
        assert!(r.t_clk_min_ns >= (r.d_max_ns - r.d_min_ns));
    }

    #[test]
    fn clock_period_is_within_paper_band() {
        // The paper reports 0.70 ns max reliable frequency at SS; the wave
        // analysis must land in the same regime (sub-2 ns, super-0.5 ns).
        let r = report();
        assert!((0.5..2.0).contains(&r.t_clk_min_ns), "T_clk {}", r.t_clk_min_ns);
    }

    #[test]
    fn multiple_waves_in_flight() {
        let r = report();
        assert!(r.waves_in_flight >= 1);
        assert!(r.waves_in_flight <= 4);
        assert!(r.clk2_offset_ns >= 0.0 && r.clk2_offset_ns <= r.t_clk_min_ns);
    }

    #[test]
    fn ss_corner_is_slowest() {
        assert!(Corner::SS.derate() > Corner::TT.derate());
        assert!(Corner::FF.derate() < Corner::TT.derate());
    }

    #[test]
    fn bigger_arrays_need_slower_clocks() {
        let small = analyze(&DesignConfig::reference(), &DelayConstants::reference());
        let big = analyze(
            &DesignConfig { m: 4096, ..DesignConfig::reference() },
            &DelayConstants::reference(),
        );
        assert!(big.t_clk_min_ns > small.t_clk_min_ns);
    }
}
