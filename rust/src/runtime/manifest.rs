//! The artifact manifest emitted by `python/compile/aot.py`, parsed with the
//! in-tree JSON parser ([`crate::util::json`]).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, ensure, Context};

use crate::util::json::JsonValue;
use crate::Result;

/// CNN geometry the artifacts were lowered for (mirrors `CnnConfig`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestConfig {
    pub m: usize,
    pub c: usize,
    pub l: usize,
    pub zeta: usize,
    pub q: usize,
    pub beta: usize,
}

/// A tensor descriptor in the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorInfo {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One artifact entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactInfo {
    pub kind: String,
    pub batch: Option<usize>,
    pub entries: Option<usize>,
    pub inputs: Vec<TensorInfo>,
    pub outputs: Vec<TensorInfo>,
}

/// `manifest.json` as a whole.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub config: ManifestConfig,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

fn tensor(v: &JsonValue) -> Result<TensorInfo> {
    Ok(TensorInfo {
        name: v.req("name")?.as_str()?.to_string(),
        dtype: v.req("dtype")?.as_str()?.to_string(),
        shape: v.req("shape")?.as_array()?.iter().map(|s| s.as_usize()).collect::<Result<_>>()?,
    })
}

impl Manifest {
    /// Parse from a JSON string.
    pub fn from_json(text: &str) -> Result<Self> {
        let v = JsonValue::parse(text).context("parsing manifest.json")?;
        let cfg = v.req("config")?;
        let config = ManifestConfig {
            m: cfg.req("m")?.as_usize()?,
            c: cfg.req("c")?.as_usize()?,
            l: cfg.req("l")?.as_usize()?,
            zeta: cfg.req("zeta")?.as_usize()?,
            q: cfg.req("q")?.as_usize()?,
            beta: cfg.req("beta")?.as_usize()?,
        };
        let mut artifacts = BTreeMap::new();
        for (name, a) in v.req("artifacts")?.as_object()? {
            let info = ArtifactInfo {
                kind: a.req("kind")?.as_str()?.to_string(),
                batch: match a.get("batch") {
                    Some(b) => Some(b.as_usize()?),
                    None => None,
                },
                entries: match a.get("entries") {
                    Some(e) => Some(e.as_usize()?),
                    None => None,
                },
                inputs: a.req("inputs")?.as_array()?.iter().map(tensor).collect::<Result<_>>()?,
                outputs: a.req("outputs")?.as_array()?.iter().map(tensor).collect::<Result<_>>()?,
            };
            artifacts.insert(name.clone(), info);
        }
        let m = Manifest { config, artifacts };
        m.validate()?;
        Ok(m)
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::from_json(&text)
    }

    pub fn validate(&self) -> Result<()> {
        let c = &self.config;
        ensure!(c.m > 0 && c.c > 0 && c.l > 0 && c.zeta > 0, "non-positive geometry");
        ensure!(c.m % c.zeta == 0, "ζ must divide M");
        ensure!(c.beta == c.m / c.zeta, "β inconsistent with M/ζ");
        ensure!(c.q == c.c * (c.l.trailing_zeros() as usize), "q inconsistent with c·log2(l)");
        for (name, a) in &self.artifacts {
            ensure!(!a.inputs.is_empty(), "artifact {name} has no inputs");
            ensure!(!a.outputs.is_empty(), "artifact {name} has no outputs");
            if a.kind == "decode" {
                let Some(b) = a.batch else { bail!("decode {name} missing batch") };
                ensure!(
                    a.outputs[0].shape == vec![b, c.beta],
                    "decode {name} enables shape mismatch"
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> String {
        r#"{
            "config": {"m": 64, "c": 3, "l": 8, "zeta": 8, "q": 9, "beta": 8},
            "artifacts": {
                "gd_decode_b2": {
                    "kind": "decode",
                    "batch": 2,
                    "inputs": [
                        {"name": "idx", "dtype": "s32", "shape": [2, 3]},
                        {"name": "w", "dtype": "f32", "shape": [24, 64]}
                    ],
                    "outputs": [
                        {"name": "enables", "dtype": "f32", "shape": [2, 8]},
                        {"name": "lam", "dtype": "s32", "shape": [2]}
                    ]
                }
            }
        }"#
        .to_string()
    }

    #[test]
    fn parse_and_validate() {
        let m = Manifest::from_json(&sample_json()).unwrap();
        assert_eq!(m.config.beta, 8);
        assert_eq!(m.artifacts["gd_decode_b2"].batch, Some(2));
        assert_eq!(m.artifacts["gd_decode_b2"].inputs[1].shape, vec![24, 64]);
    }

    #[test]
    fn validation_rejects_inconsistent_beta() {
        let mut m = Manifest::from_json(&sample_json()).unwrap();
        m.config.beta = 9;
        assert!(m.validate().is_err());
    }

    #[test]
    fn validation_rejects_bad_q() {
        let mut m = Manifest::from_json(&sample_json()).unwrap();
        m.config.q = 10;
        assert!(m.validate().is_err());
    }

    #[test]
    fn validation_rejects_enable_shape_mismatch() {
        let mut m = Manifest::from_json(&sample_json()).unwrap();
        m.artifacts.get_mut("gd_decode_b2").unwrap().outputs[0].shape = vec![2, 9];
        assert!(m.validate().is_err());
    }

    #[test]
    fn real_manifest_parses_if_built() {
        let dir = crate::runtime::default_artifact_dir();
        let p = dir.join("manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(m.artifacts.keys().any(|k| k.starts_with("gd_decode_b")));
        }
    }
}
