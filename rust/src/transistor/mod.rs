//! Structural transistor counting (the paper's +3.4 % overhead claim).
//!
//! Counts are *structural* — cells × transistors-per-cell plus explicit
//! peripheral circuits — with documented assumptions; nothing here is fitted
//! to the paper's 3.4 %.  The absolute overhead we predict depends on
//! peripheral sizing the paper does not publish, but the *shape* — a small
//! single-digit-percent overhead that shrinks as the data payload grows —
//! is structural and holds.


pub mod area;

use crate::cam::CellKind;
use crate::config::DesignConfig;

/// Transistor inventory of one design.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransistorCount {
    /// CAM tag array cells (M × N × cell transistors).
    pub cam_cells: usize,
    /// Output data SRAM (M × data_width × 6T) — both designs store the
    /// payload the CAM retrieves.
    pub data_sram: usize,
    /// CAM peripherals: SL drivers, ML precharge, sense amps, priority
    /// encoder, read/write column circuitry.
    pub cam_periphery: usize,
    /// CNN weight SRAM (c · l · M bits × 6T).
    pub cnn_sram: usize,
    /// CNN logic: one-hot decoders, P_II c-input ANDs, ζ-group ORs,
    /// compare-enable drivers, SRAM read periphery.
    pub cnn_logic: usize,
}

impl TransistorCount {
    /// Grand total.
    pub fn total(&self) -> usize {
        self.cam_cells + self.data_sram + self.cam_periphery + self.cnn_sram + self.cnn_logic
    }

    /// The CNN's addition on top of the CAM macro.
    pub fn cnn_total(&self) -> usize {
        self.cnn_sram + self.cnn_logic
    }
}

/// Structural assumptions (documented; defaults are standard-cell ballparks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransistorAssumptions {
    /// Width of the data word each entry retrieves (the paper's macro is a
    /// tag-CAM + data-RAM pair; Table II configs quote tag width only — we
    /// default data to the same width).
    pub data_width: usize,
    /// Per-ML precharge + sense + valid gating.
    pub per_row_ml_circuit: usize,
    /// Per-bit SL driver pair.
    pub per_bit_sl_driver: usize,
    /// Priority-encoder transistors per entry.
    pub encoder_per_entry: usize,
    /// SRAM column circuitry (precharge + write + sense) per column.
    pub sram_col_circuit: usize,
}

impl Default for TransistorAssumptions {
    fn default() -> Self {
        TransistorAssumptions {
            data_width: 128,
            per_row_ml_circuit: 12,
            per_bit_sl_driver: 8,
            encoder_per_entry: 4,
            sram_col_circuit: 10,
        }
    }
}

/// Conventional monolithic CAM (tag array + data RAM + peripherals).
pub fn conventional_count(
    m: usize,
    n: usize,
    cell: CellKind,
    a: &TransistorAssumptions,
) -> TransistorCount {
    TransistorCount {
        cam_cells: m * n * cell.transistors(),
        data_sram: m * a.data_width * 6 + a.data_width * a.sram_col_circuit,
        cam_periphery: m * a.per_row_ml_circuit + n * a.per_bit_sl_driver + m * a.encoder_per_entry,
        cnn_sram: 0,
        cnn_logic: 0,
    }
}

/// The proposed design: sub-blocked CAM (same cells, per-block enable
/// gating) + the CNN classifier of Fig. 4.
pub fn proposed_count(cfg: &DesignConfig, a: &TransistorAssumptions) -> TransistorCount {
    let mut t = conventional_count(cfg.m, cfg.n, CellKind::Xor9T, a);
    // per-block compare-enable gating on the precharge path: 2T per row +
    // a 4T driver per block.
    t.cam_periphery += cfg.m * 2 + cfg.beta() * 4;
    // CNN weight SRAM: c blocks of l rows × M columns, 6T bits + column circuitry.
    t.cnn_sram = cfg.c * cfg.l * cfg.m * 6 + cfg.c * cfg.m * a.sram_col_circuit;
    // CNN logic: c decoders (≈4T per output line), M c-input AND gates
    // (2·c T each), β ζ-input OR gates (2·ζ T each), β enable drivers (4T).
    t.cnn_logic =
        cfg.cl() * 4 + cfg.m * 2 * cfg.c + cfg.beta() * 2 * cfg.zeta + cfg.beta() * 4;
    t
}

/// Overhead of the proposed design relative to the conventional NAND design
/// (the paper's +3.4 % comparison).
pub fn overhead_vs_nand(cfg: &DesignConfig, a: &TransistorAssumptions) -> f64 {
    let nand = conventional_count(cfg.m, cfg.n, CellKind::Nand10T, a).total() as f64;
    let prop = proposed_count(cfg, a).total() as f64;
    prop / nand - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_overhead_is_small_single_digit_percent() {
        // Paper: +3.4 %.  Structurally (XOR-9T vs NAND-10T cells offsetting
        // most of the CNN SRAM) we land in the low single digits; the exact
        // figure depends on unpublished peripheral sizing.
        let cfg = DesignConfig::reference();
        let ovh = overhead_vs_nand(&cfg, &TransistorAssumptions::default());
        assert!((0.0..0.10).contains(&ovh), "overhead {ovh}");
    }

    #[test]
    fn cnn_sram_dominates_cnn_addition() {
        let cfg = DesignConfig::reference();
        let t = proposed_count(&cfg, &TransistorAssumptions::default());
        assert!(t.cnn_sram > 5 * t.cnn_logic);
    }

    #[test]
    fn reference_cnn_sram_size() {
        // c·l·M = 3·8·512 = 12 288 weight bits → 73 728 storage transistors.
        let cfg = DesignConfig::reference();
        let t = proposed_count(&cfg, &TransistorAssumptions::default());
        assert_eq!(t.cnn_sram, 12_288 * 6 + 3 * 512 * 10);
    }

    #[test]
    fn overhead_shrinks_with_wider_data() {
        let cfg = DesignConfig::reference();
        let narrow = overhead_vs_nand(
            &cfg,
            &TransistorAssumptions { data_width: 128, ..Default::default() },
        );
        let wide = overhead_vs_nand(
            &cfg,
            &TransistorAssumptions { data_width: 512, ..Default::default() },
        );
        assert!(wide < narrow);
    }

    #[test]
    fn overhead_grows_with_l() {
        // Doubling l doubles the weight SRAM — the §II-B complexity argument
        // against training on full-length tags.
        let cfg = DesignConfig::reference();
        let big = DesignConfig { l: 64, c: 3, ..DesignConfig::reference() };
        let a = TransistorAssumptions::default();
        assert!(
            proposed_count(&big, &a).cnn_total() > 4 * proposed_count(&cfg, &a).cnn_total()
        );
    }

    #[test]
    fn totals_are_consistent() {
        let cfg = DesignConfig::reference();
        let t = proposed_count(&cfg, &TransistorAssumptions::default());
        assert_eq!(
            t.total(),
            t.cam_cells + t.data_sram + t.cam_periphery + t.cnn_sram + t.cnn_logic
        );
        assert_eq!(t.cnn_total(), t.cnn_sram + t.cnn_logic);
    }
}
