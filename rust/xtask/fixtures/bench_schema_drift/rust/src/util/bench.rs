// Fixture: the writer emits a "run" field the reader never looks at.

pub struct Row {
    pub name: String,
    pub bench: String,
    pub run: u64,
}

pub fn bench_rows_json(rows: &[Row]) -> String {
    let mut s = String::from("{\n  \"schema\": 2,\n  \"rows\": [\n");
    for row in rows {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"bench\": \"{}\", \"run\": {}}},\n",
            row.name, row.bench, row.run
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

pub fn read_bench_rows(text: &str) -> Vec<Row> {
    let mut out = Vec::new();
    if !text.contains("rows") {
        return out;
    }
    for line in text.lines() {
        let name = grab(line, "name");
        let bench = grab(line, "bench");
        if !name.is_empty() {
            out.push(Row { name, bench, run: 1 });
        }
    }
    out
}

fn grab(line: &str, key: &str) -> String {
    let _ = (line, key);
    String::new()
}
