//! Loopback integration tests for the network serving layer: wire-protocol
//! results must be *bit-identical* to in-process `ShardedCamServer`
//! lookups — same matched global address, same λ, same energy breakdown,
//! same delay — across all three placement modes and both tag
//! distributions.  Wire lookups execute directly on the reactor's worker
//! pool against the published snapshots (no admission queue), so the
//! admission cap cannot shed them; the in-process non-blocking admission
//! sheds with the typed `EngineError::Busy`, and `Full` stays reserved
//! for "no free CAM slot".  Since protocol v6 the server multiplexes: a
//! connection's responses may arrive in completion order, and the client
//! must re-match them by request id (proven deterministically against a
//! scripted server below).  The load generator must emit a measured
//! bench-JSON row.

use cscam::bits::BitVec;
use cscam::config::DesignConfig;
use cscam::coordinator::{BatchPolicy, EngineError};
use cscam::net::{CamClient, CamTcpServer, LoadGen, NetConfig, NetServerHandle, WireError};
use cscam::shard::{PlacementMode, ShardedCamServer, ShardedServerHandle};
use cscam::util::Rng;
use cscam::workload::{QueryMix, TagDistribution};
use std::time::Duration;

fn fleet_cfg() -> DesignConfig {
    // 4 banks × 64 entries = one 256-entry fleet
    DesignConfig { m: 256, n: 32, zeta: 4, c: 3, l: 4, shards: 4, ..DesignConfig::reference() }
}

fn policy() -> BatchPolicy {
    BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(100) }
}

fn start(
    mode: PlacementMode,
    queue_cap: Option<usize>,
    net: NetConfig,
) -> (NetServerHandle, ShardedServerHandle, String) {
    let mut builder = ShardedCamServer::new(&fleet_cfg(), mode, policy());
    if let Some(cap) = queue_cap {
        builder = builder.with_queue_capacity(cap);
    }
    let fleet = builder.spawn();
    let server = CamTcpServer::bind(fleet.clone(), "127.0.0.1:0", net).expect("bind loopback");
    let addr = server.local_addr().expect("local addr").to_string();
    let handle = server.spawn().expect("spawn server");
    (handle, fleet, addr)
}

/// The property: insert a population over the wire, then require every
/// wire lookup — single and pipelined bulk — to equal the in-process
/// `ShardedServerHandle` answer on the same fleet, field for field.
fn wire_matches_inprocess(
    dist: TagDistribution,
    seed: u64,
    mode_for: impl Fn(&[BitVec]) -> PlacementMode,
) {
    let mut rng = Rng::seed_from_u64(seed);
    let tags = dist.sample_distinct(32, 120, &mut rng);
    let (server, fleet, addr) = start(mode_for(&tags), None, NetConfig::default());
    let mut client = CamClient::connect(addr).expect("connect");
    let hello = *client.server_info().expect("hello");
    assert_eq!(hello.shards, 4);
    assert_eq!(hello.bank_m, 64);
    assert_eq!(hello.tag_bits, 32);
    assert!(hello.multiplex, "a v6 server must advertise multiplexing");
    assert!(client.multiplexed());

    let mut stored = Vec::new();
    for t in &tags {
        match client.insert(t) {
            Ok(g) => {
                // the wire address is live immediately: in-process sees it
                assert_eq!(fleet.lookup(t.clone()).unwrap().addr, Some(g as usize));
                stored.push(t.clone());
            }
            Err(WireError::Engine(EngineError::Full)) => {} // skewed bank filled up
            Err(e) => panic!("insert failed: {e}"),
        }
    }
    assert!(stored.len() >= 90, "only {} of 120 inserts landed", stored.len());

    let mix = QueryMix { hit_ratio: 0.7, zipf_s: 0.0 };
    let queries: Vec<BitVec> = (0..300).map(|_| mix.sample(&stored, 32, &mut rng).0).collect();
    let mut hits = 0usize;
    for q in &queries {
        let wire = client.lookup(q).expect("wire lookup");
        let local = fleet.lookup(q.clone()).expect("in-process lookup");
        assert_eq!(wire, local, "wire outcome must be bit-identical to in-process");
        hits += wire.addr.is_some() as usize;
    }
    assert!((150..260).contains(&hits), "hit mix off: {hits}");

    // pipelined bulk (frames of 32) preserves order and stays identical
    let bulk = client.lookup_bulk(&queries, 32).expect("bulk");
    let local_bulk = fleet.lookup_many(queries.clone());
    assert_eq!(bulk.len(), local_bulk.len());
    for (i, (w, l)) in bulk.iter().zip(&local_bulk).enumerate() {
        assert_eq!(
            w.as_ref().expect("wire bulk item"),
            l.as_ref().expect("local bulk item"),
            "bulk item {i} diverged"
        );
    }

    client.shutdown().expect("shutdown");
    server.join();
}

#[test]
fn wire_equals_inprocess_uniform_hash() {
    wire_matches_inprocess(TagDistribution::Uniform, 201, |_| PlacementMode::TagHash);
}

#[test]
fn wire_equals_inprocess_uniform_broadcast() {
    wire_matches_inprocess(TagDistribution::Uniform, 202, |_| PlacementMode::Broadcast);
}

#[test]
fn wire_equals_inprocess_uniform_learned() {
    wire_matches_inprocess(TagDistribution::Uniform, 203, |s| PlacementMode::learned(4, s, 32));
}

#[test]
fn wire_equals_inprocess_correlated_hash() {
    wire_matches_inprocess(
        TagDistribution::Correlated { fixed_bits: 8, mirror_span: 8 },
        204,
        |_| PlacementMode::TagHash,
    );
}

#[test]
fn wire_equals_inprocess_correlated_broadcast() {
    wire_matches_inprocess(
        TagDistribution::Correlated { fixed_bits: 8, mirror_span: 8 },
        205,
        |_| PlacementMode::Broadcast,
    );
}

#[test]
fn wire_equals_inprocess_correlated_learned() {
    wire_matches_inprocess(
        TagDistribution::Correlated { fixed_bits: 8, mirror_span: 8 },
        206,
        |s| PlacementMode::learned(4, s, 32),
    );
}

#[test]
fn wire_reads_bypass_the_admission_queue_while_inprocess_sheds_busy() {
    // queue capacity 0: the in-process non-blocking admission sheds every
    // queued lookup with the typed Busy (NOT Full — that means "no free
    // CAM slot").  Wire lookups run directly on the reactor's worker
    // pool against the published snapshot, so the zero-capacity queue
    // cannot touch them: they must keep answering.
    let (server, fleet, addr) = start(PlacementMode::TagHash, Some(0), NetConfig::default());
    let mut client = CamClient::connect(addr).expect("connect");
    let mut rng = Rng::seed_from_u64(207);
    let tags = TagDistribution::Uniform.sample_distinct(32, 8, &mut rng);
    let mut addrs = Vec::new();
    for t in &tags {
        addrs.push(client.insert(t).expect("inserts are barriers, not shed"));
    }
    // in-process queued admission sheds with Busy...
    assert_eq!(fleet.try_lookup(tags[0].clone()).unwrap_err(), EngineError::Busy);
    assert_eq!(fleet.try_lookup_many(tags.clone()).unwrap_err(), EngineError::Busy);
    // ...and the wire still serves, single and bulk, with correct answers
    for (t, &g) in tags.iter().zip(&addrs) {
        let out = client.lookup(t).expect("direct wire read must not shed");
        assert_eq!(out.addr, Some(g as usize));
    }
    let bulk = client.lookup_bulk(&tags, 4).expect("bulk transport fine");
    assert_eq!(bulk.len(), 8);
    for (r, &g) in bulk.iter().zip(&addrs) {
        assert_eq!(r.as_ref().unwrap().addr, Some(g as usize));
    }
    client.shutdown().expect("shutdown");
    server.join();
}

#[test]
fn engine_errors_cross_the_wire_typed() {
    let (server, _fleet, addr) = start(PlacementMode::TagHash, None, NetConfig::default());
    let mut client = CamClient::connect(addr).expect("connect");
    // bad address
    match client.delete(999_999) {
        Err(WireError::Engine(EngineError::BadAddress(a))) => assert_eq!(a, 999_999),
        other => panic!("expected BadAddress, got {other:?}"),
    }
    // tag width mismatch (fleet expects N = 32)
    let narrow = BitVec::zeros(16);
    match client.lookup(&narrow) {
        Err(WireError::Engine(EngineError::TagWidth { got: 16, want: 32 })) => {}
        other => panic!("expected TagWidth, got {other:?}"),
    }
    match client.insert(&narrow) {
        Err(WireError::Engine(EngineError::TagWidth { .. })) => {}
        other => panic!("expected TagWidth, got {other:?}"),
    }
    client.shutdown().expect("shutdown");
    server.join();
}

#[test]
fn narrow_tag_under_learned_placement_is_a_typed_error_not_a_crash() {
    // The learned-prefix router reads fixed bit positions, so a too-narrow
    // tag would panic it; the server must reject the width before routing
    // and keep the connection serving.
    let mut rng = Rng::seed_from_u64(212);
    let sample = TagDistribution::Uniform.sample_distinct(32, 64, &mut rng);
    let (server, _fleet, addr) =
        start(PlacementMode::learned(4, &sample, 32), None, NetConfig::default());
    let mut client = CamClient::connect(addr).expect("connect");
    let narrow = BitVec::zeros(8);
    for _ in 0..2 {
        match client.lookup(&narrow) {
            Err(WireError::Engine(EngineError::TagWidth { got: 8, want: 32 })) => {}
            other => panic!("expected TagWidth, got {other:?}"),
        }
    }
    match client.insert(&narrow) {
        Err(WireError::Engine(EngineError::TagWidth { .. })) => {}
        other => panic!("expected TagWidth, got {other:?}"),
    }
    // a bulk frame holding any bad-width tag is rejected whole, and the
    // client expands the frame-level error per item
    let bulk =
        client.lookup_bulk(&[narrow.clone(), sample[0].clone()], 8).expect("transport ok");
    assert_eq!(bulk.len(), 2);
    for r in bulk {
        assert!(matches!(r, Err(EngineError::TagWidth { .. })), "got {r:?}");
    }
    // the same connection still serves well-formed traffic
    let g = client.insert(&sample[0]).expect("insert after rejects");
    assert_eq!(client.lookup(&sample[0]).expect("lookup").addr, Some(g as usize));
    client.shutdown().expect("shutdown");
    server.join();
}

#[test]
fn handshake_rejects_garbage_and_keeps_serving() {
    use std::io::{Read, Write};
    let (server, _fleet, addr) = start(PlacementMode::TagHash, None, NetConfig::default());
    // raw garbage instead of a client hello: the server hangs up…
    let mut raw = std::net::TcpStream::connect(&addr).expect("raw connect");
    raw.write_all(b"NOTCSCAM").expect("write garbage");
    let mut buf = [0u8; 64];
    let n = raw.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "server must close on a bad magic, not answer");
    drop(raw);
    // …and a well-behaved client still gets served afterwards
    let mut client = CamClient::connect(addr).expect("connect after garbage");
    let mut rng = Rng::seed_from_u64(208);
    let t = TagDistribution::Uniform.sample(32, &mut rng);
    let g = client.insert(&t).expect("insert");
    assert_eq!(client.lookup(&t).expect("lookup").addr, Some(g as usize));
    client.shutdown().expect("shutdown");
    server.join();
}

#[test]
fn connection_cap_answers_busy() {
    let net = NetConfig { max_connections: 1, ..NetConfig::default() };
    let (server, _fleet, addr) = start(PlacementMode::TagHash, None, net);
    let client1 = CamClient::connect(addr.clone()).expect("first connection");
    // second connection: the hello carries the busy flag
    match CamClient::connect(addr.clone()) {
        Err(WireError::Busy) => {}
        other => panic!("expected Busy, got {:?}", other.map(|_| "connected")),
    }
    // freeing the slot lets a new client in (the conn thread notices the
    // disconnect within its idle poll)
    drop(client1);
    let mut ok = false;
    for _ in 0..100 {
        std::thread::sleep(Duration::from_millis(20));
        if let Ok(mut c) = CamClient::connect(addr.clone()) {
            c.shutdown().expect("shutdown");
            ok = true;
            break;
        }
    }
    assert!(ok, "slot never freed after the first client disconnected");
    server.join();
}

#[test]
fn shutdown_drains_and_stops_accepting() {
    let (server, fleet, addr) = start(PlacementMode::TagHash, None, NetConfig::default());
    let mut client = CamClient::connect(addr.clone()).expect("connect");
    let mut rng = Rng::seed_from_u64(209);
    let tags = TagDistribution::Uniform.sample_distinct(32, 20, &mut rng);
    for t in &tags {
        client.insert(t).expect("insert");
    }
    for t in &tags {
        assert!(client.lookup(t).expect("lookup").addr.is_some());
    }
    client.shutdown().expect("shutdown ack");
    server.join();
    // the fleet behind the server is drained but alive: metrics survive
    let fm = fleet.fleet_metrics().expect("engines still up");
    assert_eq!(fm.aggregate.inserts, 20);
    assert!(fm.aggregate.lookups >= 20);
    // and the port is closed
    assert!(
        std::net::TcpStream::connect(&addr).is_err(),
        "accept loop must be gone after shutdown"
    );
}

#[test]
fn client_reconnects_on_demand() {
    let (server, _fleet, addr) = start(PlacementMode::TagHash, None, NetConfig::default());
    let mut client = CamClient::connect(addr).expect("connect");
    let mut rng = Rng::seed_from_u64(210);
    let t = TagDistribution::Uniform.sample(32, &mut rng);
    let g = client.insert(&t).expect("insert");
    client.reconnect().expect("reconnect");
    assert_eq!(client.lookup(&t).expect("lookup on fresh conn").addr, Some(g as usize));
    client.shutdown().expect("shutdown");
    server.join();
}

#[test]
fn loadgen_emits_a_measured_bench_row() {
    use cscam::util::bench::{read_bench_rows, write_bench_json};

    let (server, _fleet, addr) = start(PlacementMode::TagHash, None, NetConfig::default());
    let driver = LoadGen {
        addr: addr.clone(),
        threads: 2,
        lookups: 2_000,
        chunk: 64,
        hit_ratio: 0.9,
        population: 120,
        rate: 0.0,
        conns: 0,
        seed: 211,
    };
    let report = driver.run().expect("loadgen run");
    assert_eq!(report.lookups, 2_000);
    assert!(report.hit_ratio() > 0.5, "hit ratio {}", report.hit_ratio());
    assert!(report.throughput_lps > 0.0);
    assert!(report.mean_energy_fj > 0.0, "wire outcomes must carry the energy model");

    // the row lands in the merged bench-JSON trajectory under the net tag
    let path = std::env::temp_dir().join("cscam_net_roundtrip_bench.json");
    let _ = std::fs::remove_file(&path);
    write_bench_json(&path, "net", &[report.to_record()]).expect("write row");
    let rows = read_bench_rows(&std::fs::read_to_string(&path).expect("read back"));
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].bench, "net");
    assert!(rows[0].rec.name.starts_with("net/shards=4"));
    let tp = rows[0]
        .rec
        .metrics
        .iter()
        .find(|(k, _)| k == "throughput_lps")
        .expect("throughput metric")
        .1;
    assert!(tp > 0.0, "measured throughput must be positive");
    let _ = std::fs::remove_file(&path);

    let mut c = CamClient::connect(addr).expect("connect");
    c.shutdown().expect("shutdown");
    server.join();
}

#[test]
fn open_loop_loadgen_paces_arrivals_and_tags_its_row() {
    let (server, _fleet, addr) = start(PlacementMode::TagHash, None, NetConfig::default());
    // 1000 lookups at 10 000/s offered: the arrival schedule alone spans
    // ~100 ms, so a run that ignored pacing would finish far sooner.
    let driver = LoadGen {
        addr: addr.clone(),
        threads: 2,
        lookups: 1_000,
        chunk: 64,
        hit_ratio: 0.9,
        population: 120,
        rate: 10_000.0,
        conns: 0,
        seed: 213,
    };
    let report = driver.run().expect("open-loop run");
    assert!(report.open_loop);
    assert_eq!(report.rate, 10_000.0);
    assert_eq!(report.lookups + report.errors, 1_000);
    assert!(
        report.wall_s >= 0.05,
        "open-loop run finished in {:.3} s — arrivals were not paced",
        report.wall_s
    );
    let rec = report.to_record();
    assert!(rec.name.ends_with("/open"), "open-loop rows get their own scenario: {}", rec.name);
    let get = |key: &str| rec.metrics.iter().find(|(k, _)| k == key).map(|(_, v)| *v);
    assert_eq!(get("open_loop"), Some(1.0));
    assert_eq!(get("rate"), Some(10_000.0));
    assert!(get("p99_ns").unwrap_or(0.0) > 0.0, "latency histogram must be populated");

    let mut c = CamClient::connect(addr).expect("connect");
    c.shutdown().expect("shutdown");
    server.join();
}

#[test]
fn metrics_cross_the_wire_as_prometheus_text() {
    let (server, fleet, addr) = start(PlacementMode::TagHash, None, NetConfig::default());
    let mut client = CamClient::connect(addr).expect("connect");
    let mut rng = Rng::seed_from_u64(214);
    let tags = TagDistribution::Uniform.sample_distinct(32, 12, &mut rng);
    for t in &tags {
        client.insert(t).expect("insert");
    }
    for t in &tags {
        assert!(client.lookup(t).expect("lookup").addr.is_some());
    }
    let text = client.metrics().expect("metrics over the wire");
    // the exposition reflects this fleet's counters…
    let fm = fleet.fleet_metrics().expect("fleet metrics");
    assert!(
        text.contains(&format!("cscam_lookups_total {}", fm.aggregate.lookups)),
        "lookup counter missing or stale:\n{text}"
    );
    assert!(text.contains(&format!("cscam_inserts_total {}", fm.aggregate.inserts)));
    // …with per-bank hot-fraction labels and both shed reasons
    assert!(text.contains("cscam_bank_hot_fraction{bank=\"0\"}"), "{text}");
    assert!(text.contains("cscam_shed_total{reason=\"busy\"}"), "{text}");
    assert!(text.contains("cscam_shed_total{reason=\"full\"}"), "{text}");
    // served over the wire and over HTTP from the same renderer, the text
    // must be identical modulo traffic that arrived in between; fetch
    // twice and require monotone growth instead of equality
    let again = client.metrics().expect("second fetch");
    assert!(again.contains("cscam_lookups_total"));
    client.shutdown().expect("shutdown");
    server.join();
}

#[test]
fn client_rematches_reordered_bulk_responses_by_id() {
    // The real server reorders only when the worker pool happens to finish
    // out of order; this scripted server *always* answers the window in
    // reverse, so the id re-match is proven deterministically: chunk 1
    // gets Busy, chunk 2 gets Full, and a positional client would swap
    // them.
    use cscam::net::proto::{self, Request, Response, ServerHello};
    use std::io::{BufReader, BufWriter, Read, Write};

    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().expect("accept");
        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
        let mut writer = BufWriter::new(stream);
        let mut hello = [0u8; 8];
        reader.read_exact(&mut hello).expect("client hello");
        proto::parse_client_hello(&hello).expect("magic");
        proto::write_server_hello(
            &mut writer,
            &ServerHello {
                version: proto::VERSION,
                busy: false,
                multiplex: true,
                shards: 4,
                bank_m: 64,
                tag_bits: 32,
            },
        )
        .expect("server hello");
        writer.flush().expect("flush hello");
        // the client streams its whole window before reading: both frames
        // are on the wire now
        let (id1, req1) = proto::read_request(&mut reader).expect("frame 1");
        let (id2, req2) = proto::read_request(&mut reader).expect("frame 2");
        let tags_in = |r: &Request| match r {
            Request::LookupBulk { tags } => tags.len(),
            other => panic!("expected LookupBulk, got {other:?}"),
        };
        assert_eq!(tags_in(&req1), 4);
        assert_eq!(tags_in(&req2), 4);
        assert_ne!(id1, id2);
        // answer in REVERSE submission order, with distinguishable verdicts
        proto::write_response(&mut writer, id2, &Response::Error { code: proto::ERR_FULL, aux: 0 })
            .expect("response 2");
        proto::write_response(&mut writer, id1, &Response::Error { code: proto::ERR_BUSY, aux: 0 })
            .expect("response 1");
        writer.flush().expect("flush responses");
        // hold the connection open until the client hangs up
        let mut sink = [0u8; 64];
        let _ = reader.read(&mut sink);
    });

    let mut client = CamClient::connect(addr).expect("connect to scripted server");
    assert!(client.multiplexed(), "the scripted hello advertises multiplexing");
    let tags: Vec<BitVec> = (0..8).map(|_| BitVec::zeros(32)).collect();
    let out = client.lookup_bulk(&tags, 4).expect("bulk against scripted server");
    assert_eq!(out.len(), 8);
    for r in &out[..4] {
        assert!(matches!(r, Err(EngineError::Busy)), "chunk 1 must keep its verdict: {r:?}");
    }
    for r in &out[4..] {
        assert!(matches!(r, Err(EngineError::Full)), "chunk 2 must keep its verdict: {r:?}");
    }
    drop(client);
    server.join().expect("scripted server");
}
