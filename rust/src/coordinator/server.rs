//! The serve loop: a single-owner engine thread fed by an mpsc channel,
//! with dynamic batching of the decode stage and per-request response
//! channels.
//!
//! Shape: `ServerHandle` (cheap to clone, one per client thread) → mpsc →
//! engine thread.  Lookups are queued into the [`Batcher`]; inserts /
//! deletes / metrics are *barriers* (they flush the pending batch first, so
//! a lookup never observes a half-applied mutation).  The decode stage runs
//! either natively (bit-packed CNN) or — with the `pjrt` cargo feature —
//! through the PJRT artifact ([`crate::runtime::ArtifactStore`]), the
//! three-layer configuration with Python strictly at build time.

use std::sync::mpsc;
use std::time::Instant;

use crate::bits::BitVec;
use crate::config::DesignConfig;
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::engine::{EngineError, LookupEngine, LookupOutcome};
use crate::coordinator::metrics::Metrics;
use crate::runtime::DecodeOutput;
#[cfg(feature = "pjrt")]
use crate::runtime::ArtifactStore;

/// Owner of the PJRT artifact store for the trip onto the engine thread.
///
/// The unsafety is scoped to this newtype on purpose: blessing the whole
/// [`DecodeBackend`] enum would silently extend to any variant added later.
//
// SAFETY: the xla crate's PJRT handles are `!Send` only because
// `PjRtClient` wraps its FFI handle in an `Rc`.  `ArtifactStore` creates
// the client itself and owns every object cloned from it (executables,
// resident buffers), so all `Rc` clones live inside the one store.  The
// server moves the whole store onto its single engine thread at spawn and
// never aliases it afterwards — every clone crosses threads together,
// exactly once, which is the condition `Rc` needs.
#[cfg(feature = "pjrt")]
pub struct SendArtifactStore(pub Box<ArtifactStore>);

#[cfg(feature = "pjrt")]
unsafe impl Send for SendArtifactStore {}

/// Which implementation runs the CNN decode stage.
pub enum DecodeBackend {
    /// Bit-packed native decode (reference hot path).
    Native,
    /// AOT-compiled PJRT artifact (the three-layer stack).
    #[cfg(feature = "pjrt")]
    Pjrt(SendArtifactStore),
}

#[cfg(feature = "pjrt")]
impl DecodeBackend {
    /// Wrap an artifact store for the engine thread.
    pub fn pjrt(store: ArtifactStore) -> Self {
        DecodeBackend::Pjrt(SendArtifactStore(Box::new(store)))
    }
}

impl std::fmt::Debug for DecodeBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeBackend::Native => write!(f, "Native"),
            #[cfg(feature = "pjrt")]
            DecodeBackend::Pjrt(_) => write!(f, "Pjrt"),
        }
    }
}

type LookupResp = mpsc::SyncSender<Result<LookupOutcome, EngineError>>;

type BulkResp = mpsc::SyncSender<Vec<Result<LookupOutcome, EngineError>>>;

enum Request {
    Lookup { tag: BitVec, enqueued: Instant, resp: LookupResp },
    BulkLookup { tags: Vec<BitVec>, enqueued: Instant, resp: BulkResp },
    Insert { tag: BitVec, resp: mpsc::SyncSender<Result<usize, EngineError>> },
    Delete { addr: usize, resp: mpsc::SyncSender<Result<(), EngineError>> },
    Metrics { resp: mpsc::SyncSender<Box<Metrics>> },
    Drain { resp: mpsc::SyncSender<()> },
}

/// Cloneable client handle to a running [`CamServer`].
///
/// All methods block the calling thread until the engine thread responds;
/// issue requests from multiple threads to exercise batching.  A send or
/// receive failure means the engine thread is gone, reported as
/// [`EngineError::Shutdown`].
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
}

impl ServerHandle {
    /// Lookup (dynamically batched with concurrent callers).
    pub fn lookup(&self, tag: BitVec) -> Result<LookupOutcome, EngineError> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Lookup { tag, enqueued: Instant::now(), resp })
            .map_err(|_| EngineError::Shutdown)?;
        rx.recv().map_err(|_| EngineError::Shutdown)?
    }

    /// Bulk lookup: ship many tags in one request — one channel round-trip
    /// amortized over the whole slice.  The batch is decoded in
    /// `max_batch`-sized chunks, preserving order.
    pub fn lookup_many(&self, tags: Vec<BitVec>) -> Vec<Result<LookupOutcome, EngineError>> {
        if tags.is_empty() {
            return Vec::new();
        }
        let n = tags.len();
        let (resp, rx) = mpsc::sync_channel(1);
        if self.tx.send(Request::BulkLookup { tags, enqueued: Instant::now(), resp }).is_err() {
            return (0..n).map(|_| Err(EngineError::Shutdown)).collect();
        }
        rx.recv().unwrap_or_else(|_| (0..n).map(|_| Err(EngineError::Shutdown)).collect())
    }

    /// Insert a tag; returns once the CNN + CAM are updated.
    pub fn insert(&self, tag: BitVec) -> Result<usize, EngineError> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx.send(Request::Insert { tag, resp }).map_err(|_| EngineError::Shutdown)?;
        rx.recv().map_err(|_| EngineError::Shutdown)?
    }

    /// Delete by address.
    pub fn delete(&self, addr: usize) -> Result<(), EngineError> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx.send(Request::Delete { addr, resp }).map_err(|_| EngineError::Shutdown)?;
        rx.recv().map_err(|_| EngineError::Shutdown)?
    }

    /// Snapshot of the server metrics.
    pub fn metrics(&self) -> Option<Box<Metrics>> {
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx.send(Request::Metrics { resp }).ok()?;
        rx.recv().ok()
    }

    /// Flush pending work and wait for it to complete.
    pub fn drain(&self) {
        let (resp, rx) = mpsc::sync_channel(1);
        if self.tx.send(Request::Drain { resp }).is_ok() {
            let _ = rx.recv();
        }
    }
}

/// The serve-thread owner.
pub struct CamServer {
    engine: LookupEngine,
    backend: DecodeBackend,
    policy: BatchPolicy,
    metrics: Metrics,
    /// Set on any mutation; the PJRT path re-uploads weights before the next
    /// batched decode.  (Only read by the `pjrt` decode path.)
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    weights_dirty: bool,
}

impl CamServer {
    /// Build a server around a fresh engine.
    pub fn new(cfg: DesignConfig, backend: DecodeBackend, policy: BatchPolicy) -> Self {
        Self::with_engine(LookupEngine::new(cfg), backend, policy)
    }

    /// Build around an existing (pre-populated) engine.
    pub fn with_engine(engine: LookupEngine, backend: DecodeBackend, policy: BatchPolicy) -> Self {
        CamServer { engine, backend, policy, metrics: Metrics::new(), weights_dirty: true }
    }

    /// Spawn the serve loop on a dedicated thread.  The thread exits when
    /// every [`ServerHandle`] clone has been dropped.
    pub fn spawn(self) -> ServerHandle {
        let (tx, rx) = mpsc::channel();
        std::thread::Builder::new()
            .name("cscam-server".into())
            .spawn(move || self.run(rx))
            .expect("spawn server thread");
        ServerHandle { tx }
    }

    fn run(mut self, rx: mpsc::Receiver<Request>) {
        let mut batcher: Batcher<(BitVec, Instant, LookupResp)> = Batcher::new(self.policy);
        loop {
            let req = match batcher.deadline() {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        let batch = batcher.flush();
                        self.run_batch(batch);
                        continue;
                    }
                    match rx.recv_timeout(d - now) {
                        Ok(r) => Some(r),
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            let batch = batcher.flush();
                            self.run_batch(batch);
                            continue;
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => None,
                    }
                }
                None => rx.recv().ok(),
            };
            match req {
                Some(Request::Lookup { tag, enqueued, resp }) => {
                    if let Some(batch) = batcher.push((tag, enqueued, resp), Instant::now()) {
                        self.run_batch(batch);
                    }
                    // Greedy drain: batch everything already queued, then
                    // serve immediately instead of sleeping out max_wait —
                    // the classic "batch what's there" adaptive policy.  The
                    // deadline path above remains as the bound for requests
                    // that arrive while a batch is running.
                    loop {
                        match rx.try_recv() {
                            Ok(Request::Lookup { tag, enqueued, resp }) => {
                                if let Some(batch) =
                                    batcher.push((tag, enqueued, resp), Instant::now())
                                {
                                    self.run_batch(batch);
                                }
                            }
                            Ok(other) => {
                                let batch = batcher.flush();
                                self.run_batch(batch);
                                self.handle_barrier(other);
                                break;
                            }
                            Err(mpsc::TryRecvError::Empty) => {
                                let batch = batcher.flush();
                                self.run_batch(batch);
                                break;
                            }
                            Err(mpsc::TryRecvError::Disconnected) => {
                                let batch = batcher.flush();
                                self.run_batch(batch);
                                return;
                            }
                        }
                    }
                }
                Some(other) => {
                    // barrier: mutations and snapshots see a flushed queue
                    let batch = batcher.flush();
                    self.run_batch(batch);
                    self.handle_barrier(other);
                }
                None => {
                    // all handles dropped: drain and exit
                    let batch = batcher.flush();
                    self.run_batch(batch);
                    return;
                }
            }
        }
    }

    /// Handle a non-lookup request (the pending batch is already flushed).
    fn handle_barrier(&mut self, req: Request) {
        match req {
            Request::Insert { tag, resp } => {
                let r = self.engine.insert(&tag);
                if r.is_ok() {
                    self.metrics.inserts += 1;
                    self.weights_dirty = true;
                }
                let _ = resp.send(r);
            }
            Request::Delete { addr, resp } => {
                let r = self.engine.delete(addr);
                if r.is_ok() {
                    self.metrics.deletes += 1;
                    self.weights_dirty = true;
                }
                let _ = resp.send(r);
            }
            Request::BulkLookup { tags, enqueued, resp } => {
                let results = self.run_bulk(tags, enqueued);
                let _ = resp.send(results);
            }
            Request::Metrics { resp } => {
                let _ = resp.send(Box::new(self.metrics.clone()));
            }
            Request::Drain { resp } => {
                let _ = resp.send(());
            }
            Request::Lookup { .. } => unreachable!("lookups are batched, not barriers"),
        }
    }

    /// Run the batched decode stage through the PJRT artifact; `None` falls
    /// back to the native per-query decode inside the engine.
    #[cfg(feature = "pjrt")]
    fn decode_stage<'a>(&mut self, tags: impl Iterator<Item = &'a BitVec>) -> Option<DecodeOutput> {
        match &mut self.backend {
            DecodeBackend::Native => None,
            DecodeBackend::Pjrt(store) => {
                if self.weights_dirty && store.0.set_weights(self.engine.weight_rows()).is_ok() {
                    self.weights_dirty = false;
                }
                if self.weights_dirty {
                    None // weight upload failed: fall back to native decode
                } else {
                    let idx: Vec<Vec<u16>> =
                        tags.map(|t| self.engine.cluster_indices(t)).collect();
                    store.0.decode(&idx).ok()
                }
            }
        }
    }

    #[cfg(not(feature = "pjrt"))]
    fn decode_stage<'a>(
        &mut self,
        _tags: impl Iterator<Item = &'a BitVec>,
    ) -> Option<DecodeOutput> {
        None
    }

    /// Serve a pre-assembled batch of tags in order, chunked to the batch
    /// policy (and thus to the compiled PJRT batch sizes).
    fn run_bulk(
        &mut self,
        tags: Vec<BitVec>,
        enqueued: Instant,
    ) -> Vec<Result<LookupOutcome, EngineError>> {
        let mut out = Vec::with_capacity(tags.len());
        for chunk in tags.chunks(self.policy.max_batch.max(1)) {
            self.metrics.record_batch(chunk.len());
            let decoded = self.decode_stage(chunk.iter());
            for (i, tag) in chunk.iter().enumerate() {
                let r = match &decoded {
                    Some(d) => {
                        self.engine.lookup_with_enables(tag, &d.enables[i], d.lambda[i] as usize)
                    }
                    None => self.engine.lookup(tag),
                };
                if let Ok(o) = &r {
                    self.metrics.record_lookup(o);
                }
                out.push(r);
            }
        }
        self.metrics.record_latency(enqueued.elapsed().as_nanos() as u64);
        out
    }

    fn run_batch(&mut self, batch: Vec<(BitVec, Instant, LookupResp)>) {
        if batch.is_empty() {
            return;
        }
        self.metrics.record_batch(batch.len());

        // PJRT path: one artifact call covers the whole batch's decode stage.
        let decoded = self.decode_stage(batch.iter().map(|(t, _, _)| t));

        for (i, (tag, enqueued, resp)) in batch.into_iter().enumerate() {
            let out = match &decoded {
                Some(d) => {
                    self.engine.lookup_with_enables(&tag, &d.enables[i], d.lambda[i] as usize)
                }
                None => self.engine.lookup(&tag),
            };
            if let Ok(o) = &out {
                self.metrics.record_lookup(o);
            }
            self.metrics.record_latency(enqueued.elapsed().as_nanos() as u64);
            let _ = resp.send(out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workload::TagDistribution;
    use std::time::Duration;

    fn policy() -> BatchPolicy {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) }
    }

    #[test]
    fn serve_native_roundtrip() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(1);
        let tags = TagDistribution::Uniform.sample_distinct(32, 20, &mut rng);
        for (i, t) in tags.iter().enumerate() {
            assert_eq!(h.insert(t.clone()).unwrap(), i);
        }
        for (i, t) in tags.iter().enumerate() {
            let out = h.lookup(t.clone()).unwrap();
            assert_eq!(out.addr, Some(i));
        }
        let m = h.metrics().unwrap();
        assert_eq!(m.lookups, 20);
        assert_eq!(m.hits, 20);
        assert_eq!(m.inserts, 20);
    }

    #[test]
    fn concurrent_lookups_batch_together() {
        let server = CamServer::new(
            DesignConfig::small_test(),
            DecodeBackend::Native,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) },
        );
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(2);
        let tags = TagDistribution::Uniform.sample_distinct(32, 32, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        let mut joins = Vec::new();
        for t in tags {
            let h = h.clone();
            joins.push(std::thread::spawn(move || h.lookup(t).unwrap().addr.is_some()));
        }
        let hits = joins.into_iter().map(|j| j.join().unwrap()).filter(|&b| b).count();
        assert_eq!(hits, 32);
        let m = h.metrics().unwrap();
        assert_eq!(m.lookups, 32);
        assert!(m.batches < 32, "some batching must occur: {} batches", m.batches);
        assert!(m.batch_size.mean() > 1.0);
    }

    #[test]
    fn delete_barrier_orders_before_following_lookups() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(3);
        let tags = TagDistribution::Uniform.sample_distinct(32, 4, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        h.delete(2).unwrap();
        let out = h.lookup(tags[2].clone()).unwrap();
        assert_eq!(out.addr, None);
    }

    #[test]
    fn drain_is_a_noop_on_idle_server() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        h.drain();
        assert_eq!(h.metrics().unwrap().lookups, 0);
    }

    #[test]
    fn lookup_many_matches_singles_and_preserves_order() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let mut rng = Rng::seed_from_u64(8);
        let tags = TagDistribution::Uniform.sample_distinct(32, 30, &mut rng);
        for t in &tags {
            h.insert(t.clone()).unwrap();
        }
        let singles: Vec<_> = tags.iter().map(|t| h.lookup(t.clone()).unwrap().addr).collect();
        let bulk = h.lookup_many(tags.clone());
        assert_eq!(bulk.len(), 30);
        for (i, r) in bulk.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap().addr, singles[i], "order must be preserved");
        }
        assert!(h.lookup_many(Vec::new()).is_empty());
    }

    #[test]
    fn server_exits_when_handles_drop() {
        let server = CamServer::new(DesignConfig::small_test(), DecodeBackend::Native, policy());
        let h = server.spawn();
        let h2 = h.clone();
        drop(h);
        drop(h2);
        // nothing to assert directly; the thread exiting keeps the process
        // from hanging at test end (would deadlock `cargo test` otherwise)
    }

    #[test]
    fn dropped_server_yields_shutdown_not_full() {
        // A handle whose engine thread is gone must report Shutdown — Full
        // means "no free CAM slot" and would mislead capacity-aware callers.
        let (tx, rx) = mpsc::channel();
        drop(rx);
        let h = ServerHandle { tx };
        assert_eq!(h.lookup(BitVec::zeros(32)).unwrap_err(), EngineError::Shutdown);
        assert_eq!(h.insert(BitVec::zeros(32)).unwrap_err(), EngineError::Shutdown);
        assert_eq!(h.delete(0).unwrap_err(), EngineError::Shutdown);
        let bulk = h.lookup_many(vec![BitVec::zeros(32); 3]);
        assert_eq!(bulk.len(), 3);
        for r in bulk {
            assert_eq!(r.unwrap_err(), EngineError::Shutdown);
        }
        assert!(h.metrics().is_none());
        h.drain(); // must not hang or panic
    }
}
