//! Design-space exploration behind Table I.
//!
//! §III: "a set of design points were selected among 15 different parameter
//! sets with the common goal of discovering the minimum energy consumption
//! per search, while keeping the silicon area overhead and the delay
//! reasonable."  This module enumerates the candidate (c, l, ζ) space for a
//! given CAM geometry, evaluates every point with the energy / delay /
//! transistor models, applies the paper's constraints and ranks by energy.
//!
//! Constraints ("reasonable", made concrete):
//! * cycle time ≤ `max_cycle_ns` (default 0.8 ns — NOR-class search speed);
//! * transistor overhead vs Ref. NAND ≤ `max_overhead` (default 4 %);
//! * β = M/ζ ≤ `max_blocks` (default 64 — §III-B "the number of sub-blocks
//!   should not be too many to expand the layout and to complicate the
//!   interconnections": enable-line routing grows with β).


use crate::config::DesignConfig;
use crate::energy::{proposed_search_energy, CalibrationConstants};
use crate::timing::{proposed_delay, DelayConstants};
use crate::transistor::{overhead_vs_nand, TransistorAssumptions};

/// Evaluation of one candidate design point.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    pub cfg: DesignConfig,
    /// Energy per search, fJ/bit/search.
    pub energy_fj_bit: f64,
    /// Cycle time, ns.
    pub cycle_ns: f64,
    /// Search latency, ns.
    pub latency_ns: f64,
    /// Transistor overhead vs conventional NAND.
    pub overhead: f64,
    /// Expected comparisons per search.
    pub comparisons: f64,
    /// Satisfies all constraints?
    pub feasible: bool,
}

/// Constraint set for the exploration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepConstraints {
    pub max_cycle_ns: f64,
    pub max_overhead: f64,
    pub max_blocks: usize,
}

impl Default for SweepConstraints {
    fn default() -> Self {
        SweepConstraints { max_cycle_ns: 0.8, max_overhead: 0.04, max_blocks: 64 }
    }
}

/// The candidate (c, l, ζ) sets explored for the paper's 512×128 macro —
/// 15 parameter sets as in §III.
pub fn candidate_space() -> Vec<(usize, usize, usize)> {
    vec![
        // (c, l, zeta) — q = c·log2(l)
        (2, 8, 8),   // q=6
        (3, 4, 8),   // q=6
        (2, 16, 8),  // q=8
        (4, 4, 8),   // q=8
        (3, 8, 4),   // q=9, finer blocks
        (3, 8, 8),   // q=9  ← Table I
        (3, 8, 16),  // q=9, coarser blocks
        (3, 8, 32),  // q=9, very coarse
        (5, 4, 8),   // q=10
        (2, 32, 8),  // q=10
        (4, 8, 8),   // q=12
        (3, 16, 8),  // q=12
        (6, 4, 8),   // q=12
        (4, 16, 8),  // q=16
        (2, 64, 16), // q=12, fat clusters
    ]
}

/// Evaluate one candidate.
pub fn evaluate(cfg: &DesignConfig, constraints: &SweepConstraints) -> DesignPoint {
    let calib = CalibrationConstants::reference_130nm();
    let delays = DelayConstants::reference();
    let energy = proposed_search_energy(cfg, &calib).per_bit(cfg.m, cfg.n);
    let delay = proposed_delay(cfg, &delays);
    let overhead = overhead_vs_nand(cfg, &TransistorAssumptions::default());
    let feasible = delay.cycle_ns <= constraints.max_cycle_ns
        && overhead <= constraints.max_overhead
        && cfg.beta() <= constraints.max_blocks;
    DesignPoint {
        cfg: cfg.clone(),
        energy_fj_bit: energy,
        cycle_ns: delay.cycle_ns,
        latency_ns: delay.latency_ns,
        overhead,
        comparisons: cfg.expected_comparisons(),
        feasible,
    }
}

/// Run the full exploration for an M×N macro; returns all points ranked by
/// energy (feasible first).
pub fn run_sweep(m: usize, n: usize, constraints: &SweepConstraints) -> Vec<DesignPoint> {
    let mut points: Vec<DesignPoint> = candidate_space()
        .into_iter()
        .filter(|&(_, _, zeta)| m % zeta == 0)
        .map(|(c, l, zeta)| {
            let cfg = DesignConfig { m, n, c, l, zeta, ..DesignConfig::reference() };
            evaluate(&cfg, constraints)
        })
        .collect();
    points.sort_by(|a, b| {
        b.feasible
            .cmp(&a.feasible)
            .then(a.energy_fj_bit.total_cmp(&b.energy_fj_bit))
    });
    points
}

/// The winning (minimum-energy feasible) point.
pub fn select_design(m: usize, n: usize, constraints: &SweepConstraints) -> Option<DesignPoint> {
    run_sweep(m, n, constraints).into_iter().find(|p| p.feasible)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_selects_the_table1_design_point() {
        // The headline reproduction of Table I: min-energy feasible point of
        // the 15-candidate space at 512×128 is (c=3, l=8, ζ=8) → q=9, β=64.
        let best = select_design(512, 128, &SweepConstraints::default()).expect("feasible point");
        assert_eq!(
            (best.cfg.c, best.cfg.l, best.cfg.zeta),
            (3, 8, 8),
            "selected {:?}",
            best.cfg
        );
        assert_eq!(best.cfg.q(), 9);
        assert_eq!(best.cfg.beta(), 64);
    }

    #[test]
    fn fifteen_candidates() {
        assert_eq!(candidate_space().len(), 15, "§III: 15 parameter sets");
    }

    #[test]
    fn all_candidates_evaluated_and_ranked() {
        let pts = run_sweep(512, 128, &SweepConstraints::default());
        assert_eq!(pts.len(), 15);
        // feasible points come first, each ranked by energy
        let feas: Vec<_> = pts.iter().take_while(|p| p.feasible).collect();
        assert!(!feas.is_empty());
        assert!(feas.windows(2).all(|w| w[0].energy_fj_bit <= w[1].energy_fj_bit));
    }

    #[test]
    fn area_constraint_rejects_fat_cnns() {
        // q=16 (c=4, l=16) has a 4× bigger weight SRAM — must be infeasible
        // under the 4 % overhead budget (§II-B's complexity argument).
        let pts = run_sweep(512, 128, &SweepConstraints::default());
        let fat = pts.iter().find(|p| p.cfg.c == 4 && p.cfg.l == 16).unwrap();
        assert!(!fat.feasible);
        assert!(fat.overhead > 0.04);
    }

    #[test]
    fn interconnect_constraint_rejects_tiny_blocks() {
        // ζ=4 → β=128 enable lines: cheaper energy but over the wiring
        // budget (§III-B criterion 1).
        let pts = run_sweep(512, 128, &SweepConstraints::default());
        let fine = pts.iter().find(|p| p.cfg.zeta == 4).unwrap();
        assert!(!fine.feasible);
        assert!(fine.energy_fj_bit < pts.iter().find(|p| p.feasible).unwrap().energy_fj_bit * 1.2);
    }

    #[test]
    fn relaxing_constraints_changes_the_winner() {
        // With an unconstrained wiring budget the finer-grained ζ=4 point
        // (fewer comparisons) wins on energy — evidence the constraint set,
        // not the model, drives the Table I choice.
        let relaxed =
            SweepConstraints { max_blocks: 1024, max_overhead: 1.0, ..Default::default() };
        let best = select_design(512, 128, &relaxed).unwrap();
        assert!(best.cfg.zeta < 8 || best.cfg.q() > 9, "winner {:?}", best.cfg);
    }

    #[test]
    fn infeasible_zeta_filtered_for_odd_m() {
        let pts = run_sweep(96, 64, &SweepConstraints::default());
        assert!(pts.iter().all(|p| 96 % p.cfg.zeta == 0));
    }
}
