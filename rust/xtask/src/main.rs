//! cscam maintenance tasks, invoked as `cargo xtask <command>`.
//!
//! `lint` is the only command today: it runs the cross-file invariant
//! analyzer over the working tree and exits non-zero if any invariant
//! is broken.  See [`lint`] for what is checked and for the
//! `// lint:allow(reason)` escape hatch.

mod lint;

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => run_lint(&args[1..]),
        _ => {
            eprintln!("usage: cargo xtask lint [--root <dir>]");
            ExitCode::from(2)
        }
    }
}

fn run_lint(args: &[String]) -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--root" => match it.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    eprintln!("xtask lint: --root needs a directory");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("xtask lint: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
    }
    if !root.join("rust/src").is_dir() {
        eprintln!(
            "xtask lint: `{}` does not look like the repo root (no rust/src); \
             run from the workspace root or pass --root",
            root.display()
        );
        return ExitCode::from(2);
    }
    let violations = lint::run(&root);
    for v in &violations {
        eprintln!("{v}");
    }
    if violations.is_empty() {
        eprintln!("xtask lint: all cross-file invariants hold");
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}
